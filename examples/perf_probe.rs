use koalja::prelude::*;

/// Steady-state hop-rate probe over a 4-stage chain. The injection loop
/// rides a pre-resolved `SourceHandle` — zero name resolutions after
/// deploy, like any production feeder should.
///
/// Usage: `perf_probe [prov: true|false] [trace: true|false]` — both
/// default false; the second arm turns the flight recorder on and prints
/// the obs summary next to the hop rate, so the probe doubles as a quick
/// eyeball check of the recorder's cost.
fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parse_bool = |s: &String| match s.as_str() {
        "true" | "1" => Some(true),
        "false" | "0" => Some(false),
        _ => None,
    };
    let (prov, trace) = match (args.first().map(&parse_bool), args.get(1).map(&parse_bool)) {
        (Some(None), _) | (_, Some(None)) => {
            eprintln!("usage: perf_probe [prov: true|false] [trace: true|false]");
            std::process::exit(2);
        }
        (p, t) => (p.flatten().unwrap_or(false), t.flatten().unwrap_or(false)),
    };
    let text = "[t]\n(w0) t0 (w1)\n(w1) t1 (w2)\n(w2) t2 (w3)\n(w3) t3 (w4)\n";
    for _ in 0..5 {
        let spec = parse(text).unwrap();
        let cfg = DeployConfig { provenance: prov, trace, ..Default::default() };
        let mut pipe = Pipeline::deploy(&spec, cfg).unwrap();
        let w0 = pipe.source("w0").unwrap();
        // steady-state: inject in small batches like a live stream (the
        // pre-load-everything variant measured heap churn, not the loop)
        let wall = std::time::Instant::now();
        for batch in 0..500u64 {
            for i in 0..100u64 {
                let t = batch * 100 + i;
                w0.inject_at(&mut pipe, Payload::scalar(t as f32), DataClass::Summary, RegionId::new(0), SimTime::micros(t));
            }
            pipe.run_until_idle();
        }
        let secs = wall.elapsed().as_secs_f64();
        let hops: u64 = pipe.links.iter().map(|l| l.delivered).sum();
        println!("prov={prov} trace={trace} {:.0} hops/s", hops as f64 / secs);
        if trace {
            // the obs surface rides the same facade: Pipeline derefs to
            // Coordinator, so obs()/obs_snapshot() are right there
            let o = pipe.obs();
            let wf = o.wavefront;
            let firings: u64 = o.all_task_stats().iter().map(|t| t.firings).sum();
            println!(
                "  obs: {} spans recorded ({} retained, {} evicted); \
                 {} instants / {} firings, max width {}",
                o.rec.recorded(),
                o.rec.len(),
                o.rec.dropped(),
                wf.instants,
                firings,
                wf.max_width
            );
        }
    }
}
