use koalja::prelude::*;

/// Steady-state hop-rate probe over a 4-stage chain. The injection loop
/// rides a pre-resolved `SourceHandle` — zero name resolutions after
/// deploy, like any production feeder should.
fn main() {
    let mut args = std::env::args().skip(1);
    let prov: bool = args.next().unwrap().parse().unwrap();
    let text = "[t]\n(w0) t0 (w1)\n(w1) t1 (w2)\n(w2) t2 (w3)\n(w3) t3 (w4)\n";
    for _ in 0..5 {
        let spec = parse(text).unwrap();
        let cfg = DeployConfig { provenance: prov, ..Default::default() };
        let mut pipe = Pipeline::deploy(&spec, cfg).unwrap();
        let w0 = pipe.source("w0").unwrap();
        // steady-state: inject in small batches like a live stream (the
        // pre-load-everything variant measured heap churn, not the loop)
        let wall = std::time::Instant::now();
        for batch in 0..500u64 {
            for i in 0..100u64 {
                let t = batch * 100 + i;
                w0.inject_at(&mut pipe, Payload::scalar(t as f32), DataClass::Summary, RegionId::new(0), SimTime::micros(t));
            }
            pipe.run_until_idle();
        }
        let secs = wall.elapsed().as_secs_f64();
        let hops: u64 = pipe.links.iter().map(|l| l.delivered).sum();
        println!("prov={prov} {:.0} hops/s", hops as f64 / secs);
    }
}
