//! Quickstart: the breadboard experience (§III-H).
//!
//! Wire a three-stage pipeline in the fig. 5 language, plug in user code,
//! drop data into the in-tray, and read the three provenance stories.
//! No Kubernetes, ports, or storage knowledge anywhere — that is the
//! paper's platform-transparency promise.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! Next steps — the interactive breadboard subsystem built on top of this:
//!   cargo run --release --example breadboard_session   # taps/swap/replay API
//!   cargo run --release -- bread specs/tfmodel.koalja  # scripted session
//! (`koalja bread` attaches live wire taps, hot-swaps a task with a dry-run
//! invalidation preview, and forensically replays the run — see DESIGN.md.)

use anyhow::Result;
use koalja::prelude::*;
use koalja::provenance::ProvenanceQuery;

fn main() -> Result<()> {
    // 1. Describe the wiring — the paper's breadboard. `samples` is the
    //    in-tray; `report` is the sink; `clean[4]` buffers four values.
    let spec = parse(
        "[quickstart]\n\
         # screen raw samples, keep only interesting ones\n\
         (samples) screen (clean)\n\
         # aggregate four clean chunks into one stats report\n\
         (clean[4]) aggregate (report)\n",
    )?;
    let mut koalja = Coordinator::deploy(&spec, DeployConfig::default())?;

    // 2. Plug in user code. The plugin sees only ctx + snapshot.
    koalja.set_code("screen", Box::new(ThresholdGate::new("clean", 0.5)))?;
    koalja.set_code(
        "aggregate",
        Box::new(FnTask::new(|ctx: &mut TaskCtx<'_>, snap: &Snapshot| {
            let mut peak = f32::MIN;
            let mut total = 0.0f32;
            let mut n = 0usize;
            for av in snap.all_avs() {
                let p = ctx.fetch(av)?;
                let (_, data) = p.as_tensor().unwrap();
                for x in data {
                    peak = peak.max(*x);
                    total += x;
                    n += 1;
                }
            }
            ctx.remark(&format!("aggregated {n} samples"));
            Ok(vec![Output::summary(
                "report",
                Payload::tensor(&[2], vec![peak, total / n as f32]),
            )])
        })),
    )?;

    // 3. Drop data into the in-tray at irregular times.
    let mut r = rng(2024);
    let mut t = SimTime::ZERO;
    for _ in 0..40 {
        t += SimDuration::millis(50).scale(r.exp1());
        let data: Vec<f32> = (0..16).map(|_| r.normal() as f32).collect();
        koalja.inject_at(
            "samples",
            Payload::tensor(&[1, 16], data),
            DataClass::Raw,
            RegionId::new(0),
            t,
        )?;
    }

    // 4. Let the reactive platform work.
    koalja.run_until_idle();

    // 5. Read the results + the three stories of §III-C.
    println!("reports produced: {}", koalja.collected_count("report"));
    println!("\n-- metrics --\n{}", koalja.plat.metrics.report());

    let q = ProvenanceQuery::new(&koalja.plat.prov);
    if let Some(last) = koalja.collected.get("report").and_then(|v| v.last()) {
        println!("-- story 1: traveller log of {} --", last.av.id);
        for s in &koalja.plat.prov.passport(last.av.id).unwrap().stamps {
            println!("  {}  {:?}", s.time, s.stamp);
        }
        println!(
            "  ancestry: {} artifacts back to the in-tray",
            q.ancestors(last.av.id).len()
        );
    }

    let screen = koalja.task_id("screen")?;
    println!("\n-- story 2: checkpoint log of 'screen' (first 6 entries) --");
    for e in koalja.plat.prov.checkpoint_log(screen).iter().take(6) {
        println!("  {} {} {:?}", e.time, e.run, e.event);
    }

    println!("\n-- story 3: concept map (the invariant design) --");
    for edge in koalja.plat.prov.concept_map() {
        println!("  ({}) --{:?}--> ({})", edge.from, edge.rel, edge.to);
    }
    Ok(())
}
