//! Quickstart: the handle-based client API — the canonical walkthrough.
//!
//! The paper's "serverless experience" (§III) means you talk to a
//! pipeline, not to its plumbing: no Kubernetes, ports, or storage
//! knowledge anywhere. This walkthrough adds the repo's typed spin on
//! that promise — you also never re-resolve a name after deployment:
//!
//!  1. wire the pipeline (programmatic `PipelineBuilder`, or `parse()`d
//!     fig. 5 text — both lower to the same validated spec),
//!  2. resolve typed handles ONCE: a `SourceHandle` is the only thing
//!     that can inject, a `SinkHandle` the only thing that can read,
//!     a `TaskHandle` plugs code and answers provenance queries,
//!  3. drop data into the in-tray (single and batched) and let the
//!     reactive platform work,
//!  4. read results and the three provenance stories of §III-C.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! Next steps — the interactive breadboard subsystem built on top:
//!   cargo run --release --example breadboard_session   # taps/swap/replay API
//!   cargo run --release -- bread specs/tfmodel.koalja  # scripted session

use anyhow::Result;
use koalja::prelude::*;

fn main() -> Result<()> {
    // 1. Describe the wiring — programmatically. `samples` is the in-tray;
    //    `report` is the sink; `clean[4]` buffers four values. The same
    //    pipeline in the fig. 5 text language would be
    //        [quickstart]
    //        (samples) screen (clean)
    //        (clean[4]) aggregate (report)
    //    and parse() of that text lowers to an identical spec (the test
    //    suite property-checks builder/parser equivalence).
    let mut pipe = PipelineBuilder::new("quickstart")
        .task("screen").reads("samples").emits("clean")
        .task("aggregate").reads("clean[4]").emits("report")
        .deploy(DeployConfig::default())?;

    // 2. Resolve typed handles once. Unknown names fail here — with
    //    near-miss suggestions — and never again: the handles carry their
    //    dense interned ids, so the steady-state loop below touches no
    //    strings and no resolution Results.
    let samples: SourceHandle = pipe.source("samples")?;
    let report: SinkHandle = pipe.sink("report")?;
    let screen: TaskHandle = pipe.task("screen")?;
    let aggregate: TaskHandle = pipe.task("aggregate")?;

    // Plug in task code. The plugin sees only ctx + ports: builtins
    // resolve their output port once at plug time (a typo'd wire name
    // fails HERE with did-you-mean, like any handle resolution), and
    // closure plugins emit on `io.out(..)` — no wire names in the loop.
    screen.plug(&mut pipe, Box::new(ThresholdGate::new("clean", 0.5)))?;
    aggregate.plug(
        &mut pipe,
        Box::new(PortFn::new(|ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
            let mut peak = f32::MIN;
            let mut total = 0.0f32;
            let mut n = 0usize;
            for av in io.inputs.all() {
                let p = ctx.fetch(av)?;
                let (_, data) = p.as_tensor().unwrap();
                for x in data {
                    peak = peak.max(*x);
                    total += x;
                    n += 1;
                }
            }
            ctx.remark(&format!("aggregated {n} samples"));
            let report = io.out(0)?;
            io.emitter.emit(report, Payload::tensor(&[2], vec![peak, total / n as f32]));
            Ok(())
        })),
    )?;

    // 3. Drop data into the in-tray at irregular times…
    let mut r = rng(2024);
    let mut t = SimTime::ZERO;
    for _ in 0..24 {
        t += SimDuration::millis(50).scale(r.exp1());
        let data: Vec<f32> = (0..16).map(|_| r.normal() as f32).collect();
        samples.inject_at(&mut pipe, Payload::tensor(&[1, 16], data), DataClass::Raw, RegionId::new(0), t);
    }
    // …and a burst all at once: batched injection mints the AVs and heap
    // events in one pass (one validation, one tap check, one heap
    // reservation for the whole batch — see benches/coordinator_throughput).
    let burst: Vec<Payload> = (0..16)
        .map(|_| Payload::tensor(&[1, 16], (0..16).map(|_| r.normal() as f32).collect()))
        .collect();
    let ids = samples.inject_batch(&mut pipe, &burst, DataClass::Raw);
    println!("burst of {} chunks injected as one batch", ids.len());

    // 4. Let the reactive platform work.
    pipe.run_until_idle();

    // 5. Read the results + the three stories of §III-C — all off handles.
    println!("reports produced: {}", report.count(&pipe));
    println!("\n-- metrics --\n{}", pipe.plat.metrics.report());

    let q = ProvenanceQuery::new(&pipe.plat.prov);
    if let Some(last) = report.latest(&pipe) {
        println!("-- story 1: traveller log of {} --", last.av.id);
        for s in &pipe.plat.prov.passport(last.av.id).unwrap().stamps {
            println!("  {}  {:?}", s.time, s.stamp);
        }
        println!(
            "  ancestry: {} artifacts back to the in-tray",
            q.ancestors(last.av.id).len()
        );
    }

    println!("\n-- story 2: checkpoint log of 'screen' (first 6 entries) --");
    for e in screen.checkpoint_log(&pipe).iter().take(6) {
        println!("  {} {} {:?}", e.time, e.run, e.event);
    }

    println!("\n-- story 3: concept map (the invariant design) --");
    for edge in pipe.plat.prov.concept_map() {
        println!("  ({}) --{:?}--> ({})", edge.from, edge.rel, edge.to);
    }
    Ok(())
}
