//! Fig. 6: the twin-pipeline data circuit (E9).
//!
//! "The upper pipeline shows a training process for a ... neural network,
//! which is deployed as a service consulted by the lower pipeline. The
//! lower pipeline receives sample images to be recognized and classified
//! according to the machine learning model trained by the upper pipeline.
//! ... Clearly, the timescales of the upper and lower pipelines are
//! unrelated."
//!
//! Both the train step and the serving forward pass are AOT-compiled
//! JAX+Pallas artifacts executed via PJRT from rust — Python never runs
//! here. The model server is a *service* (the fig. 5 `lookup implicit`
//! link); every deployment bumps its version, so provenance shows exactly
//! which model classified which image.
//!
//! Run: `make artifacts && cargo run --release --example twin_ml`

use anyhow::Result;
use koalja::prelude::*;
use koalja::task::compute::{pack_params, MlpDims, ModelServer, PjrtTask};
use koalja::util::TaskId;

/// Trainer: PJRT train-step with param state; deploys the packed model on
/// the `model` wire every `deploy_every` steps.
struct Trainer {
    inner: PjrtTask,
    dims: MlpDims,
    steps: u64,
    deploy_every: u64,
    losses: Vec<f32>,
}

impl UserCode for Trainer {
    fn version(&self) -> u32 {
        1
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, snap: &Snapshot) -> Result<Vec<Output>> {
        let mut outs = self.inner.run(ctx, snap)?;
        self.steps += 1;
        if let Some((_, loss)) = outs[0].payload.as_tensor() {
            self.losses.push(loss[0]);
        }
        if self.steps % self.deploy_every == 0 {
            outs.push(Output::summary("model", pack_params(&self.inner.state)?));
        }
        let _ = self.dims;
        Ok(outs)
    }

    fn compute_cost(&self, bytes: u64) -> SimDuration {
        // fwd + bwd ≈ 3x fwd flops
        SimDuration::micros(100 + 3 * self.dims.fwd_flops() / 1_000 + bytes / 4096)
    }
}

fn main() -> Result<()> {
    let mut rt = Runtime::open(Runtime::default_dir())?;
    let train_exe = rt.load("mlp_train_step")?;
    let infer_exe = rt.load("mlp_infer")?;
    let dims = MlpDims::default();
    let mut r = rng(1234);
    let init_params = dims.init_params(&mut r);

    // the twin circuit of fig. 6, in the fig. 5 wiring language
    let spec = parse(
        "[twin]\n\
         # upper pipeline: slow timescale — learning\n\
         (batch-x, batch-y) learn (loss, model)\n\
         (model) deploy (deployed)\n\
         # lower pipeline: fast timescale — recognition via the implicit\n\
         # client-server link to the deployed model\n\
         (images, classifier?) predict (classification)\n",
    )?;
    let mut koalja = Coordinator::deploy(&spec, DeployConfig::default())?;

    // the deployed model service (starts untrained)
    koalja.plat.services.register(
        "classifier",
        Box::new(ModelServer::new(infer_exe.clone(), dims, init_params.clone())),
    );

    koalja.set_code(
        "learn",
        Box::new(Trainer {
            inner: PjrtTask::new(train_exe, "loss")
                .with_state(init_params)
                .with_emit(vec![(4, "loss".into(), DataClass::Summary)])
                .with_absorb(vec![(0, 0), (1, 1), (2, 2), (3, 3)]),
            dims,
            steps: 0,
            deploy_every: 50,
            losses: vec![],
        }),
    )?;

    // deploy: push packed params into the running service
    koalja.set_code(
        "deploy",
        Box::new(FnTask::new(move |ctx: &mut TaskCtx<'_>, snap: &Snapshot| {
            let mut outs = vec![];
            for av in snap.all_avs() {
                let packed = ctx.fetch(av)?;
                let ok = ctx.plat.services.update("classifier", |s| {
                    s.update_payload(&packed);
                });
                ctx.remark(&format!("deployed model {} (ok={ok})", av.content));
                outs.push(Output::summary("deployed", Payload::scalar(1.0)));
            }
            Ok(outs)
        })),
    )?;

    // predict: consult the service (out-of-band lookup, recorded)
    koalja.set_code(
        "predict",
        Box::new(FnTask::new(|ctx: &mut TaskCtx<'_>, snap: &Snapshot| {
            let mut outs = vec![];
            for av in snap.all_avs() {
                let batch = ctx.fetch(av)?;
                let probs = ctx.lookup("classifier", &batch)?;
                let (shape, p) = probs
                    .as_tensor()
                    .ok_or_else(|| anyhow::anyhow!("bad model response"))?;
                let classes = shape[1];
                let preds: Vec<f32> = p
                    .chunks(classes)
                    .map(|row| {
                        row.iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .unwrap()
                            .0 as f32
                    })
                    .collect();
                let n = preds.len();
                outs.push(Output::summary("classification", Payload::tensor(&[n], preds)));
            }
            Ok(outs)
        })),
    )?;

    // ---- drive both timescales ----
    let stream = koalja::workload::ImageStream::new(&mut r, dims.classes, dims.input, 0.4);
    let train_period = SimDuration::millis(500); // slow: learning
    let image_period = SimDuration::millis(90); // fast: recognition
    let steps = 300u64;
    let horizon = SimTime::ZERO + train_period.scale(steps as f64 + 2.0);

    for i in 0..steps {
        let (x, labels) = stream.batch(&mut r, dims.batch);
        let y = stream.one_hot(&labels);
        let t = SimTime::ZERO + train_period.scale(i as f64);
        koalja.inject_at("batch-x", x, DataClass::Summary, RegionId::new(0), t)?;
        koalja.inject_at("batch-y", y, DataClass::Summary, RegionId::new(0), t)?;
    }
    let mut truth: Vec<Vec<usize>> = Vec::new();
    let mut t = SimTime::ZERO;
    loop {
        t += image_period;
        if t > horizon {
            break;
        }
        let (x, labels) = stream.batch(&mut r, dims.batch);
        truth.push(labels);
        koalja.inject_at("images", x, DataClass::Summary, RegionId::new(0), t)?;
    }

    koalja.run_until_idle();

    // ---- results ----
    let learn_id = koalja.task_id("learn")?;
    let _ = learn_id;
    println!("== twin pipeline run: {steps} train steps, {} image batches ==", truth.len());

    // loss curve from the collected sink
    let losses: Vec<f32> = koalja
        .collected
        .get("loss")
        .map(|v| v.iter().map(|c| c.payload.as_tensor().unwrap().1[0]).collect())
        .unwrap_or_default();
    println!("\nloss curve (every 25 steps):");
    for (i, chunk) in losses.chunks(25).enumerate() {
        println!("  step {:>4}: loss {:.4}", i * 25, chunk[0]);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.2),
        "training converged: {} -> {}",
        losses[0],
        losses.last().unwrap()
    );

    // accuracy per classification batch, split before/after first deploy
    let classifications = koalja.collected.get("classification").cloned().unwrap_or_default();
    let mut early_correct = 0usize;
    let mut early_total = 0usize;
    let mut late_correct = 0usize;
    let mut late_total = 0usize;
    let n_images = classifications.len().min(truth.len());
    for (i, c) in classifications.iter().take(n_images).enumerate() {
        let (_, preds) = c.payload.as_tensor().unwrap();
        for (p, t) in preds.iter().zip(&truth[i]) {
            let hit = (*p as usize) == *t;
            if i < n_images / 10 {
                early_total += 1;
                early_correct += hit as usize;
            } else if i > n_images * 9 / 10 {
                late_total += 1;
                late_correct += hit as usize;
            }
        }
    }
    let early_acc = early_correct as f64 / early_total.max(1) as f64;
    let late_acc = late_correct as f64 / late_total.max(1) as f64;
    println!("\nclassification accuracy: first 10% of stream {:.1}% -> last 10% {:.1}%",
        early_acc * 100.0, late_acc * 100.0);
    assert!(late_acc > early_acc, "deployed model improved the lower pipeline");
    assert!(late_acc > 0.85, "trained accuracy {late_acc}");

    // provenance: model versions visible on the serving path
    let deploys = koalja.collected_count("deployed");
    let version = koalja.plat.services.version("classifier").unwrap();
    println!("model deployments: {deploys}; serving version now v{version}");
    let predict_id = koalja.task_id("predict")?;
    let lookups = koalja
        .plat
        .prov
        .checkpoint_log(predict_id)
        .iter()
        .filter(|e| {
            matches!(
                e.event,
                koalja::provenance::CheckpointEvent::ServiceLookup { .. }
            )
        })
        .count();
    println!("recorded service lookups on the predict path: {lookups}");
    let _ = TaskId::new(0);
    println!("\n{}", koalja.plat.metrics.report());
    Ok(())
}
