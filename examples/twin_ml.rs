//! Fig. 6: the twin-pipeline data circuit (E9).
//!
//! "The upper pipeline shows a training process for a ... neural network,
//! which is deployed as a service consulted by the lower pipeline. The
//! lower pipeline receives sample images to be recognized and classified
//! according to the machine learning model trained by the upper pipeline.
//! ... Clearly, the timescales of the upper and lower pipelines are
//! unrelated."
//!
//! Both the train step and the serving forward pass are AOT-compiled
//! JAX+Pallas artifacts executed via PJRT from rust — Python never runs
//! here. The model server is a *service* (the fig. 5 `lookup implicit`
//! link); every deployment bumps its version, so provenance shows exactly
//! which model classified which image.
//!
//! Run: `make artifacts && cargo run --release --example twin_ml`

use anyhow::Result;
use koalja::prelude::*;
use koalja::task::compute::{pack_params, MlpDims, ModelServer, PjrtTask};

/// Trainer: PJRT train-step with param state; deploys the packed model on
/// the `model` port every `deploy_every` steps. Port-native: the model
/// port is resolved once at bind, the loss is read off the inner task's
/// emission — no wire names in the run loop.
struct Trainer {
    inner: PjrtTask,
    model_port: Option<OutPort>,
    dims: MlpDims,
    steps: u64,
    deploy_every: u64,
    losses: Vec<f32>,
}

impl TaskCode for Trainer {
    fn version(&self) -> u32 {
        1
    }

    fn bind(&mut self, ports: &Ports<'_>) -> Result<()> {
        self.inner.bind(ports)?;
        self.model_port = Some(ports.out("model")?);
        Ok(())
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>) -> Result<()> {
        let before = io.emitter.count();
        self.inner.run(ctx, io)?;
        self.steps += 1;
        if let Some((_, loss)) = io.emitter.emissions()[before].payload.as_tensor() {
            self.losses.push(loss[0]);
        }
        if self.steps % self.deploy_every == 0 {
            let model = self.model_port.expect("bound at install");
            io.emitter.emit(model, pack_params(&self.inner.state)?);
        }
        let _ = self.dims;
        Ok(())
    }

    fn compute_cost(&self, bytes: u64) -> SimDuration {
        // fwd + bwd ≈ 3x fwd flops
        SimDuration::micros(100 + 3 * self.dims.fwd_flops() / 1_000 + bytes / 4096)
    }
}

fn main() -> Result<()> {
    let mut rt = Runtime::open(Runtime::default_dir())?;
    let train_exe = rt.load("mlp_train_step")?;
    let infer_exe = rt.load("mlp_infer")?;
    let dims = MlpDims::default();
    let mut r = rng(1234);
    let init_params = dims.init_params(&mut r);

    // the twin circuit of fig. 6 — built programmatically this time; the
    // equivalent fig. 5 text is in the module docs of `spec`
    let mut pipe = PipelineBuilder::new("twin")
        // upper pipeline: slow timescale — learning
        .task("learn").reads("batch-x").reads("batch-y").emits("loss").emits("model")
        .task("deploy").reads("model").emits("deployed")
        // lower pipeline: fast timescale — recognition via the implicit
        // client-server link to the deployed model
        .task("predict").reads("images").looks_up("classifier").emits("classification")
        .deploy(DeployConfig::default())?;

    // typed entry points, resolved once
    let batch_x = pipe.source("batch-x")?;
    let batch_y = pipe.source("batch-y")?;
    let images = pipe.source("images")?;
    let loss_sink = pipe.sink("loss")?;
    let classification = pipe.sink("classification")?;
    let deployed = pipe.sink("deployed")?;

    // the deployed model service (starts untrained)
    pipe.plat.services.register(
        "classifier",
        Box::new(ModelServer::new(infer_exe.clone(), dims, init_params.clone())),
    );

    pipe.task("learn")?.plug(
        &mut pipe,
        Box::new(Trainer {
            inner: PjrtTask::new(train_exe, "loss")
                .with_state(init_params)
                .with_emit(vec![(4, "loss".into(), DataClass::Summary)])
                .with_absorb(vec![(0, 0), (1, 1), (2, 2), (3, 3)]),
            model_port: None,
            dims,
            steps: 0,
            deploy_every: 50,
            losses: vec![],
        }),
    )?;

    // deploy: push packed params into the running service. Service
    // mutation is live shared state, so this plugin declares itself
    // sequential — it always runs in the deterministic commit phase.
    pipe.task("deploy")?.plug(
        &mut pipe,
        Box::new(
            PortFn::new(move |ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
                let deployed = io.out(0)?;
                for av in io.inputs.all() {
                    let packed = ctx.fetch(av)?;
                    let ok = ctx.update_service("classifier", &packed)?;
                    ctx.remark(&format!("deployed model {} (ok={ok})", av.content));
                    io.emitter.emit(deployed, Payload::scalar(1.0));
                }
                Ok(())
            })
            .sequential(),
        ),
    )?;

    // predict: consult the service (out-of-band lookup, recorded) —
    // lookups need the live service directory, hence sequential too
    let predict = pipe.task("predict")?;
    predict.plug(
        &mut pipe,
        Box::new(PortFn::new(|ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
            let classification = io.out(0)?;
            for av in io.inputs.all() {
                let batch = ctx.fetch(av)?;
                let probs = ctx.lookup("classifier", &batch)?;
                let (shape, p) = probs
                    .as_tensor()
                    .ok_or_else(|| anyhow::anyhow!("bad model response"))?;
                let classes = shape[1];
                let preds: Vec<f32> = p
                    .chunks(classes)
                    .map(|row| {
                        row.iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .unwrap()
                            .0 as f32
                    })
                    .collect();
                let n = preds.len();
                io.emitter.emit(classification, Payload::tensor(&[n], preds));
            }
            Ok(())
        })
        .sequential()),
    )?;

    // ---- drive both timescales ----
    let stream = koalja::workload::ImageStream::new(&mut r, dims.classes, dims.input, 0.4);
    let train_period = SimDuration::millis(500); // slow: learning
    let image_period = SimDuration::millis(90); // fast: recognition
    let steps = 300u64;
    let horizon = SimTime::ZERO + train_period.scale(steps as f64 + 2.0);

    for i in 0..steps {
        let (x, labels) = stream.batch(&mut r, dims.batch);
        let y = stream.one_hot(&labels);
        let t = SimTime::ZERO + train_period.scale(i as f64);
        batch_x.inject_at(&mut pipe, x, DataClass::Summary, RegionId::new(0), t);
        batch_y.inject_at(&mut pipe, y, DataClass::Summary, RegionId::new(0), t);
    }
    let mut truth: Vec<Vec<usize>> = Vec::new();
    let mut t = SimTime::ZERO;
    loop {
        t += image_period;
        if t > horizon {
            break;
        }
        let (x, labels) = stream.batch(&mut r, dims.batch);
        truth.push(labels);
        images.inject_at(&mut pipe, x, DataClass::Summary, RegionId::new(0), t);
    }

    pipe.run_until_idle();

    // ---- results ----
    println!("== twin pipeline run: {steps} train steps, {} image batches ==", truth.len());

    // loss curve from the collected sink
    let losses: Vec<f32> = loss_sink
        .read(&pipe)
        .iter()
        .map(|c| c.payload.as_tensor().unwrap().1[0])
        .collect();
    println!("\nloss curve (every 25 steps):");
    for (i, chunk) in losses.chunks(25).enumerate() {
        println!("  step {:>4}: loss {:.4}", i * 25, chunk[0]);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.2),
        "training converged: {} -> {}",
        losses[0],
        losses.last().unwrap()
    );

    // accuracy per classification batch, split before/after first deploy
    let classifications = classification.read(&pipe);
    let mut early_correct = 0usize;
    let mut early_total = 0usize;
    let mut late_correct = 0usize;
    let mut late_total = 0usize;
    let n_images = classifications.len().min(truth.len());
    for (i, c) in classifications.iter().take(n_images).enumerate() {
        let (_, preds) = c.payload.as_tensor().unwrap();
        for (p, t) in preds.iter().zip(&truth[i]) {
            let hit = (*p as usize) == *t;
            if i < n_images / 10 {
                early_total += 1;
                early_correct += hit as usize;
            } else if i > n_images * 9 / 10 {
                late_total += 1;
                late_correct += hit as usize;
            }
        }
    }
    let early_acc = early_correct as f64 / early_total.max(1) as f64;
    let late_acc = late_correct as f64 / late_total.max(1) as f64;
    println!("\nclassification accuracy: first 10% of stream {:.1}% -> last 10% {:.1}%",
        early_acc * 100.0, late_acc * 100.0);
    assert!(late_acc > early_acc, "deployed model improved the lower pipeline");
    assert!(late_acc > 0.85, "trained accuracy {late_acc}");

    // provenance: model versions visible on the serving path
    let deploys = deployed.count(&pipe);
    let version = pipe.plat.services.version("classifier").unwrap();
    println!("model deployments: {deploys}; serving version now v{version}");
    let lookups = predict
        .checkpoint_log(&pipe)
        .iter()
        .filter(|e| {
            matches!(
                e.event,
                koalja::provenance::CheckpointEvent::ServiceLookup { .. }
            )
        })
        .count();
    println!("recorded service lookups on the predict path: {lookups}");
    println!("\n{}", pipe.plat.metrics.report());
    Ok(())
}
