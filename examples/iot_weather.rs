//! Fig. 7: aggregation from weather sensors at mismatched rates (E5).
//!
//! "Some sensors (e.g. wind speed) may take longer to arrive than others
//! (e.g. temperature). Should the pipeline wait for all the data, several
//! repeated measurements ... There are several common possibilities for
//! coordinating and composing data."
//!
//! Part 1 compares the three snapshot policies (§III-I) on the same
//! mismatched arrival trace — placed on the extended cloud: the fast
//! temperature sensor reports from `edge-0`, the wind sensor from the
//! EU edge `edge-1`, humidity from the datacentre, and the fuse task is
//! pinned to `central`, so every edge sample pays real WAN physics on
//! the way in (watch the WAN-bytes column move with the policy). The
//! sensors stream through the live front door: one producer *thread*
//! per sensor replays its recorded trace through a bounded feed
//! (`Feed::run_source` + `ReplaySource`) while the main thread pumps —
//! the field-deployment shape, not a pre-loaded quiescent coordinator.
//! Part 2 runs the L1 Pallas sliding-window
//! kernel (AOT-compiled, executed via PJRT) over a buffered sensor stream
//! — the `input[N/S]` feature computing real moving averages.
//!
//! Run: `make artifacts && cargo run --release --example iot_weather`

use anyhow::Result;
use koalja::prelude::*;
use koalja::task::compute::PjrtTask;

/// Feed the same three-sensor trace (temp fast, wind slow, humidity
/// slowest) into a fuse task under `policy`; report what comes out.
/// The fleet spans three regions: the sensors inject at `edge-0`,
/// `edge-1` and `central`, and the fuse task is placed at `central` —
/// so the two edge feeds cross the WAN on fetch (summaries cross
/// zones freely; only Raw is stopped at the border).
fn run_policy(policy: &str) -> Result<(usize, f64, u64)> {
    // the fuse task, built programmatically (the three sensor ports and
    // the policy attr are data here, not spec text)
    let mut pipe = PipelineBuilder::new("weather")
        .task("fuse").reads("temp").reads("wind").reads("humidity")
        .emits("sample-set").policy(policy)
        .place_at("fuse", "central")
        .source_feed("temp").source_feed("wind").source_feed("humidity")
        .deploy(DeployConfig { topology: demo_topology(2), ..Default::default() })?;
    // field deployments brown out: give the fuse task two retries with
    // exponential virtual-time backoff, and if a firing still exhausts
    // its budget, emit an empty fallback sample-set so the downstream
    // aggregation keeps flowing instead of stalling on one bad firing
    // (try `KOALJA_FAULT_SEED=7 cargo run --example iot_weather` to
    // watch the supervision engage under injected faults)
    pipe.task("fuse")?.set_fire_policy(
        &mut pipe,
        FirePolicy::retries(2)
            .with_backoff(Backoff::Exponential {
                base: SimDuration::millis(50),
                cap: SimDuration::millis(400),
            })
            .degrade(Payload::tensor(&[4], vec![0.0; 4])),
    );
    let sample_set = pipe.sink("sample-set")?;
    let mut r = rng(77);
    let mut sensors = [
        koalja::workload::SensorStream::new("temp", SimDuration::millis(100), 4, 20.0),
        koalja::workload::SensorStream::new("wind", SimDuration::millis(300), 4, 5.0),
        koalja::workload::SensorStream::new("humidity", SimDuration::millis(1000), 4, 60.0),
    ];
    // where each sensor physically reports from: temp on the near edge,
    // wind on the EU edge, humidity already in the datacentre
    let homes = ["edge-0", "edge-1", "central"]
        .map(|name| pipe.plat.net.by_name(name).expect("demo topology region"));
    let horizon = SimTime::secs(30);
    // record each sensor's trace (same rng walk as ever), then stream it
    // live: one producer thread per sensor replays through its bounded
    // feed while the main thread pumps — watermarks keep the mismatched
    // rates honest (the frontier waits for the slowest open feed), and
    // the books are byte-identical to any other interleaving
    let mut replays = Vec::new();
    for (s, home) in sensors.iter_mut().zip(homes) {
        let events: Vec<koalja::ingest::TimedEvent> = s
            .arrivals_until(&mut r, horizon)
            .into_iter()
            .map(|(t, p)| koalja::ingest::TimedEvent::new(t, p, DataClass::Summary, home))
            .collect();
        let feed = pipe.feed(&s.name)?;
        replays.push((feed, koalja::ingest::ReplaySource::new(&s.name, events, 8)));
    }
    let report = std::thread::scope(|scope| {
        for (feed, replay) in replays.drain(..) {
            scope.spawn(move || feed.run_source(replay).expect("sensor replay producer"));
        }
        pipe.pump_ingest(std::time::Duration::from_secs(60))
    });
    assert!(!report.timed_out, "all sensor feeds close, so the pump drains to idle");
    let n = sample_set.count(&pipe);
    let staleness = pipe.plat.metrics.e2e_latency.mean().as_secs_f64();
    let wan = pipe.plat.metrics.bytes(koalja::obs::NetTier::Wan);
    Ok((n, staleness, wan))
}

fn main() -> Result<()> {
    println!("== fig. 7: snapshot policies under 10:3:1 arrival-rate mismatch ==");
    println!("   (sensors report from edge-0 / edge-1 / central; fuse placed at central)");
    println!("policy          sample-sets   mean staleness    WAN bytes");
    for policy in ["allnew", "swap", "merge"] {
        let (n, stale, wan) = run_policy(policy)?;
        println!("{policy:14}  {n:10}   {stale:8.3}s   {wan:10}");
    }
    println!(
        "\nallnew waits for the slowest sensor (few, coherent sets);\n\
         swap fires on every fresh value reusing stale ones (many, mixed age);\n\
         merge folds everything FCFS into one stream (most, no tuple shape).\n\
         Every edge sample crossed the WAN to reach the central fuse task —\n\
         move the fuse with `place_at` (or let Placement::optimize pick) and\n\
         the WAN column collapses; see benches/edge_vs_central.rs.\n"
    );

    // ---- part 2: the paper's input[N/S] with the real Pallas kernel ----
    println!("== sliding windows via the AOT Pallas kernel (window_mean) ==");
    let mut rt = Runtime::open(Runtime::default_dir())?;
    let window_exe = rt.load("window_mean")?;

    // stream[256]: collect 256 one-sample AVs, then the PJRT task stacks
    // them into the (256, 8) tensor the kernel was lowered for.
    let spec = parse("[windows]\n(stream[256]) window-stats (means)\n")?;
    let mut pipe = Pipeline::deploy(&spec, DeployConfig::default())?;
    let stream = pipe.source("stream")?;
    let means = pipe.sink("means")?;
    let stats = pipe.task("window-stats")?;
    stats.plug(
        &mut pipe,
        Box::new(PjrtTask::new(window_exe.clone(), "means").with_flops(256 * 8 * 2)),
    )?;
    // the kernel path gets the stricter treatment: one retry, a deadline
    // budget on each firing, and anything that still fails is pinned in
    // the dead-letter book for a post-mortem redrive (no silent drops)
    stats.set_fire_policy(
        &mut pipe,
        FirePolicy::retries(1).with_deadline(SimDuration::secs(5)).dead_letter(),
    );
    let mut r = rng(99);
    let mut sensor = koalja::workload::SensorStream::new("chan", SimDuration::millis(20), 8, 15.0);
    for (t, p) in sensor.arrivals_until(&mut r, SimTime::secs(12)) {
        stream.inject_at(&mut pipe, p, DataClass::Summary, RegionId::new(0), t);
    }
    pipe.run_until_idle();
    let batches = means.read(&pipe).to_vec();
    println!("window batches: {} (each (29, 8) = 29 windows of [32/8])", batches.len());
    if let Some(b) = batches.first() {
        let (_, data) = b.payload.as_tensor().unwrap();
        println!(
            "first batch, channel 0 moving average across windows: {:.2} .. {:.2}",
            data[0],
            data[28 * 8]
        );
        // sanity: sensor bias is 15.0, so averages should hover nearby
        assert!((data[0] - 15.0).abs() < 2.0, "window mean near sensor bias");
    }
    println!("kernel executions on the PJRT hot path: {}", window_exe.runs());
    let letters = stats.dead_letters(&pipe);
    if letters.is_empty() {
        println!("dead-letter book: empty (every window firing fit its 5s budget)");
    } else {
        println!(
            "dead-letter book: {} firing(s) pinned for redrive (first: {})",
            letters.len(),
            letters[0].error
        );
    }
    println!("\n{}", pipe.plat.metrics.report());
    Ok(())
}
