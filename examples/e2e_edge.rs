//! END-TO-END DRIVER (E7, §III-G/§IV, figs. 11–12): the extended-cloud
//! edge pipeline on a vehicle-fleet trace.
//!
//! "A modern 'smart' vehicle may produce terabytes of data on every
//! journey ... It is not only impractical but would [be] utter madness to
//! upload such amounts from every vehicle to centralized locations."
//!
//! Full stack, all layers composing:
//!   * vehicles stream raw (1024, 8) sample chunks at four edge regions;
//!   * each edge runs the **AOT-compiled Pallas summarize kernel via PJRT**
//!     (L1/L2 on the L3 hot path) reducing every chunk to a (4, 8) sketch
//!     — a 1024x data reduction — plus an anomaly alert stream;
//!   * sketches (Summary class) legally cross sovereignty zones to HQ;
//!     raw data (Raw class) cannot and never does;
//!   * a ghost pre-flight audits the routing before real data flows
//!     ("trust, but verify", §III-K);
//!   * the centralize-everything baseline runs the same trace for
//!     comparison: WAN bytes, energy, latency, and sovereignty violations.
//!
//! Headline metric: WAN bytes moved, Koalja edge placement vs centralized.
//! Recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example e2e_edge`

use anyhow::Result;
use koalja::metrics::NetTier;
use koalja::prelude::*;
use koalja::task::compute::PjrtTask;
use koalja::workload::VehicleTrace;
use std::time::Instant;

const N_EDGE: usize = 4;

fn edge_spec() -> String {
    let mut s = String::from("[fleet]\n");
    for i in 0..N_EDGE {
        s.push_str(&format!(
            "(raw-e{i}) summarize-e{i} (sketch) @region=edge-{i}\n"
        ));
    }
    // four sketches (one per region batch) merge into a fleet report at HQ
    s.push_str(&format!(
        "(sketch[{N_EDGE}]) hq-aggregate (fleet-report) @region=central\n"
    ));
    s
}

fn central_spec() -> String {
    // same logical circuit, but raw chunks must travel to central compute
    let mut s = String::from("[fleet-central]\n");
    for i in 0..N_EDGE {
        s.push_str(&format!("(raw-e{i}) summarize-e{i} (sketch)\n"));
    }
    s.push_str(&format!("(sketch[{N_EDGE}]) hq-aggregate (fleet-report)\n"));
    s
}

struct ArmReport {
    wan_bytes: u64,
    lan_bytes: u64,
    joules: f64,
    denied: u64,
    reports: usize,
    e2e_mean_s: f64,
    kernel_runs: u64,
    wall_s: f64,
    chunks: usize,
}

fn run_arm(central: bool) -> Result<ArmReport> {
    let mut rt = Runtime::open(Runtime::default_dir())?;
    let summarize_exe = rt.load("edge_summarize")?;
    let runs_before = summarize_exe.runs();

    let spec_text = if central { central_spec() } else { edge_spec() };
    let spec = parse(&spec_text)?;
    let cfg = DeployConfig {
        topology: demo_topology(N_EDGE),
        force_central: central,
        ..Default::default()
    };
    let mut pipe = Pipeline::deploy(&spec, cfg)?;
    // handles resolved once per arm: per-edge in-trays, tasks, the sink
    let raws: Vec<SourceHandle> = (0..N_EDGE)
        .map(|i| pipe.source(&format!("raw-e{i}")))
        .collect::<Result<_>>()?;
    let fleet_report = pipe.sink("fleet-report")?;
    for i in 0..N_EDGE {
        let h = pipe.task(&format!("summarize-e{i}"))?;
        h.plug(
            &mut pipe,
            Box::new(PjrtTask::new(summarize_exe.clone(), "sketch").with_flops(1024 * 8 * 4)),
        )?;
    }
    let hq = pipe.task("hq-aggregate")?;
    hq.plug(&mut pipe, Box::new(SketchMerge::new("fleet-report")))?;

    // ghost pre-flight: verify routing with zero payload cost (§III-K)
    let edge0 = pipe.plat.net.by_name("edge-0").unwrap();
    let ghost = raws[0].inject_ghost(&mut pipe, 100 << 20, edge0);
    pipe.run_until_idle();
    let ghost_wan = pipe.plat.metrics.bytes(NetTier::Wan);
    assert_eq!(ghost_wan, 0, "ghost routing moved no payload bytes");
    let route = pipe.ghost_route(ghost);
    assert!(route.iter().any(|t| t == "summarize-e0"), "ghost reached the edge task");

    // the real trace: one vehicle fleet per edge region
    let trace = VehicleTrace {
        n_vehicles: 2,
        chunks_per_vehicle: 12,
        chunk_rows: 1024,
        dims: 8,
        chunk_period: SimDuration::secs(2),
        junk_fraction: 0.5,
    };
    let mut chunks = 0usize;
    for i in 0..N_EDGE {
        let region = pipe.plat.net.by_name(&format!("edge-{i}")).unwrap();
        let mut r = rng(1000 + i as u64);
        for c in trace.generate(&mut r) {
            raws[i].inject_at(&mut pipe, c.payload, DataClass::Raw, region, c.time);
            chunks += 1;
        }
    }
    let wall = Instant::now();
    pipe.run_until_idle();
    let wall_s = wall.elapsed().as_secs_f64();

    Ok(ArmReport {
        wan_bytes: pipe.plat.metrics.bytes(NetTier::Wan),
        lan_bytes: pipe.plat.metrics.bytes(NetTier::Lan),
        joules: pipe.plat.metrics.joules,
        denied: pipe.plat.metrics.get("sovereignty_denied"),
        reports: fleet_report.count(&pipe),
        e2e_mean_s: pipe.plat.metrics.e2e_latency.mean().as_secs_f64(),
        kernel_runs: summarize_exe.runs() - runs_before,
        wall_s,
        chunks,
    })
}

fn main() -> Result<()> {
    println!("== E7: edge summarization vs centralize-everything ==");
    println!("(4 edge regions x 2 vehicles x 12 chunks x (1024x8) f32 raw samples)\n");
    let edge = run_arm(false)?;
    let central = run_arm(true)?;

    let raw_total: u64 = (edge.chunks * 1024 * 8 * 4) as u64;
    println!("arm          WAN bytes     LAN bytes    energy(J)  denied  reports  e2e-mean   pallas-runs");
    for (name, a) in [("koalja-edge", &edge), ("centralized", &central)] {
        println!(
            "{name:12} {:>12} {:>12}   {:>8.3}  {:>6}  {:>7}  {:>7.3}s  {:>6}",
            a.wan_bytes, a.lan_bytes, a.joules, a.denied, a.reports, a.e2e_mean_s, a.kernel_runs
        );
    }
    println!("\nraw data generated at the edges: {raw_total} bytes");
    println!(
        "WAN reduction: {:.0}x fewer bytes with edge placement",
        central.wan_bytes.max(1) as f64 / edge.wan_bytes.max(1) as f64
    );
    println!(
        "energy: {:.1}x less with edge placement",
        central.joules / edge.joules.max(1e-9)
    );
    println!(
        "sovereignty: centralized arm DENIED {} raw transfers (EU data may not reach the US \
         datacentre) — those vehicles' data were simply lost; Koalja processed all {} chunks \
         in place ({} denials).",
        central.denied, edge.chunks, edge.denied
    );
    println!(
        "\ncoordinator wallclock: {:.3}s for {} chunks ({:.0} chunks/s with the Pallas kernel \
         on the PJRT hot path)",
        edge.wall_s,
        edge.chunks,
        edge.chunks as f64 / edge.wall_s
    );

    // sanity assertions: the paper's qualitative claims must hold
    assert!(edge.wan_bytes * 10 < central.wan_bytes, "edge placement saves >10x WAN");
    assert!(edge.joules < central.joules, "edge placement saves energy");
    assert_eq!(edge.denied, 0, "koalja arm violates no sovereignty");
    assert!(central.denied > 0, "central arm cannot legally move EU raw data");
    assert!(edge.reports > 0, "fleet reports were produced");
    println!("\nall E7 claims hold ✓");
    Ok(())
}
