//! Make-mode continuous delivery (E1, §III-B's first trigger case).
//!
//! A synthetic software build: 32 source files → 8 object files → 1 linked
//! binary. Demanding the binary rebuilds exactly the stale suffix; sparse
//! edits (the common case, §III-J) cost a fraction of the full build —
//! "tools like Make ha[ve] exploited [this] for decades".
//!
//! Run: `cargo run --release --example make_build`

use anyhow::Result;
use koalja::prelude::*;
use koalja::workload::BuildTree;

fn main() -> Result<()> {
    let tree = BuildTree { leaves: 32, fanin: 4, source_bytes: 4096 };
    let n_obj = tree.n_objects();

    // wiring: srcN -> compileM (4 sources each) -> link -> binary
    let mut text = String::from("[build]\n");
    for o in 0..n_obj {
        let ins: Vec<String> = (0..tree.fanin).map(|k| format!("src{}", o * tree.fanin + k)).collect();
        text.push_str(&format!("({}) compile{} (obj{})\n", ins.join(", "), o, o));
    }
    let objs: Vec<String> = (0..n_obj).map(|o| format!("obj{o}")).collect();
    text.push_str(&format!("({}) link-all (binary) @policy=swap\n", objs.join(", ")));
    let spec = parse(&text)?;
    let mut koalja = Coordinator::deploy(&spec, DeployConfig::default())?;

    // a "compiler": one artifact derived from ALL inputs (content-coupled,
    // so any changed source changes the object file)
    let compiler = |out: String| {
        FnTask::new(move |ctx: &mut TaskCtx<'_>, snap: &Snapshot| {
            let mut blob: Vec<u8> = Vec::new();
            for av in snap.all_avs() {
                if let Payload::Bytes(b) = ctx.fetch(av)? {
                    blob.extend_from_slice(&b[..b.len().min(64)]);
                    blob.extend_from_slice(&av.content.0.to_le_bytes());
                }
            }
            Ok(vec![Output::summary(&out, Payload::Bytes(blob))])
        })
    };
    for o in 0..n_obj {
        koalja.set_code(&format!("compile{o}"), Box::new(compiler(format!("obj{o}"))))?;
    }
    koalja.set_code("link-all", Box::new(compiler("binary".to_string())))?;

    // drop generation-0 of every source into the in-trays
    for i in 0..tree.leaves {
        koalja.inject(&format!("src{i}"), tree.source_payload(i, 0), DataClass::Summary)?;
    }

    // full build
    let before = koalja.plat.metrics.task_runs;
    let bin0 = koalja.demand("binary")?;
    let full_build_runs = koalja.plat.metrics.task_runs - before;
    println!("full build:        {full_build_runs} task runs -> {}", bin0.content);

    // no-op rebuild: everything cached
    let before = koalja.plat.metrics.task_runs;
    koalja.demand("binary")?;
    println!(
        "no-op rebuild:     {} task runs ({} memo hits)",
        koalja.plat.metrics.task_runs - before,
        koalja.plat.metrics.get("memo_hits")
    );

    // sparse edit: 2 of 32 files change (one object file affected each)
    let mut r = rng(5);
    for gen in 1..=3u64 {
        let dirty = tree.dirty_set(&mut r, 2);
        for &i in &dirty {
            koalja.inject(&format!("src{i}"), tree.source_payload(i, gen), DataClass::Summary)?;
        }
        let before = koalja.plat.metrics.task_runs;
        let bin = koalja.demand("binary")?;
        println!(
            "edit {dirty:?}: {} task runs (of {} total tasks) -> {}",
            koalja.plat.metrics.task_runs - before,
            n_obj + 1,
            bin.content
        );
    }

    // compare with the schedule-driven baseline: it recompiles everything
    // every tick regardless (E8's waste in the build setting)
    println!(
        "\ncron-style comparator would run all {} tasks per tick — data-aware \
         demand rebuilt only the stale suffix.",
        n_obj + 1
    );
    println!("\n{}", koalja.plat.metrics.report());
    Ok(())
}
