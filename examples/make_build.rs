//! Make-mode continuous delivery (E1, §III-B's first trigger case).
//!
//! A synthetic software build: 32 source files → 8 object files → 1 linked
//! binary. Demanding the binary rebuilds exactly the stale suffix; sparse
//! edits (the common case, §III-J) cost a fraction of the full build —
//! "tools like Make ha[ve] exploited [this] for decades".
//!
//! The wiring is *generated*, so this example uses `PipelineBuilder`
//! directly — no spec text is ever rendered — and the edit/demand loop
//! runs entirely on pre-resolved source/sink handles.
//!
//! Run: `cargo run --release --example make_build`

use anyhow::Result;
use koalja::prelude::*;
use koalja::workload::BuildTree;

fn main() -> Result<()> {
    let tree = BuildTree { leaves: 32, fanin: 4, source_bytes: 4096 };
    let n_obj = tree.n_objects();

    // wiring: srcN -> compileM (4 sources each) -> link -> binary,
    // constructed programmatically from the build tree
    let mut builder = PipelineBuilder::new("build");
    for o in 0..n_obj {
        let mut t = builder.task(&format!("compile{o}"));
        for k in 0..tree.fanin {
            t = t.reads(&format!("src{}", o * tree.fanin + k));
        }
        builder = t.emits(&format!("obj{o}")).done();
    }
    let mut link = builder.task("link-all");
    for o in 0..n_obj {
        link = link.reads(&format!("obj{o}"));
    }
    let mut pipe = link.emits("binary").policy("swap").deploy(DeployConfig::default())?;

    // a "compiler": one artifact derived from ALL inputs (content-coupled,
    // so any changed source changes the object file). Port-native: every
    // compiler emits on its task's single declared output port — no wire
    // names, so ONE closure serves all 9 tasks.
    let compiler = || {
        PortFn::new(move |ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
            let mut blob: Vec<u8> = Vec::new();
            for av in io.inputs.all() {
                if let Payload::Bytes(b) = ctx.fetch(av)? {
                    blob.extend_from_slice(&b[..b.len().min(64)]);
                    blob.extend_from_slice(&av.content.0.to_le_bytes());
                }
            }
            let out = io.out(0)?;
            io.emitter.emit(out, Payload::Bytes(blob));
            Ok(())
        })
    };
    for o in 0..n_obj {
        let h = pipe.task(&format!("compile{o}"))?;
        h.plug(&mut pipe, Box::new(compiler()))?;
    }
    let link_all = pipe.task("link-all")?;
    link_all.plug(&mut pipe, Box::new(compiler()))?;

    // resolve every source in-tray and the binary sink once; the whole
    // edit/rebuild loop below is string-free
    let srcs: Vec<SourceHandle> = (0..tree.leaves)
        .map(|i| pipe.source(&format!("src{i}")))
        .collect::<Result<_>>()?;
    let binary = pipe.sink("binary")?;

    // drop generation-0 of every source into the in-trays
    for (i, src) in srcs.iter().enumerate() {
        src.inject(&mut pipe, tree.source_payload(i, 0), DataClass::Summary);
    }

    // full build
    let before = pipe.plat.metrics.task_runs;
    let bin0 = binary.demand(&mut pipe)?;
    let full_build_runs = pipe.plat.metrics.task_runs - before;
    println!("full build:        {full_build_runs} task runs -> {}", bin0.content);

    // no-op rebuild: everything cached
    let before = pipe.plat.metrics.task_runs;
    binary.demand(&mut pipe)?;
    println!(
        "no-op rebuild:     {} task runs ({} memo hits)",
        pipe.plat.metrics.task_runs - before,
        pipe.plat.metrics.get("memo_hits")
    );

    // sparse edit: 2 of 32 files change (one object file affected each)
    let mut r = rng(5);
    for gen in 1..=3u64 {
        let dirty = tree.dirty_set(&mut r, 2);
        for &i in &dirty {
            srcs[i].inject(&mut pipe, tree.source_payload(i, gen), DataClass::Summary);
        }
        let before = pipe.plat.metrics.task_runs;
        let bin = binary.demand(&mut pipe)?;
        println!(
            "edit {dirty:?}: {} task runs (of {} total tasks) -> {}",
            pipe.plat.metrics.task_runs - before,
            n_obj + 1,
            bin.content
        );
    }

    // compare with the schedule-driven baseline: it recompiles everything
    // every tick regardless (E8's waste in the build setting)
    println!(
        "\ncron-style comparator would run all {} tasks per tick — data-aware \
         demand rebuilt only the stale suffix.",
        n_obj + 1
    );
    println!("\n{}", pipe.plat.metrics.report());
    Ok(())
}
