//! Breadboard session walkthrough — the §III-H/§III-J smart-workspace loop
//! against a live pipeline, using the library API directly:
//!
//!  1. probe wires while data flows (taps: predicate, payload capture,
//!     overhead counters, pause/step of virtual time),
//!  2. hot-swap a task's code mid-run with a dry-run invalidation preview
//!     and a version bump that lands in provenance,
//!  3. forensically replay the whole run from the injection ledger + seed
//!     and diff rebuilt content hashes against the record.
//!
//! Run: `cargo run --release --example breadboard_session`

use anyhow::Result;
use koalja::breadboard::{Breadboard, TapSpec, WINDOW_END};
use koalja::prelude::*;
use koalja::provenance::ProvenanceQuery;

/// v`version` screening code: drop chunks whose peak is under `threshold`,
/// forward the rest on the task's single output port. Bumping the version
/// (with a new threshold) is the hot-swap payload below.
fn screen_factory(threshold: f32, version: u32) -> impl Fn() -> Box<dyn TaskCode> {
    move || {
        Box::new(PortFn::versioned(
            move |ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
                let kept = io.out(0)?;
                for av in io.inputs.all() {
                    let p = ctx.fetch(av)?;
                    if let Some((_, data)) = p.as_tensor() {
                        let peak = data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                        if peak > threshold {
                            io.emitter.emit(kept, p.clone());
                        } else {
                            ctx.remark(&format!("screened (peak {peak:.2} <= {threshold})"));
                        }
                    }
                }
                Ok(())
            },
            version,
        ))
    }
}

fn main() -> Result<()> {
    // a two-stage edge screen: keep interesting chunks, count them at HQ
    let spec = parse(
        "[screening]\n\
         (samples) screen (kept)\n\
         (kept) tally (report)\n",
    )?;
    let mut bread = Breadboard::deploy(&spec, DeployConfig::default())?;
    // typed handles, resolved once (the session derefs to the Pipeline
    // facade): the in-tray for the feed loop, the tasks for plug/swap
    let samples_in = bread.source("samples")?;
    let screen = bread.task("screen")?;
    let tally = bread.task("tally")?;
    bread.plug_task(screen, screen_factory(1.5, 1))?;
    bread.plug_task(tally, || {
        Box::new(PortFn::new(|ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
            let n = io.inputs.all().count() as f32;
            for av in io.inputs.all() {
                ctx.fetch(av)?;
            }
            let report = io.out(0)?;
            io.emitter.emit(report, Payload::scalar(n));
            Ok(())
        }))
    })?;

    // 1. taps: a metadata tap on the in-tray, a payload tap on 'kept'
    //    filtered to big chunks only
    let in_tap = bread.tap("samples")?;
    let kept_tap = bread.tap_with(
        "kept",
        TapSpec::default()
            .with_capacity(16)
            .with_payloads()
            .with_predicate(|av| av.size_bytes >= 32),
    )?;

    // stream the first window of synthetic chunks
    let mut r = rng(5);
    let inject = |b: &mut Breadboard, from_ms: u64, n: u64, r: &mut koalja::util::Rng| {
        for i in 0..n {
            let data: Vec<f32> = (0..8).map(|_| (r.normal() * 1.2) as f32).collect();
            samples_in.inject_at(
                b,
                Payload::tensor(&[1, 8], data),
                DataClass::Summary,
                RegionId::new(0),
                SimTime::millis(from_ms + i * 40),
            );
        }
    };
    inject(&mut bread, 0, 20, &mut r);

    // single-step a few events (pause/step/resume of virtual time)...
    for _ in 0..3 {
        if let Some(at) = bread.step() {
            println!("stepped one event at {at}");
        }
    }
    // ...then resume to idle
    bread.run_until_idle();
    bread.run_until(SimTime::secs(2));
    let t_swap = bread.plat.now;

    let s_in = bread.tap_stats(in_tap)?.unwrap();
    let s_kept = bread.tap_stats(kept_tap)?.unwrap();
    println!("tap[samples] seen={} sampled={}", s_in.seen, s_in.sampled);
    println!(
        "tap[kept]    seen={} sampled={} (predicate-filtered, payloads captured)",
        s_kept.seen, s_kept.sampled
    );
    if let Some(s) = bread.samples(kept_tap)?.last() {
        println!("latest kept chunk: {} payload={:?}", s.av.uri(), s.payload.is_some());
    }

    // 2. hot-swap: the screen is too strict — v2 lowers the threshold.
    //    Dry-run first: what would the swap strand?
    let preview = bread.swap_preview_task(screen, 2)?;
    println!("\ndry-run: {}", preview.summary());
    let outcome = bread.hot_swap_task(screen, screen_factory(0.5, 2), false)?;
    println!(
        "committed at {}: evicted {} cached objects downstream",
        outcome.at, outcome.cache_objects_evicted
    );

    // second window under v2
    inject(&mut bread, t_swap.as_micros() / 1_000 + 100, 20, &mut r);
    bread.run_until_idle();
    let t_end = bread.plat.now;

    // version bump is in the provenance stories
    println!("\nversion changes on 'screen': {:?}", screen.version_changes(&bread));
    if let Some(col) = bread.sink("report")?.latest(&bread) {
        let q = ProvenanceQuery::new(&bread.plat.prov);
        println!("latest report touched by versions {:?}", q.versions_touching(col.av.id));
    }

    // 3. forensic replay: rebuild from ledger + seed, diff both windows
    let run = bread.forensic_replay()?;
    println!(
        "\nreplayed {} injections in {} events ({} payloads missing)",
        run.injections_replayed, run.events, run.missing_payloads
    );
    let pre = bread.diff_replay(&run, SimTime::ZERO, t_swap);
    let _ = t_end;
    let post = bread.diff_replay(&run, t_swap, WINDOW_END);
    println!("pre-swap  window: {}", pre.summary());
    println!("post-swap window: {}", post.summary());
    assert!(post.total_matched() > 0, "post-swap window must contain rebuilt outputs");
    assert!(post.drift_free(), "post-swap window must rebuild hash-identical");
    println!("\npost-swap outputs certified against the record — breadboard loop complete");
    Ok(())
}
