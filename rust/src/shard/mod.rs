//! Sharded multi-node runtime — partitioning a deployed pipeline across N
//! simulated nodes (§III-B, §IV: "tasks should be freely locatable in any
//! region, with transparent interconnection between Kubernetes
//! deployments").
//!
//! Two placement dimensions, deliberately distinct:
//!
//! * **Region** (task → [`RegionId`]) is *semantic*: it decides WAN fetch
//!   latency, sovereignty verdicts and energy tiers, so it moves the books.
//!   Regions come from `@region` attrs, [`PlacementSpec::regions`] pins, or
//!   the [`Placement`] optimizer.
//! * **Node** (task → thread) is *operational*: it decides which simulated
//!   node executes a firing and which wires cross the inter-node
//!   [`Exchange`](crate::bus::Exchange). Node assignment must never
//!   perturb a single committed byte — all cross-node effects ride the
//!   effect tape and commit in (instant, task-index) order on the
//!   coordinator thread, so sink books, provenance, dead letters and span
//!   streams are byte-identical across any node count
//!   (`rust/tests/placement_determinism.rs` is the enforcement).
//!
//! The ambient default node count is `KOALJA_NODES` (like `KOALJA_WORKERS`
//! for the worker pool), so the CI matrix can sweep placements without
//! touching code.

pub mod placement;

pub use placement::{Placement, PlacementInput};

use crate::graph::PipelineGraph;
use crate::util::{RegionId, TaskId};

use std::collections::BTreeMap;

/// Ambient default for [`PlacementSpec::nodes`]: `KOALJA_NODES`, clamped
/// to >= 1; anything unset or unparsable means a single node (the
/// seed-era behaviour).
pub fn default_nodes() -> usize {
    std::env::var("KOALJA_NODES")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(1)
        .max(1)
}

/// Deploy-time placement request: how many simulated nodes to run, plus
/// region pins (by task name) layered between `@region` spec attrs and the
/// nearest-datacentre default, and node pins for tests that want to force
/// a particular partition.
#[derive(Clone, Debug)]
pub struct PlacementSpec {
    /// Simulated node (thread) count; 1 reproduces the single-node runtime
    /// exactly.
    pub nodes: usize,
    /// task name → region name. Loses to an explicit `@region` attr in the
    /// spec text, wins over the default-region fallback. This is where
    /// [`Placement::optimize`] output and `PipelineBuilder::place_at` land.
    pub regions: BTreeMap<String, String>,
    /// task name → node index (taken modulo `nodes`). Overrides the
    /// region-rank round-robin; exists so the determinism property test
    /// can drive *arbitrary* partitions.
    pub node_pins: BTreeMap<String, usize>,
}

impl Default for PlacementSpec {
    fn default() -> Self {
        Self { nodes: default_nodes(), regions: BTreeMap::new(), node_pins: BTreeMap::new() }
    }
}

impl PlacementSpec {
    /// Explicit node count, no pins, ignoring the `KOALJA_NODES` ambient.
    pub fn on_nodes(nodes: usize) -> Self {
        Self { nodes: nodes.max(1), regions: BTreeMap::new(), node_pins: BTreeMap::new() }
    }

    pub fn pin(mut self, task: &str, region: &str) -> Self {
        self.regions.insert(task.to_string(), region.to_string());
        self
    }

    pub fn pin_node(mut self, task: &str, node: usize) -> Self {
        self.node_pins.insert(task.to_string(), node);
        self
    }
}

/// The compiled node partition: which node runs which task. Built once at
/// deploy, immutable afterwards — like the wire table, it is dense by task
/// index so the hot path never hashes.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub nodes: usize,
    /// Node index per task (dense by task index).
    pub node_of: Vec<usize>,
    /// Tasks hosted per node, in task-index order.
    pub tasks_of: Vec<Vec<TaskId>>,
}

impl ShardPlan {
    /// Partition tasks over `spec.nodes` nodes. The default assignment
    /// keeps co-located work together: distinct task regions are ranked by
    /// first appearance in task-index order, and each task lands on
    /// `rank(region) % nodes` — so a 3-region pipeline on 3 nodes gets one
    /// node per region, and on 1 node everything collapses to node 0.
    /// `spec.node_pins` override per task. Fully deterministic in
    /// (graph, regions, spec).
    pub fn build(graph: &PipelineGraph, regions: &[RegionId], spec: &PlacementSpec) -> Self {
        let nodes = spec.nodes.max(1);
        let mut rank: BTreeMap<RegionId, usize> = BTreeMap::new();
        let mut node_of = Vec::with_capacity(regions.len());
        for (i, r) in regions.iter().enumerate() {
            let next = rank.len();
            let region_rank = *rank.entry(*r).or_insert(next);
            let node = match spec.node_pins.get(&graph.tasks[i].name) {
                Some(&pin) => pin % nodes,
                None => region_rank % nodes,
            };
            node_of.push(node);
        }
        let mut tasks_of = vec![Vec::new(); nodes];
        for (i, &n) in node_of.iter().enumerate() {
            tasks_of[n].push(TaskId::new(i as u64));
        }
        Self { nodes, node_of, tasks_of }
    }

    /// The node hosting `task`.
    pub fn node(&self, task: TaskId) -> usize {
        self.node_of.get(task.index()).copied().unwrap_or(0)
    }

    /// Does a `from → to` wire cross nodes (and therefore ride the
    /// exchange)?
    pub fn is_cross(&self, from: TaskId, to: TaskId) -> bool {
        self.node(from) != self.node(to)
    }

    /// How many nodes actually host at least one task.
    pub fn occupied_nodes(&self) -> usize {
        self.tasks_of.iter().filter(|t| !t.is_empty()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::parse;

    fn graph() -> PipelineGraph {
        PipelineGraph::build(&parse("[s]\n(raw) a (x)\n(x) b (y)\n(y) c (z)\n").unwrap())
    }

    #[test]
    fn single_node_collapses_everything() {
        let g = graph();
        let regions = vec![RegionId::new(2), RegionId::new(0), RegionId::new(1)];
        let plan = ShardPlan::build(&g, &regions, &PlacementSpec::on_nodes(1));
        assert_eq!(plan.node_of, vec![0, 0, 0]);
        assert_eq!(plan.occupied_nodes(), 1);
        assert!(!plan.is_cross(TaskId::new(0), TaskId::new(1)));
    }

    #[test]
    fn regions_round_robin_by_first_appearance() {
        let g = graph();
        // a@r2, b@r0, c@r2: r2 ranks 0, r0 ranks 1
        let regions = vec![RegionId::new(2), RegionId::new(0), RegionId::new(2)];
        let plan = ShardPlan::build(&g, &regions, &PlacementSpec::on_nodes(2));
        assert_eq!(plan.node_of, vec![0, 1, 0], "co-located tasks share a node");
        assert!(plan.is_cross(TaskId::new(0), TaskId::new(1)));
        assert!(!plan.is_cross(TaskId::new(0), TaskId::new(2)));
        assert_eq!(plan.tasks_of[0], vec![TaskId::new(0), TaskId::new(2)]);
    }

    #[test]
    fn node_pins_override_the_round_robin() {
        let g = graph();
        let regions = vec![RegionId::new(0); 3];
        let spec = PlacementSpec::on_nodes(2).pin_node("b", 1).pin_node("c", 7); // 7 % 2 == 1
        let plan = ShardPlan::build(&g, &regions, &spec);
        assert_eq!(plan.node_of, vec![0, 1, 1]);
    }

    #[test]
    fn default_nodes_is_at_least_one() {
        // KOALJA_NODES is unset (or numeric) in the test environment; the
        // clamp guarantees the invariant either way
        assert!(default_nodes() >= 1);
    }
}
