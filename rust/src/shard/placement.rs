//! Transfer-minimizing region placement — greedy + local search over the
//! observed wire byte profile (the TOSCAdata move: placement is a model
//! you optimize, not an ops afterthought).
//!
//! The optimizer assigns a region to every *unpinned* task so that the
//! bytes crossing region boundaries are minimized, with sovereignty folded
//! in as a hard penalty: a Raw wire crossing zones costs six orders of
//! magnitude more than any honest transfer, so feasible placements always
//! dominate. Pinned tasks (spec `@region` attrs, `place_at` pins) are
//! fixed points. The byte profile comes from a prior run's
//! `obs::WireStats` (E7: profile centrally, then push the summarizers to
//! the edge).
//!
//! Everything iterates in dense index / `BTreeMap` order and breaks ties
//! toward the lowest `RegionId`, so the result is a pure function of its
//! inputs — a placement computed on one machine is the placement.

use crate::av::DataClass;
use crate::graph::PipelineGraph;
use crate::net::{TransferVerdict, WanTopology};
use crate::util::{RegionId, TaskId, WireId};

use std::collections::BTreeMap;

/// Cost multiplier that makes sovereignty-denied edges dominate any
/// feasible byte count.
const DENIED_PENALTY: u64 = 1_000_000;
/// Local-search improvement passes (each pass sweeps every unpinned task;
/// the loop stops early at a fixpoint).
const MAX_PASSES: usize = 32;

/// Everything the optimizer knows about one pipeline.
#[derive(Clone, Debug, Default)]
pub struct PlacementInput {
    /// Fixed task → region assignments (`@region` attrs, explicit pins).
    pub pinned: BTreeMap<TaskId, RegionId>,
    /// Observed bytes per wire from a profiling run (`obs::WireStats`);
    /// unprofiled wires count as zero bytes but still pay the per-edge
    /// crossing cost, so the optimizer never *gains* by splitting them.
    pub wire_bytes: BTreeMap<WireId, u64>,
    /// Dominant data class per wire, for the sovereignty penalty; missing
    /// wires default to [`DataClass::Summary`] (freely movable).
    pub wire_class: BTreeMap<WireId, DataClass>,
    /// Where external injections on a wire physically originate — sensors
    /// are not movable, so consumers placed away from them pay.
    pub external_region: BTreeMap<WireId, RegionId>,
}

impl PlacementInput {
    fn class(&self, wire: WireId) -> DataClass {
        self.wire_class.get(&wire).copied().unwrap_or(DataClass::Summary)
    }

    fn bytes(&self, wire: WireId) -> u64 {
        self.wire_bytes.get(&wire).copied().unwrap_or(0)
    }
}

/// An optimized assignment of every task to a region.
#[derive(Clone, Debug)]
pub struct Placement {
    /// Region per task, dense by task index.
    pub region_of: Vec<RegionId>,
    /// Estimated bytes crossing region boundaries under this placement
    /// (the objective, before the rtt tie-break terms).
    pub cross_region_bytes: u64,
}

impl Placement {
    /// Greedy construction in topological order, then bounded local
    /// search: each pass offers every unpinned task every region and takes
    /// strict improvements of its incident-edge cost.
    pub fn optimize(graph: &PipelineGraph, net: &WanTopology, input: &PlacementInput) -> Self {
        let candidates: Vec<RegionId> = net.regions.iter().map(|r| r.id).collect();
        let fallback = default_region(net);
        let n = graph.n_tasks();
        let mut region_of: Vec<RegionId> = (0..n)
            .map(|i| input.pinned.get(&TaskId::new(i as u64)).copied().unwrap_or(fallback))
            .collect();
        if candidates.len() <= 1 {
            let cross = total_cross_bytes(graph, &region_of, input);
            return Self { region_of, cross_region_bytes: cross };
        }
        // greedy: topo order means producers are (usually) settled before
        // their consumers weigh in
        for t in graph.topo_order() {
            if input.pinned.contains_key(&t) {
                continue;
            }
            region_of[t.index()] = best_region(graph, net, input, &region_of, t, &candidates);
        }
        // local search to a fixpoint (or MAX_PASSES)
        for _ in 0..MAX_PASSES {
            let mut moved = false;
            for ti in 0..n {
                let t = TaskId::new(ti as u64);
                if input.pinned.contains_key(&t) {
                    continue;
                }
                let best = best_region(graph, net, input, &region_of, t, &candidates);
                if best != region_of[ti]
                    && incident_cost(graph, net, input, &region_of, t, best)
                        < incident_cost(graph, net, input, &region_of, t, region_of[ti])
                {
                    region_of[ti] = best;
                    moved = true;
                }
            }
            if !moved {
                break;
            }
        }
        let cross = total_cross_bytes(graph, &region_of, input);
        Self { region_of, cross_region_bytes: cross }
    }

    /// Render as task-name → region-name pins for
    /// `PlacementSpec::regions` / `PipelineBuilder::place_at`.
    pub fn as_pins(&self, graph: &PipelineGraph, net: &WanTopology) -> BTreeMap<String, String> {
        self.region_of
            .iter()
            .enumerate()
            .map(|(i, r)| (graph.tasks[i].name.clone(), net.region(*r).name.clone()))
            .collect()
    }
}

/// The region deploy falls back to when nothing pins a task: the first
/// datacentre, else region 0 (must match the coordinator's default).
fn default_region(net: &WanTopology) -> RegionId {
    net.regions.iter().find(|r| !r.is_edge).map(|r| r.id).unwrap_or(RegionId::new(0))
}

/// Cost of moving `bytes` of `class` data from `a` to `b`: free in-region;
/// bytes-dominated with an rtt tie-break across regions; prohibitive when
/// sovereignty denies the move.
fn edge_cost(net: &WanTopology, class: DataClass, a: RegionId, b: RegionId, bytes: u64) -> u64 {
    if a == b {
        return 0;
    }
    match net.check(class, a, b) {
        TransferVerdict::Denied => bytes.max(1).saturating_mul(DENIED_PENALTY),
        _ => {
            let rtt_us = net.link(a, b).map(|l| l.rtt.as_micros()).unwrap_or(80_000);
            // bytes dominate; rtt/8 breaks ties among equal-byte options;
            // +1 keeps any crossing strictly worse than none
            bytes.saturating_mul(1024).saturating_add(rtt_us / 8).saturating_add(1)
        }
    }
}

/// Sum of [`edge_cost`] over every link incident to `t`, with `t` placed
/// at `r` and everyone else at their current assignment.
fn incident_cost(
    graph: &PipelineGraph,
    net: &WanTopology,
    input: &PlacementInput,
    region_of: &[RegionId],
    t: TaskId,
    r: RegionId,
) -> u64 {
    let mut cost = 0u64;
    for l in &graph.links {
        let bytes = input.bytes(l.wire_id);
        let class = input.class(l.wire_id);
        match l.from {
            None if l.to == t => {
                // external injection: the sensor end is immovable
                if let Some(&src) = input.external_region.get(&l.wire_id) {
                    cost = cost.saturating_add(edge_cost(net, class, src, r, bytes));
                }
            }
            Some(from) if from == t && l.to == t => {} // self-loop: free
            Some(from) if from == t => {
                cost =
                    cost.saturating_add(edge_cost(net, class, r, region_of[l.to.index()], bytes));
            }
            Some(from) if l.to == t => {
                cost =
                    cost.saturating_add(edge_cost(net, class, region_of[from.index()], r, bytes));
            }
            _ => {}
        }
    }
    cost
}

fn best_region(
    graph: &PipelineGraph,
    net: &WanTopology,
    input: &PlacementInput,
    region_of: &[RegionId],
    t: TaskId,
    candidates: &[RegionId],
) -> RegionId {
    let mut best = region_of[t.index()];
    let mut best_cost = incident_cost(graph, net, input, region_of, t, best);
    for &r in candidates {
        if r == best {
            continue;
        }
        let c = incident_cost(graph, net, input, region_of, t, r);
        // strict improvement only, candidates scanned in RegionId order:
        // ties keep the incumbent, and among new optima the lowest id wins
        if c < best_cost {
            best = r;
            best_cost = c;
        }
    }
    best
}

/// The headline objective: profiled bytes whose producer and consumer
/// regions differ (external injections included).
fn total_cross_bytes(graph: &PipelineGraph, region_of: &[RegionId], input: &PlacementInput) -> u64 {
    let mut total = 0u64;
    for l in &graph.links {
        let to_r = region_of[l.to.index()];
        let from_r = match l.from {
            Some(f) => region_of[f.index()],
            None => match input.external_region.get(&l.wire_id) {
                Some(&r) => r,
                None => continue,
            },
        };
        if from_r != to_r {
            total = total.saturating_add(input.bytes(l.wire_id));
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::demo_topology;
    use crate::spec::parse;

    /// sensors (pinned, edge) → summarize (free) → train (pinned, central)
    fn fleet() -> PipelineGraph {
        PipelineGraph::build(
            &parse("[fleet]\n(readings) summarize (digest)\n(digest) train (model)\n").unwrap(),
        )
    }

    #[test]
    fn summarizer_moves_to_the_heavy_edge() {
        let g = fleet();
        let net = demo_topology(2);
        let edge0 = net.by_name("edge-0").unwrap();
        let central = net.by_name("central").unwrap();
        let mut input = PlacementInput::default();
        input.pinned.insert(g.task_id("train").unwrap(), central);
        // raw readings are huge and born at the edge; digests are tiny
        input.wire_bytes.insert(g.wires.id("readings").unwrap(), 10_000_000);
        input.wire_bytes.insert(g.wires.id("digest").unwrap(), 10_000);
        input.external_region.insert(g.wires.id("readings").unwrap(), edge0);
        let p = Placement::optimize(&g, &net, &input);
        assert_eq!(p.region_of[g.task_id("summarize").unwrap().index()], edge0);
        assert_eq!(p.region_of[g.task_id("train").unwrap().index()], central);
        // only the tiny digest crosses regions now
        assert_eq!(p.cross_region_bytes, 10_000);
    }

    #[test]
    fn sovereignty_penalty_keeps_raw_in_zone() {
        let g = fleet();
        let net = demo_topology(2); // edge-0 is us-zone, edge-1/eu-dc are eu
        let edge1 = net.by_name("edge-1").unwrap(); // eu edge
        let eu_dc = net.by_name("eu-dc").unwrap();
        let mut input = PlacementInput::default();
        // readings are Raw, born at the EU edge, and heavy; train is free.
        // Without the penalty, central (the default-region fallback and a
        // us-zone datacentre) would tie-break by rtt — with it, every
        // us-zone candidate costs bytes * DENIED_PENALTY and loses.
        input.wire_bytes.insert(g.wires.id("readings").unwrap(), 5_000_000);
        input.wire_class.insert(g.wires.id("readings").unwrap(), DataClass::Raw);
        input.external_region.insert(g.wires.id("readings").unwrap(), edge1);
        let p = Placement::optimize(&g, &net, &input);
        let summ = p.region_of[g.task_id("summarize").unwrap().index()];
        let zone = &net.region(summ).zone;
        assert_eq!(zone, "eu", "raw consumer stays in the data's zone");
        assert!(summ == edge1 || summ == eu_dc);
    }

    #[test]
    fn no_profile_is_the_status_quo() {
        // with nothing profiled and nothing pinned, everything lands on
        // the default datacentre — exactly what deploy would do anyway
        let g = fleet();
        let net = demo_topology(2);
        let p = Placement::optimize(&g, &net, &PlacementInput::default());
        let central = net.by_name("central").unwrap();
        assert!(p.region_of.iter().all(|r| *r == central));
        assert_eq!(p.cross_region_bytes, 0);
    }

    #[test]
    fn as_pins_round_trips_names() {
        let g = fleet();
        let net = demo_topology(1);
        let p = Placement::optimize(&g, &net, &PlacementInput::default());
        let pins = p.as_pins(&g, &net);
        assert_eq!(pins.len(), 2);
        assert_eq!(pins.get("train").map(String::as_str), Some("central"));
    }
}
