//! Koalja: smart data plumbing for the extended cloud.
//!
//! Reproduction of Burgess & Prangsma, "Koalja: from Data Plumbing to Smart
//! Workspaces in the Extended Cloud" (CS.DC 2019), as a three-layer
//! Rust + JAX + Pallas stack. See DESIGN.md for the system inventory.
//!
//! Quick tour:
//! * [`api`] — **the documented entry point**: the [`api::Pipeline`]
//!   facade with typed source/sink/task handles, and the programmatic
//!   [`api::PipelineBuilder`] (see `examples/quickstart.rs`)
//! * [`spec`] — the fig. 5 wiring language (`(in[10/2]) task (out)`)
//! * [`coordinator`] — the pipeline manager: reactive + make triggering
//! * [`breadboard`] — the smart-workspace layer: live wire taps, hot code
//!   swaps with invalidation previews, forensic replay (§III-H/J, §IV)
//! * [`task`] / [`link`] — smart task & link agents
//! * [`fault`] — the supervised firing lifecycle: deterministic retries,
//!   quarantine breakers, dead-letter redrive, seeded fault injection
//! * [`ingest`] — the streaming front door: [`ingest::Feed`] handles,
//!   watermark-gated virtual time, credit backpressure, adaptive batching
//! * [`policy`] — snapshot policies (AllNew / SwapNewForOld / Merge / windows)
//! * [`provenance`] — the three metadata stories (traveller / checkpoint / map)
//! * [`obs`] — observability: the flight recorder + id-indexed metrics
//!   (`Coordinator::obs()`, `koalja trace`)
//! * [`runtime`] — PJRT loader for the AOT-compiled JAX+Pallas artifacts
//! * [`storage`], [`bus`], [`net`], [`cluster`], [`workspace`] — substrates
//! * [`baseline`] — cron-style and centralized comparators
//! * [`benchkit`] — the in-tree benchmark harness used by `cargo bench`

pub mod api;
pub mod av;
pub mod baseline;
pub mod benchkit;
pub mod breadboard;
pub mod bus;
pub mod cluster;
pub mod coordinator;
pub mod fault;
pub mod graph;
pub mod ingest;
pub mod link;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod platform;
pub mod policy;
pub mod provenance;
pub mod runtime;
pub mod shard;
pub mod spec;
pub mod storage;
pub mod task;
pub mod util;
pub mod workload;
pub mod workspace;

/// Convenient imports for examples and downstream users.
pub mod prelude {
    pub use crate::api::{FeedHandle, Pipeline, PipelineBuilder, SinkHandle, SourceHandle, TaskHandle};
    pub use crate::av::{DataClass, Payload};
    pub use crate::breadboard::{Breadboard, TapSpec};
    pub use crate::bus::{NotifyMode, TransferStat};
    pub use crate::coordinator::{
        default_trace, default_workers, Collected, Coordinator, DeployConfig, SinkCommit,
        SovereigntyError,
    };
    pub use crate::fault::{
        default_fault_plan, Backoff, DeadLetter, EventStorm, FaultKind, FaultPlan, FirePolicy,
        OnExhaust,
    };
    pub use crate::ingest::{
        Backpressure, Feed, IngestError, IngestReport, IngestStats, ReplaySource, Source,
        StalledFeed, TimedEvent,
    };
    pub use crate::net::{demo_topology, WanLink, WanTopology};
    pub use crate::obs::{FiringKind, Obs, SpanEvent, TaskStats, WireStats};
    pub use crate::platform::{PlacementStrategy, Service};
    pub use crate::policy::{BufferSpec, Snapshot, SnapshotPolicy};
    pub use crate::provenance::ProvenanceQuery;
    pub use crate::runtime::Runtime;
    pub use crate::shard::{
        default_nodes, Placement, PlacementInput, PlacementSpec, ShardPlan,
    };
    pub use crate::spec::parse;
    pub use crate::storage::{PurgePolicy, StorageConfig};
    pub use crate::task::builtins::*;
    pub use crate::task::{
        legacy, Emitter, InPort, Inputs, LegacyCode, OutPort, Output, PortIo, Ports, TaskCode,
        TaskCtx, UserCode,
    };
    pub use crate::util::{rng, RegionId, SimDuration, SimTime, WireId};
}
