//! Smart link agents — §III-J.
//!
//! "Smart links marshal the data as files for the task code. The logical
//! connection between the outputs from one task and the inputs of the next
//! are handled by these link agents." A link agent:
//!
//!  * enforces sovereignty before an AV may travel toward its consumer
//!    (delivery-time check; a denied AV never enters a snapshot),
//!  * publishes AV metadata on the link's bus topic (payloads stay in
//!    object storage — pub-sub moves pointers, §III-F),
//!  * keeps a bounded replay history so the feed can be "rolled back" when
//!    software/service updates force recomputation,
//!  * stamps every passport on the way through.

use crate::av::AnnotatedValue;
use crate::bus::NotifyMode;
use crate::graph::Link;
use crate::platform::Platform;
use crate::provenance::Stamp;
use crate::util::RegionId;
use std::collections::VecDeque;

/// Outcome of attempting a delivery.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Delivery {
    /// Published; consumer should be woken now (push notification).
    NotifyNow,
    /// Published; consumer polls on its own schedule.
    Queued,
    /// Sovereignty policy forbade the transfer (§IV).
    Denied,
}

/// One deployed link.
pub struct LinkAgent {
    pub link: Link,
    pub consumer_region: RegionId,
    pub notify: NotifyMode,
    /// Bounded replay history (newest last).
    history: VecDeque<AnnotatedValue>,
    pub history_cap: usize,
    pub delivered: u64,
    pub denied: u64,
}

impl LinkAgent {
    pub fn new(link: Link, consumer_region: RegionId, notify: NotifyMode) -> Self {
        Self {
            link,
            consumer_region,
            notify,
            history: VecDeque::new(),
            history_cap: 64,
            delivered: 0,
            denied: 0,
        }
    }

    /// Attempt to deliver an AV toward the consumer. The payload does not
    /// move here — the consumer's fetch pays the transfer on first touch
    /// (and its local cache absorbs repeats, Principle 2). What must be
    /// decided *now* is legality: raw data may not cross zones.
    ///
    /// Takes the AV by reference so the verdict is decided before any copy
    /// is made: a denied delivery pays zero clones (§Perf), and the
    /// publication's shared `Arc` in the event queue stays untouched — the
    /// link stamps its id only on its own bus/history copies.
    pub fn deliver(&mut self, plat: &mut Platform, av: &AnnotatedValue) -> Delivery {
        use crate::net::TransferVerdict;
        match plat.net.check(av.class, av.region, self.consumer_region) {
            TransferVerdict::Denied => {
                self.denied += 1;
                plat.metrics.bump("sovereignty_denied");
                plat.prov.stamp(
                    av.id,
                    plat.now,
                    Stamp::SovereigntyDenied { from: av.region, to: self.consumer_region },
                );
                Delivery::Denied
            }
            _ => {
                let mut av = av.clone();
                av.link = self.link.id;
                plat.prov.stamp(av.id, plat.now, Stamp::Published { link: self.link.id });
                plat.bus.publish(self.link.id, av.clone());
                self.history.push_back(av);
                while self.history.len() > self.history_cap {
                    self.history.pop_front();
                }
                self.delivered += 1;
                match self.notify {
                    NotifyMode::Push => {
                        plat.bus.record_notification();
                        plat.metrics.notifications_sent += 1;
                        Delivery::NotifyNow
                    }
                    NotifyMode::Poll(_) | NotifyMode::Manual => Delivery::Queued,
                }
            }
        }
    }

    /// Re-publish the last `n` AVs ("roll back the feed", §III-J) — used
    /// when a software or service update requires recomputation of results
    /// that already flowed past.
    pub fn replay_last(&mut self, plat: &mut Platform, n: usize) -> usize {
        let start = self.history.len().saturating_sub(n);
        let to_replay: Vec<AnnotatedValue> =
            self.history.iter().skip(start).cloned().collect();
        let count = to_replay.len();
        for av in to_replay {
            plat.metrics.bump("replays");
            plat.prov.stamp(av.id, plat.now, Stamp::Published { link: self.link.id });
            plat.bus.publish(self.link.id, av);
        }
        count
    }

    pub fn history_len(&self) -> usize {
        self.history.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::av::{DataClass, Payload};
    use crate::net::demo_topology;
    use crate::storage::StorageConfig;
    use crate::util::*;

    fn plat() -> Platform {
        Platform::new(demo_topology(2), StorageConfig::default(), 5)
    }

    fn agent(plat: &Platform, notify: NotifyMode, consumer_region: &str) -> LinkAgent {
        LinkAgent::new(
            Link {
                id: LinkId::new(0),
                wire: "x".into(),
                wire_id: WireId::new(0),
                from: Some(TaskId::new(0)),
                to: TaskId::new(1),
                to_input: "x".into(),
            },
            plat.net.by_name(consumer_region).unwrap(),
            notify,
        )
    }

    fn mint(plat: &mut Platform, class: DataClass, region: &str) -> AnnotatedValue {
        let r = plat.net.by_name(region).unwrap();
        let (av, _) = plat.mint_av(
            Payload::scalar(1.0),
            TaskId::new(0),
            RunId::new(0),
            1,
            LinkId::new(0),
            r,
            class,
            0,
            &[],
            plat.now,
        );
        av
    }

    #[test]
    fn push_delivery_notifies() {
        let mut p = plat();
        let mut l = agent(&p, NotifyMode::Push, "central");
        let av = mint(&mut p, DataClass::Summary, "central");
        assert_eq!(l.deliver(&mut p, &av), Delivery::NotifyNow);
        assert_eq!(p.bus.depth(LinkId::new(0)), 1);
        assert_eq!(p.metrics.notifications_sent, 1);
        assert_eq!(l.history_len(), 1);
    }

    #[test]
    fn poll_delivery_queues_silently() {
        let mut p = plat();
        let mut l = agent(&p, NotifyMode::Poll(SimDuration::millis(5)), "central");
        let av = mint(&mut p, DataClass::Summary, "central");
        assert_eq!(l.deliver(&mut p, &av), Delivery::Queued);
        assert_eq!(p.metrics.notifications_sent, 0);
    }

    #[test]
    fn sovereignty_denial_blocks_and_stamps() {
        let mut p = plat();
        // edge-0 is in "us"; eu-dc is in "eu" — raw cannot cross.
        let mut l = agent(&p, NotifyMode::Push, "eu-dc");
        let av = mint(&mut p, DataClass::Raw, "edge-0");
        let id = av.id;
        assert_eq!(l.deliver(&mut p, &av), Delivery::Denied);
        assert_eq!(p.bus.depth(LinkId::new(0)), 0, "nothing published");
        let pass = p.prov.passport(id).unwrap();
        assert!(pass
            .stamps
            .iter()
            .any(|s| matches!(s.stamp, Stamp::SovereigntyDenied { .. })));
        // ...but a summary may travel
        let av = mint(&mut p, DataClass::Summary, "edge-0");
        assert_eq!(l.deliver(&mut p, &av), Delivery::NotifyNow);
    }

    #[test]
    fn replay_republishes_history() {
        let mut p = plat();
        let mut l = agent(&p, NotifyMode::Push, "central");
        for _ in 0..3 {
            let av = mint(&mut p, DataClass::Summary, "central");
            l.deliver(&mut p, &av);
        }
        // consume the originals
        while p.bus.consume(LinkId::new(0)).is_some() {}
        assert_eq!(l.replay_last(&mut p, 2), 2);
        assert_eq!(p.bus.depth(LinkId::new(0)), 2);
        assert_eq!(p.metrics.get("replays"), 2);
    }

    #[test]
    fn history_is_bounded() {
        let mut p = plat();
        let mut l = agent(&p, NotifyMode::Push, "central");
        l.history_cap = 4;
        for _ in 0..10 {
            let av = mint(&mut p, DataClass::Summary, "central");
            l.deliver(&mut p, &av);
        }
        assert_eq!(l.history_len(), 4);
    }
}
