//! Local caching close to dependents — Principle 2 (§III-F) and §III-J.
//!
//! "Data that are chosen to be passed down the line to the next dependent
//! task, will be cached local to the dependent task, for a policy
//! determined length of time, if the intermediate result is combined with
//! others." And: "a suitable default behaviour could be to cache
//! everything, but to purge the caches at different rates depending on the
//! risk of recomputation."
//!
//! The cache holds *copies* of object payload bytes near a consumer, so a
//! hit avoids both the storage read and any WAN transfer. Purge policy is
//! per-cache, including the paper's risk-weighted variant that keeps
//! combined intermediates longer than pass-through ones.

use crate::util::hash::FastMap;
use crate::util::{ObjectId, SimDuration, SimTime};


/// When entries are evicted.
#[derive(Clone, Copy, Debug)]
pub enum PurgePolicy {
    /// Keep everything (the paper's suggested default for big-data reuse).
    Never,
    /// Time-to-live from last touch.
    Ttl(SimDuration),
    /// Byte-capacity LRU.
    LruBytes(u64),
    /// Risk-weighted TTL (Principle 2): intermediates that were *combined*
    /// with other inputs are costlier to recompute, so they live longer.
    RiskWeighted {
        combined_ttl: SimDuration,
        passthrough_ttl: SimDuration,
    },
}

#[derive(Clone, Debug)]
struct Entry {
    bytes: u64,
    last_used: SimTime,
    inserted: SimTime,
    /// Was this intermediate combined with other inputs downstream?
    combined: bool,
    /// LRU tiebreaker.
    touch_seq: u64,
}

/// One cache instance (the platform creates one per task agent location).
#[derive(Clone, Debug)]
pub struct CacheManager {
    policy: PurgePolicy,
    entries: FastMap<ObjectId, Entry>,
    pub bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    seq: u64,
}

impl CacheManager {
    pub fn new(policy: PurgePolicy) -> Self {
        Self {
            policy,
            entries: FastMap::default(),
            bytes: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            seq: 0,
        }
    }

    pub fn policy(&self) -> PurgePolicy {
        self.policy
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Record an object as cached here.
    pub fn insert(&mut self, id: ObjectId, bytes: u64, combined: bool, now: SimTime) {
        self.seq += 1;
        let prev = self.entries.insert(
            id,
            Entry { bytes, last_used: now, inserted: now, combined, touch_seq: self.seq },
        );
        self.bytes += bytes;
        if let Some(p) = prev {
            self.bytes -= p.bytes;
        }
        self.enforce_capacity();
    }

    /// Look up; a hit refreshes recency. Callers charge zero/local latency
    /// on hit, full storage+WAN latency on miss.
    pub fn lookup(&mut self, id: ObjectId, now: SimTime) -> bool {
        self.purge(now);
        self.seq += 1;
        match self.entries.get_mut(&id) {
            Some(e) => {
                e.last_used = now;
                e.touch_seq = self.seq;
                self.hits += 1;
                true
            }
            None => {
                self.misses += 1;
                false
            }
        }
    }

    pub fn contains(&self, id: ObjectId) -> bool {
        self.entries.contains_key(&id)
    }

    pub fn invalidate(&mut self, id: ObjectId) {
        if let Some(e) = self.entries.remove(&id) {
            self.bytes -= e.bytes;
            self.evictions += 1;
        }
    }

    /// Apply the purge policy at virtual time `now`.
    pub fn purge(&mut self, now: SimTime) {
        let expired: Vec<ObjectId> = match self.policy {
            PurgePolicy::Never | PurgePolicy::LruBytes(_) => vec![],
            PurgePolicy::Ttl(ttl) => self
                .entries
                .iter()
                .filter(|(_, e)| now.saturating_sub(e.last_used) > ttl)
                .map(|(id, _)| *id)
                .collect(),
            PurgePolicy::RiskWeighted { combined_ttl, passthrough_ttl } => self
                .entries
                .iter()
                .filter(|(_, e)| {
                    let ttl = if e.combined { combined_ttl } else { passthrough_ttl };
                    now.saturating_sub(e.last_used) > ttl
                })
                .map(|(id, _)| *id)
                .collect(),
        };
        for id in expired {
            self.invalidate(id);
        }
    }

    fn enforce_capacity(&mut self) {
        if let PurgePolicy::LruBytes(cap) = self.policy {
            while self.bytes > cap && !self.entries.is_empty() {
                // evict least-recently-used (oldest touch_seq)
                let victim = self
                    .entries
                    .iter()
                    .min_by_key(|(_, e)| e.touch_seq)
                    .map(|(id, _)| *id)
                    .unwrap();
                self.invalidate(victim);
            }
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Age of an entry (for tests and the provenance "cache kept" stamps).
    pub fn age(&self, id: ObjectId, now: SimTime) -> Option<SimDuration> {
        self.entries.get(&id).map(|e| now.saturating_sub(e.inserted))
    }

    /// Invalidation preview (breadboard swap dry-run): how many of `ids`
    /// are held here, and how many bytes they pin. Pure read.
    pub fn would_invalidate(&self, ids: &[ObjectId]) -> (usize, u64) {
        let mut count = 0;
        let mut bytes = 0;
        for id in ids {
            if let Some(e) = self.entries.get(id) {
                count += 1;
                bytes += e.bytes;
            }
        }
        (count, bytes)
    }

    /// Evict every listed entry that is present; returns (count, bytes) —
    /// the commit half of [`CacheManager::would_invalidate`].
    pub fn invalidate_many(&mut self, ids: &[ObjectId]) -> (usize, u64) {
        let mut count = 0;
        let mut bytes = 0;
        for id in ids {
            if let Some(e) = self.entries.get(id) {
                count += 1;
                bytes += e.bytes;
                self.invalidate(*id);
            }
        }
        (count, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn oid(n: u64) -> ObjectId {
        ObjectId::new(n)
    }

    #[test]
    fn hit_and_miss_accounting() {
        let mut c = CacheManager::new(PurgePolicy::Never);
        c.insert(oid(1), 100, false, SimTime::ZERO);
        assert!(c.lookup(oid(1), SimTime::millis(1)));
        assert!(!c.lookup(oid(2), SimTime::millis(1)));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn ttl_purges_idle_entries() {
        let mut c = CacheManager::new(PurgePolicy::Ttl(SimDuration::millis(10)));
        c.insert(oid(1), 10, false, SimTime::ZERO);
        assert!(c.lookup(oid(1), SimTime::millis(5))); // refreshed at 5ms
        assert!(c.lookup(oid(1), SimTime::millis(14))); // within ttl of touch
        assert!(!c.lookup(oid(1), SimTime::millis(30))); // expired
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn lru_capacity_evicts_oldest() {
        let mut c = CacheManager::new(PurgePolicy::LruBytes(250));
        c.insert(oid(1), 100, false, SimTime::micros(1));
        c.insert(oid(2), 100, false, SimTime::micros(2));
        assert!(c.lookup(oid(1), SimTime::micros(3))); // 1 is now most recent
        c.insert(oid(3), 100, false, SimTime::micros(4)); // over cap: evict 2
        assert!(c.contains(oid(1)));
        assert!(!c.contains(oid(2)));
        assert!(c.contains(oid(3)));
        assert!(c.bytes <= 250);
    }

    #[test]
    fn risk_weighted_keeps_combined_longer() {
        let mut c = CacheManager::new(PurgePolicy::RiskWeighted {
            combined_ttl: SimDuration::secs(10),
            passthrough_ttl: SimDuration::millis(1),
        });
        c.insert(oid(1), 10, true, SimTime::ZERO); // combined
        c.insert(oid(2), 10, false, SimTime::ZERO); // passthrough
        c.purge(SimTime::secs(1));
        assert!(c.contains(oid(1)));
        assert!(!c.contains(oid(2)));
    }

    #[test]
    fn reinsert_replaces_bytes() {
        let mut c = CacheManager::new(PurgePolicy::Never);
        c.insert(oid(1), 100, false, SimTime::ZERO);
        c.insert(oid(1), 40, false, SimTime::millis(1));
        assert_eq!(c.bytes, 40);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn invalidation_preview_matches_commit() {
        let mut c = CacheManager::new(PurgePolicy::Never);
        c.insert(oid(1), 100, false, SimTime::ZERO);
        c.insert(oid(2), 50, false, SimTime::ZERO);
        c.insert(oid(3), 25, false, SimTime::ZERO);
        let targets = [oid(1), oid(3), oid(99)];
        let (n, b) = c.would_invalidate(&targets);
        assert_eq!((n, b), (2, 125));
        assert_eq!(c.len(), 3, "preview is pure");
        let (n2, b2) = c.invalidate_many(&targets);
        assert_eq!((n2, b2), (n, b), "commit matches preview");
        assert_eq!(c.len(), 1);
        assert!(c.contains(oid(2)));
    }

    #[test]
    fn never_policy_keeps_everything() {
        let mut c = CacheManager::new(PurgePolicy::Never);
        for i in 0..100 {
            c.insert(oid(i), 1 << 20, false, SimTime::ZERO);
        }
        c.purge(SimTime::secs(3600));
        assert_eq!(c.len(), 100);
    }
}
