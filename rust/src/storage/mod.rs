//! Object storage, near and far — §III-G.
//!
//! Intermediate data live in an object store ("S3, MinIO, etc") under the
//! pipeline manager's control; AVs carry URIs, not bytes. Two in-region
//! tiers are modelled — host-local media and the network-attached object
//! store — each with a (base + per-KiB) latency model, so eq. 1's
//!
//! ```text
//! ρ = avg latency of internal storage / avg latency of network storage
//! ```
//!
//! is a directly sweepable parameter (experiment E2). Cross-region reads
//! are charged by the WAN topology at the link-agent layer, not here.

pub mod cache;

pub use cache::{CacheManager, PurgePolicy};

use crate::av::{DataClass, Payload};
use crate::util::hash::FastMap;
use crate::util::{ContentHash, IdGen, ObjectId, RegionId, SimDuration, SimTime};

use std::collections::HashMap;

/// Where, within a region, an object physically lives.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum StorageTier {
    /// Host-local media ("interior processor bus").
    HostLocal,
    /// In-region network object storage (S3/MinIO-like).
    ObjectStore,
}

/// Affine latency model for one tier: `base + per_kib * ceil(size/1KiB)`.
#[derive(Clone, Copy, Debug)]
pub struct TierLatency {
    pub base: SimDuration,
    pub per_kib: SimDuration,
}

impl TierLatency {
    pub fn charge(&self, bytes: u64) -> SimDuration {
        let kib = bytes.div_ceil(1024);
        SimDuration::micros(self.base.as_micros() + self.per_kib.as_micros() * kib)
    }
}

/// Storage latency configuration. Defaults model a 2019-era cloud node:
/// local NVMe ~100us base, object store ~2ms base but wider pipes.
#[derive(Clone, Copy, Debug)]
pub struct StorageConfig {
    pub host_local: TierLatency,
    pub object_store: TierLatency,
}

impl Default for StorageConfig {
    fn default() -> Self {
        Self {
            host_local: TierLatency {
                base: SimDuration::micros(100),
                per_kib: SimDuration::micros(8),
            },
            object_store: TierLatency {
                base: SimDuration::micros(2_000),
                per_kib: SimDuration::micros(4),
            },
        }
    }
}

impl StorageConfig {
    /// Build a config with a given ρ (eq. 1) at a reference object size,
    /// holding the network tier fixed and scaling the local tier.
    pub fn with_rho(rho: f64, ref_bytes: u64) -> Self {
        let base = Self::default();
        let net_us = base.object_store.charge(ref_bytes).as_micros() as f64;
        let local_us = (net_us * rho).max(1.0).round() as u64;
        // Split ~half into the per-KiB term, and put the exact remainder in
        // the base so `charge(ref_bytes)` hits local_us precisely.
        let kib = ref_bytes.div_ceil(1024).max(1);
        let per_kib = (local_us / 2) / kib;
        let base_us = local_us - per_kib * kib;
        Self {
            host_local: TierLatency {
                base: SimDuration::micros(base_us),
                per_kib: SimDuration::micros(per_kib),
            },
            object_store: base.object_store,
        }
    }

    pub fn latency(&self, tier: StorageTier, bytes: u64) -> SimDuration {
        match tier {
            StorageTier::HostLocal => self.host_local.charge(bytes),
            StorageTier::ObjectStore => self.object_store.charge(bytes),
        }
    }

    /// Measured ρ at a reference size — what eq. 1 calls the critical ratio.
    pub fn rho(&self, ref_bytes: u64) -> f64 {
        self.host_local.charge(ref_bytes).as_micros() as f64
            / self.object_store.charge(ref_bytes).as_micros() as f64
    }
}

/// One stored payload and its bookkeeping.
#[derive(Clone, Debug)]
pub struct StoredObject {
    pub payload: Payload,
    pub region: RegionId,
    pub tier: StorageTier,
    pub class: DataClass,
    pub created: SimTime,
    pub content: ContentHash,
    pub reads: u64,
}

/// The (simulated) object store: one logical namespace, objects pinned to a
/// (region, tier). Put/get return the virtual latency the caller must charge.
#[derive(Debug, Default)]
pub struct ObjectStore {
    objects: FastMap<ObjectId, StoredObject>,
    ids: IdGen,
    pub cfg_by_region: HashMap<RegionId, StorageConfig>,
    default_cfg: StorageConfig,
    pub total_bytes: u64,
    pub puts: u64,
    pub gets: u64,
}

impl ObjectStore {
    pub fn new(default_cfg: StorageConfig) -> Self {
        Self { default_cfg, ..Default::default() }
    }

    pub fn set_region_config(&mut self, region: RegionId, cfg: StorageConfig) {
        self.cfg_by_region.insert(region, cfg);
    }

    fn cfg(&self, region: RegionId) -> &StorageConfig {
        self.cfg_by_region.get(&region).unwrap_or(&self.default_cfg)
    }

    /// Store a payload; returns (id, charged latency). Ghost payloads are
    /// registered (so URIs resolve) but charge no bytes and base latency
    /// only — wireframe runs exercise routing, not plumbing capacity.
    pub fn put(
        &mut self,
        payload: Payload,
        region: RegionId,
        tier: StorageTier,
        class: DataClass,
        now: SimTime,
    ) -> (ObjectId, SimDuration) {
        let content = payload.content_hash();
        self.put_prehashed(payload, content, region, tier, class, now)
    }

    /// [`put`](Self::put) with the content hash already computed (the
    /// wavefront workers hash emissions off the commit path, §Perf).
    /// `content` must be `payload.content_hash()`.
    pub fn put_prehashed(
        &mut self,
        payload: Payload,
        content: ContentHash,
        region: RegionId,
        tier: StorageTier,
        class: DataClass,
        now: SimTime,
    ) -> (ObjectId, SimDuration) {
        let id = ObjectId::new(self.ids.next_raw());
        let bytes = payload.transfer_bytes(); // ghosts: 0 — no storage accounting
        let lat = self.cfg(region).latency(tier, bytes);
        self.total_bytes += bytes;
        self.objects.insert(
            id,
            StoredObject { payload, region, tier, class, created: now, content, reads: 0 },
        );
        self.puts += 1;
        (id, lat)
    }

    /// Read an object from within its own region. Cross-region access is a
    /// WAN transfer and must be planned by the link agent (see `net`).
    pub fn get(&mut self, id: ObjectId) -> Option<(&StoredObject, SimDuration)> {
        self.gets += 1;
        // borrow dance: compute latency before handing out the reference
        let (region, tier, bytes) = {
            let o = self.objects.get(&id)?;
            (o.region, o.tier, o.payload.transfer_bytes())
        };
        let lat = self.cfg(region).latency(tier, bytes);
        let o = self.objects.get_mut(&id)?;
        o.reads += 1;
        Some((&*o, lat))
    }

    /// Plan a read without performing it: the object plus the latency a
    /// [`get`](Self::get) would charge, moving no counters. The wavefront
    /// workers' read path — accounting is applied at commit through
    /// [`record_get`](Self::record_get) so `workers = N` moves the same
    /// counters in the same order as `workers = 1`.
    pub fn plan_get(&self, id: ObjectId) -> Option<(&StoredObject, SimDuration)> {
        let o = self.objects.get(&id)?;
        let lat = self.cfg(o.region).latency(o.tier, o.payload.transfer_bytes());
        Some((o, lat))
    }

    /// Commit-side accounting for a read planned with
    /// [`plan_get`](Self::plan_get). Mirrors [`get`](Self::get): the
    /// `gets` counter always moves (even for a missing object), the
    /// per-object read count only when the object exists.
    pub fn record_get(&mut self, id: ObjectId) {
        self.gets += 1;
        if let Some(o) = self.objects.get_mut(&id) {
            o.reads += 1;
        }
    }

    /// Metadata-only peek (no latency charged, no read recorded).
    pub fn peek(&self, id: ObjectId) -> Option<&StoredObject> {
        self.objects.get(&id)
    }

    pub fn contains(&self, id: ObjectId) -> bool {
        self.objects.contains_key(&id)
    }

    pub fn delete(&mut self, id: ObjectId) -> bool {
        self.objects.remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.objects.len()
    }

    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ObjectStore {
        ObjectStore::new(StorageConfig::default())
    }

    #[test]
    fn put_get_roundtrip() {
        let mut s = store();
        let p = Payload::tensor(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        let (id, put_lat) = s.put(
            p.clone(),
            RegionId::new(0),
            StorageTier::HostLocal,
            DataClass::Raw,
            SimTime::ZERO,
        );
        assert!(put_lat.as_micros() > 0);
        let (obj, get_lat) = s.get(id).unwrap();
        assert_eq!(obj.payload, p);
        assert_eq!(obj.reads, 1);
        assert!(get_lat.as_micros() > 0);
    }

    #[test]
    fn latency_scales_with_size_and_tier() {
        let cfg = StorageConfig::default();
        let small = cfg.latency(StorageTier::HostLocal, 1024);
        let big = cfg.latency(StorageTier::HostLocal, 1024 * 1024);
        assert!(big > small);
        // object store has higher base latency ...
        assert!(
            cfg.latency(StorageTier::ObjectStore, 1024) > cfg.latency(StorageTier::HostLocal, 1024)
        );
        // ... but lower marginal cost: crossover at large sizes.
        assert!(
            cfg.latency(StorageTier::ObjectStore, 8 << 20)
                < cfg.latency(StorageTier::HostLocal, 8 << 20)
        );
    }

    #[test]
    fn with_rho_hits_requested_ratio() {
        for rho in [0.1, 0.5, 1.0, 2.0, 8.0] {
            let cfg = StorageConfig::with_rho(rho, 64 * 1024);
            let got = cfg.rho(64 * 1024);
            assert!((got - rho).abs() / rho < 0.05, "rho {rho} got {got}");
        }
    }

    #[test]
    fn ghost_payloads_charge_base_latency_only() {
        let mut s = store();
        let (_, lat_ghost) = s.put(
            Payload::Ghost { pretend_bytes: 100 << 20 },
            RegionId::new(0),
            StorageTier::ObjectStore,
            DataClass::Ghost,
            SimTime::ZERO,
        );
        let (_, lat_real) = s.put(
            Payload::Bytes(vec![0u8; 1 << 20]),
            RegionId::new(0),
            StorageTier::ObjectStore,
            DataClass::Raw,
            SimTime::ZERO,
        );
        assert!(lat_ghost < lat_real);
    }

    #[test]
    fn missing_object_is_none() {
        let mut s = store();
        assert!(s.get(ObjectId::new(42)).is_none());
        assert!(!s.delete(ObjectId::new(42)));
    }

    #[test]
    fn per_region_config_override() {
        let mut s = store();
        let slow = StorageConfig {
            host_local: TierLatency {
                base: SimDuration::millis(50),
                per_kib: SimDuration::micros(1),
            },
            object_store: StorageConfig::default().object_store,
        };
        s.set_region_config(RegionId::new(7), slow);
        let (id, lat) = s.put(
            Payload::Bytes(vec![0; 10]),
            RegionId::new(7),
            StorageTier::HostLocal,
            DataClass::Raw,
            SimTime::ZERO,
        );
        assert!(lat >= SimDuration::millis(50));
        let (_, lat2) = s.get(id).unwrap();
        assert!(lat2 >= SimDuration::millis(50));
    }
}
