//! Built-in task plugins — the "major organs" a user grafts into the
//! skeleton (§III-A) without writing containers: pass-through replication,
//! pure-rust summarization (CPU fallback for the Pallas kernel), scaling,
//! thresholds, and closure wrappers for ad-hoc logic.
//!
//! All builtins run on the [`TaskCode`] port API: they are constructed
//! with a wire *name* (ergonomic at the call site), resolve it to an
//! [`OutPort`] exactly once in `bind` — where typos fail with the task's
//! declared output ports listed — and emit id-resolved values forever
//! after. [`FnTask`] is the legacy closure shape (`Vec<Output>` returns),
//! kept for un-migrated scripts; [`PortFn`] is its port-native successor.

use super::{OutPort, Output, PortIo, Ports, TaskCode, TaskCtx};
use crate::av::Payload;
use crate::policy::Snapshot;
use crate::util::SimDuration;
use anyhow::{anyhow, Result};

/// Replicate every input AV to one output wire (the paper's "trivial"
/// data replication/distribution case), preserving each value's class.
pub struct PassThrough {
    out: std::sync::Arc<str>,
    port: Option<OutPort>,
    pub version: u32,
}

impl PassThrough {
    pub fn new(out: &str) -> Self {
        Self { out: std::sync::Arc::from(out), port: None, version: 1 }
    }

    pub fn versioned(out: &str, version: u32) -> Self {
        Self { out: std::sync::Arc::from(out), port: None, version }
    }
}

impl TaskCode for PassThrough {
    fn version(&self) -> u32 {
        self.version
    }

    fn bind(&mut self, ports: &Ports<'_>) -> Result<()> {
        // `out_or_wire`: the coordinator's default code publishes on the
        // interned "void" fallback when a task declares no outputs, and
        // probe code may deliberately target another task's wire.
        self.port = Some(ports.out_or_wire(&self.out)?);
        Ok(())
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>) -> Result<()> {
        let port = self.port.expect("bound at install");
        for av in io.inputs.snapshot().all_avs() {
            let p = ctx.fetch(av)?;
            io.emitter.emit_class(port, p, av.class);
        }
        Ok(())
    }

    fn compute_cost(&self, bytes: u64) -> SimDuration {
        SimDuration::micros(20 + bytes / 4096)
    }
}

/// Pure-rust (N, D) → (4, D) moment sketch — same contract as the Pallas
/// `edge_summarize` artifact; used where no Runtime is wired (and as the
/// oracle in integration tests).
pub struct SummarizeRs {
    out: std::sync::Arc<str>,
    port: Option<OutPort>,
}

impl SummarizeRs {
    pub fn new(out: &str) -> Self {
        Self { out: std::sync::Arc::from(out), port: None }
    }

    /// The sketch function itself (shared with tests/benches).
    pub fn sketch(shape: &[usize], data: &[f32]) -> Result<Payload> {
        if shape.len() != 2 {
            return Err(anyhow!("summarize expects (N, D), got {shape:?}"));
        }
        let (n, d) = (shape[0], shape[1]);
        let mut out = vec![0.0f32; 4 * d];
        let (sum, sumsq, mn, mx) = (0, d, 2 * d, 3 * d);
        out[mn..mn + d].fill(f32::INFINITY);
        out[mx..mx + d].fill(f32::NEG_INFINITY);
        for row in 0..n {
            for col in 0..d {
                let x = data[row * d + col];
                out[sum + col] += x;
                out[sumsq + col] += x * x;
                out[mn + col] = out[mn + col].min(x);
                out[mx + col] = out[mx + col].max(x);
            }
        }
        Ok(Payload::tensor(&[4, d], out))
    }
}

impl TaskCode for SummarizeRs {
    fn bind(&mut self, ports: &Ports<'_>) -> Result<()> {
        self.port = Some(ports.out(&self.out)?);
        Ok(())
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>) -> Result<()> {
        let port = self.port.expect("bound at install");
        for av in io.inputs.snapshot().all_avs() {
            let p = ctx.fetch(av)?;
            let (shape, data) =
                p.as_tensor().ok_or_else(|| anyhow!("summarize: non-tensor input"))?;
            io.emitter.emit(port, Self::sketch(shape, data)?);
        }
        Ok(())
    }

    fn compute_cost(&self, bytes: u64) -> SimDuration {
        // streaming reduction: ~1 cycle/elem at 1 GHz → 1us per 4KB
        SimDuration::micros(50 + bytes / 4096)
    }
}

/// Scale every tensor element by a constant (the "matrix operations" user
/// case in miniature), preserving each value's class.
pub struct ScaleBy {
    out: std::sync::Arc<str>,
    port: Option<OutPort>,
    pub factor: f32,
}

impl ScaleBy {
    pub fn new(out: &str, factor: f32) -> Self {
        Self { out: std::sync::Arc::from(out), port: None, factor }
    }
}

impl TaskCode for ScaleBy {
    fn bind(&mut self, ports: &Ports<'_>) -> Result<()> {
        self.port = Some(ports.out(&self.out)?);
        Ok(())
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>) -> Result<()> {
        let port = self.port.expect("bound at install");
        for av in io.inputs.snapshot().all_avs() {
            let p = ctx.fetch(av)?;
            let (shape, data) = p.as_tensor().ok_or_else(|| anyhow!("scale: non-tensor"))?;
            let scaled: Vec<f32> = data.iter().map(|x| x * self.factor).collect();
            io.emitter.emit_class(port, Payload::tensor(shape, scaled), av.class);
        }
        Ok(())
    }
}

/// Emit only when a scalar statistic crosses a threshold (edge screening:
/// "most of which are junk and thus have no business travelling").
pub struct ThresholdGate {
    out: std::sync::Arc<str>,
    port: Option<OutPort>,
    pub threshold: f32,
    pub passed: u64,
    pub dropped: u64,
}

impl ThresholdGate {
    pub fn new(out: &str, threshold: f32) -> Self {
        Self { out: std::sync::Arc::from(out), port: None, threshold, passed: 0, dropped: 0 }
    }
}

impl TaskCode for ThresholdGate {
    fn bind(&mut self, ports: &Ports<'_>) -> Result<()> {
        self.port = Some(ports.out(&self.out)?);
        Ok(())
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>) -> Result<()> {
        let port = self.port.expect("bound at install");
        for av in io.inputs.snapshot().all_avs() {
            let p = ctx.fetch(av)?;
            let (_, data) = p.as_tensor().ok_or_else(|| anyhow!("gate: non-tensor"))?;
            let peak = data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            if peak > self.threshold {
                self.passed += 1;
                io.emitter.emit(port, p);
            } else {
                self.dropped += 1;
                ctx.remark(&format!("screened out chunk (peak {peak:.2} <= {})", self.threshold));
            }
        }
        Ok(())
    }
}

/// Wrap a legacy `Vec<Output>` closure as user code — the un-migrated
/// breadboarding shape. Runs through the name-resolution adapter path
/// (each distinct returned wire name resolved once per agent); new code
/// should prefer [`PortFn`]. Closures must be `Send` (wavefront workers
/// may execute them); mark closures that need the live platform —
/// `ctx.lookup`, `ctx.platform` — with [`FnTask::sequential`] so they
/// skip the parallel attempt and run in the deterministic commit phase.
pub struct FnTask<F> {
    pub f: F,
    pub version: u32,
    parallel_safe: bool,
}

impl<F> FnTask<F>
where
    F: FnMut(&mut TaskCtx<'_>, &Snapshot) -> Result<Vec<Output>> + Send,
{
    pub fn new(f: F) -> Self {
        Self { f, version: 1, parallel_safe: true }
    }

    pub fn versioned(f: F, version: u32) -> Self {
        Self { f, version, parallel_safe: true }
    }

    /// Declare this closure sequential-only (service lookups, platform
    /// access, or restart-sensitive captured state).
    pub fn sequential(mut self) -> Self {
        self.parallel_safe = false;
        self
    }
}

impl<F> TaskCode for FnTask<F>
where
    F: FnMut(&mut TaskCtx<'_>, &Snapshot) -> Result<Vec<Output>> + Send,
{
    fn version(&self) -> u32 {
        self.version
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>) -> Result<()> {
        let outs = (self.f)(ctx, io.inputs.snapshot())?;
        io.emitter.emit_outputs(outs)
    }

    fn parallel_safe(&self) -> bool {
        self.parallel_safe
    }
}

/// Wrap a port-native closure as task code — the breadboarding path for
/// examples/tests on the [`TaskCode`] API: read through `io.inputs`,
/// write through `io.emitter`, resolve ports by index (`io.out(0)`).
/// Closures must be `Send`; see [`PortFn::sequential`] for code that
/// needs the live platform.
pub struct PortFn<F> {
    pub f: F,
    pub version: u32,
    parallel_safe: bool,
}

impl<F> PortFn<F>
where
    F: FnMut(&mut TaskCtx<'_>, &mut PortIo<'_>) -> Result<()> + Send,
{
    pub fn new(f: F) -> Self {
        Self { f, version: 1, parallel_safe: true }
    }

    pub fn versioned(f: F, version: u32) -> Self {
        Self { f, version, parallel_safe: true }
    }

    /// Declare this closure sequential-only (service lookups, platform
    /// access, or restart-sensitive captured state).
    pub fn sequential(mut self) -> Self {
        self.parallel_safe = false;
        self
    }
}

impl<F> TaskCode for PortFn<F>
where
    F: FnMut(&mut TaskCtx<'_>, &mut PortIo<'_>) -> Result<()> + Send,
{
    fn version(&self) -> u32 {
        self.version
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>) -> Result<()> {
        (self.f)(ctx, io)
    }

    fn parallel_safe(&self) -> bool {
        self.parallel_safe
    }
}

/// Merge sketches from multiple regions: sum of (4, D) moment sketches is
/// the sketch of the union — the aggregation step of fig. 11's telco case.
pub struct SketchMerge {
    out: std::sync::Arc<str>,
    port: Option<OutPort>,
}

impl SketchMerge {
    pub fn new(out: &str) -> Self {
        Self { out: std::sync::Arc::from(out), port: None }
    }
}

impl TaskCode for SketchMerge {
    fn bind(&mut self, ports: &Ports<'_>) -> Result<()> {
        self.port = Some(ports.out(&self.out)?);
        Ok(())
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>) -> Result<()> {
        let port = self.port.expect("bound at install");
        let mut acc: Option<(Vec<usize>, Vec<f32>)> = None;
        for av in io.inputs.snapshot().all_avs() {
            let p = ctx.fetch(av)?;
            let (shape, data) = p.as_tensor().ok_or_else(|| anyhow!("merge: non-tensor"))?;
            if shape.len() != 2 || shape[0] != 4 {
                return Err(anyhow!("merge expects (4, D) sketches, got {shape:?}"));
            }
            match &mut acc {
                None => acc = Some((shape.to_vec(), data.to_vec())),
                Some((s, a)) => {
                    if s != shape {
                        return Err(anyhow!("sketch shape mismatch"));
                    }
                    let d = shape[1];
                    for c in 0..d {
                        a[c] += data[c]; // sum
                        a[d + c] += data[d + c]; // sumsq
                        a[2 * d + c] = a[2 * d + c].min(data[2 * d + c]); // min
                        a[3 * d + c] = a[3 * d + c].max(data[3 * d + c]); // max
                    }
                }
            }
        }
        let (shape, data) = acc.ok_or_else(|| anyhow!("merge: empty snapshot"))?;
        io.emitter.emit(port, Payload::tensor(&shape, data));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sketch_matches_manual_moments() {
        let data = vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0]; // (3, 2)
        let p = SummarizeRs::sketch(&[3, 2], &data).unwrap();
        let (shape, out) = p.as_tensor().unwrap();
        assert_eq!(shape, &[4, 2]);
        assert_eq!(&out[0..2], &[6.0, 60.0]); // sums
        assert_eq!(&out[2..4], &[14.0, 1400.0]); // sumsq
        assert_eq!(&out[4..6], &[1.0, 10.0]); // min
        assert_eq!(&out[6..8], &[3.0, 30.0]); // max
    }

    #[test]
    fn sketch_rejects_non_2d() {
        assert!(SummarizeRs::sketch(&[6], &[0.0; 6]).is_err());
    }

    #[test]
    fn bind_rejects_typos_with_declared_ports() {
        let spec = crate::spec::parse("[b]\n(raw) screen (clean)\n").unwrap();
        let wires = crate::graph::PipelineGraph::build(&spec).wires;
        let map = super::super::PortMap::mint(&spec.tasks[0], &wires);
        let ports = Ports { map: &map, wires: &wires, task: "screen" };
        let mut gate = ThresholdGate::new("claen", 0.5);
        let e = gate.bind(&ports).unwrap_err().to_string();
        assert!(e.contains("did you mean 'clean'?"), "{e}");
        let mut ok = ThresholdGate::new("clean", 0.5);
        ok.bind(&ports).unwrap();
    }
}
