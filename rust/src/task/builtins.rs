//! Built-in user-code plugins — the "major organs" a user grafts into the
//! skeleton (§III-A) without writing containers: pass-through replication,
//! pure-rust summarization (CPU fallback for the Pallas kernel), scaling,
//! thresholds, and a closure wrapper for ad-hoc logic.

use super::{Output, TaskCtx, UserCode};
use crate::av::Payload;
use crate::policy::Snapshot;
use crate::util::SimDuration;
use anyhow::{anyhow, Result};

/// Replicate every input AV to one output wire (the paper's "trivial"
/// data replication/distribution case).
pub struct PassThrough {
    pub out: std::rc::Rc<str>,
    pub version: u32,
}

impl PassThrough {
    pub fn new(out: &str) -> Self {
        Self { out: std::rc::Rc::from(out), version: 1 }
    }
}

impl UserCode for PassThrough {
    fn version(&self) -> u32 {
        self.version
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, snapshot: &Snapshot) -> Result<Vec<Output>> {
        let mut outs = Vec::new();
        for av in snapshot.all_avs() {
            let p = ctx.fetch(av)?;
            outs.push(Output::new(self.out.clone(), p, av.class));
        }
        Ok(outs)
    }

    fn compute_cost(&self, bytes: u64) -> SimDuration {
        SimDuration::micros(20 + bytes / 4096)
    }
}

/// Pure-rust (N, D) → (4, D) moment sketch — same contract as the Pallas
/// `edge_summarize` artifact; used where no Runtime is wired (and as the
/// oracle in integration tests).
pub struct SummarizeRs {
    pub out: std::rc::Rc<str>,
}

impl SummarizeRs {
    pub fn new(out: &str) -> Self {
        Self { out: std::rc::Rc::from(out) }
    }

    /// The sketch function itself (shared with tests/benches).
    pub fn sketch(shape: &[usize], data: &[f32]) -> Result<Payload> {
        if shape.len() != 2 {
            return Err(anyhow!("summarize expects (N, D), got {shape:?}"));
        }
        let (n, d) = (shape[0], shape[1]);
        let mut out = vec![0.0f32; 4 * d];
        let (sum, sumsq, mn, mx) = (0, d, 2 * d, 3 * d);
        out[mn..mn + d].fill(f32::INFINITY);
        out[mx..mx + d].fill(f32::NEG_INFINITY);
        for row in 0..n {
            for col in 0..d {
                let x = data[row * d + col];
                out[sum + col] += x;
                out[sumsq + col] += x * x;
                out[mn + col] = out[mn + col].min(x);
                out[mx + col] = out[mx + col].max(x);
            }
        }
        Ok(Payload::tensor(&[4, d], out))
    }
}

impl UserCode for SummarizeRs {
    fn run(&mut self, ctx: &mut TaskCtx<'_>, snapshot: &Snapshot) -> Result<Vec<Output>> {
        let mut outs = Vec::new();
        for av in snapshot.all_avs() {
            let p = ctx.fetch(av)?;
            let (shape, data) =
                p.as_tensor().ok_or_else(|| anyhow!("summarize: non-tensor input"))?;
            outs.push(Output::new(self.out.clone(), Self::sketch(shape, data)?, crate::av::DataClass::Summary));
        }
        Ok(outs)
    }

    fn compute_cost(&self, bytes: u64) -> SimDuration {
        // streaming reduction: ~1 cycle/elem at 1 GHz → 1us per 4KB
        SimDuration::micros(50 + bytes / 4096)
    }
}

/// Scale every tensor element by a constant (the "matrix operations" user
/// case in miniature).
pub struct ScaleBy {
    pub out: std::rc::Rc<str>,
    pub factor: f32,
}

impl UserCode for ScaleBy {
    fn run(&mut self, ctx: &mut TaskCtx<'_>, snapshot: &Snapshot) -> Result<Vec<Output>> {
        let mut outs = Vec::new();
        for av in snapshot.all_avs() {
            let p = ctx.fetch(av)?;
            let (shape, data) = p.as_tensor().ok_or_else(|| anyhow!("scale: non-tensor"))?;
            let scaled: Vec<f32> = data.iter().map(|x| x * self.factor).collect();
            outs.push(Output::new(self.out.clone(), Payload::tensor(shape, scaled), av.class));
        }
        Ok(outs)
    }
}

/// Emit only when a scalar statistic crosses a threshold (edge screening:
/// "most of which are junk and thus have no business travelling").
pub struct ThresholdGate {
    pub out: std::rc::Rc<str>,
    pub threshold: f32,
    pub passed: u64,
    pub dropped: u64,
}

impl ThresholdGate {
    pub fn new(out: &str, threshold: f32) -> Self {
        Self { out: std::rc::Rc::from(out), threshold, passed: 0, dropped: 0 }
    }
}

impl UserCode for ThresholdGate {
    fn run(&mut self, ctx: &mut TaskCtx<'_>, snapshot: &Snapshot) -> Result<Vec<Output>> {
        let mut outs = Vec::new();
        for av in snapshot.all_avs() {
            let p = ctx.fetch(av)?;
            let (_, data) = p.as_tensor().ok_or_else(|| anyhow!("gate: non-tensor"))?;
            let peak = data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            if peak > self.threshold {
                self.passed += 1;
                outs.push(Output::new(self.out.clone(), p, crate::av::DataClass::Summary));
            } else {
                self.dropped += 1;
                ctx.remark(&format!("screened out chunk (peak {peak:.2} <= {})", self.threshold));
            }
        }
        Ok(outs)
    }
}

/// Wrap a closure as user code — the breadboarding path for examples/tests.
pub struct FnTask<F> {
    pub f: F,
    pub version: u32,
}

impl<F> FnTask<F>
where
    F: FnMut(&mut TaskCtx<'_>, &Snapshot) -> Result<Vec<Output>>,
{
    pub fn new(f: F) -> Self {
        Self { f, version: 1 }
    }

    pub fn versioned(f: F, version: u32) -> Self {
        Self { f, version }
    }
}

impl<F> UserCode for FnTask<F>
where
    F: FnMut(&mut TaskCtx<'_>, &Snapshot) -> Result<Vec<Output>>,
{
    fn version(&self) -> u32 {
        self.version
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, snapshot: &Snapshot) -> Result<Vec<Output>> {
        (self.f)(ctx, snapshot)
    }
}

/// Merge sketches from multiple regions: sum of (4, D) moment sketches is
/// the sketch of the union — the aggregation step of fig. 11's telco case.
pub struct SketchMerge {
    pub out: std::rc::Rc<str>,
}

impl UserCode for SketchMerge {
    fn run(&mut self, ctx: &mut TaskCtx<'_>, snapshot: &Snapshot) -> Result<Vec<Output>> {
        let mut acc: Option<(Vec<usize>, Vec<f32>)> = None;
        for av in snapshot.all_avs() {
            let p = ctx.fetch(av)?;
            let (shape, data) = p.as_tensor().ok_or_else(|| anyhow!("merge: non-tensor"))?;
            if shape.len() != 2 || shape[0] != 4 {
                return Err(anyhow!("merge expects (4, D) sketches, got {shape:?}"));
            }
            match &mut acc {
                None => acc = Some((shape.to_vec(), data.to_vec())),
                Some((s, a)) => {
                    if s != shape {
                        return Err(anyhow!("sketch shape mismatch"));
                    }
                    let d = shape[1];
                    for c in 0..d {
                        a[c] += data[c]; // sum
                        a[d + c] += data[d + c]; // sumsq
                        a[2 * d + c] = a[2 * d + c].min(data[2 * d + c]); // min
                        a[3 * d + c] = a[3 * d + c].max(data[3 * d + c]); // max
                    }
                }
            }
        }
        let (shape, data) = acc.ok_or_else(|| anyhow!("merge: empty snapshot"))?;
        Ok(vec![Output::new(self.out.clone(), Payload::tensor(&shape, data), crate::av::DataClass::Summary)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sketch_matches_manual_moments() {
        let data = vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0]; // (3, 2)
        let p = SummarizeRs::sketch(&[3, 2], &data).unwrap();
        let (shape, out) = p.as_tensor().unwrap();
        assert_eq!(shape, &[4, 2]);
        assert_eq!(&out[0..2], &[6.0, 60.0]); // sums
        assert_eq!(&out[2..4], &[14.0, 1400.0]); // sumsq
        assert_eq!(&out[4..6], &[1.0, 10.0]); // min
        assert_eq!(&out[6..8], &[3.0, 30.0]); // max
    }

    #[test]
    fn sketch_rejects_non_2d() {
        assert!(SummarizeRs::sketch(&[6], &[0.0; 6]).is_err());
    }
}
