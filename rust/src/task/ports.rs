//! Typed ports — the deploy-time-resolved half of the task runtime API.
//!
//! PR 3 gave *clients* handles: resolve a name once at the [`Pipeline`]
//! facade, route on dense ids forever. This module gives *task authors*
//! the same deal at the plugin-container boundary (§III-I). When a task is
//! deployed (or code is plugged into it), a [`PortMap`] is minted from its
//! spec against the pipeline's [`WireTable`]: one [`OutPort`] per declared
//! output (dense [`WireId`] + default [`DataClass`]) and one [`InPort`]
//! per distinct stream-input buffer. User code resolves ports once in
//! [`TaskCode::bind`](super::TaskCode::bind) — where unknown names fail
//! with did-you-mean candidates, exactly like client handle resolution —
//! and the steady-state `run` never touches a wire name again:
//!
//!  * [`Emitter`] — write outputs straight into the agent's reusable
//!    emission buffer: [`emit`](Emitter::emit) (port default class),
//!    [`emit_class`](Emitter::emit_class), [`emit_ghost`](Emitter::emit_ghost)
//!    (§III-K wireframes) and [`emit_after`](Emitter::emit_after)
//!    (deferred publication). Every emission carries a pre-resolved
//!    [`WireId`]; the coordinator routes it without a single string
//!    comparison, and no intermediate `Vec<Output>` is allocated — the
//!    buffer is recycled run after run (§Perf).
//!  * [`Inputs`] — a port-indexed view over the [`Snapshot`]: the AVs
//!    [`on`](Inputs::on) an [`InPort`], with lazy per-port
//!    [`fetch`](Inputs::fetch) / [`fetch_stacked`](Inputs::fetch_stacked)
//!    replacing ad-hoc `ctx.fetch(av)` scans.
//!
//! Legacy [`UserCode`](super::UserCode) plugins keep working through the
//! [`LegacyCode`](super::LegacyCode) adapter: their returned wire *names*
//! are resolved against the table once and memoized in a per-agent cache,
//! so even un-migrated code stops paying per-publication re-resolution.
//! Unknown names error with the task's declared output ports listed via
//! [`util::suggest`](crate::util::suggest) instead of silently vanishing
//! into an overflow map.

use crate::av::{AnnotatedValue, DataClass, Payload};
use crate::graph::WireTable;
use crate::policy::Snapshot;
use crate::spec::TaskSpec;
use crate::util::hash::FastMap;
use crate::util::{suggest, SimDuration, WireId};
use anyhow::{anyhow, Result};
use std::sync::Arc;

use super::TaskCtx;

/// A deploy-time-minted output port: the dense interned [`WireId`] user
/// code emits on, plus the class an [`Emitter::emit`] defaults to.
/// `Copy`, like the client-side handles it mirrors.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct OutPort {
    pub(crate) wire: WireId,
    pub(crate) class: DataClass,
}

impl OutPort {
    /// The interned wire this port publishes on.
    pub fn wire_id(self) -> WireId {
        self.wire
    }

    /// The class [`Emitter::emit`] stamps by default.
    pub fn default_class(self) -> DataClass {
        self.class
    }

    /// A copy of this port with a different default class — resolve once
    /// in `bind`, keep the Raw/Summary decision out of the run loop.
    pub fn with_class(self, class: DataClass) -> Self {
        Self { wire: self.wire, class }
    }
}

/// A deploy-time-minted input port: one distinct stream-input buffer of
/// the task, in declaration order (`slot` indexes the snapshot engine's
/// buffers and the [`PortMap`]'s name table).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct InPort {
    pub(crate) wire: WireId,
    pub(crate) slot: u32,
}

impl InPort {
    /// The interned wire this port consumes.
    pub fn wire_id(self) -> WireId {
        self.wire
    }

    /// Position among the task's distinct stream inputs (spec order).
    pub fn slot(self) -> usize {
        self.slot as usize
    }
}

/// The port table minted for one task from its spec at deploy time —
/// the task-side mirror of the client handle set. Owned by the
/// [`TaskAgent`](super::TaskAgent); immutable after mint.
#[derive(Clone, Debug, Default)]
pub struct PortMap {
    pub(crate) outs: Vec<OutPort>,
    /// Parallel to `outs`: the spec names, kept for bind-time resolution
    /// and did-you-mean error lists only.
    pub(crate) out_names: Vec<Arc<str>>,
    pub(crate) ins: Vec<InPort>,
    /// Parallel to `ins`, in snapshot-buffer order.
    pub(crate) in_names: Vec<Arc<str>>,
}

impl PortMap {
    /// Mint the port table for `spec` against the deploy-time interner.
    /// Output ports default to [`DataClass::Summary`] (override per call
    /// with [`Emitter::emit_class`] or per port with
    /// [`OutPort::with_class`]). Input ports dedup stream inputs by wire,
    /// matching the snapshot engine's buffer order exactly.
    pub fn mint(spec: &TaskSpec, wires: &WireTable) -> Self {
        let mut outs = Vec::with_capacity(spec.outputs.len());
        let mut out_names = Vec::with_capacity(spec.outputs.len());
        for w in &spec.outputs {
            let wire = wires.id(w).expect("task outputs are interned at build");
            outs.push(OutPort { wire, class: DataClass::Summary });
            out_names.push(Arc::from(w.as_str()));
        }
        let mut ins = Vec::new();
        let mut in_names: Vec<Arc<str>> = Vec::new();
        for name in spec.input_ports() {
            let wire = wires.id(name).expect("stream inputs are interned at build");
            ins.push(InPort { wire, slot: ins.len() as u32 });
            in_names.push(Arc::from(name));
        }
        Self { outs, out_names, ins, in_names }
    }

    pub fn outs(&self) -> &[OutPort] {
        &self.outs
    }

    pub fn ins(&self) -> &[InPort] {
        &self.ins
    }
}

/// The bind-time resolution view handed to [`TaskCode::bind`]: the task's
/// own [`PortMap`] plus the pipeline's wire table for phantom targets.
/// This is the one place task-side names are looked up — the port-API
/// analogue of [`Pipeline::source`]/[`sink`]/[`task`].
pub struct Ports<'a> {
    pub(crate) map: &'a PortMap,
    pub(crate) wires: &'a WireTable,
    pub(crate) task: &'a str,
}

impl<'a> Ports<'a> {
    /// Resolve one of this task's declared output ports by name. Unknown
    /// names fail with the declared ports listed via did-you-mean — the
    /// same treatment client handle resolution gets.
    pub fn out(&self, name: &str) -> Result<OutPort> {
        match self.map.out_names.iter().position(|n| &**n == name) {
            Some(i) => Ok(self.map.outs[i]),
            None => Err(anyhow!(
                "task '{}' has no declared output port '{name}'{}",
                self.task,
                suggest(name, "output port", self.map.out_names.iter().map(|n| &**n))
            )),
        }
    }

    /// Resolve an emission target that may be *another* task's wire (a
    /// phantom sink: taps, currency and dense capture still apply, no
    /// consumer links). Declared outputs resolve to their port; any other
    /// interned wire resolves to a Summary-classed port on that wire;
    /// names outside the wire table fail with did-you-mean over the
    /// declared output ports.
    pub fn out_or_wire(&self, name: &str) -> Result<OutPort> {
        if let Some(i) = self.map.out_names.iter().position(|n| &**n == name) {
            return Ok(self.map.outs[i]);
        }
        match self.wires.id(name) {
            Some(wire) => Ok(OutPort { wire, class: DataClass::Summary }),
            None => Err(self.unknown_out(name)),
        }
    }

    /// Declared output port by position (spec order).
    pub fn out_at(&self, i: usize) -> Result<OutPort> {
        self.map.outs.get(i).copied().ok_or_else(|| {
            anyhow!(
                "task '{}' has {} output port(s); no port #{i}",
                self.task,
                self.map.outs.len()
            )
        })
    }

    /// All declared output ports, spec order.
    pub fn outs(&self) -> &'a [OutPort] {
        &self.map.outs
    }

    /// Resolve one of this task's stream-input ports by wire name.
    pub fn input(&self, name: &str) -> Result<InPort> {
        match self.map.in_names.iter().position(|n| &**n == name) {
            Some(i) => Ok(self.map.ins[i]),
            None => Err(anyhow!(
                "task '{}' has no stream input '{name}'{}",
                self.task,
                suggest(name, "input port", self.map.in_names.iter().map(|n| &**n))
            )),
        }
    }

    /// Stream-input port by position (spec order).
    pub fn input_at(&self, i: usize) -> Result<InPort> {
        self.map.ins.get(i).copied().ok_or_else(|| {
            anyhow!(
                "task '{}' has {} stream input(s); no port #{i}",
                self.task,
                self.map.ins.len()
            )
        })
    }

    /// All stream-input ports, spec order.
    pub fn ins(&self) -> &'a [InPort] {
        &self.map.ins
    }

    fn unknown_out(&self, name: &str) -> anyhow::Error {
        anyhow!(
            "task '{}' cannot emit on unknown wire '{name}'{}",
            self.task,
            suggest(name, "output port", self.map.out_names.iter().map(|n| &**n))
        )
    }
}

/// One pre-resolved emission: what the coordinator publishes. User code
/// never constructs these directly — the [`Emitter`] does — and the
/// coordinator consumes them without touching a wire name (§Perf).
#[derive(Clone, Debug)]
pub struct Emission {
    pub wire: WireId,
    pub payload: Payload,
    pub class: DataClass,
    /// Extra virtual time between the run's publish instant and this
    /// emission becoming visible (deferred emission; ZERO = immediate).
    pub defer: SimDuration,
}

/// Per-agent memo of legacy wire-name resolutions, so an un-migrated
/// [`UserCode`](super::UserCode) plugin pays the string hash once per
/// distinct name, not once per publication.
pub type NameCache = FastMap<Arc<str>, WireId>;

/// Where user code writes its outputs. Backed by the agent's reusable
/// emission buffer: the steady state allocates nothing per run.
pub struct Emitter<'a> {
    pub(crate) buf: &'a mut Vec<Emission>,
    pub(crate) map: &'a PortMap,
    pub(crate) wires: &'a WireTable,
    pub(crate) cache: &'a mut NameCache,
    pub(crate) task: &'a str,
}

impl Emitter<'_> {
    /// Emit `payload` on `port` with the port's default class.
    #[inline]
    pub fn emit(&mut self, port: OutPort, payload: Payload) {
        self.buf.push(Emission {
            wire: port.wire,
            payload,
            class: port.class,
            defer: SimDuration::ZERO,
        });
    }

    /// Emit with an explicit class (sovereignty decisions per value).
    #[inline]
    pub fn emit_class(&mut self, port: OutPort, payload: Payload, class: DataClass) {
        self.buf.push(Emission { wire: port.wire, payload, class, defer: SimDuration::ZERO });
    }

    /// Ghost emission (§III-K): exercise the route, pretend the size.
    pub fn emit_ghost(&mut self, port: OutPort, pretend_bytes: u64) {
        self.buf.push(Emission {
            wire: port.wire,
            payload: Payload::Ghost { pretend_bytes },
            class: DataClass::Ghost,
            defer: SimDuration::ZERO,
        });
    }

    /// Deferred emission: published `defer` after the run's other outputs
    /// (e.g. a watchdog value that should trail its trigger).
    pub fn emit_after(&mut self, port: OutPort, payload: Payload, defer: SimDuration) {
        self.buf.push(Emission { wire: port.wire, payload, class: port.class, defer });
    }

    /// Legacy name-keyed emission — the adapter path for un-migrated
    /// [`UserCode`](super::UserCode). Resolution is memoized per agent;
    /// unknown wires error with the task's declared output ports listed
    /// via did-you-mean (they no longer vanish into an overflow map).
    pub fn emit_named(&mut self, name: &str, payload: Payload, class: DataClass) -> Result<()> {
        let wire = match self.cache.get(name) {
            Some(&w) => w,
            None => {
                let w = self.wires.id(name).ok_or_else(|| {
                    anyhow!(
                        "task '{}' emitted on unknown wire '{name}'{}",
                        self.task,
                        suggest(name, "output port", self.map.out_names.iter().map(|n| &**n))
                    )
                })?;
                self.cache.insert(Arc::from(name), w);
                w
            }
        };
        self.buf.push(Emission { wire, payload, class, defer: SimDuration::ZERO });
        Ok(())
    }

    /// Drain a legacy `Vec<Output>` return into pre-resolved emissions.
    pub fn emit_outputs(&mut self, outs: Vec<super::Output>) -> Result<()> {
        self.buf.reserve(outs.len());
        for o in outs {
            self.emit_named(&o.wire, o.payload, o.class)?;
        }
        Ok(())
    }

    /// Emissions recorded so far this run (e.g. for wrapper code that
    /// inspects what an inner task produced before adding its own).
    pub fn emissions(&self) -> &[Emission] {
        self.buf
    }

    pub fn count(&self) -> usize {
        self.buf.len()
    }
}

/// Port-indexed view over the run's [`Snapshot`]: which AVs arrived on
/// which [`InPort`], with lazy per-port fetching.
pub struct Inputs<'a> {
    pub(crate) snapshot: &'a Snapshot,
    pub(crate) map: &'a PortMap,
}

impl<'a> Inputs<'a> {
    /// The raw snapshot (legacy plugins and Merge-policy code, whose one
    /// synthetic `merged` input matches no declared port).
    pub fn snapshot(&self) -> &'a Snapshot {
        self.snapshot
    }

    /// Every AV in the snapshot, all ports, oldest-first per port.
    pub fn all(&self) -> impl Iterator<Item = &'a AnnotatedValue> + 'a {
        self.snapshot.all_avs()
    }

    /// The AVs that arrived on `port` (empty if the port contributed
    /// nothing to this snapshot). Fast path: snapshot entries sit in
    /// buffer order, so the port's slot usually indexes directly; the
    /// name-checked fallback covers make-mode and Merge snapshots.
    pub fn on(&self, port: InPort) -> &'a [AnnotatedValue] {
        let name = match self.map.in_names.get(port.slot()) {
            Some(n) => n,
            None => return &[],
        };
        if let Some((n, avs)) = self.snapshot.inputs.get(port.slot()) {
            if Arc::ptr_eq(n, name) || **n == **name {
                return avs;
            }
        }
        self.snapshot
            .inputs
            .iter()
            .find(|(n, _)| **n == **name)
            .map(|(_, v)| v.as_slice())
            .unwrap_or(&[])
    }

    /// Lazily fetch every payload on `port` through the dependent-local
    /// cache (charging storage/WAN per §Perf rules), oldest first.
    pub fn fetch(&self, ctx: &mut TaskCtx<'_>, port: InPort) -> Result<Vec<Payload>> {
        self.on(port).iter().map(|av| ctx.fetch(av)).collect()
    }

    /// Fetch a port and stack its payloads into one tensor (one AV passes
    /// through; k rows stack to `(k, D)`) — the window/buffer assembly
    /// contract PJRT tasks use.
    pub fn fetch_stacked(&self, ctx: &mut TaskCtx<'_>, port: InPort) -> Result<Payload> {
        let payloads = self.fetch(ctx, port)?;
        super::compute::stack_port(&payloads)
    }

    /// True when any member is a ghost (the run routes, §III-K).
    pub fn is_ghost(&self) -> bool {
        self.snapshot.ghost
    }
}

/// What [`TaskCode::run`](super::TaskCode::run) sees besides the platform
/// ctx: the port-indexed [`Inputs`] view and the [`Emitter`]. Split into
/// two public fields so user code can read inputs while emitting.
pub struct PortIo<'a> {
    pub inputs: Inputs<'a>,
    pub emitter: Emitter<'a>,
}

impl PortIo<'_> {
    /// Declared output port by position — the string-free resolution for
    /// closure-style plugins (`io.out(0)?`). An out-of-range index is a
    /// task error (recorded like any other run failure), never a panic:
    /// closures skip the bind step, so this is their resolution point.
    pub fn out(&self, i: usize) -> Result<OutPort> {
        self.inputs.map.outs.get(i).copied().ok_or_else(|| {
            anyhow!(
                "task '{}' has {} output port(s); no port #{i}",
                self.emitter.task,
                self.inputs.map.outs.len()
            )
        })
    }

    /// All declared output ports, spec order.
    pub fn outs(&self) -> &[OutPort] {
        &self.inputs.map.outs
    }

    /// Stream-input port by position (spec order). Errors like [`out`].
    pub fn in_at(&self, i: usize) -> Result<InPort> {
        self.inputs.map.ins.get(i).copied().ok_or_else(|| {
            anyhow!(
                "task '{}' has {} stream input(s); no port #{i}",
                self.emitter.task,
                self.inputs.map.ins.len()
            )
        })
    }

    /// The raw snapshot (shorthand for `io.inputs.snapshot()`).
    pub fn snapshot(&self) -> &Snapshot {
        self.inputs.snapshot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::PipelineGraph;

    fn mint(spec_text: &str, task: usize) -> (PortMap, WireTable) {
        let spec = crate::spec::parse(spec_text).unwrap();
        let graph = PipelineGraph::build(&spec);
        (PortMap::mint(&spec.tasks[task], &graph.wires), graph.wires)
    }

    #[test]
    fn mint_orders_ports_by_spec() {
        let (map, wires) = mint("[m]\n(a, b, a) t (x, y)\n", 0);
        assert_eq!(map.outs().len(), 2);
        assert_eq!(map.ins().len(), 2, "duplicate stream input 'a' dedups");
        assert_eq!(map.outs()[0].wire_id(), wires.id("x").unwrap());
        assert_eq!(map.outs()[1].wire_id(), wires.id("y").unwrap());
        assert_eq!(map.ins()[0].wire_id(), wires.id("a").unwrap());
        assert_eq!(map.ins()[1].wire_id(), wires.id("b").unwrap());
        assert_eq!(map.ins()[1].slot(), 1);
        assert_eq!(map.outs()[0].default_class(), DataClass::Summary);
        assert_eq!(map.outs()[0].with_class(DataClass::Raw).default_class(), DataClass::Raw);
    }

    #[test]
    fn binder_resolves_with_did_you_mean() {
        let (map, wires) = mint("[b]\n(raw) screen (clean, alerts)\n", 0);
        let ports = Ports { map: &map, wires: &wires, task: "screen" };
        assert_eq!(ports.out("clean").unwrap(), ports.out_at(0).unwrap());
        assert_eq!(ports.input("raw").unwrap(), ports.input_at(0).unwrap());
        let e = ports.out("claen").unwrap_err().to_string();
        assert!(e.contains("did you mean 'clean'?"), "{e}");
        assert!(e.contains("known output ports: clean, alerts"), "{e}");
        // phantom targets resolve through the wire table…
        assert_eq!(ports.out_or_wire("raw").unwrap().wire_id(), wires.id("raw").unwrap());
        // …but names outside it still fail with the declared-port list
        let e = ports.out_or_wire("nowhere").unwrap_err().to_string();
        assert!(e.contains("unknown wire 'nowhere'"), "{e}");
        assert!(e.contains("known output ports"), "{e}");
        assert!(ports.out_at(2).is_err());
        assert!(ports.input("clean").is_err());
    }

    #[test]
    fn emitter_resolves_legacy_names_once() {
        let (map, wires) = mint("[e]\n(raw) t (x)\n", 0);
        let mut buf = Vec::new();
        let mut cache = NameCache::default();
        let mut em = Emitter { buf: &mut buf, map: &map, wires: &wires, cache: &mut cache, task: "t" };
        em.emit_named("x", Payload::scalar(1.0), DataClass::Summary).unwrap();
        em.emit_named("x", Payload::scalar(2.0), DataClass::Summary).unwrap();
        let err = em
            .emit_named("xz", Payload::scalar(3.0), DataClass::Summary)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown wire 'xz'"), "{err}");
        assert!(err.contains("did you mean 'x'?"), "{err}");
        assert_eq!(em.count(), 2);
        assert_eq!(cache.len(), 1, "one resolution for two emissions");
        assert_eq!(buf[0].wire, wires.id("x").unwrap());
    }
}
