//! PJRT-backed compute tasks: user code whose body is an AOT-compiled
//! XLA executable (L2 JAX graph + L1 Pallas kernels lowered at build time).
//!
//! Assembly contract: manifest inputs are filled left-to-right from
//! (optional) held state payloads, then from the snapshot's input ports in
//! declared order. A port holding several `(1, D)` AVs (a buffer/window of
//! stream samples) is stacked into an `(n, D)` tensor; a port holding one
//! AV is passed through. Shapes are validated against the manifest.

use super::{OutPort, PortIo, Ports, TaskCode, TaskCtx};
use crate::av::{DataClass, Payload};
use crate::platform::Service;
use crate::policy::Snapshot;
use crate::runtime::Executable;
use crate::util::SimDuration;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// Stack a port's fetched payloads into one tensor: one AV passes through;
/// k AVs of shape (1, D) (or (D,)) stack to (k, D).
pub fn stack_port(payloads: &[Payload]) -> Result<Payload> {
    match payloads {
        [] => bail!("empty input port"),
        [one] => Ok(one.clone()),
        many => {
            let (first_shape, _) =
                many[0].as_tensor().ok_or_else(|| anyhow!("stack: non-tensor"))?;
            let d: usize = first_shape.iter().product();
            let mut data = Vec::with_capacity(many.len() * d);
            for p in many {
                let (s, v) = p.as_tensor().ok_or_else(|| anyhow!("stack: non-tensor"))?;
                if s.iter().product::<usize>() != d {
                    bail!("stack: ragged payloads ({s:?} vs {first_shape:?})");
                }
                data.extend_from_slice(v);
            }
            Ok(Payload::tensor(&[many.len(), d], data))
        }
    }
}

/// Generic executable-backed task.
///
/// `state` payloads fill the first manifest inputs (e.g. model parameters);
/// snapshot ports fill the rest. `emit` maps executable output indices to
/// wires (names resolved to [`OutPort`]s once at bind time); `absorb` (if
/// set) writes output indices back into `state` (e.g. a train step's
/// updated parameters).
pub struct PjrtTask {
    pub exe: Arc<Executable>,
    pub state: Vec<Payload>,
    /// (output index, wire, class) — the configured mapping; resolved
    /// into `bound` when the task is installed.
    pub emit: Vec<(usize, String, DataClass)>,
    /// Port-resolved `emit`, minted at bind time (§Perf: the run loop
    /// publishes on ids, never names).
    bound: Vec<(usize, OutPort)>,
    /// (output index, state slot)
    pub absorb: Vec<(usize, usize)>,
    pub version: u32,
    /// Estimated FLOPs per execution (drives the virtual-time cost model;
    /// interpret-mode wallclock is not a TPU proxy — see DESIGN.md §Perf).
    pub flops: u64,
}

impl PjrtTask {
    pub fn new(exe: Arc<Executable>, out_wire: &str) -> Self {
        let n_out = exe.meta.outputs.len();
        let mut emit: Vec<(usize, String, DataClass)> =
            vec![(0, out_wire.to_string(), DataClass::Summary)];
        emit.truncate(n_out.max(1).min(1));
        Self { exe, state: vec![], emit, bound: vec![], absorb: vec![], version: 1, flops: 0 }
    }

    pub fn with_emit(mut self, emit: Vec<(usize, String, DataClass)>) -> Self {
        self.emit = emit;
        self
    }

    pub fn with_state(mut self, state: Vec<Payload>) -> Self {
        self.state = state;
        self
    }

    pub fn with_absorb(mut self, absorb: Vec<(usize, usize)>) -> Self {
        self.absorb = absorb;
        self
    }

    pub fn with_flops(mut self, flops: u64) -> Self {
        self.flops = flops;
        self
    }

    fn assemble(&self, ctx: &mut TaskCtx<'_>, snapshot: &Snapshot) -> Result<Vec<Payload>> {
        let want = self.exe.meta.inputs.len();
        let mut inputs: Vec<Payload> = self.state.clone();
        for (port, avs) in &snapshot.inputs {
            if inputs.len() >= want {
                bail!("too many inputs for {} (port '{port}' unused)", self.exe.meta.name);
            }
            let fetched: Vec<Payload> =
                avs.iter().map(|av| ctx.fetch(av)).collect::<Result<_>>()?;
            inputs.push(stack_port(&fetched)?);
        }
        if inputs.len() != want {
            bail!("{}: assembled {} inputs, manifest wants {want}", self.exe.meta.name, inputs.len());
        }
        Ok(inputs)
    }
}

impl TaskCode for PjrtTask {
    fn version(&self) -> u32 {
        self.version
    }

    fn bind(&mut self, ports: &Ports<'_>) -> Result<()> {
        // the once-per-install name resolution: every configured emission
        // wire becomes an OutPort carrying its class (phantom targets —
        // another task's wire — are legal, like any probe emission)
        self.bound = self
            .emit
            .iter()
            .map(|(oi, wire, class)| Ok((*oi, ports.out_or_wire(wire)?.with_class(*class))))
            .collect::<Result<_>>()?;
        Ok(())
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>) -> Result<()> {
        let inputs = self.assemble(ctx, io.snapshot())?;
        let refs: Vec<&Payload> = inputs.iter().collect();
        let outputs = self.exe.run(&refs)?;
        for &(oi, si) in &self.absorb {
            self.state[si] = outputs
                .get(oi)
                .ok_or_else(|| anyhow!("absorb index {oi} out of range"))?
                .clone();
        }
        for &(oi, port) in &self.bound {
            let payload = outputs
                .get(oi)
                .ok_or_else(|| anyhow!("emit index {oi} out of range"))?
                .clone();
            io.emitter.emit(port, payload);
        }
        Ok(())
    }

    fn compute_cost(&self, input_bytes: u64) -> SimDuration {
        // 1 GFLOP/s effective edge-node rate + streaming the inputs.
        SimDuration::micros(50 + self.flops / 1_000 + input_bytes / 4096)
    }
}

// ---------------------------------------------------------------------------
// MLP parameter plumbing (fig. 6 twin pipeline)
// ---------------------------------------------------------------------------

/// Dimensions of the AOT-compiled MLP (must match python/compile/aot.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MlpDims {
    pub input: usize,
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
}

impl Default for MlpDims {
    fn default() -> Self {
        Self { input: 64, hidden: 128, classes: 4, batch: 32 }
    }
}

impl MlpDims {
    pub fn param_shapes(&self) -> [Vec<usize>; 4] {
        [
            vec![self.input, self.hidden],
            vec![self.hidden],
            vec![self.hidden, self.classes],
            vec![self.classes],
        ]
    }

    /// FLOPs of one forward pass (2·B·(IN·H + H·C)).
    pub fn fwd_flops(&self) -> u64 {
        (2 * self.batch * (self.input * self.hidden + self.hidden * self.classes)) as u64
    }

    /// He-style deterministic init (rust-side; training will move it).
    pub fn init_params(&self, rng: &mut crate::util::Rng) -> Vec<Payload> {
        self.param_shapes()
            .iter()
            .enumerate()
            .map(|(i, shape)| {
                let n: usize = shape.iter().product();
                let fan_in = shape[0] as f64;
                let scale = if shape.len() == 2 { (2.0 / fan_in).sqrt() } else { 0.0 };
                let data: Vec<f32> =
                    (0..n).map(|_| (rng.normal() * scale) as f32).collect();
                let _ = i;
                Payload::tensor(shape, data)
            })
            .collect()
    }
}

/// Flatten params into one transportable tensor (for the `model` wire).
pub fn pack_params(params: &[Payload]) -> Result<Payload> {
    let mut data = Vec::new();
    for p in params {
        let (_, d) = p.as_tensor().ok_or_else(|| anyhow!("pack: non-tensor param"))?;
        data.extend_from_slice(d);
    }
    let n = data.len();
    Ok(Payload::tensor(&[n], data))
}

/// Inverse of [`pack_params`] given the dims.
pub fn unpack_params(dims: &MlpDims, packed: &Payload) -> Result<Vec<Payload>> {
    let (_, data) = packed.as_tensor().ok_or_else(|| anyhow!("unpack: non-tensor"))?;
    let mut out = Vec::new();
    let mut off = 0;
    for shape in dims.param_shapes() {
        let n: usize = shape.iter().product();
        if off + n > data.len() {
            bail!("packed params too short");
        }
        out.push(Payload::tensor(&shape, data[off..off + n].to_vec()));
        off += n;
    }
    if off != data.len() {
        bail!("packed params too long ({} extra)", data.len() - off);
    }
    Ok(out)
}

/// The deployed model server of fig. 6: a *service* (implicit link)
/// consulted by the lower pipeline, updated by the upper one. Each
/// parameter deployment bumps the service version — provenance then shows
/// exactly which model classified which image.
pub struct ModelServer {
    pub exe: Arc<Executable>,
    pub dims: MlpDims,
    params: Vec<Payload>,
    version: u32,
}

impl ModelServer {
    pub fn new(exe: Arc<Executable>, dims: MlpDims, params: Vec<Payload>) -> Self {
        Self { exe, dims, params, version: 1 }
    }
}

impl Service for ModelServer {
    fn version(&self) -> u32 {
        self.version
    }

    fn call(&mut self, query: &Payload) -> Payload {
        let mut inputs: Vec<&Payload> = self.params.iter().collect();
        inputs.push(query);
        match self.exe.run(&inputs) {
            Ok(mut outs) => outs.remove(0),
            Err(e) => Payload::Text(format!("ERR:{e}")),
        }
    }

    fn latency(&self) -> SimDuration {
        SimDuration::micros(200 + self.dims.fwd_flops() / 1_000)
    }

    fn update_payload(&mut self, p: &Payload) -> bool {
        match unpack_params(&self.dims, p) {
            Ok(params) => {
                self.params = params;
                self.version += 1;
                true
            }
            Err(_) => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_port_single_passthrough() {
        let p = Payload::tensor(&[2, 3], vec![0.0; 6]);
        assert_eq!(stack_port(&[p.clone()]).unwrap(), p);
    }

    #[test]
    fn stack_port_stacks_rows() {
        let rows: Vec<Payload> =
            (0..4).map(|i| Payload::tensor(&[1, 2], vec![i as f32, -(i as f32)])).collect();
        let s = stack_port(&rows).unwrap();
        let (shape, data) = s.as_tensor().unwrap();
        assert_eq!(shape, &[4, 2]);
        assert_eq!(data[..2], [0.0, 0.0]);
        assert_eq!(data[6..], [3.0, -3.0]);
    }

    #[test]
    fn stack_port_rejects_ragged() {
        let a = Payload::tensor(&[1, 2], vec![0.0; 2]);
        let b = Payload::tensor(&[1, 3], vec![0.0; 3]);
        assert!(stack_port(&[a, b]).is_err());
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let dims = MlpDims::default();
        let mut rng = crate::util::rng(1);
        let params = dims.init_params(&mut rng);
        let packed = pack_params(&params).unwrap();
        let back = unpack_params(&dims, &packed).unwrap();
        assert_eq!(params, back);
        // corrupted length fails
        let (_, d) = packed.as_tensor().unwrap();
        let short = Payload::tensor(&[d.len() - 1], d[..d.len() - 1].to_vec());
        assert!(unpack_params(&dims, &short).is_err());
    }

    #[test]
    fn fwd_flops_sane() {
        let dims = MlpDims::default();
        assert_eq!(dims.fwd_flops(), (2 * 32 * (64 * 128 + 128 * 4)) as u64);
    }
}
