//! Smart task agents — §III-I.
//!
//! "It makes sense to wrap container execution in some basic policy-guided
//! reasoning ... The task agent has the responsibility to wait for data
//! from its incoming links and assemble execution sets of annotated values
//! to construct the arguments for a single execution."
//!
//! [`TaskCode`] is the plugin-container boundary: user logic sees only a
//! [`TaskCtx`] (fetch inputs, call services, log) and a [`PortIo`] — a
//! port-indexed [`Inputs`] view over the snapshot plus an [`Emitter`]
//! writing pre-resolved emissions — never Kubernetes, storage tiers, or
//! regions (platform transparency, §III-B). Output ports are minted at
//! deploy/plug time ([`PortMap`]) and resolved once in [`TaskCode::bind`],
//! mirroring the client-side handle API: the steady-state `run` touches no
//! wire names and allocates no intermediate `Vec<Output>` (§Perf). The
//! legacy name-returning [`UserCode`] trait keeps working through the
//! [`LegacyCode`] adapter. The agent wraps either with snapshot policy,
//! memoization (make-style staleness), the dependent-local cache
//! (Principle 2), ghost handling (§III-K) and provenance stamping.

pub mod builtins;
pub mod compute;
pub(crate) mod effects;
pub mod ports;

pub use ports::{Emission, Emitter, InPort, Inputs, NameCache, OutPort, PortIo, PortMap, Ports};

use effects::{
    ghost_payload, is_needs_sequential, needs_sequential, DeferReason, Effect, EffectLog,
    FireFail, PreparedFiring, RecordedBody, RecordedRun, WorldView,
};

use crate::av::{AnnotatedValue, DataClass, Payload};
use crate::fault::{deadline_error, FaultKind, FireGuard, Firing};
use crate::bus::NotifyMode;
use crate::graph::WireTable;
use crate::obs::NetTier;
use crate::platform::Platform;
use crate::policy::{Snapshot, SnapshotEngine};
use crate::provenance::{CheckpointEvent, Stamp};
use crate::spec::TaskSpec;
use crate::storage::{CacheManager, ObjectStore, PurgePolicy};
use crate::util::hash::FastMap;
use crate::util::{AvId, ContentHash, ObjectId, RegionId, RunId, SimDuration, SimTime, TaskId, WireId};
use anyhow::{anyhow, Result};

/// One produced output: wire name, payload, sovereignty class.
#[derive(Clone, Debug)]
pub struct Output {
    /// Refcounted so long-lived user code cloning a held name is free (§Perf).
    pub wire: std::sync::Arc<str>,
    pub payload: Payload,
    pub class: DataClass,
}

impl Output {
    pub fn new(wire: impl Into<std::sync::Arc<str>>, payload: Payload, class: DataClass) -> Self {
        Self { wire: wire.into(), payload, class }
    }

    pub fn summary(wire: &str, payload: Payload) -> Self {
        Self { wire: std::sync::Arc::from(wire), payload, class: DataClass::Summary }
    }

    pub fn raw(wire: &str, payload: Payload) -> Self {
        Self { wire: std::sync::Arc::from(wire), payload, class: DataClass::Raw }
    }
}

/// The plugin-container boundary — the primary plugin surface. Ports are
/// resolved once in [`bind`](TaskCode::bind) (deploy/plug time, with
/// did-you-mean errors like client handle resolution); the steady-state
/// [`run`](TaskCode::run) reads through the port-indexed
/// [`Inputs`] view and writes through the [`Emitter`], never touching a
/// wire name and never allocating an output `Vec` (§Perf).
///
/// `Send` is a supertrait: the parallel wavefront scheduler executes
/// mutually independent firings on worker threads, each worker owning
/// its task's agent (code included) exclusively for the wavefront.
pub trait TaskCode: Send {
    /// Software version — provenance records it on every artifact; bumping
    /// it invalidates memoized results (§III-J "Software Updates").
    fn version(&self) -> u32 {
        1
    }

    /// Called once when this code is installed into a deployed task:
    /// resolve output/input ports here and store them. Failing the bind
    /// rejects the install and leaves the previous code in place.
    fn bind(&mut self, ports: &Ports<'_>) -> Result<()> {
        let _ = ports;
        Ok(())
    }

    /// Process one snapshot: fetch via `io.inputs` / `ctx.fetch`, call
    /// exterior services via `ctx.lookup`, emit via `io.emitter`.
    fn run(&mut self, ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>) -> Result<()>;

    /// Simulated compute cost for a snapshot of `input_bytes` (charged to
    /// virtual time on top of real fetch/storage latencies).
    fn compute_cost(&self, input_bytes: u64) -> SimDuration {
        SimDuration::micros(200 + input_bytes / 512)
    }

    /// May this code execute on a wavefront worker thread? Default yes.
    /// Return `false` when `run` needs the live platform — service
    /// lookups ([`TaskCtx::lookup`]), service updates, or
    /// [`TaskCtx::platform`] — or keeps internal mutable state that a
    /// restarted run would double-apply. Declared-sequential code always
    /// executes in the deterministic commit phase with direct platform
    /// access, exactly like `workers = 1`. (Undeclared code that touches
    /// those APIs on a worker is rolled back and re-run sequentially —
    /// agent state is restored, but any internal `&mut self` state the
    /// aborted attempt mutated is not, so stateful service users MUST
    /// declare themselves sequential rather than rely on the fallback.)
    fn parallel_safe(&self) -> bool {
        true
    }
}

/// The legacy plugin trait: return wire *names*. Still supported — wrap
/// implementations in [`LegacyCode`] to install them; the adapter resolves
/// returned names once per distinct name (memoized per agent) instead of
/// letting the coordinator re-resolve every publication. New code should
/// implement [`TaskCode`] and emit on ports. `Send` for the same reason
/// as [`TaskCode`]: the adapter carries implementations onto worker
/// threads.
pub trait UserCode: Send {
    /// Software version — provenance records it on every artifact; bumping
    /// it invalidates memoized results (§III-J "Software Updates").
    fn version(&self) -> u32 {
        1
    }

    /// Process one snapshot. Fetch payloads via `ctx.fetch(av)`; call
    /// exterior services via `ctx.lookup(name, query)`.
    fn run(&mut self, ctx: &mut TaskCtx<'_>, snapshot: &Snapshot) -> Result<Vec<Output>>;

    /// Simulated compute cost for a snapshot of `input_bytes` (charged to
    /// virtual time on top of real fetch/storage latencies).
    fn compute_cost(&self, input_bytes: u64) -> SimDuration {
        SimDuration::micros(200 + input_bytes / 512)
    }

    /// See [`TaskCode::parallel_safe`]; forwarded by the adapter.
    fn parallel_safe(&self) -> bool {
        true
    }
}

impl UserCode for Box<dyn UserCode> {
    fn version(&self) -> u32 {
        (**self).version()
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, snapshot: &Snapshot) -> Result<Vec<Output>> {
        (**self).run(ctx, snapshot)
    }

    fn compute_cost(&self, input_bytes: u64) -> SimDuration {
        (**self).compute_cost(input_bytes)
    }

    fn parallel_safe(&self) -> bool {
        (**self).parallel_safe()
    }
}

/// Adapter carrying any [`UserCode`] implementation onto the [`TaskCode`]
/// port runtime: the returned `Vec<Output>` is drained into the emitter,
/// each wire name resolved against the deploy-time table once and
/// memoized. Unknown names error with the task's declared output ports
/// listed via did-you-mean.
pub struct LegacyCode<U>(pub U);

impl<U: UserCode> LegacyCode<U> {
    pub fn new(inner: U) -> Self {
        Self(inner)
    }
}

/// Convenience: box legacy user code straight into the port runtime.
pub fn legacy<U: UserCode + 'static>(inner: U) -> Box<dyn TaskCode> {
    Box::new(LegacyCode(inner))
}

impl<U: UserCode> TaskCode for LegacyCode<U> {
    fn version(&self) -> u32 {
        self.0.version()
    }

    fn run(&mut self, ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>) -> Result<()> {
        let outs = self.0.run(ctx, io.inputs.snapshot())?;
        io.emitter.emit_outputs(outs)
    }

    fn compute_cost(&self, input_bytes: u64) -> SimDuration {
        self.0.compute_cost(input_bytes)
    }

    fn parallel_safe(&self) -> bool {
        self.0.parallel_safe()
    }
}

/// How a [`TaskCtx`] reaches the world: the direct `&mut Platform` of
/// sequential execution (`workers = 1`, make-mode demand, the commit
/// phase), or the recording mode a wavefront worker runs under — a
/// read-only [`WorldView`] plus the [`EffectLog`] the deterministic
/// commit replays.
enum CtxAccess<'a> {
    Direct(&'a mut Platform),
    Recorded { world: &'a WorldView<'a>, fx: &'a mut EffectLog },
}

/// What user code sees of the platform. The platform itself is behind
/// [`TaskCtx::platform`] (direct execution only) so the same `run` body
/// works unchanged on a wavefront worker thread, where its platform
/// mutations are recorded and replayed in deterministic commit order.
pub struct TaskCtx<'a> {
    access: CtxAccess<'a>,
    pub cache: &'a mut CacheManager,
    pub task: TaskId,
    pub task_name: &'a str,
    /// Run id of this execution — private: on a wavefront worker it
    /// holds a placeholder (real ids are drawn at commit so dispenser
    /// order matches sequential execution byte-for-byte), so reads go
    /// through [`TaskCtx::run_id`], which poisons a recording.
    run: RunId,
    pub region: RegionId,
    pub version: u32,
    /// Wireframe run: route, don't compute (§III-K).
    pub ghost: bool,
    /// Does this snapshot combine multiple inputs? (Principle 2 risk tag.)
    pub combined: bool,
    /// Accumulated virtual cost of this run (fetches, lookups, compute).
    pub cost: SimDuration,
}

impl<'a> TaskCtx<'a> {
    /// The current virtual time.
    pub fn now(&self) -> SimTime {
        match &self.access {
            CtxAccess::Direct(plat) => plat.now,
            CtxAccess::Recorded { world, .. } => world.now,
        }
    }

    /// The run id of this execution. On a wavefront worker the real id
    /// is not known yet (it is drawn at commit, in canonical order), so
    /// reading it poisons the recording: the firing rolls back and
    /// re-runs sequentially, where the id is real — code embedding run
    /// ids in remarks or outputs stays byte-identical across `workers`
    /// settings.
    pub fn run_id(&mut self) -> RunId {
        if let CtxAccess::Recorded { fx, .. } = &mut self.access {
            fx.poison();
        }
        self.run
    }

    /// Full platform access — service registration/updates, ad-hoc
    /// metrics, raw provenance queries. Only available under direct
    /// execution; on a wavefront worker this returns the
    /// needs-sequential error, which rolls the firing back and re-runs
    /// it in the deterministic commit phase. Code that calls this should
    /// declare [`TaskCode::parallel_safe`] `= false`.
    pub fn platform(&mut self) -> Result<&mut Platform> {
        match &mut self.access {
            CtxAccess::Direct(plat) => Ok(plat),
            CtxAccess::Recorded { fx, .. } => {
                // poison the recording even if the caller swallows this
                // error: the firing must re-run with direct access
                fx.poison();
                Err(needs_sequential("TaskCtx::platform"))
            }
        }
    }

    /// Push new state into a registered service (e.g. deploy fresh model
    /// parameters) — sugar over `platform()?.services.update(..)` with
    /// [`Service::update_payload`](crate::platform::Service::update_payload).
    /// Returns whether the service exists *and* accepted the payload.
    /// Direct execution only (a service mutation is ordered, shared
    /// state); falls back to sequential commit on a worker.
    pub fn update_service(&mut self, service: &str, payload: &Payload) -> Result<bool> {
        let plat = self.platform()?;
        let mut accepted = false;
        let found = plat.services.update(service, |s| accepted = s.update_payload(payload));
        Ok(found && accepted)
    }

    /// Fetch the payload an AV points to, through the dependent-local
    /// cache. Charges storage + (if remote) WAN latency on miss; stamps
    /// the passport either way. Identical observable behavior in both
    /// access modes — the recorded arm pushes the exact mutation
    /// sequence the direct arm performs, which the commit replays.
    pub fn fetch(&mut self, av: &AnnotatedValue) -> Result<Payload> {
        let now = self.now();
        if self.cache.lookup(av.object, now) {
            match &mut self.access {
                CtxAccess::Direct(plat) => {
                    plat.metrics.cache_hits += 1;
                    plat.prov.stamp(av.id, now, Stamp::CacheServed { region: self.region });
                }
                CtxAccess::Recorded { fx, .. } => {
                    fx.push(Effect::CacheHit);
                    fx.push(Effect::CacheServed { av: av.id });
                }
            }
            // served from local media: base local latency only
            self.cost += SimDuration::micros(20);
            let obj = match &self.access {
                CtxAccess::Direct(plat) => plat.store.peek(av.object),
                CtxAccess::Recorded { world, .. } => world.store.peek(av.object),
            }
            .ok_or_else(|| anyhow!("cached object {} vanished", av.object))?;
            return Ok(obj.payload.clone());
        }
        let (payload, bytes, lat) = match &mut self.access {
            CtxAccess::Direct(plat) => {
                plat.metrics.cache_misses += 1;
                let (obj, lat) = plat
                    .store
                    .get(av.object)
                    .ok_or_else(|| anyhow!("object {} not in store", av.object))?;
                let p = obj.payload.clone();
                let b = obj.payload.transfer_bytes();
                plat.metrics.storage_latency.record(lat);
                (p, b, lat)
            }
            CtxAccess::Recorded { world, fx } => {
                fx.push(Effect::CacheMiss);
                match world.store.plan_get(av.object) {
                    Some((obj, lat)) => {
                        fx.push(Effect::StoreGet { object: av.object, lat: Some(lat) });
                        (obj.payload.clone(), obj.payload.transfer_bytes(), lat)
                    }
                    None => {
                        // the direct path bumps `gets` before discovering
                        // the miss; mirror that, then error identically
                        fx.push(Effect::StoreGet { object: av.object, lat: None });
                        return Err(anyhow!("object {} not in store", av.object));
                    }
                }
            }
        };
        self.cost += lat;
        if av.region != self.region {
            let (wan_lat, tier) = match &self.access {
                CtxAccess::Direct(plat) => {
                    plat.net.plan_transfer(av.class, av.region, self.region, bytes)
                }
                CtxAccess::Recorded { world, .. } => {
                    world.net.plan_transfer(av.class, av.region, self.region, bytes)
                }
            }
            .ok_or_else(|| {
                anyhow!("sovereignty violation fetching {} into {}", av.id, self.region)
            })?;
            self.cost += wan_lat;
            match &mut self.access {
                CtxAccess::Direct(plat) => {
                    plat.metrics.moved(tier, bytes);
                    plat.prov.stamp(
                        av.id,
                        now,
                        Stamp::Transferred { from: av.region, to: self.region, bytes },
                    );
                }
                CtxAccess::Recorded { fx, .. } => {
                    fx.push(Effect::MovedBytes { tier, bytes });
                    fx.push(Effect::Transferred {
                        av: av.id,
                        from: av.region,
                        to: self.region,
                        bytes,
                    });
                }
            }
        } else {
            match &mut self.access {
                CtxAccess::Direct(plat) => plat.metrics.moved(NetTier::Lan, bytes),
                CtxAccess::Recorded { fx, .. } => {
                    fx.push(Effect::MovedBytes { tier: NetTier::Lan, bytes })
                }
            }
        }
        self.cache.insert(av.object, bytes, self.combined, now);
        Ok(payload)
    }

    /// Out-of-band service lookup (§III-D), recorded for forensics.
    /// Services are live mutable state, so lookups require direct
    /// execution — on a worker this triggers the sequential fallback.
    /// Code that performs lookups should declare
    /// [`TaskCode::parallel_safe`] `= false`.
    pub fn lookup(&mut self, service: &str, query: &Payload) -> Result<Payload> {
        let plat = match &mut self.access {
            CtxAccess::Direct(plat) => plat,
            CtxAccess::Recorded { fx, .. } => {
                // poison survives a caught error (see EffectLog::poison)
                fx.poison();
                return Err(needs_sequential("TaskCtx::lookup"));
            }
        };
        let (resp, lat, version) = plat
            .services
            .lookup(service, query, plat.now)
            .ok_or_else(|| anyhow!("no service '{service}' registered"))?;
        self.cost += lat;
        plat.prov.checkpoint(
            self.task,
            self.run,
            plat.now,
            CheckpointEvent::ServiceLookup {
                service: service.to_string(),
                service_version: version,
                query: query.content_hash(),
                response: resp.content_hash(),
            },
        );
        Ok(resp)
    }

    /// Free-text checkpoint remark (fig. 9's `[remarked: ...]`).
    pub fn remark(&mut self, msg: &str) {
        match &mut self.access {
            CtxAccess::Direct(plat) => plat.prov.checkpoint(
                self.task,
                self.run,
                plat.now,
                CheckpointEvent::Remark(msg.to_string()),
            ),
            CtxAccess::Recorded { fx, .. } => {
                fx.push(Effect::Checkpoint(CheckpointEvent::Remark(msg.to_string())))
            }
        }
    }

    /// Anomaly note (fig. 9's `[anomalous CPU spike ...]`).
    pub fn anomaly(&mut self, msg: &str) {
        match &mut self.access {
            CtxAccess::Direct(plat) => {
                plat.metrics.bump("anomalies");
                plat.prov.checkpoint(
                    self.task,
                    self.run,
                    plat.now,
                    CheckpointEvent::Anomaly(msg.to_string()),
                );
            }
            CtxAccess::Recorded { fx, .. } => {
                fx.push(Effect::Bump("anomalies"));
                fx.push(Effect::Checkpoint(CheckpointEvent::Anomaly(msg.to_string())));
            }
        }
    }

    /// Charge extra simulated compute time.
    pub fn charge(&mut self, d: SimDuration) {
        self.cost += d;
    }
}

/// Result of asking an agent to execute a snapshot.
#[derive(Debug)]
pub enum RunOutcome {
    /// Executed user code (or routed a ghost batch). `emissions` carry
    /// pre-resolved [`WireId`]s — the coordinator publishes them without
    /// a single name lookup, then hands the buffer back to the agent for
    /// reuse ([`TaskAgent::recycle_emissions`], §Perf).
    Ran { run: RunId, emissions: Vec<Emission>, cost: SimDuration, ghost: bool },
    /// Memoized: identical recipe (inputs × version) already computed;
    /// cached output objects are reused without running anything. Outputs
    /// carry the interned [`WireId`] (§Perf): replaying a memo hit routes
    /// without touching a wire name at all. The publication defer is
    /// recorded too, so a replayed deferred emission trails the run
    /// exactly like the original did.
    Memoized { outputs: Vec<MemoOutput> },
}

/// One memoized output: interned wire, stored object identity, and the
/// publication defer the original emission carried.
pub type MemoOutput = (WireId, ObjectId, ContentHash, u64, DataClass, SimDuration);

/// A memo entry: what a past run produced, keyed by interned wire.
#[derive(Clone, Debug)]
struct MemoEntry {
    outputs: Vec<MemoOutput>,
}

/// One entry in a task's versioned code-slot history (§III-J): which
/// software version occupied the slot, since when, and why it got there.
/// Provenance stamps carry the version number; this is the task-side index
/// a breadboarder reads to correlate stamps with swaps.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CodeSlot {
    pub version: u32,
    pub installed_at: SimTime,
    /// How the code arrived: "deploy" | "plug" | "update".
    pub origin: String,
}

/// The deployed smart task: spec + policy engine + user code + caches +
/// the deploy-time-minted [`PortMap`] its code binds against.
pub struct TaskAgent {
    pub id: TaskId,
    pub spec: TaskSpec,
    pub region: RegionId,
    pub engine: SnapshotEngine,
    pub code: Box<dyn TaskCode>,
    pub notify: NotifyMode,
    pub cache: CacheManager,
    memo: FastMap<ContentHash, MemoEntry>,
    pub out_seq: u64,
    /// Last snapshot run (kept so a software update can selectively
    /// recompute — §III-J rollback).
    pub last_snapshot: Option<Snapshot>,
    pub runs: u64,
    /// Versioned code slots, oldest first (the current code is the last
    /// entry). Never empty after construction.
    pub code_history: Vec<CodeSlot>,
    /// Ports minted from the spec at deploy time; every code install
    /// binds against this table.
    pub ports: PortMap,
    /// Reusable emission buffer: taken for each run, drained by the
    /// coordinator, handed back — the steady state allocates no output
    /// Vec (§Perf).
    emit_buf: Vec<Emission>,
    /// Memoized legacy name→id resolutions (the [`LegacyCode`] path).
    name_cache: NameCache,
}

impl TaskAgent {
    /// Build the agent and install its initial code: ports are minted
    /// from `spec` against `wires`, and the code binds against them —
    /// a bind failure (unknown port name) rejects the deployment.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: TaskId,
        spec: TaskSpec,
        region: RegionId,
        engine: SnapshotEngine,
        mut code: Box<dyn TaskCode>,
        notify: NotifyMode,
        cache_policy: PurgePolicy,
        wires: &WireTable,
    ) -> Result<Self> {
        let ports = PortMap::mint(&spec, wires);
        code.bind(&Ports { map: &ports, wires, task: &spec.name })?;
        let initial = CodeSlot {
            version: code.version(),
            installed_at: SimTime::ZERO,
            origin: "deploy".to_string(),
        };
        Ok(Self {
            id,
            spec,
            region,
            engine,
            code,
            notify,
            cache: CacheManager::new(cache_policy),
            memo: FastMap::default(),
            out_seq: 0,
            last_snapshot: None,
            runs: 0,
            code_history: vec![initial],
            ports,
            emit_buf: Vec::new(),
            name_cache: NameCache::default(),
        })
    }

    /// Install new user code into the versioned slot; returns the version
    /// it displaced. `origin` records how it arrived ("plug", "update").
    /// The code binds against this task's minted ports first — on bind
    /// failure nothing changes and the previous code keeps running.
    pub fn install_code(
        &mut self,
        mut code: Box<dyn TaskCode>,
        wires: &WireTable,
        now: SimTime,
        origin: &str,
    ) -> Result<u32> {
        code.bind(&Ports { map: &self.ports, wires, task: &self.spec.name })?;
        let old = self.code.version();
        self.code_history.push(CodeSlot {
            version: code.version(),
            installed_at: now,
            origin: origin.to_string(),
        });
        self.code = code;
        Ok(old)
    }

    /// Hand the drained emission buffer back after a publish cycle so the
    /// next run reuses its capacity.
    pub fn recycle_emissions(&mut self, mut buf: Vec<Emission>) {
        buf.clear();
        if buf.capacity() > self.emit_buf.capacity() {
            self.emit_buf = buf;
        }
    }

    pub fn version(&self) -> u32 {
        self.code.version()
    }

    /// The memoization key for a snapshot under the current code version.
    pub fn recipe(&self, snapshot: &Snapshot) -> ContentHash {
        let hashes: Vec<ContentHash> = snapshot.all_avs().map(|a| a.content).collect();
        Platform::recipe_hash(&hashes, self.code.version())
    }

    /// Forget memoized results (software update invalidation).
    pub fn invalidate_memo(&mut self) {
        self.memo.clear();
    }

    pub fn memo_len(&self) -> usize {
        self.memo.len()
    }

    /// Would this snapshot be served from the memo (no execution needed)?
    pub fn would_memoize(&self, plat: &Platform, snapshot: &Snapshot) -> bool {
        !snapshot.ghost && self.memo_valid_in(&plat.store, self.recipe(snapshot))
    }

    /// Is `recipe` memoized with every cached output object still in
    /// `store`? Store-parameterized so wavefront workers can probe
    /// against the frozen read-only view.
    pub(crate) fn memo_valid_in(&self, store: &ObjectStore, recipe: ContentHash) -> bool {
        self.memo
            .get(&recipe)
            .is_some_and(|hit| hit.outputs.iter().all(|(_, obj, ..)| store.contains(*obj)))
    }

    /// Execute a snapshot (or reuse the memoized result). The coordinator
    /// publishes whatever comes back. `wires` is the pipeline's interner
    /// (legacy name-keyed emissions resolve against it, once per name).
    pub fn execute(
        &mut self,
        plat: &mut Platform,
        wires: &WireTable,
        snapshot: Snapshot,
    ) -> Result<RunOutcome> {
        self.execute_inner(plat, wires, snapshot, true, FireGuard::NONE)
    }

    /// [`execute`](Self::execute) under a supervision guard: the guard may
    /// inject a seeded fault before the code runs and enforces the
    /// policy's deadline budget against the firing's compute cost.
    pub(crate) fn execute_guarded(
        &mut self,
        plat: &mut Platform,
        wires: &WireTable,
        snapshot: Snapshot,
        guard: FireGuard,
    ) -> Result<RunOutcome> {
        self.execute_inner(plat, wires, snapshot, true, guard)
    }

    /// Execute ignoring the memo — what a schedule-driven, data-unaware
    /// runner (cron/Airflow baseline, E8) does: recompute regardless.
    pub fn execute_forced(
        &mut self,
        plat: &mut Platform,
        wires: &WireTable,
        snapshot: Snapshot,
    ) -> Result<RunOutcome> {
        self.execute_inner(plat, wires, snapshot, false, FireGuard::NONE)
    }

    fn execute_inner(
        &mut self,
        plat: &mut Platform,
        wires: &WireTable,
        snapshot: Snapshot,
        use_memo: bool,
        guard: FireGuard,
    ) -> Result<RunOutcome> {
        let recipe = self.recipe(&snapshot);
        if use_memo && !snapshot.ghost {
            if let Some(hit) = self.memo.get(&recipe) {
                if hit.outputs.iter().all(|(_, obj, ..)| plat.store.contains(*obj)) {
                    plat.metrics.bump("memo_hits");
                    self.last_snapshot = Some(snapshot);
                    return Ok(RunOutcome::Memoized { outputs: hit.outputs.clone() });
                }
            }
        }

        let run = plat.next_run_id();
        let ghost = snapshot.ghost;
        let mut consumed_bytes = 0u64;
        let version = self.code.version();
        for av in snapshot.all_avs() {
            plat.prov.stamp(
                av.id,
                plat.now,
                Stamp::Consumed { task: self.id, run, version },
            );
            consumed_bytes += av.size_bytes;
        }
        plat.prov.checkpoint_batch(
            self.id,
            run,
            plat.now,
            std::iter::once(CheckpointEvent::Start)
                .chain(snapshot.all_avs().map(|av| CheckpointEvent::ReadInput { av: av.id })),
        );

        let combined = snapshot.inputs.len() > 1;
        let mut buf = std::mem::take(&mut self.emit_buf);
        let cost = if ghost {
            // Wireframe batch: expose routing, skip compute (§III-K). One
            // ghost emission per declared port, pretending the usual size
            // — already id-resolved, no wire names minted (§Perf).
            let pretend = ghost_payload(consumed_bytes);
            for p in &self.ports.outs {
                buf.push(Emission {
                    wire: p.wire,
                    payload: pretend.clone(),
                    class: DataClass::Ghost,
                    defer: SimDuration::ZERO,
                });
            }
            SimDuration::micros(10)
        } else {
            // seeded fault injection happens where a real task failure
            // would: after the inputs are consumed and the Start /
            // ReadInput checkpoints land, before user code runs —
            // identical on the recorded (worker) path
            if let Some(e) = guard.injected_failure() {
                buf.clear();
                self.emit_buf = buf;
                return Err(e);
            }
            let mut ctx = TaskCtx {
                // explicit reborrow: `plat` is needed again after the run
                // for the End checkpoint and run accounting below
                access: CtxAccess::Direct(&mut *plat),
                cache: &mut self.cache,
                task: self.id,
                task_name: &self.spec.name,
                run,
                region: self.region,
                version: self.code.version(),
                ghost: false,
                combined,
                cost: SimDuration::ZERO,
            };
            let mut io = PortIo {
                inputs: Inputs { snapshot: &snapshot, map: &self.ports },
                emitter: Emitter {
                    buf: &mut buf,
                    map: &self.ports,
                    wires,
                    cache: &mut self.name_cache,
                    task: &self.spec.name,
                },
            };
            // a panicking plugin fails its own firing (recorded like any
            // task error), never the coordinator — identical treatment on
            // wavefront workers, so workers=1 and workers=N agree
            if let Err(e) = run_code_guarded(&mut self.code, &mut ctx, &mut io) {
                drop(io);
                buf.clear();
                self.emit_buf = buf;
                return Err(e);
            }
            let mut cost = ctx.cost;
            cost += self.code.compute_cost(consumed_bytes);
            if let Some(FaultKind::CostSpike(d)) = guard.fault {
                cost += d;
            }
            if let Some(budget) = guard.deadline {
                if cost > budget {
                    drop(io);
                    buf.clear();
                    self.emit_buf = buf;
                    return Err(deadline_error(cost, budget));
                }
            }
            cost
        };

        plat.prov.checkpoint(
            self.id,
            run,
            plat.now,
            CheckpointEvent::End { outputs: buf.len() as u32 },
        );
        plat.metrics.ran_task(ghost);
        self.runs += 1;
        self.last_snapshot = Some(snapshot);
        Ok(RunOutcome::Ran { run, emissions: buf, cost, ghost })
    }

    /// Record what a run produced so identical future recipes can skip it.
    /// The memo is bounded (streams never repeat, so an unbounded map is
    /// pure leak, §Perf): when full it is flushed — a cold rebuild costs
    /// one generation, like any cache restart.
    pub fn memoize(&mut self, recipe: ContentHash, outputs: Vec<MemoOutput>) {
        const MEMO_CAP: usize = 1024;
        if self.memo.len() >= MEMO_CAP {
            self.memo.clear();
        }
        self.memo.insert(recipe, MemoEntry { outputs });
    }

    /// Execute one snapshot on a wavefront worker thread: platform
    /// mutations go to an [`EffectLog`] (replayed at commit, in canonical
    /// order), agent-local state mutates live (this worker owns the agent
    /// exclusively for the wavefront). Line-for-line mirror of
    /// [`execute_inner`](Self::execute_inner)'s run path — the memo probe
    /// happened in the caller, which routes hits (and duplicate recipes)
    /// to the deferred/direct path instead.
    ///
    /// If the code touches a direct-only API (`lookup`, `platform`, …),
    /// the agent's caches are rolled back and the untouched snapshot is
    /// returned as [`PreparedFiring::Deferred`] for sequential re-run.
    pub(crate) fn execute_recorded(
        &mut self,
        world: &WorldView<'_>,
        wires: &WireTable,
        firing: Firing,
        recipe: ContentHash,
    ) -> PreparedFiring {
        let guard = firing.guard;
        let snapshot = &firing.snapshot;
        let mut fx = EffectLog::default();
        let ghost = snapshot.ghost;
        let version = self.code.version();
        let region = self.region;
        let born = snapshot.born;
        let parents: Vec<AvId> = snapshot.all_avs().map(|a| a.id).collect();
        let mut consumed_bytes = 0u64;
        for av in snapshot.all_avs() {
            fx.push(Effect::Consumed { av: av.id });
            consumed_bytes += av.size_bytes;
        }
        fx.push(Effect::Checkpoint(CheckpointEvent::Start));
        for av in snapshot.all_avs() {
            fx.push(Effect::Checkpoint(CheckpointEvent::ReadInput { av: av.id }));
        }

        let combined = snapshot.inputs.len() > 1;
        let mut buf = std::mem::take(&mut self.emit_buf);
        let cost = if ghost {
            let pretend = ghost_payload(consumed_bytes);
            for p in &self.ports.outs {
                buf.push(Emission {
                    wire: p.wire,
                    payload: pretend.clone(),
                    class: DataClass::Ghost,
                    defer: SimDuration::ZERO,
                });
            }
            SimDuration::micros(10)
        } else {
            // seeded fault injection: same point as the direct path —
            // after the Consumed / Start / ReadInput effects are taped,
            // before user code runs
            if let Some(e) = guard.injected_failure() {
                buf.clear();
                self.emit_buf = buf;
                return PreparedFiring::Recorded(RecordedRun {
                    recipe,
                    parents,
                    born,
                    version,
                    region,
                    fx,
                    body: Err(FireFail { error: e, firing }),
                });
            }
            // snapshot the agent caches: a needs-sequential fallback must
            // leave the agent exactly as the deferred re-run expects it
            let cache_save = self.cache.clone();
            let names_save = self.name_cache.clone();
            let run_result = {
                let mut ctx = TaskCtx {
                    access: CtxAccess::Recorded { world, fx: &mut fx },
                    cache: &mut self.cache,
                    task: self.id,
                    task_name: &self.spec.name,
                    run: RunId::new(u64::MAX), // drawn at commit
                    region,
                    version,
                    ghost: false,
                    combined,
                    cost: SimDuration::ZERO,
                };
                let mut io = PortIo {
                    inputs: Inputs { snapshot, map: &self.ports },
                    emitter: Emitter {
                        buf: &mut buf,
                        map: &self.ports,
                        wires,
                        cache: &mut self.name_cache,
                        task: &self.spec.name,
                    },
                };
                run_code_guarded(&mut self.code, &mut ctx, &mut io).map(|()| ctx.cost)
            };
            // a direct-only API was touched: roll back and defer, even if
            // the plugin caught the error and returned Ok — committing
            // the recorded result would diverge from workers=1
            if fx.needs_direct() {
                buf.clear();
                self.emit_buf = buf;
                self.cache = cache_save;
                self.name_cache = names_save;
                return PreparedFiring::Deferred(firing, DeferReason::Direct);
            }
            match run_result {
                Ok(run_cost) => {
                    let mut cost = run_cost + self.code.compute_cost(consumed_bytes);
                    if let Some(FaultKind::CostSpike(d)) = guard.fault {
                        cost += d;
                    }
                    if let Some(budget) = guard.deadline {
                        if cost > budget {
                            buf.clear();
                            self.emit_buf = buf;
                            return PreparedFiring::Recorded(RecordedRun {
                                recipe,
                                parents,
                                born,
                                version,
                                region,
                                fx,
                                body: Err(FireFail {
                                    error: deadline_error(cost, budget),
                                    firing,
                                }),
                            });
                        }
                    }
                    cost
                }
                // Defensive only: every in-ctx producer of the
                // needs-sequential error poisons the log first, so the
                // needs_direct() check above already deferred. This arm
                // catches the error arriving from OUTSIDE this ctx (a
                // plugin propagating one it stored from another run, or
                // manufacturing the marker) — defer rather than commit a
                // result the author flagged as direct-only.
                Err(e) if is_needs_sequential(&e) => {
                    buf.clear();
                    self.emit_buf = buf;
                    self.cache = cache_save;
                    self.name_cache = names_save;
                    return PreparedFiring::Deferred(firing, DeferReason::Direct);
                }
                Err(e) => {
                    buf.clear();
                    self.emit_buf = buf;
                    return PreparedFiring::Recorded(RecordedRun {
                        recipe,
                        parents,
                        born,
                        version,
                        region,
                        fx,
                        body: Err(FireFail { error: e, firing }),
                    });
                }
            }
        };

        fx.push(Effect::Checkpoint(CheckpointEvent::End { outputs: buf.len() as u32 }));
        fx.push(Effect::RanTask { ghost });
        self.runs += 1;
        self.last_snapshot = Some(firing.snapshot);
        // absorb the publish-side payload hashing here, off the
        // sequential commit path (§Perf)
        let hashes: Vec<ContentHash> = buf.iter().map(|e| e.payload.content_hash()).collect();
        PreparedFiring::Recorded(RecordedRun {
            recipe,
            parents,
            born,
            version,
            region,
            fx,
            body: Ok(RecordedBody { emissions: buf, hashes, cost, ghost }),
        })
    }
}

/// Run plugin code, converting a panic into a task error so one firing's
/// crash never takes down the coordinator (or a wavefront worker). Both
/// execution modes route through here, so panic handling cannot diverge
/// between `workers = 1` and `workers = N`.
fn run_code_guarded(
    code: &mut Box<dyn TaskCode>,
    ctx: &mut TaskCtx<'_>,
    io: &mut PortIo<'_>,
) -> Result<()> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| code.run(ctx, io))) {
        Ok(r) => r,
        Err(payload) => {
            let msg = if let Some(s) = payload.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "non-string panic payload".to_string()
            };
            Err(anyhow!("task panicked: {msg}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::builtins::PassThrough;
    use super::*;
    use crate::net::demo_topology;
    use crate::policy::{BufferSpec, InputBuffer, RateControl, SnapshotPolicy};
    use crate::storage::StorageConfig;
    use crate::util::LinkId;

    fn plat() -> Platform {
        Platform::new(demo_topology(1), StorageConfig::default(), 3)
    }

    fn wires() -> WireTable {
        let spec = crate::spec::parse("(x) t (y)").unwrap();
        crate::graph::PipelineGraph::build(&spec).wires
    }

    fn agent(plat: &mut Platform, wires: &WireTable) -> TaskAgent {
        let spec = crate::spec::parse("(x) t (y)").unwrap().tasks[0].clone();
        let engine = SnapshotEngine::new(
            SnapshotPolicy::AllNew,
            vec![InputBuffer::new("x", BufferSpec::default())],
            RateControl::default(),
        );
        let _ = plat;
        TaskAgent::new(
            TaskId::new(0),
            spec,
            RegionId::new(0),
            engine,
            Box::new(PassThrough::new("y")),
            NotifyMode::Push,
            PurgePolicy::Never,
            wires,
        )
        .unwrap()
    }

    fn feed(plat: &mut Platform, agent: &mut TaskAgent, value: f32) -> Snapshot {
        let (av, _) = plat.mint_av(
            Payload::scalar(value),
            TaskId::new(9),
            RunId::new(99),
            1,
            LinkId::new(0),
            RegionId::new(0),
            DataClass::Summary,
            0,
            &[],
            plat.now,
        );
        agent.engine.push("x", av);
        agent.engine.take(plat.now).unwrap()
    }

    #[test]
    fn execute_runs_user_code_and_stamps() {
        let mut p = plat();
        let w = wires();
        let mut a = agent(&mut p, &w);
        let snap = feed(&mut p, &mut a, 5.0);
        let outcome = a.execute(&mut p, &w, snap).unwrap();
        match outcome {
            RunOutcome::Ran { emissions, cost, ghost, .. } => {
                assert_eq!(emissions.len(), 1);
                assert_eq!(emissions[0].wire, w.id("y").unwrap(), "pre-resolved emission");
                assert!(!ghost);
                assert!(cost.as_micros() > 0);
            }
            _ => panic!("expected Ran"),
        }
        assert_eq!(p.metrics.task_runs, 1);
        let log = p.prov.checkpoint_log(TaskId::new(0));
        assert!(log.iter().any(|e| matches!(e.event, CheckpointEvent::Start)));
        assert!(log.iter().any(|e| matches!(e.event, CheckpointEvent::ReadInput { .. })));
        assert!(log.iter().any(|e| matches!(e.event, CheckpointEvent::End { .. })));
    }

    #[test]
    fn memoization_skips_identical_recipes() {
        let mut p = plat();
        let w = wires();
        let mut a = agent(&mut p, &w);
        let s1 = feed(&mut p, &mut a, 5.0);
        let recipe = a.recipe(&s1);
        match a.execute(&mut p, &w, s1).unwrap() {
            RunOutcome::Ran { emissions, .. } => {
                // pretend the coordinator stored outputs and memoized
                let (av, _) = p.mint_av(
                    emissions[0].payload.clone(),
                    TaskId::new(0),
                    RunId::new(0),
                    1,
                    LinkId::new(1),
                    RegionId::new(0),
                    emissions[0].class,
                    0,
                    &[],
                    p.now,
                );
                a.memoize(
                    recipe,
                    vec![(
                        WireId::new(0),
                        av.object,
                        av.content,
                        av.size_bytes,
                        av.class,
                        SimDuration::ZERO,
                    )],
                );
            }
            _ => panic!(),
        }
        // identical content again -> memoized, no new task run
        let s2 = feed(&mut p, &mut a, 5.0);
        let runs_before = p.metrics.task_runs;
        match a.execute(&mut p, &w, s2).unwrap() {
            RunOutcome::Memoized { outputs } => assert_eq!(outputs[0].0, WireId::new(0)),
            _ => panic!("expected memo hit"),
        }
        assert_eq!(p.metrics.task_runs, runs_before);
        assert_eq!(p.metrics.get("memo_hits"), 1);
        // different content -> fresh run
        let s3 = feed(&mut p, &mut a, 6.0);
        assert!(matches!(a.execute(&mut p, &w, s3).unwrap(), RunOutcome::Ran { .. }));
    }

    #[test]
    fn version_bump_changes_recipe() {
        let mut p = plat();
        let w = wires();
        let mut a = agent(&mut p, &w);
        let s = feed(&mut p, &mut a, 5.0);
        let r1 = a.recipe(&s);
        // a legacy UserCode v2, installed through the adapter
        struct V2;
        impl UserCode for V2 {
            fn version(&self) -> u32 {
                2
            }
            fn run(&mut self, ctx: &mut TaskCtx<'_>, s: &Snapshot) -> Result<Vec<Output>> {
                let mut outs = Vec::new();
                for av in s.all_avs() {
                    outs.push(Output::new("y", ctx.fetch(av)?, av.class));
                }
                Ok(outs)
            }
        }
        a.install_code(legacy(V2), &w, p.now, "update").unwrap();
        assert_ne!(a.recipe(&s), r1, "new software version => stale recipe");
    }

    #[test]
    fn ghost_snapshot_routes_without_compute() {
        let mut p = plat();
        let w = wires();
        let mut a = agent(&mut p, &w);
        let (mut av, _) = p.mint_av(
            Payload::Ghost { pretend_bytes: 1 << 20 },
            TaskId::new(9),
            RunId::new(99),
            1,
            LinkId::new(0),
            RegionId::new(0),
            DataClass::Ghost,
            0,
            &[],
            p.now,
        );
        av.ghost = true;
        a.engine.push("x", av);
        let snap = a.engine.take(p.now).unwrap();
        match a.execute(&mut p, &w, snap).unwrap() {
            RunOutcome::Ran { emissions, ghost, .. } => {
                assert!(ghost);
                assert!(emissions[0].payload.is_ghost());
                assert_eq!(emissions[0].wire, w.id("y").unwrap(), "ghosts ride ports too");
            }
            _ => panic!(),
        }
        assert_eq!(p.metrics.ghost_runs, 1);
        assert_eq!(p.metrics.task_runs, 0, "no real run happened");
    }

    #[test]
    fn fetch_uses_cache_on_second_read() {
        let mut p = plat();
        let w = wires();
        let mut a = agent(&mut p, &w);
        let (av, _) = p.mint_av(
            Payload::tensor(&[4], vec![1.0; 4]),
            TaskId::new(9),
            RunId::new(99),
            1,
            LinkId::new(0),
            RegionId::new(0),
            DataClass::Summary,
            0,
            &[],
            p.now,
        );
        let mut ctx = TaskCtx {
            access: CtxAccess::Direct(&mut p),
            cache: &mut a.cache,
            task: TaskId::new(0),
            task_name: "t",
            run: RunId::new(1),
            region: RegionId::new(0),
            version: 1,
            ghost: false,
            combined: false,
            cost: SimDuration::ZERO,
        };
        let p1 = ctx.fetch(&av).unwrap();
        let cost_after_miss = ctx.cost;
        let p2 = ctx.fetch(&av).unwrap();
        assert_eq!(p1, p2);
        let hit_cost = ctx.cost.as_micros() - cost_after_miss.as_micros();
        assert!(hit_cost < cost_after_miss.as_micros(), "hit far cheaper than miss");
        let plat = ctx.platform().unwrap();
        assert_eq!(plat.metrics.cache_hits, 1);
        assert_eq!(plat.metrics.cache_misses, 1);
    }

    #[test]
    fn recorded_fetch_mirrors_direct_fetch() {
        // same object fetched under a direct ctx and a recording ctx:
        // payload, cost and cache movement must agree, and applying the
        // recorded log must land the identical platform deltas
        let mk_av = |p: &mut Platform| {
            let (av, _) = p.mint_av(
                Payload::tensor(&[8], vec![2.0; 8]),
                TaskId::new(9),
                RunId::new(99),
                1,
                LinkId::new(0),
                RegionId::new(0),
                DataClass::Summary,
                0,
                &[],
                p.now,
            );
            av
        };
        // direct arm
        let mut pd = plat();
        let avd = mk_av(&mut pd);
        let mut cache_d = CacheManager::new(PurgePolicy::Never);
        let mut ctx = TaskCtx {
            access: CtxAccess::Direct(&mut pd),
            cache: &mut cache_d,
            task: TaskId::new(0),
            task_name: "t",
            run: RunId::new(1),
            region: RegionId::new(0),
            version: 1,
            ghost: false,
            combined: false,
            cost: SimDuration::ZERO,
        };
        let pay_d = ctx.fetch(&avd).unwrap();
        let cost_d = ctx.cost;
        drop(ctx);
        // recorded arm (fresh platform, identical history)
        let mut pr = plat();
        let avr = mk_av(&mut pr);
        let mut cache_r = CacheManager::new(PurgePolicy::Never);
        let mut fx = EffectLog::default();
        {
            let world = WorldView { store: &pr.store, net: &pr.net, now: pr.now };
            let mut ctx = TaskCtx {
                access: CtxAccess::Recorded { world: &world, fx: &mut fx },
                cache: &mut cache_r,
                task: TaskId::new(0),
                task_name: "t",
                run: RunId::new(u64::MAX),
                region: RegionId::new(0),
                version: 1,
                ghost: false,
                combined: false,
                cost: SimDuration::ZERO,
            };
            let pay_r = ctx.fetch(&avr).unwrap();
            assert_eq!(pay_r, pay_d, "identical payload either way");
            assert_eq!(ctx.cost, cost_d, "identical virtual cost either way");
            // direct-only APIs signal the sequential fallback
            let e = ctx.lookup("dns", &Payload::scalar(0.0)).unwrap_err();
            assert!(is_needs_sequential(&e), "{e}");
            assert!(ctx.platform().is_err());
        }
        fx.apply(&mut pr, TaskId::new(0), RunId::new(1), 1, RegionId::new(0));
        assert_eq!(pr.metrics.cache_misses, pd.metrics.cache_misses);
        assert_eq!(pr.metrics.cache_hits, pd.metrics.cache_hits);
        assert_eq!(pr.store.gets, pd.store.gets, "storage read accounting replayed");
        assert_eq!(
            pr.metrics.bytes(NetTier::Lan),
            pd.metrics.bytes(NetTier::Lan),
            "bytes-moved accounting replayed"
        );
        assert_eq!(cache_r.len(), cache_d.len(), "dependent-local cache state agrees");
    }
}
