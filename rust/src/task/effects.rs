//! Recorded platform effects — the worker-side half of the parallel
//! wavefront scheduler (DESIGN.md §Perf notes).
//!
//! When task firings execute on wavefront worker threads, they must not
//! touch the shared [`Platform`]: the provenance registry, metrics sink
//! and storage counters are single-writer state whose *mutation order*
//! the byte-identical-provenance contract pins to sequential execution.
//! Instead, the recording [`TaskCtx`](super::TaskCtx) writes every
//! would-be mutation into an [`EffectLog`] — in exactly the order the
//! direct (`workers = 1`) path would have performed it — and the
//! coordinator's deterministic commit replays the log with full platform
//! access, in canonical task-index order. Per-registry mutation order is
//! therefore identical to sequential execution; the seq-vs-par property
//! test (`rust/tests/wavefront_determinism.rs`) checks the mirror.
//!
//! Run ids are *not* known on the worker (they are drawn from the shared
//! dispenser at commit, in canonical order, so `workers = 4` allocates
//! the same ids as `workers = 1`); effects that reference the run carry
//! only their payload here and are stamped with the real id at
//! [`EffectLog::apply`] time.

use crate::av::Payload;
use crate::fault::Firing;
use crate::obs::NetTier;
use crate::net::WanTopology;
use crate::platform::Platform;
use crate::provenance::{CheckpointEvent, Stamp};
use crate::storage::ObjectStore;
use crate::task::Emission;
use crate::util::{AvId, ContentHash, ObjectId, RegionId, RunId, SimDuration, SimTime, TaskId};

/// The read-only world a wavefront worker executes against: committed
/// storage, the WAN topology, and the frozen virtual instant. Everything
/// here is `Sync` by construction — no interior mutability crosses the
/// thread boundary.
pub(crate) struct WorldView<'a> {
    pub store: &'a ObjectStore,
    pub net: &'a WanTopology,
    pub now: SimTime,
}

/// One deferred platform mutation, recorded in execution order.
pub(crate) enum Effect {
    /// `Stamp::Consumed` on an input AV (run id filled at commit).
    Consumed { av: AvId },
    /// Checkpoint-log entry (run id filled at commit).
    Checkpoint(CheckpointEvent),
    /// `Stamp::CacheServed` after a dependent-local cache hit.
    CacheServed { av: AvId },
    /// Bytes-moved accounting for a fetch (LAN or WAN tier).
    MovedBytes { tier: NetTier, bytes: u64 },
    /// `Stamp::Transferred` after a cross-region fetch.
    Transferred { av: AvId, from: RegionId, to: RegionId, bytes: u64 },
    /// Storage read accounting: the `gets` counter always moves (the
    /// direct path bumps it before discovering a missing object); the
    /// latency histogram records only on a successful read.
    StoreGet { object: ObjectId, lat: Option<SimDuration> },
    CacheHit,
    CacheMiss,
    /// Named metrics counter bump (`anomalies`, …).
    Bump(&'static str),
    /// `Metrics::ran_task` at the end of a successful run.
    RanTask { ghost: bool },
}

/// The ordered mutation tape of one recorded firing.
#[derive(Default)]
pub(crate) struct EffectLog {
    effects: Vec<Effect>,
    /// Set the moment a recording context refuses a direct-only API
    /// (lookup / platform / update_service). Checked *after* the run
    /// returns, independently of the run's Result: user code that
    /// catches the needs-sequential error and carries on (e.g.
    /// `ctx.lookup(..).unwrap_or(default)`) would otherwise commit a
    /// divergent recorded result — the poison guarantees the firing is
    /// rolled back and re-run sequentially instead.
    needs_direct: bool,
}

impl EffectLog {
    #[inline]
    pub(crate) fn push(&mut self, e: Effect) {
        self.effects.push(e);
    }

    /// Mark this recording as requiring direct execution (see field doc).
    pub(crate) fn poison(&mut self) {
        self.needs_direct = true;
    }

    pub(crate) fn needs_direct(&self) -> bool {
        self.needs_direct
    }

    /// Replay the tape against the live platform — the commit half.
    /// `run` is the id the commit drew for this firing; `version` and
    /// `region` were captured when the worker executed.
    pub(crate) fn apply(
        self,
        plat: &mut Platform,
        task: TaskId,
        run: RunId,
        version: u32,
        region: RegionId,
    ) {
        let now = plat.now;
        for e in self.effects {
            match e {
                Effect::Consumed { av } => {
                    plat.prov.stamp(av, now, Stamp::Consumed { task, run, version });
                }
                Effect::Checkpoint(event) => plat.prov.checkpoint(task, run, now, event),
                Effect::CacheServed { av } => {
                    plat.prov.stamp(av, now, Stamp::CacheServed { region });
                }
                Effect::MovedBytes { tier, bytes } => plat.metrics.moved(tier, bytes),
                Effect::Transferred { av, from, to, bytes } => {
                    plat.prov.stamp(av, now, Stamp::Transferred { from, to, bytes });
                }
                Effect::StoreGet { object, lat } => {
                    plat.store.record_get(object);
                    if let Some(lat) = lat {
                        plat.metrics.storage_latency.record(lat);
                    }
                }
                Effect::CacheHit => plat.metrics.cache_hits += 1,
                Effect::CacheMiss => plat.metrics.cache_misses += 1,
                Effect::Bump(key) => plat.metrics.bump(key),
                Effect::RanTask { ghost } => plat.metrics.ran_task(ghost),
            }
        }
    }
}

/// Why a firing skipped (or abandoned) the worker pool. Carried on
/// [`PreparedFiring::Deferred`] so the commit phase can tell the flight
/// recorder *which* scheduling story happened — the reasons are spans
/// (`deferred-sequential` / `rollback-rerun`) and wavefront counters, not
/// behavior: every reason resolves through the identical `workers = 1`
/// path.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum DeferReason {
    /// Code declares `parallel_safe() == false`: never attempted on a
    /// worker.
    Sequential,
    /// Memo hit or duplicate recipe within the wavefront: the commit
    /// phase re-probes and resolves it (usually as a memo republish).
    MemoHit,
    /// A worker execution touched a direct-only API and was rolled back
    /// (needs-sequential sentinel or poisoned effect log).
    Direct,
}

/// What the wavefront scheduler gets back for one firing.
pub(crate) enum PreparedFiring {
    /// Execute at commit with direct platform access: memo hits,
    /// duplicate recipes within the wavefront (the earlier firing's
    /// memoization must land first), code declared `parallel_safe() ==
    /// false`, and sentinel fallbacks all take this path — it is exactly
    /// the `workers = 1` path, so deferral is always behavior-preserving.
    Deferred(Firing, DeferReason),
    /// Executed on a worker: commit replays the effect tape, then
    /// publishes the emissions.
    Recorded(RecordedRun),
}

/// A failed recorded attempt: the error plus the whole supervised firing
/// (snapshot pinned) so the commit-side supervision can retry,
/// dead-letter, quarantine, or degrade it.
pub(crate) struct FireFail {
    pub error: anyhow::Error,
    pub firing: Firing,
}

/// A worker-executed firing, ready to commit.
pub(crate) struct RecordedRun {
    pub recipe: ContentHash,
    pub parents: Vec<AvId>,
    pub born: SimTime,
    pub version: u32,
    pub region: RegionId,
    pub fx: EffectLog,
    /// `Err` is a task error (including caught panics): commit replays
    /// the partial tape — the direct path records those effects before
    /// erroring too — then hands the failed firing to the supervision
    /// machinery (retry / dead-letter / quarantine / degrade).
    pub body: std::result::Result<RecordedBody, FireFail>,
}

/// The successful half of a recorded run.
pub(crate) struct RecordedBody {
    pub emissions: Vec<Emission>,
    /// Payload content hashes, one per emission, computed on the worker
    /// so the sequential commit never hashes a payload (§Perf).
    pub hashes: Vec<ContentHash>,
    pub cost: SimDuration,
    pub ghost: bool,
}

/// Marker embedded in the error a recording context returns for
/// operations that need the live platform (service lookups, service
/// updates, raw platform access). The scheduler detects it, rolls the
/// agent back, and re-runs the firing in the deterministic commit phase
/// with direct access. Detection is by message (the vendored `anyhow`
/// shim flattens errors to strings), so context-wrapping the error does
/// not defeat the fallback.
pub(crate) const NEEDS_SEQUENTIAL: &str = "koalja::needs-sequential";

pub(crate) fn needs_sequential(op: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "{NEEDS_SEQUENTIAL}: {op} requires direct platform access; the firing will be \
         re-run in the deterministic commit phase (implement parallel_safe() = false on \
         the task code to skip the parallel attempt entirely)"
    )
}

pub(crate) fn is_needs_sequential(e: &anyhow::Error) -> bool {
    e.to_string().contains(NEEDS_SEQUENTIAL)
}

/// The ghost-emission payload helper shared by the direct and recorded
/// ghost paths (one pretend-sized emission per declared output port).
pub(crate) fn ghost_payload(consumed_bytes: u64) -> Payload {
    Payload::Ghost { pretend_bytes: consumed_bytes.max(1) }
}
