//! The shared platform state every agent operates against: storage, bus,
//! provenance, metrics, cluster, WAN topology, workspaces, services, clock.
//!
//! One `Platform` per deployment. Agents receive `&mut Platform`; the
//! coordinator owns it alongside the agent vectors (split borrows).

pub mod service;

pub use service::{RecordedLookup, Service, ServiceDirectory};

use crate::av::{AnnotatedValue, DataClass, Payload};
use crate::bus::Bus;
use crate::cluster::{Cluster, ScalePolicy};
use crate::obs::Metrics;
use crate::net::WanTopology;
use crate::provenance::{ProvenanceRegistry, Stamp};
use crate::storage::{ObjectStore, StorageConfig, StorageTier};
use crate::util::{AvId, ContentHash, IdGen, LinkId, RegionId, Rng, RunId, SimTime, TaskId};
use crate::workspace::WorkspaceRegistry;

/// Where payloads are put by default (the paper bets on network-attached
/// storage, §III-F — "we choose to place our money on the network attached
/// storage"). `HostLocal` is the contrarian strategy the ρ sweep compares.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementStrategy {
    NetworkAttached,
    HostLocal,
}

/// The assembled world.
pub struct Platform {
    pub now: SimTime,
    pub store: ObjectStore,
    pub bus: Bus,
    pub prov: ProvenanceRegistry,
    pub metrics: Metrics,
    pub cluster: Cluster,
    pub net: WanTopology,
    pub workspaces: WorkspaceRegistry,
    pub services: ServiceDirectory,
    pub rng: Rng,
    pub storage_placement: PlacementStrategy,
    av_ids: IdGen,
    run_ids: IdGen,
}

impl Platform {
    pub fn new(net: WanTopology, storage: StorageConfig, seed: u64) -> Self {
        Self {
            now: SimTime::ZERO,
            store: ObjectStore::new(storage),
            bus: Bus::new(),
            prov: ProvenanceRegistry::new(),
            metrics: Metrics::new(),
            cluster: Cluster::new(ScalePolicy::default()),
            net,
            workspaces: WorkspaceRegistry::new(),
            services: ServiceDirectory::new(),
            rng: Rng::seed_from_u64(seed),
            storage_placement: PlacementStrategy::NetworkAttached,
            av_ids: IdGen::new(),
            run_ids: IdGen::new(),
        }
    }

    pub fn next_av_id(&mut self) -> AvId {
        AvId::new(self.av_ids.next_raw())
    }

    pub fn next_run_id(&mut self) -> RunId {
        RunId::new(self.run_ids.next_raw())
    }

    pub fn storage_tier(&self) -> StorageTier {
        match self.storage_placement {
            PlacementStrategy::NetworkAttached => StorageTier::ObjectStore,
            PlacementStrategy::HostLocal => StorageTier::HostLocal,
        }
    }

    /// Store a payload and mint the AV that points at it — the "annotated
    /// value" handover of §III-I. Returns (av, storage latency charged).
    #[allow(clippy::too_many_arguments)]
    pub fn mint_av(
        &mut self,
        payload: Payload,
        source_task: TaskId,
        run: RunId,
        version: u32,
        link: LinkId,
        region: RegionId,
        class: DataClass,
        seq: u64,
        parents: &[AvId],
        born: SimTime,
    ) -> (AnnotatedValue, crate::util::SimDuration) {
        let content = payload.content_hash();
        self.mint_av_prehashed(
            payload, content, source_task, run, version, link, region, class, seq, parents, born,
        )
    }

    /// [`mint_av`](Self::mint_av) with the payload's content hash already
    /// computed — wavefront workers hash emissions off the commit path,
    /// so the sequential commit only stores and stamps (§Perf). `content`
    /// must be `payload.content_hash()`; passing anything else corrupts
    /// make-style staleness detection.
    #[allow(clippy::too_many_arguments)]
    pub fn mint_av_prehashed(
        &mut self,
        payload: Payload,
        content: crate::util::ContentHash,
        source_task: TaskId,
        run: RunId,
        version: u32,
        link: LinkId,
        region: RegionId,
        class: DataClass,
        seq: u64,
        parents: &[AvId],
        born: SimTime,
    ) -> (AnnotatedValue, crate::util::SimDuration) {
        let ghost = payload.is_ghost();
        let size_bytes = payload.size_bytes();
        let tier = self.storage_tier();
        let (object, lat) = self.store.put_prehashed(payload, content, region, tier, class, self.now);
        let av = AnnotatedValue {
            id: self.next_av_id(),
            source_task,
            link,
            object,
            region,
            created: self.now,
            seq,
            size_bytes,
            content,
            class,
            ghost,
            born,
        };
        self.prov.birth(
            av.id,
            parents,
            self.now,
            Stamp::Emitted { task: source_task, run, version, region },
        );
        // av → object index: swap previews resolve stale artifacts to the
        // cached intermediates they occupy (breadboard dry-run)
        self.prov.register_object(av.id, object, size_bytes);
        (av, lat)
    }

    /// Recipe hash for memoization: fold input content hashes (port order)
    /// with the software version — the Makefile staleness rule of §III-B/J.
    pub fn recipe_hash(inputs: &[ContentHash], version: u32) -> ContentHash {
        let mut h = ContentHash(version as u64 ^ 0x9E37_79B9_7F4A_7C15);
        for i in inputs {
            h = h.combine(*i);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::demo_topology;

    fn plat() -> Platform {
        Platform::new(demo_topology(2), StorageConfig::default(), 1)
    }

    #[test]
    fn mint_av_stores_and_stamps() {
        let mut p = plat();
        let (av, lat) = p.mint_av(
            Payload::scalar(1.0),
            TaskId::new(0),
            RunId::new(0),
            1,
            LinkId::new(0),
            RegionId::new(0),
            DataClass::Summary,
            0,
            &[],
            SimTime::ZERO,
        );
        assert!(lat.as_micros() > 0);
        assert!(p.store.contains(av.object));
        let passport = p.prov.passport(av.id).unwrap();
        assert_eq!(passport.stamps.len(), 1);
        assert_eq!(av.size_bytes, 4);
    }

    #[test]
    fn ids_are_unique() {
        let mut p = plat();
        let a = p.next_av_id();
        let b = p.next_av_id();
        assert_ne!(a, b);
        assert_ne!(p.next_run_id(), p.next_run_id());
    }

    #[test]
    fn recipe_hash_sensitive_to_version_and_inputs() {
        let i1 = ContentHash::of_str("x");
        let i2 = ContentHash::of_str("y");
        let base = Platform::recipe_hash(&[i1, i2], 1);
        assert_ne!(base, Platform::recipe_hash(&[i1, i2], 2), "version matters");
        assert_ne!(base, Platform::recipe_hash(&[i2, i1], 1), "order matters");
        assert_eq!(base, Platform::recipe_hash(&[i1, i2], 1), "deterministic");
    }

    #[test]
    fn placement_picks_tier() {
        let mut p = plat();
        assert_eq!(p.storage_tier(), StorageTier::ObjectStore);
        p.storage_placement = PlacementStrategy::HostLocal;
        assert_eq!(p.storage_tier(), StorageTier::HostLocal);
    }
}
