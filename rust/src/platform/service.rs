//! Out-of-band services — §III-D.
//!
//! "client-server interactions for address lookups, database queries, and
//! more, are an essential ingredient in every data pipeline ... A sudden
//! change of address or database revision might alter the course of
//! pipeline artifacts radically. So it is very much in the interests of
//! forensic traceability to incorporate knowledge of these lookups into a
//! pipeline process."
//!
//! A [`Service`] is a mutable external dependency (DNS, a database, a
//! deployed model). Every call through the directory is *recorded*: query
//! hash, response hash, service version — so outcomes can be traced back
//! through lookups, and responses can be replayed forensically.

use crate::av::Payload;
use crate::util::{ContentHash, SimDuration, SimTime};
use std::collections::HashMap;

/// A mutable exterior dependency.
pub trait Service {
    /// Version of the service's state (bumped on every mutation) — what
    /// the paper wants captured: "which versions were involved?".
    fn version(&self) -> u32;
    /// Answer a query. May be stateful.
    fn call(&mut self, query: &Payload) -> Payload;
    /// Simulated round-trip cost of one lookup.
    fn latency(&self) -> SimDuration {
        SimDuration::micros(300)
    }

    /// Push new state into the service (e.g. deploy fresh model
    /// parameters). Implementations that accept it must bump `version()`.
    /// Default: not supported.
    fn update_payload(&mut self, _p: &Payload) -> bool {
        false
    }
}

/// One recorded lookup (the forensic cache of §III-D).
#[derive(Clone, Debug)]
pub struct RecordedLookup {
    pub time: SimTime,
    pub service: String,
    pub service_version: u32,
    pub query: ContentHash,
    pub response: ContentHash,
    /// The cached response itself ("cache the response for forensic
    /// traceability") — replayable.
    pub response_payload: Payload,
}

/// Registry of named services + the forensic lookup log.
#[derive(Default)]
pub struct ServiceDirectory {
    services: HashMap<String, Box<dyn Service>>,
    pub lookups: Vec<RecordedLookup>,
}

impl ServiceDirectory {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, name: &str, svc: Box<dyn Service>) {
        self.services.insert(name.to_string(), svc);
    }

    pub fn contains(&self, name: &str) -> bool {
        self.services.contains_key(name)
    }

    pub fn version(&self, name: &str) -> Option<u32> {
        self.services.get(name).map(|s| s.version())
    }

    /// Perform + record a lookup. Returns (response, latency, version).
    pub fn lookup(
        &mut self,
        name: &str,
        query: &Payload,
        now: SimTime,
    ) -> Option<(Payload, SimDuration, u32)> {
        let svc = self.services.get_mut(name)?;
        let response = svc.call(query);
        let version = svc.version();
        let latency = svc.latency();
        self.lookups.push(RecordedLookup {
            time: now,
            service: name.to_string(),
            service_version: version,
            query: query.content_hash(),
            response: response.content_hash(),
            response_payload: response.clone(),
        });
        Some((response, latency, version))
    }

    /// Mutate a service through the directory (e.g. deploy a new model).
    pub fn update<F: FnOnce(&mut dyn Service)>(&mut self, name: &str, f: F) -> bool {
        match self.services.get_mut(name) {
            Some(s) => {
                f(s.as_mut());
                true
            }
            None => false,
        }
    }

    /// Replay: the recorded response for a (service, query) pair, newest
    /// first — forensic reconstruction without re-contacting the mutable
    /// source.
    pub fn replay(&self, service: &str, query: ContentHash) -> Option<&RecordedLookup> {
        self.lookups.iter().rev().find(|l| l.service == service && l.query == query)
    }
}

/// A simple key-value service (DNS-like) whose contents can be mutated —
/// the paper's canonical mutable-external-source example.
pub struct KvService {
    pub table: HashMap<String, String>,
    pub version: u32,
}

impl KvService {
    pub fn new(entries: &[(&str, &str)]) -> Self {
        Self {
            table: entries.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            version: 1,
        }
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.table.insert(key.to_string(), value.to_string());
        self.version += 1;
    }
}

impl Service for KvService {
    fn version(&self) -> u32 {
        self.version
    }

    fn call(&mut self, query: &Payload) -> Payload {
        let key = match query {
            Payload::Text(s) => s.as_str(),
            _ => return Payload::Text("ERR:non-text-query".into()),
        };
        match self.table.get(key) {
            Some(v) => Payload::Text(v.clone()),
            None => Payload::Text(format!("NXDOMAIN:{key}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_records_and_replays() {
        let mut dir = ServiceDirectory::new();
        dir.register("dns", Box::new(KvService::new(&[("db", "10.0.0.5")])));
        let q = Payload::Text("db".into());
        let (resp, lat, v) = dir.lookup("dns", &q, SimTime::ZERO).unwrap();
        assert_eq!(resp, Payload::Text("10.0.0.5".into()));
        assert!(lat.as_micros() > 0);
        assert_eq!(v, 1);
        // forensic replay finds the cached response
        let rec = dir.replay("dns", q.content_hash()).unwrap();
        assert_eq!(rec.response_payload, Payload::Text("10.0.0.5".into()));
    }

    #[test]
    fn version_changes_are_visible() {
        let mut dir = ServiceDirectory::new();
        dir.register("dns", Box::new(KvService::new(&[("db", "10.0.0.5")])));
        dir.update("dns", |s| {
            // downcast-free mutation isn't possible through dyn Service;
            // version bump is modelled by re-registering in callers. Here
            // we just verify update reaches the service.
            let _ = s.version();
        });
        dir.register("dns", Box::new(KvService::new(&[("db", "10.9.9.9")])));
        let q = Payload::Text("db".into());
        let (resp, _, _) = dir.lookup("dns", &q, SimTime::millis(1)).unwrap();
        assert_eq!(resp, Payload::Text("10.9.9.9".into()));
        // both lookups recorded, newest replayed first
        assert_eq!(dir.lookups.len(), 1);
    }

    #[test]
    fn missing_service_is_none() {
        let mut dir = ServiceDirectory::new();
        assert!(dir.lookup("nope", &Payload::scalar(0.0), SimTime::ZERO).is_none());
        assert!(!dir.contains("nope"));
    }

    #[test]
    fn nxdomain_response() {
        let mut dir = ServiceDirectory::new();
        dir.register("dns", Box::new(KvService::new(&[])));
        let (resp, _, _) = dir.lookup("dns", &Payload::Text("ghost".into()), SimTime::ZERO).unwrap();
        assert_eq!(resp, Payload::Text("NXDOMAIN:ghost".into()));
    }
}
