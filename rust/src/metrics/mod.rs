//! Platform metrics: counters, latency histograms, bytes-moved and an
//! energy proxy.
//!
//! The paper frames transport avoidance as "rapidly becoming a global
//! sustainability imperative" (§III-G); to make that measurable we account
//! every byte by the network tier it crossed and convert to a joule proxy
//! (E7, fig. 11 experiments).

use crate::util::{SimDuration, SimTime};

use std::collections::BTreeMap;

/// Which hop a transfer crossed — the cost hierarchy of §III-G.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum NetTier {
    /// Same host: RAM / local disk.
    Local,
    /// Same region: storage network / fibre channel.
    Lan,
    /// Cross-region: the expensive, contended wide-area path.
    Wan,
}

/// Energy proxy constants (J/byte moved, J/task-run overhead). Absolute
/// values are order-of-magnitude literature figures; the *ratios* between
/// tiers are what the experiments depend on.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    pub j_per_byte_local: f64,
    pub j_per_byte_lan: f64,
    pub j_per_byte_wan: f64,
    pub j_per_run: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            j_per_byte_local: 1e-9,
            j_per_byte_lan: 2e-8,
            j_per_byte_wan: 2e-6,
            j_per_run: 1e-2,
        }
    }
}

impl EnergyModel {
    pub fn per_byte(&self, tier: NetTier) -> f64 {
        match tier {
            NetTier::Local => self.j_per_byte_local,
            NetTier::Lan => self.j_per_byte_lan,
            NetTier::Wan => self.j_per_byte_wan,
        }
    }
}

/// Fixed-boundary latency histogram (power-of-2 microsecond buckets).
#[derive(Clone, Debug, Default)]
pub struct LatencyHistogram {
    /// bucket i counts samples in [2^i, 2^{i+1}) microseconds; bucket 0
    /// includes 0.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl LatencyHistogram {
    pub fn record(&mut self, d: SimDuration) {
        let us = d.as_micros();
        let idx = (64 - us.leading_zeros()) as usize; // 0 -> 0
        if self.buckets.len() <= idx {
            self.buckets.resize(idx + 1, 0);
        }
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> SimDuration {
        if self.count == 0 {
            return SimDuration::ZERO;
        }
        SimDuration::micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> SimDuration {
        SimDuration::micros(self.max_us)
    }

    /// Upper bucket boundary below which `q` of the mass falls.
    pub fn quantile(&self, q: f64) -> SimDuration {
        if self.count == 0 {
            return SimDuration::ZERO;
        }
        let target = (self.count as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return SimDuration::micros(if i == 0 { 0 } else { 1 << i });
            }
        }
        self.max()
    }
}

/// The platform-wide metrics sink. Cheap to update on the hot path.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub counters: BTreeMap<String, u64>,
    pub bytes_moved: BTreeMap<NetTier, u64>,
    pub task_runs: u64,
    pub ghost_runs: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub wasted_runs: u64,
    pub notifications_sent: u64,
    pub polls_performed: u64,
    pub polls_empty: u64,
    pub energy: EnergyModel,
    pub joules: f64,
    pub e2e_latency: LatencyHistogram,
    pub storage_latency: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn bump(&mut self, key: &str) {
        self.add(key, 1);
    }

    pub fn add(&mut self, key: &str, n: u64) {
        *self.counters.entry(key.to_string()).or_insert(0) += n;
    }

    pub fn get(&self, key: &str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    /// Account a transfer of `bytes` across `tier` (bytes + joules).
    pub fn moved(&mut self, tier: NetTier, bytes: u64) {
        *self.bytes_moved.entry(tier).or_insert(0) += bytes;
        self.joules += bytes as f64 * self.energy.per_byte(tier);
    }

    pub fn bytes(&self, tier: NetTier) -> u64 {
        self.bytes_moved.get(&tier).copied().unwrap_or(0)
    }

    pub fn ran_task(&mut self, ghost: bool) {
        if ghost {
            self.ghost_runs += 1;
        } else {
            self.task_runs += 1;
            self.joules += self.energy.j_per_run;
        }
    }

    /// Record an end-to-end artifact latency: source stamp → sink arrival.
    pub fn e2e(&mut self, born: SimTime, done: SimTime) {
        self.e2e_latency.record(done.saturating_sub(born));
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "task_runs={} ghost_runs={} wasted_runs={} cache_hit/miss={}/{}\n",
            self.task_runs, self.ghost_runs, self.wasted_runs, self.cache_hits, self.cache_misses
        ));
        s.push_str(&format!(
            "bytes local={} lan={} wan={}  energy={:.3}J\n",
            self.bytes(NetTier::Local),
            self.bytes(NetTier::Lan),
            self.bytes(NetTier::Wan),
            self.joules
        ));
        s.push_str(&format!(
            "notify={} polls={} (empty {})  e2e mean={} p99~{} n={}\n",
            self.notifications_sent,
            self.polls_performed,
            self.polls_empty,
            self.e2e_latency.mean(),
            self.e2e_latency.quantile(0.99),
            self.e2e_latency.count()
        ));
        for (k, v) in &self.counters {
            s.push_str(&format!("  {k}={v}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_quantile() {
        let mut h = LatencyHistogram::default();
        for us in [1u64, 2, 4, 8, 1000] {
            h.record(SimDuration::micros(us));
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.mean().as_micros(), (1 + 2 + 4 + 8 + 1000) / 5);
        assert!(h.quantile(0.5).as_micros() <= 8);
        assert!(h.quantile(1.0).as_micros() >= 1000);
    }

    #[test]
    fn energy_scales_with_tier() {
        let mut m = Metrics::new();
        m.moved(NetTier::Local, 1_000_000);
        let local_j = m.joules;
        m.moved(NetTier::Wan, 1_000_000);
        // WAN must dominate by orders of magnitude (the E7 premise).
        assert!(m.joules - local_j > local_j * 100.0);
        assert_eq!(m.bytes(NetTier::Wan), 1_000_000);
    }

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.bump("snapshots");
        m.add("snapshots", 2);
        assert_eq!(m.get("snapshots"), 3);
        assert_eq!(m.get("absent"), 0);
    }

    #[test]
    fn e2e_latency_saturates() {
        let mut m = Metrics::new();
        m.e2e(SimTime::micros(100), SimTime::micros(50)); // clock skew guard
        assert_eq!(m.e2e_latency.max().as_micros(), 0);
    }
}
