//! Compatibility shim: the metrics types moved into the observability
//! layer ([`crate::obs`]) when the id-indexed registries and the flight
//! recorder landed. Existing `koalja::metrics::{NetTier, ...}` paths keep
//! working; new code should import from `crate::obs` directly.

pub use crate::obs::{EnergyModel, LatencyHistogram, Metrics, NetTier};
