//! The container-scheduling underlay — what the paper delegates to
//! Kubernetes, rebuilt as an in-process simulator (DESIGN.md substitution
//! table).
//!
//! Koalja's platform-transparency promise (§III-B: "no reference should
//! ever be made to Kubernetes ... in the description of processes") means
//! the user API never touches this module; only the coordinator does.
//! Modelled here: nodes per region, pod placement, elastic replica scaling
//! driven by queue depth, and scale-to-zero when links go quiet ("when no
//! work is arriving, resources can be scaled down to zero as long as cache
//! is not lost", §III-E).

use crate::util::{RegionId, SimDuration, SimTime, TaskId};

use std::collections::HashMap;

/// One machine in a region.
#[derive(Clone, Debug)]
pub struct Node {
    pub region: RegionId,
    /// How many pods this node can host.
    pub capacity: u32,
    pub pods: u32,
}

/// Lifecycle of a task's pod set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PodState {
    Running,
    /// Scaled to zero — next dispatch pays a cold-start penalty.
    Zero,
}

/// The deployment record for one task.
#[derive(Clone, Debug)]
pub struct Deployment {
    pub task: TaskId,
    pub region: RegionId,
    pub node: usize,
    pub replicas: u32,
    pub state: PodState,
    pub last_active: SimTime,
    pub cold_starts: u64,
    /// When the deployment last entered `PodState::Zero` (None while
    /// running) — the open end of the current zero-dwell interval.
    pub zero_since: Option<SimTime>,
    /// Total time spent scaled to zero across *closed* intervals; an open
    /// interval is added on top by [`Cluster::zero_dwell`].
    pub zero_dwell: SimDuration,
}

/// Elastic-scaling policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct ScalePolicy {
    /// Queue depth per replica that triggers scale-up.
    pub depth_per_replica: usize,
    pub max_replicas: u32,
    /// Idle time before scale-to-zero.
    pub idle_to_zero: SimDuration,
    /// Cold-start penalty when dispatching to a Zero deployment.
    pub cold_start: SimDuration,
}

impl Default for ScalePolicy {
    fn default() -> Self {
        Self {
            depth_per_replica: 8,
            max_replicas: 8,
            idle_to_zero: SimDuration::secs(30),
            cold_start: SimDuration::millis(800),
        }
    }
}

/// The cluster: nodes + deployments, with k8s-ish placement.
#[derive(Clone, Debug, Default)]
pub struct Cluster {
    pub nodes: Vec<Node>,
    pub deployments: HashMap<TaskId, Deployment>,
    pub policy: ScalePolicy,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub to_zero: u64,
}

impl Cluster {
    pub fn new(policy: ScalePolicy) -> Self {
        Self { policy, ..Default::default() }
    }

    pub fn add_node(&mut self, region: RegionId, capacity: u32) -> usize {
        self.nodes.push(Node { region, capacity, pods: 0 });
        self.nodes.len() - 1
    }

    /// Place a task in `region` on the least-loaded node there (the paper's
    /// "Kubernetes plays a role here in scheduling related tasks in local
    /// rackspace", §III-G). Falls back to adding a node if the region has
    /// none — the simulated cloud is elastic.
    pub fn place(&mut self, task: TaskId, region: RegionId, now: SimTime) -> usize {
        let node = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.region == region && n.pods < n.capacity)
            .min_by_key(|(_, n)| n.pods)
            .map(|(i, _)| i)
            .unwrap_or_else(|| self.add_node(region, 16));
        self.nodes[node].pods += 1;
        self.deployments.insert(
            task,
            Deployment {
                task,
                region,
                node,
                replicas: 1,
                state: PodState::Running,
                last_active: now,
                cold_starts: 0,
                zero_since: None,
                zero_dwell: SimDuration::ZERO,
            },
        );
        node
    }

    pub fn deployment(&self, task: TaskId) -> Option<&Deployment> {
        self.deployments.get(&task)
    }

    /// Called by the coordinator before dispatching work. Returns the
    /// dispatch penalty (cold start if scaled to zero) and marks activity.
    pub fn activate(&mut self, task: TaskId, now: SimTime) -> SimDuration {
        let policy = self.policy;
        let Some(d) = self.deployments.get_mut(&task) else {
            return SimDuration::ZERO;
        };
        d.last_active = now;
        if d.state == PodState::Zero {
            d.state = PodState::Running;
            d.cold_starts += 1;
            if let Some(since) = d.zero_since.take() {
                d.zero_dwell += now.saturating_sub(since);
            }
            policy.cold_start
        } else {
            SimDuration::ZERO
        }
    }

    /// Queue-depth-driven replica adjustment; returns new replica count.
    pub fn autoscale(&mut self, task: TaskId, queue_depth: usize) -> u32 {
        let policy = self.policy;
        let Some(d) = self.deployments.get_mut(&task) else {
            return 0;
        };
        let want = ((queue_depth as f64 / policy.depth_per_replica as f64).ceil() as u32)
            .clamp(1, policy.max_replicas);
        if want > d.replicas {
            self.scale_ups += 1;
        } else if want < d.replicas {
            self.scale_downs += 1;
        }
        d.replicas = want;
        want
    }

    /// Sweep deployments; scale idle ones to zero. Cache is *not* lost —
    /// only pods are reclaimed (the paper's condition for zero-scaling).
    pub fn scale_to_zero_sweep(&mut self, now: SimTime) -> usize {
        let idle = self.policy.idle_to_zero;
        let mut count = 0;
        for d in self.deployments.values_mut() {
            if d.state == PodState::Running && now.saturating_sub(d.last_active) > idle {
                d.state = PodState::Zero;
                d.replicas = 0;
                d.zero_since = Some(now);
                self.to_zero += 1;
                count += 1;
            }
        }
        count
    }

    /// Effective parallelism for a task (≥1 even when zero-scaled; the
    /// dispatch path revives it first).
    pub fn replicas(&self, task: TaskId) -> u32 {
        self.deployments.get(&task).map_or(1, |d| d.replicas.max(1))
    }

    /// Total zero-scaled dwell for `task` as of `now`: every closed
    /// Zero→Running interval plus the currently-open one, if any. This is
    /// what `koalja trace` reports per task — scale-to-zero as *observed
    /// time parked*, not just an event count.
    pub fn zero_dwell(&self, task: TaskId, now: SimTime) -> SimDuration {
        self.deployments.get(&task).map_or(SimDuration::ZERO, |d| {
            let open = d.zero_since.map_or(SimDuration::ZERO, |s| now.saturating_sub(s));
            d.zero_dwell + open
        })
    }

    /// Cold starts recorded for `task` (0 for unknown tasks).
    pub fn cold_starts(&self, task: TaskId) -> u64 {
        self.deployments.get(&task).map_or(0, |d| d.cold_starts)
    }

    pub fn running_pods(&self) -> u32 {
        self.deployments
            .values()
            .filter(|d| d.state == PodState::Running)
            .map(|d| d.replicas)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> Cluster {
        let mut c = Cluster::new(ScalePolicy::default());
        c.add_node(RegionId::new(0), 4);
        c.add_node(RegionId::new(0), 4);
        c
    }

    #[test]
    fn placement_balances_nodes() {
        let mut c = cluster();
        let n1 = c.place(TaskId::new(0), RegionId::new(0), SimTime::ZERO);
        let n2 = c.place(TaskId::new(1), RegionId::new(0), SimTime::ZERO);
        assert_ne!(n1, n2, "least-loaded placement should alternate");
    }

    #[test]
    fn placement_in_empty_region_adds_node() {
        let mut c = cluster();
        let n = c.place(TaskId::new(0), RegionId::new(9), SimTime::ZERO);
        assert_eq!(c.nodes[n].region, RegionId::new(9));
    }

    #[test]
    fn scale_to_zero_and_cold_start() {
        let mut c = cluster();
        c.place(TaskId::new(0), RegionId::new(0), SimTime::ZERO);
        assert_eq!(c.scale_to_zero_sweep(SimTime::secs(60)), 1);
        assert_eq!(c.deployment(TaskId::new(0)).unwrap().state, PodState::Zero);
        let penalty = c.activate(TaskId::new(0), SimTime::secs(61));
        assert_eq!(penalty, c.policy.cold_start);
        assert_eq!(c.deployment(TaskId::new(0)).unwrap().state, PodState::Running);
        // second dispatch is warm
        assert_eq!(c.activate(TaskId::new(0), SimTime::secs(62)), SimDuration::ZERO);
    }

    #[test]
    fn zero_dwell_accumulates_across_intervals() {
        let mut c = cluster();
        let t = TaskId::new(0);
        c.place(t, RegionId::new(0), SimTime::ZERO);
        // parked at 60s, revived at 100s: 40s of closed dwell
        c.scale_to_zero_sweep(SimTime::secs(60));
        assert_eq!(c.zero_dwell(t, SimTime::secs(90)), SimDuration::secs(30), "open interval");
        c.activate(t, SimTime::secs(100));
        assert_eq!(c.zero_dwell(t, SimTime::secs(500)), SimDuration::secs(40));
        // parked again at 200s: the open interval rides on top
        c.scale_to_zero_sweep(SimTime::secs(200));
        assert_eq!(c.zero_dwell(t, SimTime::secs(250)), SimDuration::secs(90));
        assert_eq!(c.cold_starts(t), 1);
        assert_eq!(c.zero_dwell(TaskId::new(9), SimTime::secs(1)), SimDuration::ZERO);
    }

    #[test]
    fn active_deployment_not_zeroed() {
        let mut c = cluster();
        c.place(TaskId::new(0), RegionId::new(0), SimTime::ZERO);
        c.activate(TaskId::new(0), SimTime::secs(50));
        assert_eq!(c.scale_to_zero_sweep(SimTime::secs(60)), 0);
    }

    #[test]
    fn autoscale_tracks_queue_depth() {
        let mut c = cluster();
        c.place(TaskId::new(0), RegionId::new(0), SimTime::ZERO);
        assert_eq!(c.autoscale(TaskId::new(0), 100), 8); // clamped at max
        assert_eq!(c.autoscale(TaskId::new(0), 9), 2);
        assert_eq!(c.autoscale(TaskId::new(0), 0), 1);
        assert!(c.scale_ups >= 1 && c.scale_downs >= 1);
    }
}
