//! Query tools over the metadata registry — §III-L.
//!
//! "Thanks to a strict data format, special tools can be provided for
//! querying these logs, so that users don't need to rely on matching text
//! against expensive regular expressions and hoping for the best."
//!
//! Includes the E6 "mashed potato" estimator: how many candidate journeys
//! would an investigator have to consider to reconstruct a packet's path
//! *without* the traveller log, versus just reading the passport with it.

use super::{CheckpointEvent, ProvenanceRegistry, Stamp};
use crate::util::{AvId, RunId, SimTime, TaskId};
use std::collections::{HashSet, VecDeque};

/// Read-only query facade over a registry.
pub struct ProvenanceQuery<'a> {
    reg: &'a ProvenanceRegistry,
}

impl<'a> ProvenanceQuery<'a> {
    pub fn new(reg: &'a ProvenanceRegistry) -> Self {
        Self { reg }
    }

    /// Full ancestry (transitive parents) of an AV — the forensic
    /// "which inputs led to this outcome" question.
    pub fn ancestors(&self, av: AvId) -> Vec<AvId> {
        let mut seen = HashSet::new();
        let mut queue = VecDeque::from([av]);
        let mut out = Vec::new();
        while let Some(cur) = queue.pop_front() {
            if let Some(p) = self.reg.passport(cur) {
                for &parent in &p.parents {
                    if seen.insert(parent) {
                        out.push(parent);
                        queue.push_back(parent);
                    }
                }
            }
        }
        out
    }

    /// Transitive descendants — "which outcomes must be recomputed if this
    /// input (or the software that read it) was wrong" (§III-J rollback).
    pub fn descendants(&self, av: AvId) -> Vec<AvId> {
        let mut seen = HashSet::new();
        let mut queue = VecDeque::from([av]);
        let mut out = Vec::new();
        while let Some(cur) = queue.pop_front() {
            for &child in self.reg.children_of(cur) {
                if seen.insert(child) {
                    out.push(child);
                    queue.push_back(child);
                }
            }
        }
        out
    }

    /// The software versions that touched an AV, in stamp order — "which
    /// software version processed it and in what order?" (§III-C).
    pub fn versions_touching(&self, av: AvId) -> Vec<(TaskId, u32)> {
        self.reg
            .passport(av)
            .map(|p| {
                p.stamps
                    .iter()
                    .filter_map(|s| match s.stamp {
                        Stamp::Emitted { task, version, .. } => Some((task, version)),
                        Stamp::Consumed { task, version, .. } => Some((task, version)),
                        _ => None,
                    })
                    .collect()
            })
            .unwrap_or_default()
    }

    /// The task runs involved in producing an AV (itself + ancestors) —
    /// the forensic reconstruction of a transactional process.
    pub fn contributing_runs(&self, av: AvId) -> Vec<RunId> {
        let mut avs = vec![av];
        avs.extend(self.ancestors(av));
        let mut runs = Vec::new();
        let mut seen = HashSet::new();
        for a in avs {
            if let Some(p) = self.reg.passport(a) {
                for s in &p.stamps {
                    if let Stamp::Emitted { run, .. } = s.stamp {
                        if seen.insert(run) {
                            runs.push(run);
                        }
                    }
                }
            }
        }
        runs
    }

    /// Every AV a task ever emitted (ascending id — deterministic). The
    /// swap preview seeds its stale set from this: a version bump makes
    /// these and their descendants candidates for recomputation (§III-J).
    pub fn emitted_by(&self, task: TaskId) -> Vec<AvId> {
        let mut out: Vec<AvId> = self
            .reg
            .passports_iter()
            .filter(|(_, p)| {
                p.stamps
                    .iter()
                    .any(|s| matches!(s.stamp, Stamp::Emitted { task: t, .. } if t == task))
            })
            .map(|(id, _)| *id)
            .collect();
        out.sort_unstable();
        out
    }

    /// Software-version changes stamped on a task's checkpoint log, in
    /// time order: (when, from, to). Hot-swaps land here.
    pub fn version_changes(&self, task: TaskId) -> Vec<(SimTime, u32, u32)> {
        self.reg
            .checkpoint_log(task)
            .iter()
            .filter_map(|e| match e.event {
                CheckpointEvent::VersionChange { from, to } => Some((e.time, from, to)),
                _ => None,
            })
            .collect()
    }

    /// Did the AV ever cross a region boundary, and how many bytes moved?
    pub fn wan_hops(&self, av: AvId) -> Vec<(u64, String)> {
        self.reg
            .passport(av)
            .map(|p| {
                p.stamps
                    .iter()
                    .filter_map(|s| match &s.stamp {
                        Stamp::Transferred { from, to, bytes } => {
                            Some((*bytes, format!("{from}->{to}")))
                        }
                        _ => None,
                    })
                    .collect()
            })
            .unwrap_or_default()
    }

    /// E6 estimator. With the passport, reconstructing a journey costs
    /// O(stamps). Without it, an investigator must consider every
    /// combination of candidate producer runs along the pipeline: given
    /// `runs_per_stage` observed runs at each of `depth` stages, that is
    /// runs_per_stage^depth candidate paths (capped to avoid overflow).
    /// Returns (with_metadata_steps, without_metadata_paths).
    pub fn reconstruction_cost(&self, av: AvId, runs_per_stage: u64) -> (u64, u64) {
        let with = self.reg.passport(av).map_or(0, |p| p.stamps.len() as u64)
            + self.ancestors(av).len() as u64;
        let depth = 1 + self
            .ancestors(av)
            .iter()
            .filter(|a| {
                self.reg
                    .passport(**a)
                    .map(|p| p.stamps.iter().any(|s| matches!(s.stamp, Stamp::Emitted { .. })))
                    .unwrap_or(false)
            })
            .count() as u32;
        let without = runs_per_stage.saturating_pow(depth.min(20));
        (with.max(1), without)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::Stamp;
    use crate::util::{RegionId, SimTime};

    fn emitted(task: u64, run: u64) -> Stamp {
        Stamp::Emitted {
            task: TaskId::new(task),
            run: RunId::new(run),
            version: 1,
            region: RegionId::new(0),
        }
    }

    /// Build a 3-stage chain a -> b -> c with a side parent d -> c.
    fn chain() -> ProvenanceRegistry {
        let mut reg = ProvenanceRegistry::new();
        reg.birth(AvId::new(0), &[], SimTime::micros(0), emitted(0, 0)); // a
        reg.birth(AvId::new(3), &[], SimTime::micros(0), emitted(3, 3)); // d
        reg.birth(AvId::new(1), &[AvId::new(0)], SimTime::micros(1), emitted(1, 1)); // b
        reg.birth(
            AvId::new(2),
            &[AvId::new(1), AvId::new(3)],
            SimTime::micros(2),
            emitted(2, 2),
        ); // c
        reg
    }

    #[test]
    fn ancestors_are_transitive() {
        let reg = chain();
        let q = ProvenanceQuery::new(&reg);
        let mut anc = q.ancestors(AvId::new(2));
        anc.sort();
        assert_eq!(anc, vec![AvId::new(0), AvId::new(1), AvId::new(3)]);
    }

    #[test]
    fn descendants_are_transitive() {
        let reg = chain();
        let q = ProvenanceQuery::new(&reg);
        let mut desc = q.descendants(AvId::new(0));
        desc.sort();
        assert_eq!(desc, vec![AvId::new(1), AvId::new(2)]);
        assert_eq!(q.descendants(AvId::new(2)), vec![]);
    }

    #[test]
    fn contributing_runs_cover_lineage() {
        let reg = chain();
        let q = ProvenanceQuery::new(&reg);
        let mut runs = q.contributing_runs(AvId::new(2));
        runs.sort();
        assert_eq!(runs, vec![RunId::new(0), RunId::new(1), RunId::new(2), RunId::new(3)]);
    }

    #[test]
    fn wan_hops_read_from_stamps() {
        let mut reg = chain();
        reg.stamp(
            AvId::new(1),
            SimTime::micros(5),
            Stamp::Transferred { from: RegionId::new(0), to: RegionId::new(1), bytes: 512 },
        );
        let q = ProvenanceQuery::new(&reg);
        let hops = q.wan_hops(AvId::new(1));
        assert_eq!(hops.len(), 1);
        assert_eq!(hops[0].0, 512);
        assert!(q.wan_hops(AvId::new(0)).is_empty());
    }

    #[test]
    fn emitted_by_and_version_changes() {
        let mut reg = chain();
        let q = ProvenanceQuery::new(&reg);
        assert_eq!(q.emitted_by(TaskId::new(1)), vec![AvId::new(1)]);
        assert_eq!(q.emitted_by(TaskId::new(7)), Vec::<AvId>::new());
        reg.checkpoint(
            TaskId::new(1),
            RunId::new(5),
            crate::util::SimTime::millis(2),
            crate::provenance::CheckpointEvent::VersionChange { from: 1, to: 2 },
        );
        let q = ProvenanceQuery::new(&reg);
        assert_eq!(
            q.version_changes(TaskId::new(1)),
            vec![(crate::util::SimTime::millis(2), 1, 2)]
        );
        assert!(q.version_changes(TaskId::new(0)).is_empty());
    }

    #[test]
    fn reconstruction_cost_explodes_without_metadata() {
        let reg = chain();
        let q = ProvenanceQuery::new(&reg);
        let (with, without) = q.reconstruction_cost(AvId::new(2), 10);
        // passport walk is linear; inference is exponential in depth
        assert!(with < 20);
        assert!(without >= 10u64.pow(3));
        assert!(without / with.max(1) > 50);
    }
}
