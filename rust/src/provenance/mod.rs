//! Enterprise-grade metadata: the three stories of §III-C, §III-L.
//!
//! 1. **Traveller log** — "every data packet's travel documents get stamped
//!    according to the journey taken"; per-AV passports kept by the
//!    pipeline manager in a secure registry.
//! 2. **Checkpoint log** — per-task visitor log: which AVs/events passed
//!    through, when, and what was done to them (fig. 9).
//! 3. **Concept map** — the long-term design map of invariant
//!    relationships: topology, promises, semantics (fig. 10).
//!
//! Also recorded: out-of-band service lookups (§III-D — "if data were read
//! from a mutable external source, say DNS, cache the response for forensic
//! traceability") and software versions involved in every recomputation.
//!
//! The registry supports the "mashed potato" accounting of §III-L: metadata
//! kept per packet is tiny versus the combinatoric cost of reconstructing
//! journeys by inference later (experiment E6).

pub mod query;

pub use query::ProvenanceQuery;

use crate::av::DataClass;
use crate::util::hash::FastMap;
use crate::util::{AvId, ContentHash, LinkId, ObjectId, RegionId, RunId, SimTime, TaskId};


/// One passport stamp in an AV's traveller log.
#[derive(Clone, Debug, PartialEq)]
pub enum Stamp {
    /// Born at a source or emitted by a task run.
    Emitted { task: TaskId, run: RunId, version: u32, region: RegionId },
    /// Published onto a link topic.
    Published { link: LinkId },
    /// Transferred across regions (WAN hop).
    Transferred { from: RegionId, to: RegionId, bytes: u64 },
    /// Served from a dependent-local cache (Principle 2 in action).
    CacheServed { region: RegionId },
    /// Entered a task's snapshot (consumed).
    Consumed { task: TaskId, run: RunId, version: u32 },
    /// Denied a transfer by sovereignty policy.
    SovereigntyDenied { from: RegionId, to: RegionId },
}

/// A stamped entry: when + what.
#[derive(Clone, Debug)]
pub struct StampedEntry {
    pub time: SimTime,
    pub stamp: Stamp,
}

/// The passport of one AV: stamps plus lineage (which AVs it derives from).
#[derive(Clone, Debug, Default)]
pub struct Passport {
    pub stamps: Vec<StampedEntry>,
    pub parents: Vec<AvId>,
}

/// Checkpoint-log event kinds (fig. 9's vocabulary).
#[derive(Clone, Debug, PartialEq)]
pub enum CheckpointEvent {
    Start,
    ReadInput { av: AvId },
    /// §III-D: out-of-band lookup, response cached for forensics.
    ServiceLookup {
        service: String,
        service_version: u32,
        query: ContentHash,
        response: ContentHash,
    },
    Emit { av: AvId },
    Remark(String),
    Anomaly(String),
    /// Software version changed (triggers recompute downstream).
    VersionChange { from: u32, to: u32 },
    End { outputs: u32 },
}

#[derive(Clone, Debug)]
pub struct CheckpointEntry {
    pub time: SimTime,
    pub run: RunId,
    pub event: CheckpointEvent,
}

/// Concept-map relations (fig. 10: "precedes", "may determine", ...).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Relation {
    Precedes,
    MayDetermine,
    Produces,
    Consumes,
    ExpressesAs,
}

/// One invariant edge in the concept map. Deduplicated: the map records
/// what is *always* true of the design, not per-event occurrences.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ConceptEdge {
    pub from: String,
    pub rel: Relation,
    pub to: String,
}

/// One externally-injected arrival, as the forensic ledger records it.
/// Together with the deployment seed this is sufficient to replay a run:
/// the payload is still addressable through `object`, and `content` pins
/// what the bytes were (drift detection if storage was tampered with).
#[derive(Clone, Debug)]
pub struct InjectionRecord {
    pub av: AvId,
    /// Interned at deploy and shared across records: a refcount bump per
    /// event, not an allocation — large injection batches stay O(1) in
    /// per-event ledger setup.
    pub wire: std::sync::Arc<str>,
    pub at: SimTime,
    pub region: RegionId,
    pub class: DataClass,
    pub object: ObjectId,
    pub content: ContentHash,
}

/// The pipeline manager's secure metadata registry.
#[derive(Clone, Debug, Default)]
pub struct ProvenanceRegistry {
    passports: FastMap<AvId, Passport>,
    checkpoints: FastMap<TaskId, Vec<CheckpointEntry>>,
    concept_edges: Vec<ConceptEdge>,
    concept_seen: std::collections::HashSet<ConceptEdge>,
    /// children index for forward tracing (descendants)
    children: FastMap<AvId, Vec<AvId>>,
    /// external-arrival ledger, injection order (breadboard replay source)
    injections: Vec<InjectionRecord>,
    /// AV → stored object (and size): lets swap previews find which cached
    /// intermediates a version bump strands
    objects: FastMap<AvId, (ObjectId, u64)>,
    /// total stamps recorded (for the E6 overhead accounting)
    pub stamp_count: u64,
    pub enabled: bool,
}

impl ProvenanceRegistry {
    pub fn new() -> Self {
        Self { enabled: true, ..Default::default() }
    }

    /// Metadata can be disabled to measure its overhead (E6 control arm).
    pub fn disabled() -> Self {
        Self { enabled: false, ..Default::default() }
    }

    // ---- traveller log ----------------------------------------------------

    pub fn birth(&mut self, av: AvId, parents: &[AvId], time: SimTime, stamp: Stamp) {
        if !self.enabled {
            return;
        }
        let p = self.passports.entry(av).or_default();
        p.parents = parents.to_vec();
        if p.stamps.capacity() == 0 {
            p.stamps.reserve(4); // typical journey: emit/publish/consume(+1)
        }
        p.stamps.push(StampedEntry { time, stamp });
        self.stamp_count += 1;
        for &parent in parents {
            self.children.entry(parent).or_default().push(av);
        }
    }

    pub fn stamp(&mut self, av: AvId, time: SimTime, stamp: Stamp) {
        if !self.enabled {
            return;
        }
        self.passports.entry(av).or_default().stamps.push(StampedEntry { time, stamp });
        self.stamp_count += 1;
    }

    pub fn passport(&self, av: AvId) -> Option<&Passport> {
        self.passports.get(&av)
    }

    /// Iterate every passport (order unspecified — sort by id for
    /// deterministic output).
    pub fn passports_iter(&self) -> impl Iterator<Item = (&AvId, &Passport)> {
        self.passports.iter()
    }

    // ---- forensic ledger --------------------------------------------------

    /// Record one external arrival (called by the coordinator at
    /// injection time).
    pub fn record_injection(&mut self, rec: InjectionRecord) {
        if !self.enabled {
            return;
        }
        self.injections.push(rec);
    }

    /// The external-arrival ledger, injection order.
    pub fn injections(&self) -> &[InjectionRecord] {
        &self.injections
    }

    /// Index an AV's storage location (called wherever AVs are minted).
    pub fn register_object(&mut self, av: AvId, object: ObjectId, bytes: u64) {
        if !self.enabled {
            return;
        }
        self.objects.insert(av, (object, bytes));
    }

    /// Storage object (and size) behind an AV, if indexed.
    pub fn object_of(&self, av: AvId) -> Option<(ObjectId, u64)> {
        self.objects.get(&av).copied()
    }

    // ---- checkpoint log ---------------------------------------------------

    pub fn checkpoint(&mut self, task: TaskId, run: RunId, time: SimTime, event: CheckpointEvent) {
        if !self.enabled {
            return;
        }
        self.checkpoints.entry(task).or_default().push(CheckpointEntry { time, run, event });
    }

    /// Batched checkpoint append — one map lookup for a whole run's
    /// events (§Perf; the hot path logs Start + N reads + End together).
    pub fn checkpoint_batch(
        &mut self,
        task: TaskId,
        run: RunId,
        time: SimTime,
        events: impl IntoIterator<Item = CheckpointEvent>,
    ) {
        if !self.enabled {
            return;
        }
        let log = self.checkpoints.entry(task).or_default();
        for event in events {
            log.push(CheckpointEntry { time, run, event });
        }
    }

    pub fn checkpoint_log(&self, task: TaskId) -> &[CheckpointEntry] {
        self.checkpoints.get(&task).map_or(&[], |v| v.as_slice())
    }

    // ---- concept map ------------------------------------------------------

    pub fn concept(&mut self, from: &str, rel: Relation, to: &str) {
        if !self.enabled {
            return;
        }
        let edge = ConceptEdge { from: from.to_string(), rel, to: to.to_string() };
        if self.concept_seen.insert(edge.clone()) {
            self.concept_edges.push(edge);
        }
    }

    pub fn concept_map(&self) -> &[ConceptEdge] {
        &self.concept_edges
    }

    // ---- accounting ---------------------------------------------------------

    /// Approximate bytes of metadata held (for E6's overhead-vs-payload
    /// comparison). Stamps are small fixed records; concept map is O(design).
    pub fn metadata_bytes(&self) -> u64 {
        // ~40 B per stamp record, ~48 B per checkpoint entry, ~96 B per
        // edge, ~72 B per ledger entry, ~24 B per object index row
        let cp: usize = self.checkpoints.values().map(|v| v.len()).sum();
        (self.stamp_count * 40)
            + (cp as u64 * 48)
            + (self.concept_edges.len() as u64 * 96)
            + (self.injections.len() as u64 * 72)
            + (self.objects.len() as u64 * 24)
    }

    pub fn passports_held(&self) -> usize {
        self.passports.len()
    }

    pub(crate) fn children_of(&self, av: AvId) -> &[AvId] {
        self.children.get(&av).map_or(&[], |v| v.as_slice())
    }

    /// Dump everything as JSON (the "special tools ... for querying these
    /// logs" of §III-L start from a strict format).
    pub fn dump_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let passports = self
            .passports
            .iter()
            .map(|(id, p)| {
                Json::obj(vec![
                    ("av", Json::str(id.to_string())),
                    (
                        "parents",
                        Json::Arr(p.parents.iter().map(|a| Json::str(a.to_string())).collect()),
                    ),
                    (
                        "stamps",
                        Json::Arr(
                            p.stamps
                                .iter()
                                .map(|s| {
                                    Json::obj(vec![
                                        ("t_us", Json::num(s.time.as_micros() as f64)),
                                        ("stamp", Json::str(format!("{:?}", s.stamp))),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let checkpoints = self
            .checkpoints
            .iter()
            .map(|(t, es)| {
                Json::obj(vec![
                    ("task", Json::str(t.to_string())),
                    ("entries", Json::num(es.len() as f64)),
                ])
            })
            .collect();
        let concept = self
            .concept_edges
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("from", Json::str(e.from.clone())),
                    ("rel", Json::str(format!("{:?}", e.rel))),
                    ("to", Json::str(e.to.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("passports", Json::Arr(passports)),
            ("checkpoint_logs", Json::Arr(checkpoints)),
            ("concept_map", Json::Arr(concept)),
            ("stamp_count", Json::num(self.stamp_count as f64)),
            ("metadata_bytes", Json::num(self.metadata_bytes() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(n: u64) -> (AvId, TaskId, RunId) {
        (AvId::new(n), TaskId::new(n), RunId::new(n))
    }

    #[test]
    fn passport_records_journey_in_order() {
        let mut reg = ProvenanceRegistry::new();
        let (av, task, run) = ids(0);
        reg.birth(
            av,
            &[],
            SimTime::micros(1),
            Stamp::Emitted { task, run, version: 1, region: RegionId::new(0) },
        );
        reg.stamp(av, SimTime::micros(2), Stamp::Published { link: LinkId::new(0) });
        reg.stamp(
            av,
            SimTime::micros(9),
            Stamp::Consumed { task: TaskId::new(1), run: RunId::new(1), version: 3 },
        );
        let p = reg.passport(av).unwrap();
        assert_eq!(p.stamps.len(), 3);
        assert!(p.stamps.windows(2).all(|w| w[0].time <= w[1].time));
        // which software versions touched it is readable from the passport:
        let versions: Vec<u32> = p
            .stamps
            .iter()
            .filter_map(|s| match s.stamp {
                Stamp::Emitted { version, .. } | Stamp::Consumed { version, .. } => Some(version),
                _ => None,
            })
            .collect();
        assert_eq!(versions, vec![1, 3]);
    }

    #[test]
    fn lineage_builds_children_index() {
        let mut reg = ProvenanceRegistry::new();
        let parent = AvId::new(0);
        reg.birth(
            parent,
            &[],
            SimTime::ZERO,
            Stamp::Emitted {
                task: TaskId::new(0),
                run: RunId::new(0),
                version: 1,
                region: RegionId::new(0),
            },
        );
        for i in 1..=2 {
            reg.birth(
                AvId::new(i),
                &[parent],
                SimTime::micros(i),
                Stamp::Emitted {
                    task: TaskId::new(1),
                    run: RunId::new(i),
                    version: 1,
                    region: RegionId::new(0),
                },
            );
        }
        assert_eq!(reg.children_of(parent), &[AvId::new(1), AvId::new(2)]);
    }

    #[test]
    fn concept_map_deduplicates() {
        let mut reg = ProvenanceRegistry::new();
        reg.concept("convert", Relation::Precedes, "predict");
        reg.concept("convert", Relation::Precedes, "predict");
        reg.concept("predict", Relation::Consumes, "json");
        assert_eq!(reg.concept_map().len(), 2);
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let mut reg = ProvenanceRegistry::disabled();
        let (av, task, run) = ids(0);
        reg.birth(
            av,
            &[],
            SimTime::ZERO,
            Stamp::Emitted { task, run, version: 1, region: RegionId::new(0) },
        );
        reg.checkpoint(task, run, SimTime::ZERO, CheckpointEvent::Start);
        reg.concept("a", Relation::Precedes, "b");
        assert!(reg.passport(av).is_none());
        assert_eq!(reg.metadata_bytes(), 0);
    }

    #[test]
    fn metadata_bytes_grow_linearly() {
        let mut reg = ProvenanceRegistry::new();
        let before = reg.metadata_bytes();
        for i in 0..100 {
            reg.stamp(AvId::new(i), SimTime::ZERO, Stamp::Published { link: LinkId::new(0) });
        }
        let after = reg.metadata_bytes();
        assert_eq!(after - before, 100 * 40);
    }

    #[test]
    fn injection_ledger_and_object_index() {
        let mut reg = ProvenanceRegistry::new();
        reg.record_injection(InjectionRecord {
            av: AvId::new(0),
            wire: "raw".into(),
            at: SimTime::millis(3),
            region: RegionId::new(0),
            class: crate::av::DataClass::Summary,
            object: crate::util::ObjectId::new(9),
            content: ContentHash::of_str("x"),
        });
        reg.register_object(AvId::new(0), crate::util::ObjectId::new(9), 128);
        assert_eq!(reg.injections().len(), 1);
        assert_eq!(&*reg.injections()[0].wire, "raw");
        assert_eq!(reg.object_of(AvId::new(0)), Some((crate::util::ObjectId::new(9), 128)));
        assert_eq!(reg.object_of(AvId::new(1)), None);
        // disabled registries keep no ledger
        let mut off = ProvenanceRegistry::disabled();
        off.record_injection(reg.injections()[0].clone());
        off.register_object(AvId::new(0), crate::util::ObjectId::new(9), 128);
        assert!(off.injections().is_empty());
        assert_eq!(off.object_of(AvId::new(0)), None);
    }

    #[test]
    fn dump_json_is_well_formed() {
        let mut reg = ProvenanceRegistry::new();
        reg.concept("a", Relation::MayDetermine, "b");
        let v = reg.dump_json();
        assert_eq!(v.get("concept_map").unwrap().as_arr().unwrap().len(), 1);
        assert!(v.get("metadata_bytes").unwrap().as_u64().unwrap() > 0);
        // emitted text reparses
        let text = v.to_string();
        assert_eq!(crate::util::Json::parse(&text).unwrap(), v);
    }
}
