//! Data-arrival and snapshot policies — §III-E, §III-I, fig. 7.
//!
//! A smart task's inputs arrive as streams of Annotated Values on separate
//! links, at unrelated rates. The task agent's wrapper assembles *snapshots*
//! (execution sets) from them according to policy, so user code never deals
//! with rate mismatch itself. The paper names three aggregation policies:
//!
//!  * **All new** — no reuse; each snapshot is a non-overlapping set of
//!    completely fresh data ("what usually happens in a stream").
//!  * **Swap new for old** — fresh values where available, previous values
//!    where not ("like the aggregations in a Makefile").
//!  * **Merge** — multiple links folded FCFS into a single scalar stream
//!    (same type required).
//!
//! plus buffers `input[N]` (minimum count) and sliding windows `input[N/S]`
//! (window of N advancing S at a time), and a rate control to stop
//! "needless unintended recomputation, and the possibility of Denial of
//! Service attacks on the inputs".

use crate::av::AnnotatedValue;
use crate::util::{SimDuration, SimTime};
use std::collections::VecDeque;
use std::sync::Arc;

/// Task-level aggregation policy across inputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SnapshotPolicy {
    /// Fire only on fully fresh tuples.
    #[default]
    AllNew,
    /// Fire when anything is fresh; reuse old values elsewhere.
    SwapNewForOld,
    /// Fold all inputs into one FCFS stream.
    Merge,
}

impl SnapshotPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "allnew" | "all-new" | "all_new" => Some(Self::AllNew),
            "swap" | "swapnewforold" | "swap-new-for-old" => Some(Self::SwapNewForOld),
            "merge" => Some(Self::Merge),
            _ => None,
        }
    }
}

/// Per-input buffer/window spec — the `name[N]` / `name[N/S]` annotations of
/// the wiring language (fig. 5: `(in[10/2]) convert (json)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BufferSpec {
    /// Values per snapshot (window size). 1 = plain streaming input.
    pub count: usize,
    /// Slide: how many fresh values advance the window per snapshot.
    /// `slide == count` means non-overlapping (plain buffer `[N]`);
    /// `slide < count` is the sliding window `[N/S]`.
    pub slide: usize,
}

impl Default for BufferSpec {
    fn default() -> Self {
        Self { count: 1, slide: 1 }
    }
}

impl BufferSpec {
    pub fn buffer(n: usize) -> Self {
        Self { count: n.max(1), slide: n.max(1) }
    }

    pub fn window(n: usize, s: usize) -> Self {
        Self { count: n.max(1), slide: s.clamp(1, n.max(1)) }
    }

    pub fn is_window(&self) -> bool {
        self.slide < self.count
    }
}

/// One input port's arrival buffer.
#[derive(Clone, Debug)]
pub struct InputBuffer {
    /// Port name; refcounted so snapshot assembly is allocation-free (§Perf).
    pub name: Arc<str>,
    pub spec: BufferSpec,
    /// Last `spec.count` values (the window), oldest first.
    window: VecDeque<AnnotatedValue>,
    /// Arrivals not yet consumed by a snapshot.
    fresh: usize,
    /// Total ever received.
    pub received: u64,
}

impl InputBuffer {
    pub fn new(name: &str, spec: BufferSpec) -> Self {
        Self { name: Arc::from(name), spec, window: VecDeque::new(), fresh: 0, received: 0 }
    }

    pub fn push(&mut self, av: AnnotatedValue) {
        self.window.push_back(av);
        while self.window.len() > self.spec.count {
            self.window.pop_front();
        }
        self.fresh = (self.fresh + 1).min(self.spec.count);
        self.received += 1;
    }

    pub fn fresh(&self) -> usize {
        self.fresh
    }

    pub fn window_full(&self) -> bool {
        self.window.len() >= self.spec.count
    }

    pub fn has_any(&self) -> bool {
        !self.window.is_empty()
    }

    fn snapshot_values(&self) -> Vec<AnnotatedValue> {
        self.window.iter().cloned().collect()
    }

    /// Oldest unconsumed AV (for Merge draining).
    fn pop_fresh_front(&mut self) -> Option<AnnotatedValue> {
        if self.fresh == 0 {
            return None;
        }
        // fresh values are the tail of the window; the oldest fresh one is
        // at len - fresh.
        let idx = self.window.len() - self.fresh;
        let av = self.window.get(idx).cloned();
        if av.is_some() {
            self.fresh -= 1;
        }
        av
    }
}

/// A ready execution set: per input, the AVs to feed user code.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// (input name, values oldest-first). For Merge there is one synthetic
    /// input named `merged`.
    pub inputs: Vec<(Arc<str>, Vec<AnnotatedValue>)>,
    /// Earliest born timestamp among members (e2e latency tracking).
    pub born: SimTime,
    /// True if any member is a ghost (the whole run becomes a ghost run).
    pub ghost: bool,
}

impl Snapshot {
    pub fn all_avs(&self) -> impl Iterator<Item = &AnnotatedValue> {
        self.inputs.iter().flat_map(|(_, avs)| avs.iter())
    }

    pub fn input(&self, name: &str) -> Option<&[AnnotatedValue]> {
        self.inputs.iter().find(|(n, _)| &**n == name).map(|(_, v)| v.as_slice())
    }

    /// Assemble a snapshot from parts; `born` is the oldest member's birth
    /// time (or `fallback_born` for an empty/source snapshot).
    pub fn new(inputs: Vec<(Arc<str>, Vec<AnnotatedValue>)>, fallback_born: SimTime) -> Self {
        let born = inputs
            .iter()
            .flat_map(|(_, avs)| avs.iter().map(|a| a.born))
            .min()
            .unwrap_or(fallback_born);
        let ghost = inputs.iter().any(|(_, avs)| avs.iter().any(|a| a.ghost));
        Self { inputs, born, ghost }
    }

    fn from_parts(inputs: Vec<(Arc<str>, Vec<AnnotatedValue>)>) -> Self {
        Self::new(inputs, SimTime::ZERO)
    }
}

/// Rate control: a minimum interval between snapshots (DoS guard, §III-I).
#[derive(Clone, Copy, Debug, Default)]
pub struct RateControl {
    pub min_interval: SimDuration,
    last_fire: Option<SimTime>,
}

impl RateControl {
    pub fn new(min_interval: SimDuration) -> Self {
        Self { min_interval, last_fire: None }
    }

    pub fn allow(&self, now: SimTime) -> bool {
        match self.last_fire {
            None => true,
            Some(t) => now.saturating_sub(t) >= self.min_interval,
        }
    }

    pub fn fired(&mut self, now: SimTime) {
        self.last_fire = Some(now);
    }

    /// When the next snapshot may fire (for poll scheduling).
    pub fn next_allowed(&self, now: SimTime) -> SimTime {
        match self.last_fire {
            None => now,
            Some(t) => {
                let next = t + self.min_interval;
                if next > now {
                    next
                } else {
                    now
                }
            }
        }
    }
}

/// The snapshot assembly engine for one task: buffers + policy + rate.
#[derive(Clone, Debug)]
pub struct SnapshotEngine {
    pub policy: SnapshotPolicy,
    pub buffers: Vec<InputBuffer>,
    pub rate: RateControl,
    pub snapshots_built: u64,
    pub suppressed_by_rate: u64,
}

impl SnapshotEngine {
    pub fn new(policy: SnapshotPolicy, buffers: Vec<InputBuffer>, rate: RateControl) -> Self {
        Self { policy, buffers, rate, snapshots_built: 0, suppressed_by_rate: 0 }
    }

    pub fn buffer_mut(&mut self, name: &str) -> Option<&mut InputBuffer> {
        self.buffers.iter_mut().find(|b| &*b.name == name)
    }

    pub fn push(&mut self, input: &str, av: AnnotatedValue) -> bool {
        match self.buffer_mut(input) {
            Some(b) => {
                b.push(av);
                true
            }
            None => false,
        }
    }

    /// Hot-path variant: push by precomputed buffer position (§Perf).
    pub fn push_idx(&mut self, idx: usize, av: AnnotatedValue) {
        self.buffers[idx].push(av);
    }

    /// Total fresh values across inputs (autoscaling signal).
    pub fn backlog(&self) -> usize {
        self.buffers.iter().map(|b| b.fresh()).sum()
    }

    /// Is a snapshot ready under the policy (ignoring rate control)?
    pub fn ready(&self) -> bool {
        if self.buffers.is_empty() {
            return false;
        }
        match self.policy {
            SnapshotPolicy::AllNew => self
                .buffers
                .iter()
                .all(|b| b.window_full() && b.fresh() >= b.spec.slide),
            SnapshotPolicy::SwapNewForOld => {
                self.buffers.iter().all(|b| b.has_any())
                    && self.buffers.iter().any(|b| b.fresh() > 0)
            }
            SnapshotPolicy::Merge => {
                let need: usize = self.buffers.first().map(|b| b.spec.count).unwrap_or(1);
                self.backlog() >= need
            }
        }
    }

    /// Try to assemble a snapshot at `now`. Respects rate control.
    pub fn take(&mut self, now: SimTime) -> Option<Snapshot> {
        if !self.ready() {
            return None;
        }
        if !self.rate.allow(now) {
            self.suppressed_by_rate += 1;
            return None;
        }
        let snap = match self.policy {
            SnapshotPolicy::AllNew => {
                let inputs = self
                    .buffers
                    .iter_mut()
                    .map(|b| {
                        let vals = b.snapshot_values();
                        // The emitted snapshot covers everything currently
                        // in the window; the next one needs `slide` new
                        // arrivals. (Bounded buffer: a burst larger than
                        // the window drops the oldest positions — the
                        // window always covers the *latest* N values.)
                        b.fresh = 0;
                        (b.name.clone(), vals)
                    })
                    .collect();
                Snapshot::from_parts(inputs)
            }
            SnapshotPolicy::SwapNewForOld => {
                let inputs = self
                    .buffers
                    .iter_mut()
                    .map(|b| {
                        let vals = b.snapshot_values();
                        b.fresh = 0; // everything current is now "old"
                        (b.name.clone(), vals)
                    })
                    .collect();
                Snapshot::from_parts(inputs)
            }
            SnapshotPolicy::Merge => {
                let need: usize = self.buffers.first().map(|b| b.spec.count).unwrap_or(1);
                // FCFS across inputs by (created, seq): repeatedly take the
                // oldest fresh head.
                let mut merged: Vec<AnnotatedValue> = Vec::with_capacity(need);
                for _ in 0..need {
                    let next = self
                        .buffers
                        .iter_mut()
                        .filter(|b| b.fresh() > 0)
                        .min_by_key(|b| {
                            let idx = b.window.len() - b.fresh;
                            b.window.get(idx).map(|a| (a.created, a.seq)).unwrap()
                        })
                        .and_then(|b| b.pop_fresh_front());
                    match next {
                        Some(av) => merged.push(av),
                        None => break,
                    }
                }
                Snapshot::from_parts(vec![(Arc::from("merged"), merged)])
            }
        };
        self.rate.fired(now);
        self.snapshots_built += 1;
        Some(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::av::DataClass;
    use crate::util::*;

    fn av(seq: u64, t_us: u64) -> AnnotatedValue {
        AnnotatedValue {
            id: AvId::new(seq),
            source_task: TaskId::new(0),
            link: LinkId::new(0),
            object: ObjectId::new(seq),
            region: RegionId::new(0),
            created: SimTime::micros(t_us),
            seq,
            size_bytes: 4,
            content: ContentHash::of_str("v"),
            class: DataClass::Summary,
            ghost: false,
            born: SimTime::micros(t_us),
        }
    }

    fn engine(policy: SnapshotPolicy, specs: &[(&str, BufferSpec)]) -> SnapshotEngine {
        SnapshotEngine::new(
            policy,
            specs.iter().map(|(n, s)| InputBuffer::new(n, *s)).collect(),
            RateControl::default(),
        )
    }

    #[test]
    fn allnew_waits_for_full_fresh_tuple() {
        let mut e = engine(
            SnapshotPolicy::AllNew,
            &[("a", BufferSpec::default()), ("b", BufferSpec::default())],
        );
        e.push("a", av(0, 10));
        assert!(!e.ready(), "b still empty");
        e.push("b", av(1, 20));
        assert!(e.ready());
        let snap = e.take(SimTime::micros(30)).unwrap();
        assert_eq!(snap.inputs.len(), 2);
        assert_eq!(snap.born, SimTime::micros(10));
        // consumed: not ready again until BOTH receive fresh data
        assert!(!e.ready());
        e.push("a", av(2, 40));
        assert!(!e.ready());
        e.push("b", av(3, 50));
        assert!(e.ready());
    }

    #[test]
    fn allnew_buffer_needs_count() {
        let mut e = engine(SnapshotPolicy::AllNew, &[("a", BufferSpec::buffer(3))]);
        e.push("a", av(0, 1));
        e.push("a", av(1, 2));
        assert!(!e.ready());
        e.push("a", av(2, 3));
        let snap = e.take(SimTime::micros(4)).unwrap();
        assert_eq!(snap.input("a").unwrap().len(), 3);
        assert!(!e.ready(), "non-overlapping: all consumed");
    }

    #[test]
    fn sliding_window_advances_by_slide() {
        // the paper's input[10/2]: window 10, two refreshed per snapshot
        let mut e = engine(SnapshotPolicy::AllNew, &[("in", BufferSpec::window(10, 2))]);
        for i in 0..10 {
            e.push("in", av(i, i));
        }
        let s1 = e.take(SimTime::micros(100)).unwrap();
        assert_eq!(s1.input("in").unwrap().len(), 10);
        assert!(!e.ready(), "needs 2 fresh to slide");
        e.push("in", av(10, 110));
        assert!(!e.ready());
        e.push("in", av(11, 120));
        let s2 = e.take(SimTime::micros(130)).unwrap();
        let seqs: Vec<u64> = s2.input("in").unwrap().iter().map(|a| a.seq).collect();
        assert_eq!(seqs, (2..12).collect::<Vec<u64>>(), "slid by 2");
    }

    #[test]
    fn swap_new_for_old_reuses_stale_inputs() {
        let mut e = engine(
            SnapshotPolicy::SwapNewForOld,
            &[("src", BufferSpec::default()), ("cfg", BufferSpec::default())],
        );
        e.push("src", av(0, 1));
        assert!(!e.ready(), "cfg never seen: cannot run");
        e.push("cfg", av(1, 2));
        let s1 = e.take(SimTime::micros(3)).unwrap();
        assert_eq!(s1.all_avs().count(), 2);
        // only src updates; cfg value is reused
        e.push("src", av(2, 10));
        assert!(e.ready());
        let s2 = e.take(SimTime::micros(11)).unwrap();
        assert_eq!(s2.input("src").unwrap()[0].seq, 2);
        assert_eq!(s2.input("cfg").unwrap()[0].seq, 1, "old cfg reused");
        assert!(!e.ready(), "nothing fresh now");
    }

    #[test]
    fn merge_is_fcfs_across_inputs() {
        let mut e = engine(
            SnapshotPolicy::Merge,
            &[("x", BufferSpec::buffer(4)), ("y", BufferSpec::buffer(4))],
        );
        e.push("x", av(0, 10));
        e.push("y", av(1, 5));
        e.push("x", av(2, 20));
        e.push("y", av(3, 15));
        let s = e.take(SimTime::micros(100)).unwrap();
        let merged = s.input("merged").unwrap();
        let times: Vec<u64> = merged.iter().map(|a| a.created.as_micros()).collect();
        assert_eq!(times, vec![5, 10, 15, 20], "FCFS by creation time");
    }

    #[test]
    fn rate_control_suppresses_then_allows() {
        let mut e = SnapshotEngine::new(
            SnapshotPolicy::AllNew,
            vec![InputBuffer::new("a", BufferSpec::default())],
            RateControl::new(SimDuration::millis(10)),
        );
        e.push("a", av(0, 0));
        assert!(e.take(SimTime::micros(1)).is_some());
        e.push("a", av(1, 2));
        assert!(e.take(SimTime::micros(3)).is_none(), "too soon");
        assert_eq!(e.suppressed_by_rate, 1);
        assert!(e.take(SimTime::millis(11)).is_some());
        assert_eq!(e.snapshots_built, 2);
    }

    #[test]
    fn ghost_marker_propagates() {
        let mut e = engine(SnapshotPolicy::AllNew, &[("a", BufferSpec::default())]);
        let mut g = av(0, 1);
        g.ghost = true;
        e.push("a", g);
        let s = e.take(SimTime::micros(2)).unwrap();
        assert!(s.ghost);
    }

    #[test]
    fn backlog_counts_fresh() {
        let mut e = engine(
            SnapshotPolicy::AllNew,
            &[("a", BufferSpec::buffer(2)), ("b", BufferSpec::default())],
        );
        e.push("a", av(0, 1));
        e.push("b", av(1, 2));
        e.push("b", av(2, 3)); // b window cap 1: fresh saturates at count
        assert_eq!(e.backlog(), 2);
    }

    #[test]
    fn unknown_input_rejected() {
        let mut e = engine(SnapshotPolicy::AllNew, &[("a", BufferSpec::default())]);
        assert!(!e.push("zzz", av(0, 1)));
    }
}
