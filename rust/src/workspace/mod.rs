//! Workspaces — §IV.
//!
//! "users would be able to access shared data, but simultaneously protect
//! it from wider release, regardless of geographical constraints ...
//! workspaces could also be made to overlap as 'friends', through a form
//! of Role Based Access Control — thus avoiding the limitations of a
//! hierarchy of mutual exclusion zones. Koalja's design ... follows
//! CFEngine's overlapping-set-based model of inclusion."
//!
//! A workspace is a *set* of principals and a *set* of granted resources.
//! Sets overlap freely: a principal may belong to many workspaces, a
//! resource may be granted to many. Access = ∃ workspace containing both.

use crate::util::WorkspaceId;

use std::cell::Cell;
use std::collections::BTreeSet;

/// What can be granted to a workspace.
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum Resource {
    /// A whole pipeline by name.
    Pipeline(String),
    /// A wire (link name) — e.g. grant the summary stream but not the raw.
    Wire(String),
    /// Provenance records of a pipeline.
    Provenance(String),
}

#[derive(Clone, Debug)]
pub struct Workspace {
    pub id: WorkspaceId,
    pub name: String,
    pub members: BTreeSet<String>,
    pub grants: BTreeSet<Resource>,
}

/// The overlapping-set registry.
///
/// The allow/deny tallies are `Cell`s so [`WorkspaceRegistry::check`] takes
/// `&self`: access checks are logically reads, and read paths (e.g.
/// `Coordinator::read_sink`) must not demand exclusive access to the whole
/// platform just to bump an audit counter.
#[derive(Clone, Debug, Default)]
pub struct WorkspaceRegistry {
    spaces: Vec<Workspace>,
    denied: Cell<u64>,
    allowed: Cell<u64>,
}

impl WorkspaceRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn create(&mut self, name: &str) -> WorkspaceId {
        let id = WorkspaceId::new(self.spaces.len() as u64);
        self.spaces.push(Workspace {
            id,
            name: name.to_string(),
            members: BTreeSet::new(),
            grants: BTreeSet::new(),
        });
        id
    }

    pub fn add_member(&mut self, ws: WorkspaceId, principal: &str) {
        self.spaces[ws.index()].members.insert(principal.to_string());
    }

    pub fn grant(&mut self, ws: WorkspaceId, r: Resource) {
        self.spaces[ws.index()].grants.insert(r);
    }

    pub fn revoke(&mut self, ws: WorkspaceId, r: &Resource) {
        self.spaces[ws.index()].grants.remove(r);
    }

    /// Friend overlap: share everything `from` grants into `to` as well.
    /// (The paper's workspaces "overlap as 'friends'".)
    pub fn befriend(&mut self, from: WorkspaceId, to: WorkspaceId) {
        let grants: Vec<Resource> = self.spaces[from.index()].grants.iter().cloned().collect();
        for g in grants {
            self.spaces[to.index()].grants.insert(g);
        }
    }

    /// Access check: any workspace that contains the principal and the
    /// grant. Takes `&self` (counters are interior-mutable) so shared-
    /// reference read paths can be gated too.
    pub fn check(&self, principal: &str, r: &Resource) -> bool {
        let ok = self
            .spaces
            .iter()
            .any(|w| w.members.contains(principal) && w.grants.contains(r));
        if ok {
            self.allowed.set(self.allowed.get() + 1);
        } else {
            self.denied.set(self.denied.get() + 1);
        }
        ok
    }

    /// Checks that found no workspace holding both principal and grant.
    pub fn denied(&self) -> u64 {
        self.denied.get()
    }

    /// Checks that succeeded.
    pub fn allowed(&self) -> u64 {
        self.allowed.get()
    }

    /// All resources visible to a principal (union over its workspaces) —
    /// the "map" view an end user gets of the plumbing they may touch.
    pub fn visible(&self, principal: &str) -> BTreeSet<Resource> {
        self.spaces
            .iter()
            .filter(|w| w.members.contains(principal))
            .flat_map(|w| w.grants.iter().cloned())
            .collect()
    }

    pub fn workspaces_of(&self, principal: &str) -> Vec<WorkspaceId> {
        self.spaces
            .iter()
            .filter(|w| w.members.contains(principal))
            .map(|w| w.id)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wire(n: &str) -> Resource {
        Resource::Wire(n.to_string())
    }

    #[test]
    fn membership_grants_access() {
        let mut reg = WorkspaceRegistry::new();
        let ws = reg.create("telco-hq");
        reg.add_member(ws, "alice");
        reg.grant(ws, wire("monthly-summary"));
        assert!(reg.check("alice", &wire("monthly-summary")));
        assert!(!reg.check("bob", &wire("monthly-summary")));
        assert!(!reg.check("alice", &wire("raw-records")));
        assert_eq!((reg.allowed(), reg.denied()), (1, 2));
    }

    #[test]
    fn overlapping_sets_not_hierarchy() {
        let mut reg = WorkspaceRegistry::new();
        let af = reg.create("africa-ops");
        let hq = reg.create("hq");
        reg.add_member(af, "amara");
        reg.add_member(hq, "amara"); // one principal, two overlapping sets
        reg.grant(af, wire("raw-records"));
        reg.grant(hq, wire("monthly-summary"));
        let vis = reg.visible("amara");
        assert!(vis.contains(&wire("raw-records")));
        assert!(vis.contains(&wire("monthly-summary")));
        assert_eq!(reg.workspaces_of("amara").len(), 2);
    }

    #[test]
    fn friendship_shares_grants() {
        let mut reg = WorkspaceRegistry::new();
        let a = reg.create("a");
        let b = reg.create("b");
        reg.add_member(b, "bea");
        reg.grant(a, wire("model"));
        assert!(!reg.check("bea", &wire("model")));
        reg.befriend(a, b);
        assert!(reg.check("bea", &wire("model")));
    }

    #[test]
    fn revoke_removes_access() {
        let mut reg = WorkspaceRegistry::new();
        let ws = reg.create("x");
        reg.add_member(ws, "p");
        reg.grant(ws, wire("w"));
        assert!(reg.check("p", &wire("w")));
        reg.revoke(ws, &wire("w"));
        assert!(!reg.check("p", &wire("w")));
    }

    // ---- deny paths: overlap is not transitive access ---------------------

    #[test]
    fn overlapping_sets_deny_split_membership_and_grant() {
        // access requires ONE workspace holding BOTH the principal and the
        // grant — membership in A plus a grant in B (even when A and B
        // overlap through another member) must deny.
        let mut reg = WorkspaceRegistry::new();
        let a = reg.create("a");
        let b = reg.create("b");
        reg.add_member(a, "carol");
        reg.add_member(a, "shared");
        reg.add_member(b, "shared"); // a and b overlap through 'shared'
        reg.grant(b, wire("secret"));
        assert!(!reg.check("carol", &wire("secret")), "split membership/grant");
        assert!(reg.check("shared", &wire("secret")), "co-located pair allows");
        assert!(reg.visible("carol").is_empty());
        assert_eq!(reg.denied(), 1);
    }

    #[test]
    fn revoked_grant_stays_denied_across_overlaps() {
        // revocation in one workspace must not be resurrected by another
        // workspace that never held the grant.
        let mut reg = WorkspaceRegistry::new();
        let a = reg.create("a");
        let b = reg.create("b");
        reg.add_member(a, "dan");
        reg.add_member(b, "dan");
        reg.grant(a, wire("records"));
        assert!(reg.check("dan", &wire("records")));
        reg.revoke(a, &wire("records"));
        assert!(!reg.check("dan", &wire("records")), "revocation is final");
        assert!(!reg.visible("dan").contains(&wire("records")));
        // ...but an independent grant elsewhere re-allows (set semantics,
        // no deny-list): this is the documented overlapping-set model.
        reg.grant(b, wire("records"));
        assert!(reg.check("dan", &wire("records")));
    }

    #[test]
    fn resource_variants_do_not_bleed_into_each_other() {
        // a Pipeline grant is not a Wire grant on the same name, and vice
        // versa — the breadboard relies on this separation (tap vs swap).
        let mut reg = WorkspaceRegistry::new();
        let ws = reg.create("ops");
        reg.add_member(ws, "erin");
        reg.grant(ws, Resource::Pipeline("p".into()));
        assert!(reg.check("erin", &Resource::Pipeline("p".into())));
        assert!(!reg.check("erin", &wire("p")));
        assert!(!reg.check("erin", &Resource::Provenance("p".into())));
    }
}
