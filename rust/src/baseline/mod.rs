//! Comparator baselines.
//!
//! The paper positions Koalja against "simple-minded tools like Airflow
//! that treat processing as a series of scheduled tasks without being
//! 'data aware'" (§I), and against the push-everything-to-the-datacentre
//! reflex (§III-G). Two concrete strawmen exercise the same workloads:
//!
//!  * [`ScheduledRunner`] — a cron/Airflow-style driver: every `period`,
//!    run *every* task in topological order on whatever its inputs
//!    currently hold, regardless of whether anything changed. Unchanged
//!    recipes still execute (`wasted_runs`); data arriving mid-period
//!    waits for the next tick (staleness).
//!  * Central placement — `DeployConfig::force_central` ignores `@region`
//!    attrs so all compute (and therefore all raw data) lands in the
//!    nearest datacentre; the E7 bench compares its WAN bill against
//!    edge placement.

use crate::coordinator::{Coordinator, DeployConfig};
use crate::policy::Snapshot;
use crate::util::{SimDuration, SimTime, TaskId};
use anyhow::Result;

/// Deploy config for schedule-driven operation: links queue silently
/// (Manual notify) so arrivals update wire currency but trigger nothing —
/// the cron tick is the only driver, as in Airflow.
pub fn scheduled_config() -> DeployConfig {
    DeployConfig {
        default_notify: crate::bus::NotifyMode::Manual,
        ..Default::default()
    }
}

/// Cron-style schedule-driven execution over a deployed pipeline.
pub struct ScheduledRunner {
    pub period: SimDuration,
    pub ticks: u64,
    pub runs: u64,
    pub wasted: u64,
    pub skipped_no_input: u64,
}

impl ScheduledRunner {
    pub fn new(period: SimDuration) -> Self {
        Self { period, ticks: 0, runs: 0, wasted: 0, skipped_no_input: 0 }
    }

    /// One schedule tick at the coordinator's current virtual time: run
    /// every task (topo order) on the latest value of each input.
    pub fn tick(&mut self, coord: &mut Coordinator) -> Result<()> {
        self.ticks += 1;
        coord.plat.metrics.bump("schedule_ticks");
        let order = coord.graph.topo_order();
        for task in order {
            self.run_task(coord, task)?;
        }
        Ok(())
    }

    fn run_task(&mut self, coord: &mut Coordinator, task: TaskId) -> Result<()> {
        let ports: Vec<String> =
            coord.graph.task(task).stream_inputs().map(|i| i.wire.clone()).collect();
        if ports.is_empty() {
            return Ok(()); // pure sources are driven by injection
        }
        let mut inputs = Vec::with_capacity(ports.len());
        for wire in &ports {
            match coord.latest_on_wire.get(wire) {
                Some(av) => inputs.push((std::sync::Arc::from(wire.as_str()), vec![av.clone()])),
                None => {
                    self.skipped_no_input += 1;
                    return Ok(()); // nothing ever arrived; cron skips
                }
            }
        }
        let snapshot = Snapshot::new(inputs, coord.plat.now);
        // Data-unawareness: if nothing changed, Koalja would have skipped
        // this entirely — the cron baseline burns the run anyway.
        if coord.agents[task.index()].would_memoize(&coord.plat, &snapshot) {
            self.wasted += 1;
            coord.plat.metrics.wasted_runs += 1;
        }
        self.runs += 1;
        coord.suppress_routing = true;
        let r = coord.fire_snapshot_forced(task, snapshot);
        coord.suppress_routing = false;
        r
    }

    /// Drive ticks from the current time until `horizon`. Deliveries are
    /// drained up to each tick (so wire currency advances with time), but
    /// with [`scheduled_config`] nothing fires between ticks.
    pub fn run(&mut self, coord: &mut Coordinator, horizon: SimTime) -> Result<()> {
        let mut t = coord.plat.now + self.period;
        while t <= horizon {
            coord.run_until(t);
            coord.plat.now = t;
            self.tick(coord)?;
            t += self.period;
        }
        coord.run_until(horizon);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::av::{DataClass, Payload};
    use crate::coordinator::DeployConfig;
    use crate::spec::parse;

    fn pipeline() -> Coordinator {
        let spec = parse("[b]\n(raw) work (out)\n").unwrap();
        Coordinator::deploy(&spec, scheduled_config()).unwrap()
    }

    #[test]
    fn scheduled_runner_burns_unchanged_recipes() {
        let mut coord = pipeline();
        coord.inject("raw", Payload::scalar(1.0), DataClass::Summary).unwrap();
        coord.run_until_idle(); // reactive delivery populates latest_on_wire
        let mut cron = ScheduledRunner::new(SimDuration::secs(1));
        // 5 ticks, data never changes: 1 real run + 4 wasted
        cron.run(&mut coord, SimTime::secs(5)).unwrap();
        assert_eq!(cron.runs, 5);
        assert!(cron.wasted >= 4, "wasted {}", cron.wasted);
        assert_eq!(coord.plat.metrics.wasted_runs, cron.wasted);
    }

    #[test]
    fn scheduled_runner_skips_tasks_with_no_data() {
        let mut coord = pipeline();
        let mut cron = ScheduledRunner::new(SimDuration::secs(1));
        cron.run(&mut coord, SimTime::secs(3)).unwrap();
        assert_eq!(cron.runs, 0);
        assert_eq!(cron.skipped_no_input, 3);
    }

    #[test]
    fn scheduled_staleness_vs_reactive() {
        // data arrives at t=0.1s; cron with 1s period produces output at
        // t=1s — reactive Koalja produced it within milliseconds.
        let spec = parse("[b]\n(raw) work (out)\n").unwrap();
        let mut coord = Coordinator::deploy(&spec, DeployConfig::default()).unwrap();
        coord
            .inject_at(
                "raw",
                Payload::scalar(2.0),
                DataClass::Summary,
                crate::util::RegionId::new(0),
                SimTime::millis(100),
            )
            .unwrap();
        coord.run_until_idle();
        let reactive_latency = coord.plat.metrics.e2e_latency.mean();
        assert!(reactive_latency < SimDuration::millis(100));

        let mut coord2 = pipeline();
        coord2
            .inject_at(
                "raw",
                Payload::scalar(2.0),
                DataClass::Summary,
                crate::util::RegionId::new(0),
                SimTime::millis(100),
            )
            .unwrap();
        // cron never lets the reactive path run; drain deliveries only
        // (they queue in topics but Wake fires... to isolate, use make-less
        // approach: tick at 1s with latest_on_wire set by injection)
        let mut cron = ScheduledRunner::new(SimDuration::secs(1));
        cron.run(&mut coord2, SimTime::secs(2)).unwrap();
        let cron_latency = coord2.plat.metrics.e2e_latency.mean();
        assert!(
            cron_latency > reactive_latency.scale(2.0),
            "cron {cron_latency} vs reactive {reactive_latency}"
        );
    }
}
