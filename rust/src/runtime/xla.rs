//! Offline stub for the `xla` crate (PJRT bindings).
//!
//! The real runtime links `xla-rs` + `xla_extension` (a multi-GB C++
//! dependency) to compile and execute the AOT-lowered HLO text on a PJRT
//! CPU client. This build environment vendors no native deps, so the same
//! API surface is stubbed here: every type signature `runtime/mod.rs`
//! needs exists and compiles, and [`PjRtClient::cpu`] reports — rather than
//! segfaults — that no backend is present. Integration tests that need a
//! live PJRT client (`rust/tests/runtime_e2e.rs`) detect the error and
//! skip; everything else in the platform (coordinator, breadboard, pure-
//! rust task bodies) is backend-free.
//!
//! To wire the real backend: delete this module, add `xla = "0.1"` (with
//! `XLA_EXTENSION_DIR` set) to Cargo.toml, and remove the `mod xla;` line
//! in `runtime/mod.rs` — the call sites are written against the real API.

use std::fmt;
use std::path::Path;

/// Error type standing in for `xla::Error`.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable() -> XlaError {
    XlaError(
        "PJRT backend not vendored in this offline build; \
         see DESIGN.md §Runtime for wiring the real `xla` crate"
            .to_string(),
    )
}

/// Host-side tensor literal (f32 only — all koalja artifacts are f32).
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

/// Element types extractable from a [`Literal`].
pub trait Element: Sized {
    fn extract(lit: &Literal) -> Vec<Self>;
}

impl Element for f32 {
    fn extract(lit: &Literal) -> Vec<f32> {
        lit.data.clone()
    }
}

impl Literal {
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { data: v.to_vec(), dims: vec![v.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(XlaError(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable())
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, XlaError> {
        Ok(T::extract(self))
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto, XlaError> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| XlaError(format!("{}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// An XLA computation built from an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable;

/// One device buffer holding an execution result.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

/// The PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// In the real crate this boots the PJRT CPU plugin; here it reports
    /// that no backend is vendored so callers can degrade gracefully.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub-no-pjrt".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
    }

    #[test]
    fn literal_reshape_checks_element_count() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }
}
