//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! rust hot path. Python never runs here — `make artifacts` ran once at
//! build time (L2/L1), emitting `artifacts/*.hlo.txt` + `manifest.json`.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. Modules are lowered with `return_tuple=True`, so
//! every execution returns a tuple literal we decompose.

// Offline builds resolve `xla::` to the in-tree stub (see xla.rs for how
// to swap in the real PJRT bindings — call sites match the real API).
mod xla;

use crate::av::Payload;
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shape+dtype of one executable input/output, from the manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Manifest entry for one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub doc: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensor specs"))?
        .iter()
        .map(|t| {
            let shape = t
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensor spec missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            let dtype = t
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("float32")
                .to_string();
            Ok(TensorSpec { shape, dtype })
        })
        .collect()
}

/// Parse `manifest.json` text.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactMeta>> {
    let v = Json::parse(text).context("manifest.json parse")?;
    let arts = v
        .get("artifacts")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
    arts.iter()
        .map(|a| {
            Ok(ArtifactMeta {
                name: a
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing name"))?
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing file"))?
                    .to_string(),
                doc: a.get("doc").and_then(Json::as_str).unwrap_or("").to_string(),
                inputs: tensor_specs(a.get("inputs").ok_or_else(|| anyhow!("no inputs"))?)?,
                outputs: tensor_specs(a.get("outputs").ok_or_else(|| anyhow!("no outputs"))?)?,
            })
        })
        .collect()
}

/// One compiled executable. Compilation happens once at load; `run` is the
/// request-path operation.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    /// executions performed (metrics). Atomic (not `Cell`) so executables
    /// can be shared across the wavefront scheduler's worker threads —
    /// `Arc<Executable>` must be `Send`, which needs `Executable: Sync`.
    pub runs: AtomicU64,
}

impl Executable {
    /// Executions performed so far (metrics read).
    pub fn runs(&self) -> u64 {
        self.runs.load(Ordering::Relaxed)
    }
}

impl Executable {
    /// Execute with f32 tensor payloads; shapes are validated against the
    /// manifest. Returns one `Payload::Tensor` per manifest output.
    pub fn run(&self, inputs: &[&Payload]) -> Result<Vec<Payload>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (p, spec)) in inputs.iter().zip(&self.meta.inputs).enumerate() {
            let (shape, data) = p
                .as_tensor()
                .ok_or_else(|| anyhow!("{}: input {i} is not a tensor", self.meta.name))?;
            if shape != spec.shape.as_slice() {
                bail!(
                    "{}: input {i} shape {:?} != manifest {:?}",
                    self.meta.name,
                    shape,
                    spec.shape
                );
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.meta.name,
                parts.len(),
                self.meta.outputs.len()
            );
        }
        self.runs.fetch_add(1, Ordering::Relaxed);
        parts
            .into_iter()
            .zip(&self.meta.outputs)
            .map(|(lit, spec)| {
                let data = lit.to_vec::<f32>()?;
                if data.len() != spec.elements() {
                    bail!("{}: output size mismatch", self.meta.name);
                }
                Ok(Payload::tensor(&spec.shape, data))
            })
            .collect()
    }
}

/// The artifact registry: PJRT CPU client + compiled executables by name.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Vec<ArtifactMeta>,
    compiled: HashMap<String, Arc<Executable>>,
}

impl Runtime {
    /// Open `dir` (containing manifest.json + *.hlo.txt). Executables are
    /// compiled lazily on first `load`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
        })?;
        let manifest = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, dir, manifest, compiled: HashMap::new() })
    }

    /// Default artifacts directory (workspace-relative).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    pub fn manifest(&self) -> &[ArtifactMeta] {
        &self.manifest
    }

    /// Load (compile-once) an artifact by name.
    pub fn load(&mut self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.compiled.get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(self.dir.join(&meta.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let rc = Arc::new(Executable { meta, exe, runs: AtomicU64::new(0) });
        self.compiled.insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{"format":"hlo-text/return-tuple","artifacts":[
            {"name":"m","file":"m.hlo.txt","doc":"d",
             "inputs":[{"shape":[2,3],"dtype":"float32"}],
             "outputs":[{"shape":[3],"dtype":"float32"}]}]}"#;
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].name, "m");
        assert_eq!(m[0].inputs[0].shape, vec![2, 3]);
        assert_eq!(m[0].outputs[0].elements(), 3);
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(parse_manifest("{}").is_err());
        assert!(parse_manifest(r#"{"artifacts":[{"file":"x"}]}"#).is_err());
        assert!(parse_manifest("not json").is_err());
    }

    // Execution-path tests (real PJRT + real artifacts) live in
    // rust/tests/runtime_e2e.rs — they need `make artifacts` to have run.
}
