//! The vendored concurrency primitives under a feed: a bounded MPSC
//! channel (crossbeam-style, built in-tree like the other offline shims)
//! plus the wake bell the pump parks on.
//!
//! One channel per feed. Producers on external threads `push` (blocking
//! while full) or `try_push` (returning a structured
//! [`Backpressure`](super::Backpressure) rejection); the pump drains the
//! whole buffer under one lock acquisition per cycle. The per-feed low
//! watermark lives *inside* the channel state on purpose: the pump reads
//! `(buffered events, watermark, closed)` atomically under the channel
//! lock, so the watermark it observes can never run ahead of the events
//! it drained — the ordering that makes sealing sound (see
//! `super::pump`).

use crate::av::{DataClass, Payload};
use crate::util::{RegionId, SimTime};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// One event queued on a feed, stamped with the per-feed push sequence
/// (the canonical tiebreak when same-instant events from several feeds
/// are merged — see `super::pump`).
pub(crate) struct QueuedEvent {
    pub at: SimTime,
    pub seq: u64,
    pub payload: Payload,
    pub class: DataClass,
    pub region: RegionId,
}

struct FeedState {
    buf: VecDeque<QueuedEvent>,
    /// Low watermark: the producer promises every future push on this
    /// feed arrives strictly after it. `None` = nothing promised yet.
    wm: Option<SimTime>,
    closed: bool,
    next_seq: u64,
    /// `try_push` rejections since the last drain (backpressure events).
    rejected: u64,
}

/// What one drain observed, atomically: every buffered event plus the
/// watermark/closed state *as of the same lock acquisition*.
pub(crate) struct Drained {
    pub events: Vec<QueuedEvent>,
    pub wm: Option<SimTime>,
    pub closed: bool,
    pub rejected: u64,
}

/// Outcome of a push attempt, before it is dressed up as an
/// [`IngestError`](super::IngestError) (the channel layer knows depths
/// and capacities; the feed layer knows its name).
pub(crate) enum PushRefusal {
    Full { depth: usize },
    BehindWatermark { at: SimTime, watermark: SimTime },
    Closed,
}

/// The bounded MPSC core shared by a [`Feed`](super::Feed)'s clones and
/// its pump-side endpoint.
pub(crate) struct FeedCore {
    state: Mutex<FeedState>,
    not_full: Condvar,
    cap: usize,
    bell: Arc<WakeBell>,
}

impl FeedCore {
    pub fn new(cap: usize, bell: Arc<WakeBell>) -> Self {
        Self {
            state: Mutex::new(FeedState {
                buf: VecDeque::new(),
                wm: None,
                closed: false,
                next_seq: 0,
                rejected: 0,
            }),
            not_full: Condvar::new(),
            cap: cap.max(1),
            bell,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Blocking push: waits while the buffer is full (credit returns when
    /// the pump drains), then enqueues and rings the pump's bell.
    pub fn push(
        &self,
        at: SimTime,
        payload: Payload,
        class: DataClass,
        region: RegionId,
    ) -> Result<(), PushRefusal> {
        let mut s = self.state.lock().unwrap();
        loop {
            if s.closed {
                return Err(PushRefusal::Closed);
            }
            if let Some(wm) = s.wm {
                if at <= wm {
                    return Err(PushRefusal::BehindWatermark { at, watermark: wm });
                }
            }
            if s.buf.len() < self.cap {
                let seq = s.next_seq;
                s.next_seq += 1;
                s.buf.push_back(QueuedEvent { at, seq, payload, class, region });
                drop(s);
                self.bell.ring();
                return Ok(());
            }
            s = self.not_full.wait(s).unwrap();
        }
    }

    /// Non-blocking push: a full buffer is a structured refusal carrying
    /// the observed depth, so producers can shed or retry on their own
    /// schedule (credit-based backpressure without blocking).
    pub fn try_push(
        &self,
        at: SimTime,
        payload: Payload,
        class: DataClass,
        region: RegionId,
    ) -> Result<(), PushRefusal> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(PushRefusal::Closed);
        }
        if let Some(wm) = s.wm {
            if at <= wm {
                return Err(PushRefusal::BehindWatermark { at, watermark: wm });
            }
        }
        if s.buf.len() >= self.cap {
            let depth = s.buf.len();
            s.rejected += 1;
            return Err(PushRefusal::Full { depth });
        }
        let seq = s.next_seq;
        s.next_seq += 1;
        s.buf.push_back(QueuedEvent { at, seq, payload, class, region });
        drop(s);
        self.bell.ring();
        Ok(())
    }

    /// Advance the feed's low watermark: every future push must arrive
    /// strictly after `t`. Monotonic (a lower `t` is a no-op); errors
    /// after close.
    pub fn advance(&self, t: SimTime) -> Result<(), PushRefusal> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(PushRefusal::Closed);
        }
        s.wm = Some(s.wm.map_or(t, |w| w.max(t)));
        drop(s);
        self.bell.ring();
        Ok(())
    }

    /// Close the feed: no more pushes; blocked producers wake with
    /// [`PushRefusal::Closed`]. Idempotent.
    pub fn close(&self) {
        let mut s = self.state.lock().unwrap();
        s.closed = true;
        drop(s);
        self.not_full.notify_all();
        self.bell.ring();
    }

    /// Pump-side: take every buffered event and read the watermark/closed
    /// state under the same lock (the consistency the sealing proof
    /// needs), then wake blocked producers — the drained capacity is
    /// their credit.
    pub fn drain(&self) -> Drained {
        let mut s = self.state.lock().unwrap();
        let events: Vec<QueuedEvent> = s.buf.drain(..).collect();
        let out = Drained {
            events,
            wm: s.wm,
            closed: s.closed,
            rejected: std::mem::take(&mut s.rejected),
        };
        drop(s);
        self.not_full.notify_all();
        out
    }

    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().buf.len()
    }

    pub fn watermark(&self) -> Option<SimTime> {
        self.state.lock().unwrap().wm
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

/// The pump's doorbell: every feed push / advance / close rings it, and
/// the pump parks on it when there is nothing to seal and nothing to run
/// — the fix for the busy-spin an empty heap with open feeds used to
/// cause. Epoch-counted so a ring between "pump decides to park" and
/// "pump actually waits" is never lost: the pump snapshots the epoch
/// before draining and waits only while the epoch is unchanged.
pub(crate) struct WakeBell {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl WakeBell {
    pub fn new() -> Self {
        Self { epoch: Mutex::new(0), cv: Condvar::new() }
    }

    pub fn ring(&self) {
        *self.epoch.lock().unwrap() += 1;
        self.cv.notify_all();
    }

    pub fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap()
    }

    /// Park until the epoch moves past `seen` or `timeout` elapses.
    /// Returns `true` when woken by a ring, `false` on timeout.
    pub fn wait_past(&self, seen: u64, timeout: Duration) -> bool {
        let g = self.epoch.lock().unwrap();
        let (g, res) = self.cv.wait_timeout_while(g, timeout, |e| *e == seen).unwrap();
        let woken = !res.timed_out() || *g != seen;
        drop(g);
        woken
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn core(cap: usize) -> FeedCore {
        FeedCore::new(cap, Arc::new(WakeBell::new()))
    }

    #[test]
    fn drain_sees_events_and_watermark_atomically() {
        let c = core(8);
        c.push(SimTime::micros(5), Payload::scalar(1.0), DataClass::Summary, RegionId::new(0))
            .ok()
            .unwrap();
        c.advance(SimTime::micros(5)).ok().unwrap();
        let d = c.drain();
        assert_eq!(d.events.len(), 1);
        assert_eq!(d.wm, Some(SimTime::micros(5)));
        assert!(!d.closed);
        // a push at or behind the promised watermark is refused
        let refusal = c
            .push(SimTime::micros(5), Payload::scalar(2.0), DataClass::Summary, RegionId::new(0))
            .err()
            .unwrap();
        assert!(matches!(refusal, PushRefusal::BehindWatermark { .. }));
    }

    #[test]
    fn try_push_counts_rejections() {
        let c = core(1);
        c.try_push(SimTime::micros(1), Payload::scalar(0.0), DataClass::Summary, RegionId::new(0))
            .ok()
            .unwrap();
        let r = c
            .try_push(SimTime::micros(2), Payload::scalar(0.0), DataClass::Summary, RegionId::new(0))
            .err()
            .unwrap();
        assert!(matches!(r, PushRefusal::Full { depth: 1 }));
        let d = c.drain();
        assert_eq!(d.rejected, 1);
        assert_eq!(d.events.len(), 1);
        assert_eq!(c.drain().rejected, 0, "rejection counter resets per drain");
    }

    #[test]
    fn close_wakes_and_refuses() {
        let c = core(4);
        c.close();
        assert!(matches!(
            c.push(SimTime::ZERO, Payload::scalar(0.0), DataClass::Summary, RegionId::new(0)),
            Err(PushRefusal::Closed)
        ));
        assert!(c.is_closed());
    }

    #[test]
    fn bell_epoch_prevents_lost_wakeups() {
        let bell = WakeBell::new();
        let seen = bell.epoch();
        bell.ring();
        // the ring landed before the wait: wait_past returns immediately
        assert!(bell.wait_past(seen, Duration::from_millis(1)));
        // nothing rings: the wait times out
        let seen = bell.epoch();
        assert!(!bell.wait_past(seen, Duration::from_millis(1)));
    }
}
