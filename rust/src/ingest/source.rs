//! The producer-facing surface: [`Feed`] handles external threads push
//! through, the [`Source`] connector trait for pull-style adapters, and
//! the structured errors producers react to.

use super::channel::{FeedCore, PushRefusal};
use crate::av::{DataClass, Payload};
use crate::util::{RegionId, SimTime, WireId};
use std::sync::Arc;

/// One timestamped event bound for a feed's wire.
#[derive(Clone)]
pub struct TimedEvent {
    pub at: SimTime,
    pub payload: Payload,
    pub class: DataClass,
    pub region: RegionId,
}

impl TimedEvent {
    pub fn new(at: SimTime, payload: Payload, class: DataClass, region: RegionId) -> Self {
        Self { at, payload, class, region }
    }
}

/// The credit refusal a non-blocking push returns when a feed's bounded
/// queue is full: which queue, how deep, and its capacity — enough for a
/// producer to shed load, slow down, or switch to the blocking `push`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Backpressure {
    pub queue: String,
    pub depth: usize,
    pub capacity: usize,
}

/// Why a push or advance was refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum IngestError {
    /// The bounded queue is full (only `try_push` surfaces this; `push`
    /// blocks until the pump drains credit back).
    Backpressure(Backpressure),
    /// The event arrived at or behind the feed's own advanced watermark
    /// — accepting it would break event-time completeness.
    BehindWatermark { feed: String, at: SimTime, watermark: SimTime },
    /// The feed was closed.
    Closed { feed: String },
}

impl std::fmt::Display for IngestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IngestError::Backpressure(bp) => write!(
                f,
                "backpressure on feed '{}': queue at {}/{}",
                bp.queue, bp.depth, bp.capacity
            ),
            IngestError::BehindWatermark { feed, at, watermark } => write!(
                f,
                "feed '{feed}': event at {at} is not after the advanced watermark {watermark}"
            ),
            IngestError::Closed { feed } => write!(f, "feed '{feed}' is closed"),
        }
    }
}

impl std::error::Error for IngestError {}

/// A pull-style connector the pump (or a producer thread via
/// [`Feed::run_source`]) polls for batches of timestamped events.
///
/// Each `poll` appends zero or more events to `out` and returns the
/// feed's new low watermark — the promise that every event from later
/// polls arrives strictly after it. Returning `None` means the source is
/// exhausted and the feed should close.
pub trait Source: Send {
    /// The external wire this source feeds.
    fn wire(&self) -> &str;
    /// Produce the next batch; return the new low watermark, or `None`
    /// when exhausted.
    fn poll(&mut self, out: &mut Vec<TimedEvent>) -> Option<SimTime>;
}

/// Replays a pre-recorded, time-sorted event trace in chunks — the
/// connector for tests, examples, and soak benches. Chunks end only at
/// strict timestamp increases so the watermark promise ("everything
/// later is strictly after") holds even when the trace has repeated
/// timestamps.
pub struct ReplaySource {
    wire: String,
    events: Vec<TimedEvent>,
    next: usize,
    chunk: usize,
}

impl ReplaySource {
    /// `events` must be sorted by `at` (checked); `chunk` is the nominal
    /// poll size (stretched to the next strict increase).
    pub fn new(wire: &str, events: Vec<TimedEvent>, chunk: usize) -> Self {
        debug_assert!(
            events.windows(2).all(|w| w[0].at <= w[1].at),
            "ReplaySource trace must be sorted by timestamp"
        );
        Self { wire: wire.to_string(), events, next: 0, chunk: chunk.max(1) }
    }
}

impl Source for ReplaySource {
    fn wire(&self) -> &str {
        &self.wire
    }

    fn poll(&mut self, out: &mut Vec<TimedEvent>) -> Option<SimTime> {
        if self.next >= self.events.len() {
            return None;
        }
        let mut end = (self.next + self.chunk).min(self.events.len());
        // stretch to a strict-increase boundary: never split a run of
        // equal timestamps across a watermark
        while end < self.events.len() && self.events[end].at == self.events[end - 1].at {
            end += 1;
        }
        out.extend(self.events[self.next..end].iter().cloned());
        self.next = end;
        Some(self.events[end - 1].at)
    }
}

/// A cloneable, thread-safe handle onto one external wire's bounded
/// ingest queue. Obtained from `Coordinator::open_feed` (or
/// `Pipeline::open_feed`); any number of producer threads may push
/// through clones concurrently with pipeline execution.
#[derive(Clone)]
pub struct Feed {
    pub(crate) wire: WireId,
    pub(crate) name: Arc<str>,
    pub(crate) core: Arc<FeedCore>,
}

impl Feed {
    /// The external wire this feed injects into.
    pub fn wire_name(&self) -> &str {
        &self.name
    }

    pub(crate) fn wire_id(&self) -> WireId {
        self.wire
    }

    /// Blocking push: waits for queue credit when full. The timestamp
    /// must be strictly after any watermark this feed has advanced.
    pub fn push(
        &self,
        at: SimTime,
        payload: Payload,
        class: DataClass,
        region: RegionId,
    ) -> Result<(), IngestError> {
        self.core.push(at, payload, class, region).map_err(|r| self.dress(r))
    }

    /// Non-blocking push: a full queue returns
    /// [`IngestError::Backpressure`] with the observed depth instead of
    /// waiting.
    pub fn try_push(
        &self,
        at: SimTime,
        payload: Payload,
        class: DataClass,
        region: RegionId,
    ) -> Result<(), IngestError> {
        self.core.try_push(at, payload, class, region).map_err(|r| self.dress(r))
    }

    /// Advance this feed's low watermark: a promise that every future
    /// push arrives strictly after `t`. The pipeline frontier (and with
    /// it virtual time) only moves when every open feed has advanced.
    pub fn advance(&self, t: SimTime) -> Result<(), IngestError> {
        self.core.advance(t).map_err(|r| self.dress(r))
    }

    /// Close the feed: no further pushes; once every feed closes the
    /// pump drains to idle. Idempotent.
    pub fn close(&self) {
        self.core.close();
    }

    pub fn watermark(&self) -> Option<SimTime> {
        self.core.watermark()
    }

    pub fn is_closed(&self) -> bool {
        self.core.is_closed()
    }

    /// Current queue depth (racy by nature; for monitoring).
    pub fn depth(&self) -> usize {
        self.core.depth()
    }

    pub fn capacity(&self) -> usize {
        self.core.capacity()
    }

    /// Drive a pull-style [`Source`] to exhaustion through this feed:
    /// poll, blocking-push each event, advance the returned watermark,
    /// close when the source returns `None`. The usual body of a
    /// producer thread.
    pub fn run_source(&self, mut src: impl Source) -> Result<(), IngestError> {
        let mut buf = Vec::new();
        loop {
            buf.clear();
            let wm = src.poll(&mut buf);
            for ev in buf.drain(..) {
                self.push(ev.at, ev.payload, ev.class, ev.region)?;
            }
            match wm {
                Some(t) => self.advance(t)?,
                None => {
                    self.close();
                    return Ok(());
                }
            }
        }
    }

    fn dress(&self, r: PushRefusal) -> IngestError {
        match r {
            PushRefusal::Full { depth } => IngestError::Backpressure(Backpressure {
                queue: self.name.to_string(),
                depth,
                capacity: self.core.capacity(),
            }),
            PushRefusal::BehindWatermark { at, watermark } => IngestError::BehindWatermark {
                feed: self.name.to_string(),
                at,
                watermark,
            },
            PushRefusal::Closed => IngestError::Closed { feed: self.name.to_string() },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ingest::channel::WakeBell;

    fn feed(cap: usize) -> Feed {
        Feed {
            wire: WireId::new(0),
            name: Arc::from("raw"),
            core: Arc::new(FeedCore::new(cap, Arc::new(WakeBell::new()))),
        }
    }

    fn ev(us: u64) -> TimedEvent {
        TimedEvent::new(
            SimTime::micros(us),
            Payload::scalar(us as f32),
            DataClass::Summary,
            RegionId::new(0),
        )
    }

    #[test]
    fn backpressure_error_carries_queue_depth_and_capacity() {
        let f = feed(2);
        f.try_push(SimTime::micros(1), Payload::scalar(0.0), DataClass::Summary, RegionId::new(0))
            .unwrap();
        f.try_push(SimTime::micros(2), Payload::scalar(0.0), DataClass::Summary, RegionId::new(0))
            .unwrap();
        let err = f
            .try_push(SimTime::micros(3), Payload::scalar(0.0), DataClass::Summary, RegionId::new(0))
            .unwrap_err();
        assert_eq!(
            err,
            IngestError::Backpressure(Backpressure {
                queue: "raw".to_string(),
                depth: 2,
                capacity: 2,
            })
        );
        assert_eq!(err.to_string(), "backpressure on feed 'raw': queue at 2/2");
    }

    #[test]
    fn behind_watermark_error_names_feed_and_times() {
        let f = feed(8);
        f.advance(SimTime::micros(10)).unwrap();
        let err = f
            .push(SimTime::micros(10), Payload::scalar(0.0), DataClass::Summary, RegionId::new(0))
            .unwrap_err();
        assert_eq!(
            err,
            IngestError::BehindWatermark {
                feed: "raw".to_string(),
                at: SimTime::micros(10),
                watermark: SimTime::micros(10),
            }
        );
        f.close();
        let err = f
            .push(SimTime::micros(11), Payload::scalar(0.0), DataClass::Summary, RegionId::new(0))
            .unwrap_err();
        assert_eq!(err, IngestError::Closed { feed: "raw".to_string() });
    }

    #[test]
    fn replay_source_never_splits_equal_timestamps() {
        let trace = vec![ev(1), ev(2), ev(2), ev(2), ev(3)];
        let mut src = ReplaySource::new("raw", trace, 2);
        let mut out = Vec::new();
        // nominal chunk of 2 stretches to cover the whole t=2 run
        assert_eq!(src.poll(&mut out), Some(SimTime::micros(2)));
        assert_eq!(out.len(), 4);
        out.clear();
        assert_eq!(src.poll(&mut out), Some(SimTime::micros(3)));
        assert_eq!(out.len(), 1);
        out.clear();
        assert_eq!(src.poll(&mut out), None, "exhausted source closes the feed");
    }

    #[test]
    fn run_source_replays_through_the_feed_and_closes() {
        let f = feed(64);
        f.run_source(ReplaySource::new("raw", vec![ev(1), ev(2), ev(3)], 2)).unwrap();
        assert!(f.is_closed());
        assert_eq!(f.depth(), 3);
        assert_eq!(f.watermark(), Some(SimTime::micros(3)));
    }
}
