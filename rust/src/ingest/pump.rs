//! The pump: the single consumer that moves events from feed queues
//! into the coordinator, interleaved with wavefront execution.
//!
//! # The canonical cycle
//!
//! Each cycle: drain every feed (one lock each, observing events +
//! watermark + closed atomically), fold the observations into the
//! [`WatermarkClock`], and compute the frontier `w`. Events at or below
//! `w` are *sealed* — event time there is complete, no feed can ever
//! push into it again — so they are sorted into the canonical order
//! `(at, feed registration index, per-feed push sequence)` and walked
//! instant by instant, **merged with the coordinator's own pending
//! events**: at each step the next instant `T` is the earlier of the
//! next sealed instant and the next heap instant (≤ w); sealed events at
//! `T` are injected (grouped into maximal consecutive
//! `(wire, class, region)` runs, one `inject_batch_at_id` each), then
//! `run_until(T)` executes everything due.
//!
//! # Why every arrangement commits the same books
//!
//! The merged instant walk is a pure function of (per-feed event
//! sequences, pipeline state): producer interleaving only changes *when*
//! events surface in a drain, never their `(at, feed, seq)` key; the
//! frontier is monotone however advances are batched; and a cycle
//! boundary (or the adaptive credit truncating a cycle between instants)
//! just pauses the walk — the next cycle resumes it at the same point.
//! So AV mint order, delivery order, and commit order — hence sink
//! books, commit logs, provenance, and span projections — are
//! byte-identical for any producer thread count, pump cadence, batch
//! credit, worker count, or node count. Batching changes *when* events
//! enter the coordinator, never *what* runs at each instant.
//!
//! Every injection also happens with `at > now` (strict), except a
//! genuine event at virtual zero before anything ran — exactly the
//! currency semantics of classic future-dated `inject_at`.

use super::batcher::AdaptiveBatcher;
use super::channel::WakeBell;
use super::source::Feed;
use super::watermark::{Frontier, StalledFeed, WatermarkClock};
use super::IngestStats;
use crate::av::{DataClass, Payload};
use crate::coordinator::Coordinator;
use crate::util::{RegionId, SimDuration, SimTime, WireId};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A drained event staged for sealing, stamped with its canonical sort
/// key: `(at, feed registration index, per-feed push sequence)`.
struct StagedEvent {
    at: SimTime,
    feed: u32,
    seq: u64,
    wire: WireId,
    payload: Payload,
    class: DataClass,
    region: RegionId,
}

/// What one cycle accomplished — the pump loop's parking decision.
pub(crate) struct CycleOutcome {
    /// Bell epoch snapshotted *before* the drains: parking waits past
    /// this, so a push racing the cycle is never a lost wakeup.
    pub epoch: u64,
    /// Drained, injected, or executed anything.
    pub progress: bool,
    /// Every feed closed and every staged event injected.
    pub done: bool,
}

/// Outcome of [`Coordinator::pump_ingest`]: final ingest statistics plus
/// how the loop ended.
#[derive(Debug, Clone)]
pub struct IngestReport {
    pub stats: IngestStats,
    /// The `drain_deadline` elapsed before every feed closed and
    /// drained — the escape hatch that keeps tests from hanging on a
    /// producer that never closes.
    pub timed_out: bool,
    /// Open feeds pinning the frontier behind their peers when the loop
    /// ended (empty on a clean drain).
    pub stalled: Vec<StalledFeed>,
}

/// Default virtual-time gap behind the leading watermark before an open
/// feed is reported as stalled.
pub const DEFAULT_STALL_THRESHOLD: SimDuration = SimDuration(30_000_000);

/// How long one park lasts before the loop re-checks its deadline.
const PARK_SLICE: Duration = Duration::from_millis(20);

pub(crate) struct IngestPump {
    feeds: Vec<Feed>,
    clock: WatermarkClock,
    staged: Vec<StagedEvent>,
    batcher: AdaptiveBatcher,
    pub(crate) bell: Arc<WakeBell>,
    pub(crate) stats: IngestStats,
    stall_threshold: SimDuration,
    /// Last reported stall set (dedup so a persistent laggard warns once).
    last_stalls: Vec<StalledFeed>,
}

impl IngestPump {
    pub fn new() -> Self {
        Self {
            feeds: Vec::new(),
            clock: WatermarkClock::new(),
            staged: Vec::new(),
            batcher: AdaptiveBatcher::new(),
            bell: Arc::new(WakeBell::new()),
            stats: IngestStats::default(),
            stall_threshold: DEFAULT_STALL_THRESHOLD,
            last_stalls: Vec::new(),
        }
    }

    pub fn set_stall_threshold(&mut self, t: SimDuration) {
        self.stall_threshold = t;
    }

    /// Register a feed (already validated to target an injectable wire).
    /// Registration order is the canonical same-instant tiebreak.
    pub fn register(&mut self, feed: Feed) {
        self.clock.register(feed.wire_name());
        self.feeds.push(feed);
    }

    pub fn feed_named(&self, name: &str) -> Option<&Feed> {
        self.feeds.iter().find(|f| f.wire_name() == name)
    }

    pub fn stalled(&self) -> Vec<StalledFeed> {
        self.clock.stalled(self.stall_threshold)
    }

    /// One canonical cycle: drain → seal → merged instant walk.
    pub fn cycle(&mut self, coord: &mut Coordinator) -> CycleOutcome {
        let epoch = self.bell.epoch();
        self.stats.cycles += 1;

        // -- drain every feed; fold watermarks into the clock
        let mut drained = 0usize;
        for (i, f) in self.feeds.iter().enumerate() {
            let d = f.core.drain();
            self.clock.observe(i as u32, d.wm, d.closed);
            self.stats.backpressure_rejections += d.rejected;
            drained += d.events.len();
            for ev in d.events {
                self.staged.push(StagedEvent {
                    at: ev.at,
                    feed: i as u32,
                    seq: ev.seq,
                    wire: f.wire_id(),
                    payload: ev.payload,
                    class: ev.class,
                    region: ev.region,
                });
            }
        }
        let backlog = self.staged.len();
        self.stats.depth_high_water = self.stats.depth_high_water.max(backlog);

        // -- frontier: how far event time is complete
        let frontier = self.clock.frontier();
        let seal_to = match frontier {
            Frontier::Unknown => None,
            Frontier::At(t) => Some(t),
            // all feeds closed: everything staged is final
            Frontier::Open => self.staged.iter().map(|e| e.at).max(),
        };
        if let (Some(newest), Frontier::At(t)) =
            (self.staged.iter().map(|e| e.at).max(), frontier)
        {
            let lag = newest.saturating_sub(t);
            self.stats.watermark_lag_max = self.stats.watermark_lag_max.max(lag);
        }

        let mut injected = 0usize;
        let mut cycle_batches = 0u32;
        let mut cycle_largest = 0usize;
        let mut ran = 0u64;
        if let Some(w) = seal_to {
            // frontier handoff: the injection feeds' contribution to the
            // coordinator's input frontier (see coordinator::frontier) —
            // event time ≤ w is complete, no feed can push below it again
            coord.note_ingest_frontier(w);
            // -- seal: pull out everything at or below the frontier
            let mut ready: Vec<StagedEvent> = Vec::new();
            let mut i = 0;
            while i < self.staged.len() {
                if self.staged[i].at <= w {
                    ready.push(self.staged.swap_remove(i));
                } else {
                    i += 1;
                }
            }
            ready.sort_unstable_by_key(|e| (e.at, e.feed, e.seq));
            let mut ready: VecDeque<StagedEvent> = ready.into();

            // -- merged instant walk (see module docs)
            let credit = self.batcher.cycle_credit(backlog);
            loop {
                if injected >= credit {
                    break; // truncate between instants; next cycle resumes
                }
                let next_staged = ready.front().map(|e| e.at);
                let next_heap = coord.next_event_at().filter(|&t| t <= w);
                let t = match (next_staged, next_heap) {
                    (None, None) => break,
                    (Some(a), None) => a,
                    (None, Some(b)) => b,
                    (Some(a), Some(b)) => a.min(b),
                };
                if next_staged == Some(t) {
                    // inject this whole instant, in canonical runs
                    let mut instant: Vec<StagedEvent> = Vec::new();
                    while ready.front().is_some_and(|e| e.at == t) {
                        instant.push(ready.pop_front().unwrap());
                    }
                    let mut s = 0;
                    while s < instant.len() {
                        let (wire, class, region) =
                            (instant[s].wire, instant[s].class, instant[s].region);
                        let mut e = s + 1;
                        while e < instant.len()
                            && instant[e].wire == wire
                            && instant[e].class == class
                            && instant[e].region == region
                        {
                            e += 1;
                        }
                        let payloads: Vec<Payload> = instant[s..e]
                            .iter_mut()
                            .map(|ev| {
                                std::mem::replace(&mut ev.payload, Payload::Ghost {
                                    pretend_bytes: 0,
                                })
                            })
                            .collect();
                        let n = payloads.len();
                        coord
                            .inject_batch_at_id(wire, payloads, class, region, t)
                            .expect("feed wire validated at open_feed");
                        self.batcher.note_batch(n);
                        cycle_batches += 1;
                        cycle_largest = cycle_largest.max(n);
                        s = e;
                    }
                    injected += instant.len();
                }
                ran += coord.run_until(t);
            }

            if ready.is_empty() && injected < credit {
                // the walk completed: advance virtual time to the
                // frontier so due timers/polls don't wait for the next
                // external event (processes nothing — the walk already
                // drained every instant ≤ w)
                ran += coord.run_until(w);
            } else {
                // truncated: un-walked events resume next cycle
                self.staged.extend(ready);
            }
        }

        self.stats.events += injected as u64;
        self.stats.batches = self.batcher.batches();
        self.stats.largest_batch = self.batcher.largest();
        self.stats.batched_events = self.batcher.batched_events();
        if injected > 0 && coord.obs_mut().enabled {
            let now = coord.plat.now;
            coord.obs_mut().ingest_flush(
                now,
                injected as u32,
                cycle_batches,
                cycle_largest as u32,
                backlog as u32,
            );
        }

        let done = self.clock.all_closed() && self.staged.is_empty();
        let progress = drained > 0 || injected > 0 || ran > 0;
        if !progress && !done {
            let stalls = self.clock.stalled(self.stall_threshold);
            if !stalls.is_empty() && stalls != self.last_stalls {
                self.stats.stall_warnings += 1;
                coord.plat.metrics.bump("ingest_stalled_feeds");
                self.last_stalls = stalls;
            }
        }
        CycleOutcome { epoch, progress, done }
    }

    /// The pump loop: cycle until every feed has closed and drained
    /// (then flush the coordinator to idle), parking on the bell when a
    /// cycle makes no progress. `deadline` is the wall-clock escape
    /// hatch — on expiry the loop returns with `timed_out` set instead
    /// of hanging on a producer that never closes.
    pub fn run(&mut self, coord: &mut Coordinator, deadline: Duration) -> IngestReport {
        let start = Instant::now();
        loop {
            let out = self.cycle(coord);
            if out.done {
                coord.run_until_idle();
                return self.report(false);
            }
            if out.progress {
                continue;
            }
            let elapsed = start.elapsed();
            if elapsed >= deadline {
                return self.report(true);
            }
            let nap = PARK_SLICE.min(deadline - elapsed);
            self.stats.parked += 1;
            self.bell.wait_past(out.epoch, nap);
        }
    }

    fn report(&self, timed_out: bool) -> IngestReport {
        IngestReport { stats: self.stats.clone(), timed_out, stalled: self.stalled() }
    }
}
