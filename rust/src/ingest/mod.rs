//! Streaming ingestion: the front door that turns the coordinator from
//! a library you call into a service that absorbs load.
//!
//! Everything else in the repo injects from the client thread and then
//! drains. This subsystem lets external producer threads push
//! timestamped events *concurrently with execution*:
//!
//! - [`Feed`] — a cloneable handle onto one external wire's bounded
//!   queue (in-tree MPSC; `push` blocks for credit, `try_push` returns a
//!   structured [`Backpressure`] refusal).
//! - [`Source`] — the pull-style connector trait ([`ReplaySource`] for
//!   recorded traces); [`Feed::run_source`] is the standard producer
//!   thread body.
//! - [`WatermarkClock`] — event-time completeness: virtual time advances
//!   only when every open feed's low watermark has passed, and feeds
//!   pinning the frontier are surfaced as [`StalledFeed`] anomalies.
//!   Each sealed watermark is also handed to the coordinator's frontier
//!   tracker (`coordinator::frontier`) as the feeds' contribution to the
//!   input frontier that drives pipelined multi-instant scheduling.
//! - An adaptive batcher whose per-cycle injection credit grows with
//!   queue depth, so `inject_batch_at_id`'s amortized setup makes
//!   throughput *improve* under pressure.
//! - The pump (driven by `Coordinator::pump_ingest` /
//!   `Coordinator::ingest_cycle`), which interleaves feed draining with
//!   wavefront execution and parks on a wake bell when idle instead of
//!   busy-spinning.
//!
//! The subsystem preserves the repo's core invariant — for fixed
//! per-feed event sequences the books are byte-identical regardless of
//! producer interleaving, pump cadence, batch credit, worker count, or
//! node count; `pump.rs` documents the argument and
//! `rust/tests/ingest_determinism.rs` proves it across the matrix.

mod batcher;
mod channel;
mod pump;
mod source;
mod watermark;

pub use pump::{IngestReport, DEFAULT_STALL_THRESHOLD};
pub use source::{Backpressure, Feed, IngestError, ReplaySource, Source, TimedEvent};
pub use watermark::{Frontier, StalledFeed, WatermarkClock};

pub(crate) use channel::FeedCore;
pub(crate) use pump::IngestPump;

use crate::util::SimDuration;

/// Default bounded-queue capacity for feeds opened without an explicit
/// one: deep enough to ride out a pump cycle, small enough that a
/// runaway producer feels backpressure quickly.
pub const DEFAULT_FEED_CAPACITY: usize = 1024;

/// Cumulative ingestion counters, kept by the pump and surfaced through
/// `Coordinator::ingest_stats` / [`IngestReport`].
#[derive(Debug, Clone, Default)]
pub struct IngestStats {
    /// Events injected into the coordinator.
    pub events: u64,
    /// `inject_batch_at_id` calls issued.
    pub batches: u64,
    /// Events that went through those batches (= `events`; kept separate
    /// so `mean_batch` stays honest if the accounting ever diverges).
    pub batched_events: u64,
    /// Pump cycles run.
    pub cycles: u64,
    /// Times the pump parked on the wake bell instead of spinning.
    pub parked: u64,
    /// `try_push` refusals observed across all feeds.
    pub backpressure_rejections: u64,
    /// Deepest combined backlog (staged + freshly drained) seen at a
    /// cycle boundary.
    pub depth_high_water: usize,
    /// Largest single injection batch.
    pub largest_batch: usize,
    /// Furthest any buffered event ran ahead of the sealable frontier.
    pub watermark_lag_max: SimDuration,
    /// Distinct stall anomalies reported (set-change-deduplicated).
    pub stall_warnings: u64,
}

impl IngestStats {
    /// Mean events per injection batch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_events as f64 / self.batches as f64
        }
    }
}
