//! Event-time completeness: the clock that decides how far virtual time
//! may safely advance.
//!
//! Each feed carries a *low watermark* — the producer's promise that
//! every future event on that feed arrives strictly after it. The
//! pipeline-wide **frontier** is the minimum watermark over open feeds:
//! below the frontier the event-time order is complete, so the pump may
//! seal those events and let the coordinator run them. A feed that never
//! advances (or falls far behind its peers) pins the frontier; the clock
//! surfaces such feeds as stall anomalies instead of silently freezing
//! the pipeline.

use crate::util::{SimDuration, SimTime};

/// How far event time is known-complete across all registered feeds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Frontier {
    /// Every feed has closed: all event time is complete.
    Open,
    /// Complete through this instant inclusive (min open-feed watermark).
    At(SimTime),
    /// Some open feed has never advanced its watermark — nothing can be
    /// sealed yet.
    Unknown,
}

/// A feed pinning the frontier well behind its peers (or behind the
/// pump's idle clock): the anomaly report for "why is nothing running?".
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StalledFeed {
    pub feed: String,
    /// Its watermark, if it ever advanced one.
    pub watermark: Option<SimTime>,
    /// How far the most advanced peer watermark is ahead of this feed.
    pub behind: SimDuration,
}

struct FeedTrack {
    name: String,
    wm: Option<SimTime>,
    closed: bool,
}

/// Tracks every registered feed's watermark and computes the frontier.
/// Pure bookkeeping — the pump copies channel-observed state in via
/// [`observe`](WatermarkClock::observe), so the clock never races the
/// channels.
pub struct WatermarkClock {
    feeds: Vec<FeedTrack>,
}

impl WatermarkClock {
    pub fn new() -> Self {
        Self { feeds: Vec::new() }
    }

    /// Register a feed; returns its dense index (the pump's feed id, and
    /// the canonical same-instant tiebreak order — registration order).
    pub fn register(&mut self, name: &str) -> u32 {
        let id = self.feeds.len() as u32;
        self.feeds.push(FeedTrack { name: name.to_string(), wm: None, closed: false });
        id
    }

    /// Record what a drain observed for feed `id`. Watermarks are
    /// monotone; closed is sticky.
    pub fn observe(&mut self, id: u32, wm: Option<SimTime>, closed: bool) {
        let f = &mut self.feeds[id as usize];
        if let Some(t) = wm {
            f.wm = Some(f.wm.map_or(t, |w| w.max(t)));
        }
        f.closed |= closed;
    }

    pub fn is_empty(&self) -> bool {
        self.feeds.is_empty()
    }

    pub fn all_closed(&self) -> bool {
        self.feeds.iter().all(|f| f.closed)
    }

    /// The pipeline-wide frontier: min watermark over open feeds.
    /// Monotone nondecreasing because each feed's watermark is.
    pub fn frontier(&self) -> Frontier {
        let mut min: Option<SimTime> = None;
        for f in self.feeds.iter().filter(|f| !f.closed) {
            match f.wm {
                None => return Frontier::Unknown,
                Some(w) => min = Some(min.map_or(w, |m| m.min(w))),
            }
        }
        match min {
            Some(t) => Frontier::At(t),
            None => Frontier::Open,
        }
    }

    /// Open feeds whose watermark trails the most advanced peer by more
    /// than `threshold` (a feed that never advanced counts as trailing
    /// from zero). Empty when no feed has pulled ahead — uniform silence
    /// is idleness, not a stall.
    pub fn stalled(&self, threshold: SimDuration) -> Vec<StalledFeed> {
        let lead = match self.feeds.iter().filter(|f| !f.closed).filter_map(|f| f.wm).max() {
            Some(t) => t,
            None => return Vec::new(),
        };
        self.feeds
            .iter()
            .filter(|f| !f.closed)
            .filter(|f| lead.saturating_sub(f.wm.unwrap_or(SimTime::ZERO)) > threshold)
            .map(|f| StalledFeed {
                feed: f.name.clone(),
                watermark: f.wm,
                behind: lead.saturating_sub(f.wm.unwrap_or(SimTime::ZERO)),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frontier_is_min_over_open_feeds() {
        let mut c = WatermarkClock::new();
        let a = c.register("a");
        let b = c.register("b");
        assert_eq!(c.frontier(), Frontier::Unknown, "unadvanced feed blocks sealing");
        c.observe(a, Some(SimTime::micros(10)), false);
        assert_eq!(c.frontier(), Frontier::Unknown, "one feed still silent");
        c.observe(b, Some(SimTime::micros(4)), false);
        assert_eq!(c.frontier(), Frontier::At(SimTime::micros(4)));
        // closing the laggard releases the frontier to the leader
        c.observe(b, None, true);
        assert_eq!(c.frontier(), Frontier::At(SimTime::micros(10)));
        c.observe(a, None, true);
        assert_eq!(c.frontier(), Frontier::Open);
        assert!(c.all_closed());
    }

    #[test]
    fn watermarks_are_monotone_under_observation() {
        let mut c = WatermarkClock::new();
        let a = c.register("a");
        c.observe(a, Some(SimTime::micros(9)), false);
        c.observe(a, Some(SimTime::micros(3)), false);
        assert_eq!(c.frontier(), Frontier::At(SimTime::micros(9)));
    }

    #[test]
    fn stall_detection_names_the_laggard() {
        let mut c = WatermarkClock::new();
        let a = c.register("fast");
        let _b = c.register("silent");
        let d = c.register("slow");
        c.observe(a, Some(SimTime::secs(10)), false);
        c.observe(d, Some(SimTime::secs(9)), false);
        let stalls = c.stalled(SimDuration::secs(5));
        assert_eq!(stalls.len(), 1, "slow is within threshold; silent is not");
        assert_eq!(stalls[0].feed, "silent");
        assert_eq!(stalls[0].watermark, None);
        assert_eq!(stalls[0].behind, SimDuration::secs(10));
        // closed laggards are not stalls
        c.observe(1, None, true);
        assert!(c.stalled(SimDuration::secs(5)).is_empty());
    }

    #[test]
    fn uniform_silence_is_not_a_stall() {
        let mut c = WatermarkClock::new();
        c.register("a");
        c.register("b");
        assert!(c.stalled(SimDuration::micros(1)).is_empty());
    }
}
