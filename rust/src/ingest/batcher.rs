//! Adaptive batch sizing: the deeper the backlog, the bigger the
//! injection batches.
//!
//! Each pump cycle asks for a *credit* — the maximum number of sealed
//! events to inject before yielding back to execution. Under light load
//! the credit stays small so enqueue-to-commit latency stays low; as
//! queue depth grows the credit grows with it, amortizing per-batch
//! validation and heap reservation (`inject_batch_at_id` pays its setup
//! once per batch), so throughput *improves* under pressure.
//!
//! Determinism note: the credit changes how many instants a cycle seals
//! — i.e. *when* events enter the coordinator — never how same-instant
//! events are grouped or ordered. Canonical grouping happens after
//! sealing (see `super::pump`), so batch sizing is invisible to the
//! books.

/// Smallest per-cycle injection credit (light-load latency floor).
pub(crate) const MIN_CREDIT: usize = 32;
/// Largest per-cycle injection credit (keeps cycles preemptible).
pub(crate) const MAX_CREDIT: usize = 4096;

pub(crate) struct AdaptiveBatcher {
    /// Smoothed backlog estimate (integer EWMA, alpha = 1/4).
    smoothed_depth: usize,
    batches: u64,
    batched_events: u64,
    largest: usize,
}

impl AdaptiveBatcher {
    pub fn new() -> Self {
        Self { smoothed_depth: 0, batches: 0, batched_events: 0, largest: 0 }
    }

    /// Injection credit for a cycle that observed `depth` queued events
    /// across all feeds: proportional to the smoothed backlog, clamped
    /// to [MIN_CREDIT, MAX_CREDIT].
    pub fn cycle_credit(&mut self, depth: usize) -> usize {
        // EWMA keeps one deep burst from whipsawing the credit
        self.smoothed_depth = (self.smoothed_depth * 3 + depth) / 4;
        self.smoothed_depth.max(depth / 2).clamp(MIN_CREDIT, MAX_CREDIT)
    }

    /// Record one `inject_batch_at_id` call of `n` events.
    pub fn note_batch(&mut self, n: usize) {
        self.batches += 1;
        self.batched_events += n as u64;
        self.largest = self.largest.max(n);
    }

    pub fn batches(&self) -> u64 {
        self.batches
    }

    pub fn batched_events(&self) -> u64 {
        self.batched_events
    }

    pub fn largest(&self) -> usize {
        self.largest
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_events as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn credit_grows_with_sustained_depth_and_clamps() {
        let mut b = AdaptiveBatcher::new();
        assert_eq!(b.cycle_credit(0), MIN_CREDIT, "empty queues get the floor");
        assert_eq!(b.cycle_credit(10), MIN_CREDIT, "shallow backlog stays at the floor");
        let mut last = MIN_CREDIT;
        for _ in 0..16 {
            let c = b.cycle_credit(2000);
            assert!(c >= last, "credit is nondecreasing under sustained depth");
            last = c;
        }
        assert!(last > MIN_CREDIT, "sustained backlog grows the credit");
        for _ in 0..32 {
            last = b.cycle_credit(1_000_000);
        }
        assert_eq!(last, MAX_CREDIT, "credit clamps at the ceiling");
    }

    #[test]
    fn credit_decays_when_load_drops() {
        let mut b = AdaptiveBatcher::new();
        for _ in 0..32 {
            b.cycle_credit(4000);
        }
        for _ in 0..64 {
            b.cycle_credit(0);
        }
        assert_eq!(b.cycle_credit(0), MIN_CREDIT, "credit returns to the floor when idle");
    }

    #[test]
    fn batch_stats_track_mean_and_largest() {
        let mut b = AdaptiveBatcher::new();
        assert_eq!(b.mean_batch(), 0.0);
        b.note_batch(10);
        b.note_batch(30);
        assert_eq!(b.batches(), 2);
        assert_eq!(b.batched_events(), 40);
        assert_eq!(b.largest(), 30);
        assert!((b.mean_batch() - 20.0).abs() < 1e-9);
    }
}
