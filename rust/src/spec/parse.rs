//! Parser for the fig. 5 wiring language. Hand-rolled recursive descent —
//! the grammar is line-oriented and tiny:
//!
//! ```text
//! pipeline := header? line*
//! header   := '[' name ']'
//! line     := '(' inputs? ')' taskname '(' outputs? ')' attr*
//! inputs   := input (',' input)*
//! input    := wire ('[' N ('/' S)? ']')? '?'?
//! attr     := '@' key '=' value
//! ```
//! `#` starts a comment; blank lines are ignored.

use super::{InputSpec, PipelineSpec, TaskSpec};
use crate::policy::BufferSpec;
use std::collections::BTreeMap;

/// Parse failure with line context.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, msg: impl Into<String>) -> ParseError {
    ParseError { line, msg: msg.into() }
}

/// Parse a full pipeline description.
pub fn parse(src: &str) -> Result<PipelineSpec, ParseError> {
    let mut spec = PipelineSpec { name: "pipeline".to_string(), tasks: Vec::new() };
    for (idx, raw) in src.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated [pipeline-name]"))?;
            if name.is_empty() {
                return Err(err(lineno, "empty pipeline name"));
            }
            spec.name = name.trim().to_string();
            continue;
        }
        spec.tasks.push(parse_task_line(line, lineno)?);
    }
    Ok(spec)
}

fn strip_comment(line: &str) -> &str {
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

fn parse_task_line(line: &str, lineno: usize) -> Result<TaskSpec, ParseError> {
    let (inputs_src, rest) = take_parens(line, lineno)?;
    let rest = rest.trim_start();
    let name_end = rest
        .find('(')
        .ok_or_else(|| err(lineno, "expected '(' starting output list"))?;
    let name = rest[..name_end].trim();
    if name.is_empty() {
        return Err(err(lineno, "missing task name between input and output lists"));
    }
    if !valid_name(name) {
        return Err(err(lineno, format!("bad task name '{name}'")));
    }
    let (outputs_src, tail) = take_parens(&rest[name_end..], lineno)?;

    let inputs = split_list(inputs_src)
        .into_iter()
        .map(|item| parse_input(&item, lineno))
        .collect::<Result<Vec<_>, _>>()?;
    let outputs: Vec<String> = split_list(outputs_src)
        .into_iter()
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();

    let mut attrs = BTreeMap::new();
    for tok in tail.split_whitespace() {
        let tok = tok
            .strip_prefix('@')
            .ok_or_else(|| err(lineno, format!("unexpected trailing token '{tok}'")))?;
        let (k, v) = tok
            .split_once('=')
            .ok_or_else(|| err(lineno, format!("attribute '@{tok}' missing '=value'")))?;
        attrs.insert(k.to_string(), v.to_string());
    }

    Ok(TaskSpec { name: name.to_string(), inputs, outputs, attrs })
}

/// Extract `(...)` from the front; return (contents, remainder).
fn take_parens<'a>(src: &'a str, lineno: usize) -> Result<(&'a str, &'a str), ParseError> {
    let src = src.trim_start();
    let inner = src
        .strip_prefix('(')
        .ok_or_else(|| err(lineno, "expected '('"))?;
    let close = inner
        .find(')')
        .ok_or_else(|| err(lineno, "unterminated '('"))?;
    Ok((&inner[..close], &inner[close + 1..]))
}

fn split_list(src: &str) -> Vec<String> {
    src.split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

fn parse_input(item: &str, lineno: usize) -> Result<InputSpec, ParseError> {
    parse_input_token(item).map_err(|msg| err(lineno, msg))
}

/// Legal wire/task name: alphanumerics plus `-`, `_`, `.` — one rule for
/// both front ends (the text parser and `api::PipelineBuilder`).
pub fn valid_name(name: &str) -> bool {
    !name.is_empty() && name.chars().all(|c| c.is_alphanumeric() || matches!(c, '-' | '_' | '.'))
}

/// One input-port token: `wire`, `wire[N]`, `wire[N/S]`, each optionally
/// suffixed `?` (implicit service lookup). This is THE port grammar —
/// `api::PipelineBuilder::reads` calls it too, so a port spelled in a
/// `.koalja` file and the same string handed to the builder can never
/// diverge in meaning.
pub fn parse_input_token(item: &str) -> Result<InputSpec, String> {
    let mut item = item.trim();
    let service = item.ends_with('?');
    if service {
        item = item[..item.len() - 1].trim_end();
    }
    let (wire, buffer) = match item.find('[') {
        None => (item, BufferSpec::default()),
        Some(i) => {
            let wire = &item[..i];
            let spec = item[i + 1..]
                .strip_suffix(']')
                .ok_or_else(|| format!("unterminated '[' in '{item}'"))?;
            let buffer = match spec.split_once('/') {
                None => BufferSpec::buffer(
                    spec.parse().map_err(|_| format!("bad buffer size '{spec}'"))?,
                ),
                Some((n, s)) => {
                    let n: usize =
                        n.parse().map_err(|_| format!("bad window size '{n}'"))?;
                    let s: usize = s.parse().map_err(|_| format!("bad slide '{s}'"))?;
                    if s > n || s == 0 || n == 0 {
                        return Err(format!("bad window [{n}/{s}]"));
                    }
                    BufferSpec::window(n, s)
                }
            };
            (wire, buffer)
        }
    };
    if !valid_name(wire) {
        return Err(format!("bad wire name '{wire}'"));
    }
    Ok(InputSpec { wire: wire.to_string(), buffer, service })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_source_task() {
        let p = parse("() ingest (raw)").unwrap();
        assert_eq!(p.tasks.len(), 1);
        assert!(p.tasks[0].inputs.is_empty());
        assert_eq!(p.tasks[0].outputs, vec!["raw"]);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let p = parse("# hello\n\n[p] # trailing\n() s (x) # more\n").unwrap();
        assert_eq!(p.name, "p");
        assert_eq!(p.tasks.len(), 1);
    }

    #[test]
    fn buffer_and_window_specs() {
        let p = parse("(a[5], b[10/2], c) t (o)").unwrap();
        let t = &p.tasks[0];
        assert_eq!(t.inputs[0].buffer, BufferSpec::buffer(5));
        assert_eq!(t.inputs[1].buffer, BufferSpec::window(10, 2));
        assert_eq!(t.inputs[2].buffer, BufferSpec::default());
    }

    #[test]
    fn service_suffix() {
        let p = parse("(x, dns?) t (o)").unwrap();
        assert!(!p.tasks[0].inputs[0].service);
        assert!(p.tasks[0].inputs[1].service);
        assert_eq!(p.tasks[0].inputs[1].wire, "dns");
    }

    #[test]
    fn attributes_parse() {
        let p = parse("(a) t (b) @policy=merge @region=edge-1 @notify=poll:50ms").unwrap();
        let t = &p.tasks[0];
        assert_eq!(t.attr("policy"), Some("merge"));
        assert_eq!(t.attr("region"), Some("edge-1"));
        assert_eq!(t.attr("notify"), Some("poll:50ms"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("[ok]\n(a t (b)\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("(a) t (b) garbage").unwrap_err();
        assert!(e.msg.contains("garbage"));
        let e = parse("(a[3/9]) t (b)").unwrap_err();
        assert!(e.msg.contains("window"));
        let e = parse("(a) bad name (b)").unwrap_err();
        assert!(e.msg.contains("bad task name"));
    }

    #[test]
    fn empty_window_bracket_rejected() {
        assert!(parse("(a[]) t (b)").is_err());
        assert!(parse("(a[0/0]) t (b)").is_err());
    }
}
