//! The wiring language — fig. 5.
//!
//! ```text
//! [tfmodel]
//! (in) learn-tf (model)
//! (in[10/2]) convert (json)
//! (json, lookup?) predict (result)
//! ```
//!
//! Each line wires `(inputs) task (outputs)`. Inputs may carry buffer
//! specs `name[N]`, sliding windows `name[N/S]` (§III-I) or a `?` suffix
//! marking an *implicit service lookup* (§III-D — the client-server call
//! recorded for forensics rather than wired as a stream). Wires connect by
//! name: any task producing wire `x` feeds every task consuming `x`.
//! Cycles are legal (DCGs, §I). Wires nobody produces are pipeline inputs
//! (file-drop/sensor in-trays); wires nobody consumes are pipeline outputs.
//!
//! Per-task attributes extend the fig. 5 syntax after the output list:
//! `@policy=swap @region=edge-0 @notify=poll:100ms @rate=50ms @cache=risk`.
//! Kubernetes never appears — platform transparency is promise #1 (§III-B).

pub mod parse;

pub use parse::{parse, parse_input_token, valid_name, ParseError};

use crate::policy::{BufferSpec, SnapshotPolicy};
use std::collections::BTreeMap;

/// One input port reference.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InputSpec {
    /// Wire name this port consumes.
    pub wire: String,
    pub buffer: BufferSpec,
    /// `name?` — an implicit out-of-band service lookup, not a stream.
    pub service: bool,
}

/// One task line of the wiring diagram.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskSpec {
    pub name: String,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
    /// Raw `@key=value` attributes.
    pub attrs: BTreeMap<String, String>,
}

impl TaskSpec {
    pub fn attr(&self, key: &str) -> Option<&str> {
        self.attrs.get(key).map(|s| s.as_str())
    }

    /// Parsed snapshot policy (default AllNew).
    pub fn policy(&self) -> SnapshotPolicy {
        self.attr("policy").and_then(SnapshotPolicy::parse).unwrap_or_default()
    }

    pub fn is_source(&self) -> bool {
        self.inputs.iter().all(|i| i.service)
    }

    pub fn stream_inputs(&self) -> impl Iterator<Item = &InputSpec> {
        self.inputs.iter().filter(|i| !i.service)
    }

    /// Distinct stream-input wires in declaration order — the task's
    /// input *port table*. Snapshot-engine buffers and the task runtime's
    /// `InPort` map are both built in exactly this order, so a port's
    /// position here IS its dense slot index everywhere.
    pub fn input_ports(&self) -> Vec<&str> {
        let mut seen: Vec<&str> = Vec::new();
        for i in self.stream_inputs() {
            if !seen.contains(&i.wire.as_str()) {
                seen.push(&i.wire);
            }
        }
        seen
    }

    pub fn service_inputs(&self) -> impl Iterator<Item = &InputSpec> {
        self.inputs.iter().filter(|i| i.service)
    }
}

/// A parsed pipeline description.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PipelineSpec {
    pub name: String,
    pub tasks: Vec<TaskSpec>,
}

/// Validation failure, with the task at fault where applicable.
#[derive(Clone, Debug, PartialEq)]
pub enum SpecError {
    DuplicateTask(String),
    BadWindow { task: String, count: usize, slide: usize },
    BadAttr { task: String, key: String, value: String },
    SelfLoop { task: String, wire: String },
    Empty,
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::DuplicateTask(name) => write!(f, "duplicate task name '{name}'"),
            SpecError::BadWindow { task, count, slide } => {
                write!(f, "task '{task}': window slide {slide} exceeds window size {count}")
            }
            SpecError::BadAttr { task, key, value } => {
                write!(f, "task '{task}': unknown attribute value '@{key}={value}'")
            }
            SpecError::SelfLoop { task, wire } => write!(
                f,
                "task '{task}' consumes its own output '{wire}' directly (degenerate 1-cycle)"
            ),
            SpecError::Empty => write!(f, "pipeline has no tasks"),
        }
    }
}

impl std::error::Error for SpecError {}

impl PipelineSpec {
    /// Static validation: structural sanity before deployment. Cycles are
    /// *not* errors (the paper's DCGs), but self-loops through the same
    /// wire are (a task re-triggering itself on every output is a bug).
    pub fn validate(&self) -> Result<(), SpecError> {
        if self.tasks.is_empty() {
            return Err(SpecError::Empty);
        }
        let mut names = std::collections::HashSet::new();
        for t in &self.tasks {
            if !names.insert(&t.name) {
                return Err(SpecError::DuplicateTask(t.name.clone()));
            }
            for i in &t.inputs {
                if i.buffer.slide > i.buffer.count {
                    return Err(SpecError::BadWindow {
                        task: t.name.clone(),
                        count: i.buffer.count,
                        slide: i.buffer.slide,
                    });
                }
                if !i.service && t.outputs.contains(&i.wire) {
                    return Err(SpecError::SelfLoop { task: t.name.clone(), wire: i.wire.clone() });
                }
            }
            if let Some(p) = t.attr("policy") {
                if SnapshotPolicy::parse(p).is_none() {
                    return Err(SpecError::BadAttr {
                        task: t.name.clone(),
                        key: "policy".into(),
                        value: p.into(),
                    });
                }
            }
            if let Some(n) = t.attr("notify") {
                if n != "push" && !n.starts_with("poll:") {
                    return Err(SpecError::BadAttr {
                        task: t.name.clone(),
                        key: "notify".into(),
                        value: n.into(),
                    });
                }
            }
        }
        Ok(())
    }

    pub fn task(&self, name: &str) -> Option<&TaskSpec> {
        self.tasks.iter().find(|t| t.name == name)
    }

    /// Wires nobody produces — the pipeline's external in-trays.
    pub fn external_wires(&self) -> Vec<String> {
        let produced: std::collections::HashSet<&str> =
            self.tasks.iter().flat_map(|t| t.outputs.iter().map(|s| s.as_str())).collect();
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for t in &self.tasks {
            for i in t.stream_inputs() {
                if !produced.contains(i.wire.as_str()) && seen.insert(i.wire.clone()) {
                    out.push(i.wire.clone());
                }
            }
        }
        out
    }

    /// Wires nobody consumes — the pipeline's outputs.
    pub fn sink_wires(&self) -> Vec<String> {
        let consumed: std::collections::HashSet<&str> = self
            .tasks
            .iter()
            .flat_map(|t| t.stream_inputs().map(|i| i.wire.as_str()))
            .collect();
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for t in &self.tasks {
            for w in &t.outputs {
                if !consumed.contains(w.as_str()) && seen.insert(w.clone()) {
                    out.push(w.clone());
                }
            }
        }
        out
    }

    /// Pretty-print back to the fig. 5 syntax (round-trip tested).
    pub fn to_text(&self) -> String {
        let mut s = format!("[{}]\n", self.name);
        for t in &self.tasks {
            let ins: Vec<String> = t
                .inputs
                .iter()
                .map(|i| {
                    let mut x = i.wire.clone();
                    if i.buffer.is_window() {
                        x.push_str(&format!("[{}/{}]", i.buffer.count, i.buffer.slide));
                    } else if i.buffer.count > 1 {
                        x.push_str(&format!("[{}]", i.buffer.count));
                    }
                    if i.service {
                        x.push('?');
                    }
                    x
                })
                .collect();
            s.push_str(&format!("({}) {} ({})", ins.join(", "), t.name, t.outputs.join(", ")));
            for (k, v) in &t.attrs {
                s.push_str(&format!(" @{k}={v}"));
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tfmodel() -> PipelineSpec {
        parse(
            "[tfmodel]\n\
             # fig. 5 of the paper\n\
             (in) learn-tf (model)\n\
             (in[10/2]) convert (json)\n\
             (json, lookup?) predict (result)\n",
        )
        .unwrap()
    }

    #[test]
    fn fig5_parses_and_validates() {
        let p = tfmodel();
        assert_eq!(p.name, "tfmodel");
        assert_eq!(p.tasks.len(), 3);
        p.validate().unwrap();
        let convert = p.task("convert").unwrap();
        assert_eq!(convert.inputs[0].buffer, BufferSpec::window(10, 2));
        let predict = p.task("predict").unwrap();
        assert!(predict.inputs[1].service, "lookup? is a service input");
    }

    #[test]
    fn input_ports_dedup_in_declaration_order() {
        let p = parse("[ip]\n(a, b[3], a, svc?, c) t (o)\n").unwrap();
        assert_eq!(p.tasks[0].input_ports(), vec!["a", "b", "c"], "deduped, ordered, no services");
    }

    #[test]
    fn external_and_sink_wires() {
        let p = tfmodel();
        assert_eq!(p.external_wires(), vec!["in".to_string()]);
        let sinks = p.sink_wires();
        assert!(sinks.contains(&"result".to_string()));
        assert!(sinks.contains(&"model".to_string()), "model feeds a service, not a wire");
    }

    #[test]
    fn roundtrip_to_text() {
        let p = tfmodel();
        let p2 = parse(&p.to_text()).unwrap();
        assert_eq!(p, p2);
    }

    #[test]
    fn duplicate_task_rejected() {
        let p = parse("[x]\n(a) t (b)\n(b) t (c)\n").unwrap();
        assert_eq!(p.validate(), Err(SpecError::DuplicateTask("t".into())));
    }

    #[test]
    fn self_loop_rejected_but_long_cycles_allowed() {
        let p = parse("[x]\n(a) t (a)\n").unwrap();
        assert!(matches!(p.validate(), Err(SpecError::SelfLoop { .. })));
        // two-task feedback loop is a legal DCG
        let p = parse("[x]\n(a, fb) t (b)\n(b) u (fb)\n").unwrap();
        p.validate().unwrap();
    }

    #[test]
    fn bad_policy_attr_rejected() {
        let p = parse("[x]\n(a) t (b) @policy=frobnicate\n").unwrap();
        assert!(matches!(p.validate(), Err(SpecError::BadAttr { .. })));
    }

    #[test]
    fn policy_attr_parsed() {
        let p = parse("[x]\n(a, c) t (b) @policy=swap\n").unwrap();
        assert_eq!(p.task("t").unwrap().policy(), SnapshotPolicy::SwapNewForOld);
    }
}
