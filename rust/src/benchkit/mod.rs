//! In-tree micro-benchmark harness (criterion is not vendored in this
//! offline environment). Good enough for the repo's needs: warmup,
//! calibrated iteration counts, median-of-samples timing, table-style
//! output that EXPERIMENTS.md records verbatim, and a machine-readable
//! JSON report ([`write_json`]) so each bench run appends a point to the
//! repo's perf trajectory (`BENCH_*.json`, archived by `ci.sh`).

use crate::util::Json;
use std::time::{Duration, Instant};

/// One measured series entry.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub label: String,
    pub value: f64,
    pub unit: String,
}

impl Measurement {
    pub fn new(label: impl Into<String>, value: f64, unit: impl Into<String>) -> Self {
        Self { label: label.into(), value, unit: unit.into() }
    }
}

/// Serialize measurements to `path` as the repo's bench-JSON schema:
/// `{"schema": 1, "bench": <file stem>, "results": [{label, value, unit}]}`.
/// The bench name is derived from the file stem (`BENCH_foo.json` → `foo`),
/// so trajectory tooling can group reports without parsing labels.
pub fn write_json(path: impl AsRef<std::path::Path>, measurements: &[Measurement]) -> std::io::Result<()> {
    let path = path.as_ref();
    let bench = path
        .file_stem()
        .and_then(|s| s.to_str())
        .map(|s| s.strip_prefix("BENCH_").unwrap_or(s))
        .unwrap_or("unknown");
    let results: Vec<Json> = measurements
        .iter()
        .map(|m| {
            Json::obj(vec![
                ("label", Json::str(m.label.clone())),
                ("value", Json::num(m.value)),
                ("unit", Json::str(m.unit.clone())),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("bench", Json::str(bench)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(path, doc.to_string() + "\n")
}

/// Time a closure: warm up, pick an iteration count targeting ~`budget`,
/// then report the median per-iteration time over `samples` batches.
pub fn bench_fn<F: FnMut()>(mut f: F, budget: Duration, samples: usize) -> Duration {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = ((budget.as_secs_f64() / samples as f64) / once.as_secs_f64())
        .clamp(1.0, 1e7) as u64;
    let mut per_iter: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t.elapsed() / iters as u32);
    }
    per_iter.sort();
    per_iter[samples / 2]
}

/// Convenience: ns/op for quick ratios.
pub fn bench_ns<F: FnMut()>(f: F) -> f64 {
    bench_fn(f, Duration::from_millis(300), 5).as_nanos() as f64
}

/// Print a table header + alignment rule.
pub fn table_header(title: &str, cols: &[&str]) {
    println!("\n== {title} ==");
    println!("{}", cols.join("\t"));
}

/// Print one row.
pub fn row(cells: &[String]) {
    println!("{}", cells.join("\t"));
}

/// Format helpers.
pub fn f(x: f64) -> String {
    if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_returns_positive() {
        let d = bench_fn(|| { std::hint::black_box(1 + 1); }, Duration::from_millis(20), 3);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn format_helper() {
        assert_eq!(f(1234.5), "1234"); // ties-to-even
        assert_eq!(f(42.0), "42.0");
        assert_eq!(f(1.23456), "1.235");
    }

    #[test]
    fn write_json_roundtrips_schema() {
        let path = std::env::temp_dir().join("BENCH_benchkit_selftest.json");
        let ms = vec![
            Measurement::new("fanout4/events_per_sec", 1234.5, "events/s"),
            Measurement::new("fanout4/ns_per_event", 810.0, "ns"),
        ];
        write_json(&path, &ms).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_u64(), Some(1));
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("benchkit_selftest"));
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[0].get("label").unwrap().as_str(),
            Some("fanout4/events_per_sec")
        );
        assert_eq!(results[1].get("value").unwrap().as_f64(), Some(810.0));
        assert_eq!(results[0].get("unit").unwrap().as_str(), Some("events/s"));
        let _ = std::fs::remove_file(&path);
    }
}
