//! In-tree micro-benchmark harness (criterion is not vendored in this
//! offline environment). Good enough for the repo's needs: warmup,
//! calibrated iteration counts, median-of-samples timing, and table-style
//! output that EXPERIMENTS.md records verbatim.

use std::time::{Duration, Instant};

/// One measured series entry.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub label: String,
    pub value: f64,
    pub unit: String,
}

/// Time a closure: warm up, pick an iteration count targeting ~`budget`,
/// then report the median per-iteration time over `samples` batches.
pub fn bench_fn<F: FnMut()>(mut f: F, budget: Duration, samples: usize) -> Duration {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = ((budget.as_secs_f64() / samples as f64) / once.as_secs_f64())
        .clamp(1.0, 1e7) as u64;
    let mut per_iter: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t.elapsed() / iters as u32);
    }
    per_iter.sort();
    per_iter[samples / 2]
}

/// Convenience: ns/op for quick ratios.
pub fn bench_ns<F: FnMut()>(f: F) -> f64 {
    bench_fn(f, Duration::from_millis(300), 5).as_nanos() as f64
}

/// Print a table header + alignment rule.
pub fn table_header(title: &str, cols: &[&str]) {
    println!("\n== {title} ==");
    println!("{}", cols.join("\t"));
}

/// Print one row.
pub fn row(cells: &[String]) {
    println!("{}", cells.join("\t"));
}

/// Format helpers.
pub fn f(x: f64) -> String {
    if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_returns_positive() {
        let d = bench_fn(|| { std::hint::black_box(1 + 1); }, Duration::from_millis(20), 3);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn format_helper() {
        assert_eq!(f(1234.5), "1234"); // ties-to-even
        assert_eq!(f(42.0), "42.0");
        assert_eq!(f(1.23456), "1.235");
    }
}
