//! Annotated Values — §III-I.
//!
//! "Smart tasks arrange for data to arrive at user containers as sets of
//! 'Annotated Values' ... The value is in fact a message that points to a
//! storage location for the data, thus avoiding the need to send actual
//! data through from link to link." The annotation carries:
//!   * a unique identifier for forensic tracing,
//!   * the source task that produced it,
//!   * pointers to the links and storage locations of the actual data,
//!   * a local timestamp referring to the source agent's clock.

use crate::util::{AvId, ContentHash, LinkId, ObjectId, RegionId, SimTime, TaskId};


/// Sovereignty / sensitivity classification of a payload (§IV, fig. 11):
/// raw data may be forbidden from leaving its region while summaries are
/// free to travel.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum DataClass {
    /// Full-resolution source data — sovereignty-restricted by default.
    Raw,
    /// Derived/aggregated data (sketches, windows, model params) — portable.
    Summary,
    /// Ghost/wireframe marker batches (§III-K) — metadata only, always portable.
    Ghost,
}

/// The actual bytes an AV points to. Tensors are what the PJRT-backed
/// compute tasks exchange; `Ghost` carries only a pretend size so wireframe
/// runs can exercise routing without payload cost (§III-K).
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    Tensor { shape: Vec<usize>, data: Vec<f32> },
    Bytes(Vec<u8>),
    Text(String),
    Ghost { pretend_bytes: u64 },
}

impl Payload {
    pub fn tensor(shape: &[usize], data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Payload::Tensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Self {
        Payload::Tensor { shape: vec![1], data: vec![v] }
    }

    /// Size on the wire / in storage.
    pub fn size_bytes(&self) -> u64 {
        match self {
            Payload::Tensor { data, .. } => (data.len() * 4) as u64,
            Payload::Bytes(b) => b.len() as u64,
            Payload::Text(s) => s.len() as u64,
            Payload::Ghost { pretend_bytes } => *pretend_bytes,
        }
    }

    /// Ghosts cost nothing to move — that is their point.
    pub fn transfer_bytes(&self) -> u64 {
        match self {
            Payload::Ghost { .. } => 0,
            p => p.size_bytes(),
        }
    }

    pub fn is_ghost(&self) -> bool {
        matches!(self, Payload::Ghost { .. })
    }

    pub fn content_hash(&self) -> ContentHash {
        match self {
            Payload::Tensor { shape, data } => {
                let mut h = ContentHash::EMPTY;
                for &d in shape {
                    h = h.combine(ContentHash(d as u64));
                }
                h.combine(ContentHash::of_f32s(data))
            }
            Payload::Bytes(b) => ContentHash::of_bytes(b),
            Payload::Text(s) => ContentHash::of_str(s),
            Payload::Ghost { pretend_bytes } => {
                ContentHash(0x6007_0000).combine(ContentHash(*pretend_bytes))
            }
        }
    }

    pub fn as_tensor(&self) -> Option<(&[usize], &[f32])> {
        match self {
            Payload::Tensor { shape, data } => Some((shape, data)),
            _ => None,
        }
    }
}

/// The routable unit: metadata plus a URI-style pointer into object storage.
#[derive(Clone, Debug)]
pub struct AnnotatedValue {
    /// Unique id for forensic tracing.
    pub id: AvId,
    /// Task that produced this value as output.
    pub source_task: TaskId,
    /// Link this AV was published on.
    pub link: LinkId,
    /// Storage location of the actual data ("URI reference", not the data).
    pub object: ObjectId,
    /// Region whose store holds the object (where it was produced).
    pub region: RegionId,
    /// Local timestamp of creation — the *source agent's* clock (§III-I).
    pub created: SimTime,
    /// Sequence number on the producing link (FCFS ordering).
    pub seq: u64,
    /// Size of the payload pointed to, for transfer planning.
    pub size_bytes: u64,
    /// Content hash of the payload, for make-style staleness checks.
    pub content: ContentHash,
    /// Sovereignty class.
    pub class: DataClass,
    /// True for wireframe batches.
    pub ghost: bool,
    /// Birth time of the *oldest source sample* this value derives from —
    /// carried forward so sinks can measure true end-to-end latency.
    pub born: SimTime,
}

impl AnnotatedValue {
    /// A human-readable URI for logs and the traveller passport.
    pub fn uri(&self) -> String {
        format!("koalja://{}/{}#{}", self.region, self.object, self.content)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn av(class: DataClass, ghost: bool) -> AnnotatedValue {
        AnnotatedValue {
            id: AvId::new(1),
            source_task: TaskId::new(2),
            link: LinkId::new(3),
            object: ObjectId::new(4),
            region: RegionId::new(0),
            created: SimTime::millis(5),
            seq: 0,
            size_bytes: 128,
            content: ContentHash::of_str("x"),
            class,
            ghost,
            born: SimTime::millis(5),
        }
    }

    #[test]
    fn payload_sizes() {
        assert_eq!(Payload::tensor(&[2, 3], vec![0.0; 6]).size_bytes(), 24);
        assert_eq!(Payload::Bytes(vec![0; 10]).size_bytes(), 10);
        assert_eq!(Payload::Ghost { pretend_bytes: 1 << 20 }.size_bytes(), 1 << 20);
        // ...but ghosts are free to move:
        assert_eq!(Payload::Ghost { pretend_bytes: 1 << 20 }.transfer_bytes(), 0);
    }

    #[test]
    fn content_hash_distinguishes_shape() {
        let a = Payload::tensor(&[2, 3], vec![1.0; 6]);
        let b = Payload::tensor(&[3, 2], vec![1.0; 6]);
        assert_ne!(a.content_hash(), b.content_hash());
    }

    #[test]
    fn content_hash_stable() {
        let p = Payload::tensor(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p.content_hash(), p.content_hash());
    }

    #[test]
    fn uri_mentions_region_object_and_hash() {
        let v = av(DataClass::Raw, false);
        let uri = v.uri();
        assert!(uri.starts_with("koalja://region-0/obj-4#"));
    }

    #[test]
    fn scalar_roundtrip() {
        let p = Payload::scalar(7.5);
        let (shape, data) = p.as_tensor().unwrap();
        assert_eq!(shape, &[1]);
        assert_eq!(data, &[7.5]);
    }
}
