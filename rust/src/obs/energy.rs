//! Transport tiers and the byte-to-joule energy proxy.
//!
//! The paper frames transport avoidance as "rapidly becoming a global
//! sustainability imperative" (§III-G); to make that measurable we account
//! every byte by the network tier it crossed and convert to a joule proxy
//! (E7, fig. 11 experiments). Rehomed from the old string-keyed `metrics`
//! island: the per-wire byte counters in [`super::Obs`] feed the same
//! model.

/// Which hop a transfer crossed — the cost hierarchy of §III-G.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum NetTier {
    /// Same host: RAM / local disk.
    Local,
    /// Same region: storage network / fibre channel.
    Lan,
    /// Cross-region: the expensive, contended wide-area path.
    Wan,
}

/// Energy proxy constants (J/byte moved, J/task-run overhead). Absolute
/// values are order-of-magnitude literature figures; the *ratios* between
/// tiers are what the experiments depend on.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    pub j_per_byte_local: f64,
    pub j_per_byte_lan: f64,
    pub j_per_byte_wan: f64,
    pub j_per_run: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            j_per_byte_local: 1e-9,
            j_per_byte_lan: 2e-8,
            j_per_byte_wan: 2e-6,
            j_per_run: 1e-2,
        }
    }
}

impl EnergyModel {
    pub fn per_byte(&self, tier: NetTier) -> f64 {
        match tier {
            NetTier::Local => self.j_per_byte_local,
            NetTier::Lan => self.j_per_byte_lan,
            NetTier::Wan => self.j_per_byte_wan,
        }
    }
}
