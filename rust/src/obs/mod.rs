//! Observability: the flight recorder + the id-indexed metrics registry.
//!
//! The paper's enterprise pitch is "full tracing of provenance and
//! forensic reconstruction of transactional processes" — this layer makes
//! the *runtime* side of that story inspectable: what fired, when, why
//! (memo hit / deferral / rollback), what was published where, and what
//! it cost, all joined against the provenance ledger by the same dense
//! ids.
//!
//! Three pieces:
//!  * [`FlightRecorder`] ([`span`]) — a bounded ring of structured span
//!    events carrying `TaskId`/`WireId`/`RunId`/`AvId` + virtual instant;
//!  * [`Obs`] — per-task firing counters and latency histograms, per-wire
//!    publication/byte counters, wavefront occupancy, all `Vec`-indexed
//!    by the interned ids (no string ever touches the recording path);
//!  * the always-on platform sink [`Metrics`] ([`counters`]) plus the
//!    [`NetTier`]/[`EnergyModel`] byte-to-joule accounting ([`energy`]),
//!    rehomed here from the old string-keyed `metrics` island.
//!
//! Gating mirrors `prov.enabled`: every instrumentation site in the
//! coordinator guards with `if self.obs.enabled { ... }`, so a disabled
//! deployment pays one predictable branch per site — benchmarked by the
//! `obs-overhead` shape pair in `benches/coordinator_throughput.rs`, and
//! gated in CI (`tools/bench_delta.py`: trace-off ≤ 5% vs baseline,
//! trace-on ≤ 15% over trace-off).
//!
//! Recording is deterministic by construction: spans and counters update
//! only on the coordinator thread, at commit, in the wavefront's
//! canonical task-index order — workers never record (their observable
//! actions already funnel through the `EffectLog` replay, which *is* the
//! deterministic merge point). See DESIGN.md §Observability.

pub mod counters;
pub mod energy;
pub mod hist;
pub mod span;

pub use counters::Metrics;
pub use energy::{EnergyModel, NetTier};
pub use hist::LatencyHistogram;
pub use span::{FiringKind, FlightRecorder, Span, SpanEvent, NO_RUN};

use crate::util::{AvId, Json, RunId, SimDuration, SimTime, TaskId, WireId};

/// Per-task observability: how its firings resolved, and what they cost.
#[derive(Clone, Debug, Default)]
pub struct TaskStats {
    /// Completed user-code executions (direct or worker-recorded).
    pub firings: u64,
    /// Firings resolved from the memo (cached objects republished).
    pub memo_hits: u64,
    /// Firings that errored (including caught panics).
    pub errors: u64,
    /// Firings that skipped the worker pool (`parallel_safe() == false`).
    pub deferred: u64,
    /// Worker executions rolled back for a sequential re-run (direct-only
    /// API touched mid-recording).
    pub rollbacks: u64,
    /// Virtual cost of completed executions.
    pub latency: LatencyHistogram,
}

/// Per-wire observability (dense, `Copy` — one slot per interned wire).
#[derive(Clone, Copy, Debug, Default)]
pub struct WireStats {
    /// Values published onto this wire by producing tasks.
    pub publications: u64,
    /// Values injected externally (in-tray drops).
    pub injections: u64,
    /// Payload bytes that crossed this wire (published + injected).
    pub bytes: u64,
    /// Values that reached this wire as a sink and entered the commit log.
    pub sink_commits: u64,
}

/// Wavefront scheduler occupancy. Unlike spans, these may legitimately
/// differ between `workers` settings (parallel instants, deferral counts
/// are strategy); the determinism contract covers books, not occupancy.
#[derive(Clone, Copy, Debug, Default)]
pub struct WavefrontStats {
    /// Instants that flushed at least one firing.
    pub instants: u64,
    /// Total firings across all wavefronts.
    pub firings: u64,
    /// Widest single wavefront seen.
    pub max_width: u32,
    /// Instants that took the worker-pool path (`workers > 1`, ≥ 2 busy).
    pub parallel_instants: u64,
    /// Sum of busy-task counts over parallel instants (mean occupancy =
    /// `busy_accum / parallel_instants`).
    pub busy_accum: u64,
    /// Firings deferred from the pool to the commit phase (all reasons).
    pub deferred: u64,
    /// Deferred firings that were worker rollbacks specifically.
    pub rollbacks: u64,
    /// Frontier occupancy: instants extracted for pipelined execution
    /// while at least one earlier instant was still in flight (each one
    /// also records a [`SpanEvent::FrontierAdvance`] pipelining note).
    pub frontier_advances: u64,
    /// Sum of `behind` counts over those advances (mean overlap depth =
    /// `frontier_behind_accum / frontier_advances`).
    pub frontier_behind_accum: u64,
    /// Deepest overlap seen: the most in-flight earlier instants any
    /// single extraction ran ahead of.
    pub frontier_peak_behind: u32,
}

/// Streaming-ingestion observability: pump flush counters (see
/// [`crate::ingest`]). Like [`WavefrontStats`], these describe *pacing*
/// — they may legitimately differ between producer arrangements; the
/// determinism contract covers books, not cycle chopping.
#[derive(Clone, Copy, Debug, Default)]
pub struct IngestObs {
    /// Pump cycles that injected at least one event.
    pub flushes: u64,
    /// Events injected by the pump.
    pub events: u64,
    /// `inject_batch` calls the pump issued.
    pub batches: u64,
    /// Largest single pump injection batch.
    pub max_batch: u32,
    /// Deepest combined feed backlog seen at a cycle boundary.
    pub depth_high_water: u32,
}

/// The observability registry: one per deployed coordinator, sized to its
/// interned id spaces at deploy. All recording methods assume the caller
/// already checked [`Obs::enabled`] — that keeps the disabled cost to
/// exactly one branch per site, with no call into this module at all.
#[derive(Debug)]
pub struct Obs {
    /// Mirror of `DeployConfig::trace`. Sites guard on this.
    pub enabled: bool,
    pub rec: FlightRecorder,
    tasks: Vec<TaskStats>,
    wires: Vec<WireStats>,
    pub wavefront: WavefrontStats,
    pub ingest: IngestObs,
}

impl Obs {
    /// Registry for a pipeline with `n_tasks` tasks and `n_wires` wires.
    /// A disabled registry allocates nothing but the empty ring.
    pub fn sized(enabled: bool, n_tasks: usize, n_wires: usize) -> Self {
        let (nt, nw) = if enabled { (n_tasks, n_wires) } else { (0, 0) };
        Self {
            enabled,
            rec: FlightRecorder::default(),
            tasks: (0..nt).map(|_| TaskStats::default()).collect(),
            wires: vec![WireStats::default(); nw],
            wavefront: WavefrontStats::default(),
            ingest: IngestObs::default(),
        }
    }

    pub fn disabled() -> Self {
        Self::sized(false, 0, 0)
    }

    // ---- recording (call sites guard on `enabled`) --------------------

    /// One injection batch's span (`count` payloads; singles are batches
    /// of 1). Byte accounting happens per payload in [`Obs::inject_value`]
    /// — the batch path amortizes the span, never the bookkeeping.
    pub fn inject_span(&mut self, at: SimTime, wire: WireId, count: u32) {
        self.rec.record(at, SpanEvent::InjectBatch { wire, count });
    }

    /// Per-payload injection accounting (stats only — the caller's batch
    /// emits the span).
    pub fn inject_value(&mut self, wire: WireId, bytes: u64) {
        let w = &mut self.wires[wire.index()];
        w.injections += 1;
        w.bytes += bytes;
    }

    pub fn instant(&mut self, at: SimTime, events: u32) {
        self.rec.record(at, SpanEvent::InstantDrain { events });
    }

    /// Wavefront phases 1+2 begin: extract + execute spans, width stats.
    pub fn wavefront_begin(&mut self, at: SimTime, width: u32) {
        self.rec.record(at, SpanEvent::WavefrontExtract { width });
        self.rec.record(at, SpanEvent::WavefrontExecute { width });
        self.wavefront.instants += 1;
        self.wavefront.firings += width as u64;
        self.wavefront.max_width = self.wavefront.max_width.max(width);
    }

    /// Stats-only occupancy note for a worker-pool instant (no span: the
    /// busy count differs across `workers` settings and spans must not).
    pub fn wavefront_parallel(&mut self, busy: u32) {
        self.wavefront.parallel_instants += 1;
        self.wavefront.busy_accum += busy as u64;
    }

    pub fn wavefront_commit(&mut self, at: SimTime, width: u32) {
        self.rec.record(at, SpanEvent::WavefrontCommit { width });
    }

    /// Pipelining note + occupancy: virtual instant `at` was extracted
    /// for execution while `behind` earlier instants were still in
    /// flight. Only recorded with `behind >= 1` (running alone is not an
    /// advance); projected out of cross-window span comparisons
    /// ([`SpanEvent::is_pipelining_note`]).
    pub fn frontier_advance(&mut self, at: SimTime, behind: u32) {
        self.rec.record(at, SpanEvent::FrontierAdvance { behind });
        self.wavefront.frontier_advances += 1;
        self.wavefront.frontier_behind_accum += behind as u64;
        self.wavefront.frontier_peak_behind = self.wavefront.frontier_peak_behind.max(behind);
    }

    pub fn firing_run(&mut self, at: SimTime, task: TaskId, run: RunId, cost: SimDuration) {
        self.rec.record(at, SpanEvent::Firing { task, run, kind: FiringKind::Run });
        let t = &mut self.tasks[task.index()];
        t.firings += 1;
        t.latency.record(cost);
    }

    pub fn firing_memo(&mut self, at: SimTime, task: TaskId, run: RunId) {
        self.rec.record(at, SpanEvent::Firing { task, run, kind: FiringKind::MemoHit });
        self.tasks[task.index()].memo_hits += 1;
    }

    pub fn firing_failed(&mut self, at: SimTime, task: TaskId, run: RunId, panicked: bool) {
        let kind = if panicked { FiringKind::Panic } else { FiringKind::Error };
        self.rec.record(at, SpanEvent::Firing { task, run, kind });
        self.tasks[task.index()].errors += 1;
    }

    /// Supervision: a failed firing scheduled a virtual-time retry
    /// (`attempt` is the attempt that just failed). Span-only — the
    /// failure itself already counted in [`TaskStats::errors`].
    pub fn firing_retry(&mut self, at: SimTime, task: TaskId, run: RunId, attempt: u32) {
        self.rec.record(at, SpanEvent::FiringRetry { task, run, attempt });
    }

    /// Supervision: a firing exhausted its retry budget (`attempts`
    /// consumed; 0 = dropped unexecuted by an open circuit breaker).
    pub fn firing_exhausted(&mut self, at: SimTime, task: TaskId, run: RunId, attempts: u32) {
        self.rec.record(at, SpanEvent::FiringExhausted { task, run, attempts });
    }

    /// Supervision: the task's circuit breaker flipped (`open` =
    /// quarantined, `!open` = reset by operator or hot-swap).
    pub fn quarantine(&mut self, at: SimTime, task: TaskId, open: bool) {
        self.rec.record(at, SpanEvent::Quarantine { task, open });
    }

    /// Supervision: `count` dead-lettered firings were redriven.
    pub fn redrive(&mut self, at: SimTime, task: TaskId, count: u32) {
        self.rec.record(at, SpanEvent::Redrive { task, count });
    }

    /// Supervision: an exhausted firing emitted its declared fallback.
    pub fn firing_degraded(&mut self, at: SimTime, task: TaskId, run: RunId) {
        self.rec.record(at, SpanEvent::FiringDegraded { task, run });
    }

    /// Scheduling note: `parallel_safe() == false` code skipped the pool.
    pub fn note_deferred_sequential(&mut self, at: SimTime, task: TaskId) {
        self.rec.record(
            at,
            SpanEvent::Firing { task, run: NO_RUN, kind: FiringKind::DeferredSequential },
        );
        self.tasks[task.index()].deferred += 1;
        self.wavefront.deferred += 1;
    }

    /// Scheduling note: a worker recording was rolled back for sequential
    /// re-run.
    pub fn note_rollback(&mut self, at: SimTime, task: TaskId) {
        self.rec
            .record(at, SpanEvent::Firing { task, run: NO_RUN, kind: FiringKind::RollbackRerun });
        self.tasks[task.index()].rollbacks += 1;
        self.wavefront.deferred += 1;
        self.wavefront.rollbacks += 1;
    }

    /// Memo-valid snapshot routed to the commit phase (no span: the memo
    /// firing span follows when it resolves).
    pub fn note_deferred_memo(&mut self) {
        self.wavefront.deferred += 1;
    }

    pub fn publish(&mut self, at: SimTime, task: TaskId, wire: WireId, av: AvId, bytes: u64) {
        self.rec.record(at, SpanEvent::Publish { task, wire, av, bytes });
        let w = &mut self.wires[wire.index()];
        w.publications += 1;
        w.bytes += bytes;
    }

    pub fn sink_commit(&mut self, at: SimTime, wire: WireId, av: AvId) {
        self.rec.record(at, SpanEvent::SinkCommit { wire, av });
        self.wires[wire.index()].sink_commits += 1;
    }

    pub fn tap_observe(&mut self, at: SimTime, wire: WireId, av: AvId) {
        self.rec.record(at, SpanEvent::TapObserve { wire, av });
    }

    pub fn demand(&mut self, at: SimTime, wire: WireId) {
        self.rec.record(at, SpanEvent::Demand { wire });
    }

    /// Exchange movement note: `bytes` crossed from node `from` to node
    /// `to` over `wire` at `tier`. Recorded on the coordinator thread in
    /// delivery order, so `koalja trace` reconstructs data movement end to
    /// end; projected out of cross-placement span comparisons
    /// ([`SpanEvent::is_movement_note`]).
    pub fn transfer(&mut self, at: SimTime, wire: WireId, from: u32, to: u32, bytes: u64, tier: NetTier) {
        self.rec.record(at, SpanEvent::Transfer { wire, from, to, bytes, tier });
    }

    /// One ingest pump flush: a cycle sealed and injected `events` across
    /// `batches` `inject_batch` calls (`largest` = biggest of them),
    /// having observed `depth` backlogged events at the cycle boundary.
    /// The span is a pacing note ([`SpanEvent::is_pacing_note`]).
    pub fn ingest_flush(
        &mut self,
        at: SimTime,
        events: u32,
        batches: u32,
        largest: u32,
        depth: u32,
    ) {
        self.rec.record(at, SpanEvent::IngestFlush { events, batches });
        self.ingest.flushes += 1;
        self.ingest.events += events as u64;
        self.ingest.batches += batches as u64;
        self.ingest.max_batch = self.ingest.max_batch.max(largest);
        self.ingest.depth_high_water = self.ingest.depth_high_water.max(depth);
    }

    // ---- reading ------------------------------------------------------

    pub fn task_stats(&self, task: TaskId) -> Option<&TaskStats> {
        self.tasks.get(task.index())
    }

    pub fn wire_stats(&self, wire: WireId) -> Option<WireStats> {
        self.wires.get(wire.index()).copied()
    }

    pub fn all_task_stats(&self) -> &[TaskStats] {
        &self.tasks
    }

    pub fn all_wire_stats(&self) -> &[WireStats] {
        &self.wires
    }

    /// Schema'd JSON export (schema 1): the whole registry plus the
    /// retained span dump, names resolved once here — ids stay in the
    /// rows so external tooling can join against provenance dumps.
    pub fn snapshot(&self, pipeline: &str, task_names: &[&str], wire_names: &[&str]) -> Json {
        let tasks: Vec<Json> = self
            .tasks
            .iter()
            .enumerate()
            .map(|(i, t)| {
                Json::obj(vec![
                    ("id", Json::num(i as u32)),
                    ("name", Json::str(*task_names.get(i).unwrap_or(&"?"))),
                    ("firings", Json::num(t.firings as u32)),
                    ("memo_hits", Json::num(t.memo_hits as u32)),
                    ("errors", Json::num(t.errors as u32)),
                    ("deferred", Json::num(t.deferred as u32)),
                    ("rollbacks", Json::num(t.rollbacks as u32)),
                    (
                        "latency",
                        Json::obj(vec![
                            ("count", Json::num(t.latency.count() as u32)),
                            ("mean_us", Json::num(t.latency.mean().as_micros() as u32)),
                            ("max_us", Json::num(t.latency.max().as_micros() as u32)),
                            ("p99_us", Json::num(t.latency.quantile(0.99).as_micros() as u32)),
                            (
                                "buckets",
                                Json::Arr(
                                    t.latency
                                        .buckets()
                                        .iter()
                                        .map(|&b| Json::num(b as u32))
                                        .collect(),
                                ),
                            ),
                        ]),
                    ),
                ])
            })
            .collect();
        let wires: Vec<Json> = self
            .wires
            .iter()
            .enumerate()
            .map(|(i, w)| {
                Json::obj(vec![
                    ("id", Json::num(i as u32)),
                    ("name", Json::str(*wire_names.get(i).unwrap_or(&"?"))),
                    ("publications", Json::num(w.publications as u32)),
                    ("injections", Json::num(w.injections as u32)),
                    ("bytes", Json::num(w.bytes as f64)),
                    ("sink_commits", Json::num(w.sink_commits as u32)),
                ])
            })
            .collect();
        let wf = &self.wavefront;
        let spans: Vec<Json> = self.rec.spans().map(span_json).collect();
        Json::obj(vec![
            ("schema", Json::num(1)),
            ("pipeline", Json::str(pipeline)),
            ("enabled", Json::Bool(self.enabled)),
            ("tasks", Json::Arr(tasks)),
            ("wires", Json::Arr(wires)),
            (
                "wavefront",
                Json::obj(vec![
                    ("instants", Json::num(wf.instants as f64)),
                    ("firings", Json::num(wf.firings as f64)),
                    ("max_width", Json::num(wf.max_width)),
                    ("parallel_instants", Json::num(wf.parallel_instants as f64)),
                    ("busy_accum", Json::num(wf.busy_accum as f64)),
                    ("deferred", Json::num(wf.deferred as f64)),
                    ("rollbacks", Json::num(wf.rollbacks as f64)),
                    (
                        "frontier",
                        Json::obj(vec![
                            ("advances", Json::num(wf.frontier_advances as f64)),
                            ("behind_accum", Json::num(wf.frontier_behind_accum as f64)),
                            ("peak_behind", Json::num(wf.frontier_peak_behind)),
                        ]),
                    ),
                ]),
            ),
            (
                "ingest",
                Json::obj(vec![
                    ("flushes", Json::num(self.ingest.flushes as f64)),
                    ("events", Json::num(self.ingest.events as f64)),
                    ("batches", Json::num(self.ingest.batches as f64)),
                    ("max_batch", Json::num(self.ingest.max_batch)),
                    ("depth_high_water", Json::num(self.ingest.depth_high_water)),
                ]),
            ),
            (
                "recorder",
                Json::obj(vec![
                    ("recorded", Json::num(self.rec.recorded() as f64)),
                    ("retained", Json::num(self.rec.len() as u32)),
                    ("dropped", Json::num(self.rec.dropped() as f64)),
                    ("cap", Json::num(span::DEFAULT_SPAN_CAP as u32)),
                ]),
            ),
            ("spans", Json::Arr(spans)),
        ])
    }
}

/// One span as a JSON row: event name + whichever dense ids it carries.
fn span_json(s: &Span) -> Json {
    let mut pairs = vec![
        ("seq", Json::num(s.seq as f64)),
        ("at_us", Json::num(s.at.as_micros() as f64)),
        ("event", Json::str(s.event.name())),
    ];
    if let Some(t) = s.event.task() {
        pairs.push(("task", Json::num(t.index() as u32)));
    }
    if let Some(w) = s.event.wire() {
        pairs.push(("wire", Json::num(w.0)));
    }
    if let Some(r) = s.event.run() {
        pairs.push(("run", Json::num(r.0 as f64)));
    }
    match s.event {
        SpanEvent::InjectBatch { count, .. } => pairs.push(("count", Json::num(count))),
        SpanEvent::InstantDrain { events } => pairs.push(("events", Json::num(events))),
        SpanEvent::WavefrontExtract { width }
        | SpanEvent::WavefrontExecute { width }
        | SpanEvent::WavefrontCommit { width } => pairs.push(("width", Json::num(width))),
        SpanEvent::Firing { kind, .. } => pairs.push(("kind", Json::str(kind.as_str()))),
        SpanEvent::Publish { av, bytes, .. } => {
            pairs.push(("av", Json::num(av.0 as f64)));
            pairs.push(("bytes", Json::num(bytes as f64)));
        }
        SpanEvent::SinkCommit { av, .. } | SpanEvent::TapObserve { av, .. } => {
            pairs.push(("av", Json::num(av.0 as f64)));
        }
        SpanEvent::Demand { .. } => {}
        SpanEvent::FiringRetry { attempt, .. } => pairs.push(("attempt", Json::num(attempt))),
        SpanEvent::FiringExhausted { attempts, .. } => {
            pairs.push(("attempts", Json::num(attempts)));
        }
        SpanEvent::Quarantine { open, .. } => pairs.push(("open", Json::Bool(open))),
        SpanEvent::Redrive { count, .. } => pairs.push(("count", Json::num(count))),
        SpanEvent::FiringDegraded { .. } => {}
        SpanEvent::IngestFlush { events, batches } => {
            pairs.push(("events", Json::num(events)));
            pairs.push(("batches", Json::num(batches)));
        }
        SpanEvent::FrontierAdvance { behind } => pairs.push(("behind", Json::num(behind))),
        SpanEvent::Transfer { from, to, bytes, tier, .. } => {
            pairs.push(("from_node", Json::num(from)));
            pairs.push(("to_node", Json::num(to)));
            pairs.push(("bytes", Json::num(bytes as f64)));
            let tier_name = match tier {
                NetTier::Local => "local",
                NetTier::Lan => "lan",
                NetTier::Wan => "wan",
            };
            pairs.push(("tier", Json::str(tier_name)));
        }
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_allocates_no_slots() {
        let o = Obs::sized(false, 100, 100);
        assert!(!o.enabled);
        assert!(o.all_task_stats().is_empty());
        assert!(o.all_wire_stats().is_empty());
    }

    #[test]
    fn stats_accumulate_per_id() {
        let mut o = Obs::sized(true, 2, 3);
        let at = SimTime::micros(10);
        o.firing_run(at, TaskId::new(1), RunId::new(0), SimDuration::micros(5));
        o.firing_run(at, TaskId::new(1), RunId::new(1), SimDuration::micros(7));
        o.firing_memo(at, TaskId::new(0), RunId::new(2));
        o.publish(at, TaskId::new(1), WireId::new(2), AvId::new(0), 128);
        o.inject_span(at, WireId::new(0), 3);
        for _ in 0..3 {
            o.inject_value(WireId::new(0), 32);
        }
        o.sink_commit(at, WireId::new(2), AvId::new(0));
        let t1 = o.task_stats(TaskId::new(1)).unwrap();
        assert_eq!(t1.firings, 2);
        assert_eq!(t1.latency.count(), 2);
        assert_eq!(o.task_stats(TaskId::new(0)).unwrap().memo_hits, 1);
        let w2 = o.wire_stats(WireId::new(2)).unwrap();
        assert_eq!(w2.publications, 1);
        assert_eq!(w2.bytes, 128);
        assert_eq!(w2.sink_commits, 1);
        let w0 = o.wire_stats(WireId::new(0)).unwrap();
        assert_eq!(w0.injections, 3);
        assert_eq!(w0.bytes, 96);
        // 6 spans were recorded (one per call above)
        assert_eq!(o.rec.len(), 6);
    }

    #[test]
    fn snapshot_is_valid_schema1_json() {
        let mut o = Obs::sized(true, 1, 2);
        o.wavefront_begin(SimTime::micros(1), 1);
        o.firing_run(SimTime::micros(1), TaskId::new(0), RunId::new(0), SimDuration::micros(3));
        o.publish(SimTime::micros(2), TaskId::new(0), WireId::new(1), AvId::new(4), 32);
        o.wavefront_commit(SimTime::micros(2), 1);
        let j = o.snapshot("demo", &["t0"], &["in", "out"]);
        let text = j.to_string();
        let back = Json::parse(&text).expect("snapshot round-trips");
        assert_eq!(back.get("schema").and_then(|v| v.as_u64()), Some(1));
        assert_eq!(back.get("pipeline").and_then(|v| v.as_str()), Some("demo"));
        let tasks = back.get("tasks").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(tasks[0].get("name").and_then(|v| v.as_str()), Some("t0"));
        assert_eq!(tasks[0].get("firings").and_then(|v| v.as_u64()), Some(1));
        let spans = back.get("spans").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(spans.len(), 5); // extract, execute, firing, publish, commit
        assert_eq!(
            back.get("wavefront").and_then(|w| w.get("firings")).and_then(|v| v.as_u64()),
            Some(1)
        );
    }
}
