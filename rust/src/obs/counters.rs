//! The platform-wide metrics sink: counters, bytes-moved, energy, e2e
//! latency. Cheap to update on the hot path.
//!
//! This is the always-on half of observability — the substrates (storage,
//! bus, links) account here unconditionally, exactly as they did when this
//! lived in the old `metrics` module. The per-task / per-wire Vec-indexed
//! registries and the flight recorder live in [`super::Obs`] and are
//! gated; see the module doc for the split.

use super::{EnergyModel, LatencyHistogram, NetTier};
use crate::util::{SimDuration, SimTime};
use std::collections::BTreeMap;

/// The platform-wide metrics sink. Cheap to update on the hot path.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub counters: BTreeMap<String, u64>,
    pub bytes_moved: BTreeMap<NetTier, u64>,
    pub task_runs: u64,
    pub ghost_runs: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub wasted_runs: u64,
    pub notifications_sent: u64,
    pub polls_performed: u64,
    pub polls_empty: u64,
    pub energy: EnergyModel,
    pub joules: f64,
    pub e2e_latency: LatencyHistogram,
    pub storage_latency: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn bump(&mut self, key: &str) {
        self.add(key, 1);
    }

    pub fn add(&mut self, key: &str, n: u64) {
        *self.counters.entry(key.to_string()).or_insert(0) += n;
    }

    pub fn get(&self, key: &str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    /// Account a transfer of `bytes` across `tier` (bytes + joules).
    pub fn moved(&mut self, tier: NetTier, bytes: u64) {
        *self.bytes_moved.entry(tier).or_insert(0) += bytes;
        self.joules += bytes as f64 * self.energy.per_byte(tier);
    }

    pub fn bytes(&self, tier: NetTier) -> u64 {
        self.bytes_moved.get(&tier).copied().unwrap_or(0)
    }

    pub fn ran_task(&mut self, ghost: bool) {
        if ghost {
            self.ghost_runs += 1;
        } else {
            self.task_runs += 1;
            self.joules += self.energy.j_per_run;
        }
    }

    /// Record an end-to-end artifact latency: source stamp → sink arrival.
    pub fn e2e(&mut self, born: SimTime, done: SimTime) {
        self.e2e_latency.record(done.saturating_sub(born));
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "task_runs={} ghost_runs={} wasted_runs={} cache_hit/miss={}/{}\n",
            self.task_runs, self.ghost_runs, self.wasted_runs, self.cache_hits, self.cache_misses
        ));
        s.push_str(&format!(
            "bytes local={} lan={} wan={}  energy={:.3}J\n",
            self.bytes(NetTier::Local),
            self.bytes(NetTier::Lan),
            self.bytes(NetTier::Wan),
            self.joules
        ));
        s.push_str(&format!(
            "notify={} polls={} (empty {})  e2e mean={} p99~{} n={}\n",
            self.notifications_sent,
            self.polls_performed,
            self.polls_empty,
            self.e2e_latency.mean(),
            self.e2e_latency.quantile(0.99),
            self.e2e_latency.count()
        ));
        for (k, v) in &self.counters {
            s.push_str(&format!("  {k}={v}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_scales_with_tier() {
        let mut m = Metrics::new();
        m.moved(NetTier::Local, 1_000_000);
        let local_j = m.joules;
        m.moved(NetTier::Wan, 1_000_000);
        // WAN must dominate by orders of magnitude (the E7 premise).
        assert!(m.joules - local_j > local_j * 100.0);
        assert_eq!(m.bytes(NetTier::Wan), 1_000_000);
    }

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.bump("snapshots");
        m.add("snapshots", 2);
        assert_eq!(m.get("snapshots"), 3);
        assert_eq!(m.get("absent"), 0);
    }

    #[test]
    fn e2e_latency_saturates() {
        let mut m = Metrics::new();
        m.e2e(SimTime::micros(100), SimTime::micros(50)); // clock skew guard
        assert_eq!(m.e2e_latency.max().as_micros(), 0);
    }
}
