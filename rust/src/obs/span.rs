//! The flight recorder: a bounded ring of structured span events.
//!
//! Every span carries dense ids ([`TaskId`] / [`WireId`] / [`RunId`] /
//! [`AvId`]) plus the virtual instant it happened, so a trace joins
//! directly against the provenance ledger (checkpoint logs key on the
//! same `RunId`s, traveller passports on the same `AvId`s) for forensic
//! reconstruction. Spans are recorded *at commit* on the coordinator
//! thread, in the wavefront's canonical task-index order — so the
//! recorded sequence is identical for every `workers` setting (see
//! DESIGN.md §Observability for the merge argument), and turning the
//! recorder on cannot perturb a single committed byte.

use super::NetTier;
use crate::util::{AvId, RunId, SimTime, TaskId, WireId};
use std::collections::VecDeque;

/// Sentinel run id for spans that describe scheduling (not an execution):
/// no run was drawn for them, and none ever will be.
pub const NO_RUN: RunId = RunId(u64::MAX);

/// How a firing resolved.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FiringKind {
    /// User code executed (direct or worker-recorded — indistinguishable
    /// by contract).
    Run,
    /// Recipe matched the memo: cached objects republished, no compute.
    MemoHit,
    /// Scheduling note: the firing skipped the worker pool because its
    /// code declares `parallel_safe() == false`; it ran in the commit
    /// phase (a `Run`/`MemoHit`/`Panic` span follows).
    DeferredSequential,
    /// Scheduling note: a worker execution touched a direct-only API and
    /// was rolled back for a sequential re-run (a `Run`/`Panic` span
    /// follows).
    RollbackRerun,
    /// The firing died from a caught panic (the panic guard marks its
    /// errors, so panics and plain errors record distinct kinds).
    Panic,
    /// The firing returned a plain task error.
    Error,
}

impl FiringKind {
    /// Scheduling notes describe *strategy* (which execution phase ran the
    /// firing), not behavior — they only occur when `workers > 1`, so the
    /// span-identity comparison across worker counts projects them out.
    pub fn is_scheduling_note(self) -> bool {
        matches!(self, FiringKind::DeferredSequential | FiringKind::RollbackRerun)
    }

    pub fn as_str(self) -> &'static str {
        match self {
            FiringKind::Run => "run",
            FiringKind::MemoHit => "memo-hit",
            FiringKind::DeferredSequential => "deferred-sequential",
            FiringKind::RollbackRerun => "rollback-rerun",
            FiringKind::Panic => "panic",
            FiringKind::Error => "error",
        }
    }
}

/// One structured trace event. Everything is a dense id or a count — no
/// strings on the recording path; names resolve at render time.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SpanEvent {
    /// External data landed on an in-tray wire (`count` payloads in one
    /// batch; single injections are batches of 1).
    InjectBatch { wire: WireId, count: u32 },
    /// One virtual instant's event-queue drain (`events` dispatched).
    InstantDrain { events: u32 },
    /// Wavefront phase 1: `width` ready firings extracted this instant.
    WavefrontExtract { width: u32 },
    /// Wavefront phase 2 begins. Deliberately carries the width only —
    /// never the worker or busy count, which would differ between
    /// `workers` settings and break span-identity across them (occupancy
    /// lives in [`super::WavefrontStats`]).
    WavefrontExecute { width: u32 },
    /// Wavefront phase 3 finished: `width` firings committed.
    WavefrontCommit { width: u32 },
    /// One task firing resolved (see [`FiringKind`]).
    Firing { task: TaskId, run: RunId, kind: FiringKind },
    /// A produced AV was published onto a wire.
    Publish { task: TaskId, wire: WireId, av: AvId, bytes: u64 },
    /// A published AV reached a sink wire and entered the commit log.
    SinkCommit { wire: WireId, av: AvId },
    /// A breadboard tap observed a value on its wire.
    TapObserve { wire: WireId, av: AvId },
    /// Make-mode: a target wire was demanded (§III-B pull trigger).
    Demand { wire: WireId },
    /// A failed supervised firing scheduled a retry (virtual-time
    /// backoff); `attempt` is the attempt that just failed.
    FiringRetry { task: TaskId, run: RunId, attempt: u32 },
    /// A supervised firing exhausted its retry budget (`attempts`
    /// consumed; 0 = dropped by an open circuit breaker).
    FiringExhausted { task: TaskId, run: RunId, attempts: u32 },
    /// The task's circuit breaker flipped (`open` = quarantined).
    Quarantine { task: TaskId, open: bool },
    /// `count` dead-lettered firings were redriven through the task.
    Redrive { task: TaskId, count: u32 },
    /// An exhausted firing emitted its declared fallback (Degrade).
    FiringDegraded { task: TaskId, run: RunId },
    /// An AV crossed the inter-node exchange: `bytes` moved from node
    /// `from` to node `to` over `wire` at `tier`. Like scheduling notes,
    /// this is a *movement note*: it describes which node partition ran
    /// the pipeline, not what the pipeline computed, so span-identity
    /// comparisons across placements project it out
    /// (see [`SpanEvent::is_movement_note`]).
    Transfer { wire: WireId, from: u32, to: u32, bytes: u64, tier: NetTier },
    /// One ingest pump cycle sealed and injected events (`events` across
    /// `batches` `inject_batch` calls). Like scheduling and movement
    /// notes, this is a *pacing note*: how many instants a cycle sealed
    /// depends on wall-clock producer/pump interleaving and the adaptive
    /// credit, so span-identity comparisons across ingestion
    /// arrangements project it out ([`SpanEvent::is_pacing_note`]).
    IngestFlush { events: u32, batches: u32 },
    /// The frontier tracker extracted virtual instant `at` for pipelined
    /// execution while `behind` earlier instants were still in flight
    /// (extracted but not yet retired). `behind >= 1` is the proof that
    /// instant overlap actually occurred. Like scheduling notes, this is
    /// a *pipelining note*: it describes which instants the scheduler
    /// chose to overlap under the current `reorder_window`, never what
    /// the pipeline computed, so span-identity comparisons across window
    /// settings project it out ([`SpanEvent::is_pipelining_note`]).
    FrontierAdvance { behind: u32 },
}

impl SpanEvent {
    pub fn task(&self) -> Option<TaskId> {
        match self {
            SpanEvent::Firing { task, .. }
            | SpanEvent::Publish { task, .. }
            | SpanEvent::FiringRetry { task, .. }
            | SpanEvent::FiringExhausted { task, .. }
            | SpanEvent::Quarantine { task, .. }
            | SpanEvent::Redrive { task, .. }
            | SpanEvent::FiringDegraded { task, .. } => Some(*task),
            _ => None,
        }
    }

    pub fn wire(&self) -> Option<WireId> {
        match self {
            SpanEvent::InjectBatch { wire, .. }
            | SpanEvent::Publish { wire, .. }
            | SpanEvent::SinkCommit { wire, .. }
            | SpanEvent::TapObserve { wire, .. }
            | SpanEvent::Transfer { wire, .. }
            | SpanEvent::Demand { wire } => Some(*wire),
            _ => None,
        }
    }

    pub fn run(&self) -> Option<RunId> {
        match self {
            SpanEvent::Firing { run, .. }
            | SpanEvent::FiringRetry { run, .. }
            | SpanEvent::FiringExhausted { run, .. }
            | SpanEvent::FiringDegraded { run, .. }
                if *run != NO_RUN =>
            {
                Some(*run)
            }
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SpanEvent::InjectBatch { .. } => "inject-batch",
            SpanEvent::InstantDrain { .. } => "instant-drain",
            SpanEvent::WavefrontExtract { .. } => "wavefront-extract",
            SpanEvent::WavefrontExecute { .. } => "wavefront-execute",
            SpanEvent::WavefrontCommit { .. } => "wavefront-commit",
            SpanEvent::Firing { .. } => "firing",
            SpanEvent::Publish { .. } => "publish",
            SpanEvent::SinkCommit { .. } => "sink-commit",
            SpanEvent::TapObserve { .. } => "tap-observe",
            SpanEvent::Demand { .. } => "demand",
            SpanEvent::FiringRetry { .. } => "firing-retry",
            SpanEvent::FiringExhausted { .. } => "firing-exhausted",
            SpanEvent::Quarantine { .. } => "quarantine",
            SpanEvent::Redrive { .. } => "redrive",
            SpanEvent::FiringDegraded { .. } => "firing-degraded",
            SpanEvent::Transfer { .. } => "transfer",
            SpanEvent::IngestFlush { .. } => "ingest-flush",
            SpanEvent::FrontierAdvance { .. } => "frontier-advance",
        }
    }

    /// Movement notes record *where* data physically travelled under the
    /// current node partition. They are placement-dependent by design —
    /// the one sanctioned span-stream difference between node counts — so
    /// the placement determinism property projects them out, exactly as
    /// worker-count comparisons project out scheduling notes.
    pub fn is_movement_note(&self) -> bool {
        matches!(self, SpanEvent::Transfer { .. })
    }

    /// Pacing notes record *how* the ingest pump chopped the stream into
    /// cycles — wall-clock- and credit-dependent by design, the one
    /// sanctioned span-stream difference between ingestion arrangements.
    /// Span-identity comparisons across producer thread counts and pump
    /// cadences project them out, exactly as worker-count comparisons
    /// project out scheduling notes.
    pub fn is_pacing_note(&self) -> bool {
        matches!(self, SpanEvent::IngestFlush { .. })
    }

    /// Pipelining notes record *which* virtual instants the frontier
    /// tracker chose to overlap — a pure function of the
    /// `reorder_window` setting, never of the data. They only occur when
    /// `reorder_window > 1`, so span-identity comparisons across window
    /// settings project them out, exactly as worker-count comparisons
    /// project out scheduling notes.
    pub fn is_pipelining_note(&self) -> bool {
        matches!(self, SpanEvent::FrontierAdvance { .. })
    }
}

/// One recorded span: what happened, when, and in which record position.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub at: SimTime,
    /// Monotonic record sequence — total order over the whole session,
    /// surviving ring evictions (span `seq` N is the N+1th ever recorded).
    pub seq: u64,
    pub event: SpanEvent,
}

/// Default ring capacity: 64Ki spans ≈ a few MB resident, enough to hold
/// the full tail of any bench shape while bounding a long-running session.
pub const DEFAULT_SPAN_CAP: usize = 65_536;

/// The bounded span ring. Recording is push-back / pop-front; eviction is
/// counted, never silent.
#[derive(Debug)]
pub struct FlightRecorder {
    ring: VecDeque<Span>,
    cap: usize,
    next_seq: u64,
    dropped: u64,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_SPAN_CAP)
    }
}

impl FlightRecorder {
    pub fn with_capacity(cap: usize) -> Self {
        Self { ring: VecDeque::new(), cap: cap.max(1), next_seq: 0, dropped: 0 }
    }

    #[inline]
    pub fn record(&mut self, at: SimTime, event: SpanEvent) {
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.dropped += 1;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.ring.push_back(Span { at, seq, event });
    }

    /// The retained spans, oldest first.
    pub fn spans(&self) -> impl Iterator<Item = &Span> {
        self.ring.iter()
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Spans evicted from the front of the ring since deploy.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Total spans ever recorded (retained + dropped).
    pub fn recorded(&self) -> u64 {
        self.next_seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_counts_evictions() {
        let mut r = FlightRecorder::with_capacity(3);
        for i in 0..5u64 {
            r.record(SimTime::micros(i), SpanEvent::InstantDrain { events: i as u32 });
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        assert_eq!(r.recorded(), 5);
        // oldest retained span is the 3rd ever recorded (seq 2)
        let seqs: Vec<u64> = r.spans().map(|s| s.seq).collect();
        assert_eq!(seqs, vec![2, 3, 4]);
    }

    #[test]
    fn span_accessors_join_on_ids() {
        let e = SpanEvent::Firing { task: TaskId::new(3), run: RunId::new(7), kind: FiringKind::Run };
        assert_eq!(e.task(), Some(TaskId::new(3)));
        assert_eq!(e.run(), Some(RunId::new(7)));
        assert_eq!(e.wire(), None);
        let note = SpanEvent::Firing {
            task: TaskId::new(3),
            run: NO_RUN,
            kind: FiringKind::DeferredSequential,
        };
        assert_eq!(note.run(), None, "scheduling notes carry no run id");
        assert!(FiringKind::DeferredSequential.is_scheduling_note());
        assert!(FiringKind::RollbackRerun.is_scheduling_note());
        assert!(!FiringKind::Run.is_scheduling_note());
        let p = SpanEvent::Publish {
            task: TaskId::new(1),
            wire: WireId::new(2),
            av: AvId::new(9),
            bytes: 64,
        };
        assert_eq!(p.wire(), Some(WireId::new(2)));
        assert_eq!(p.name(), "publish");
        let t = SpanEvent::Transfer {
            wire: WireId::new(2),
            from: 0,
            to: 1,
            bytes: 4096,
            tier: NetTier::Wan,
        };
        assert!(t.is_movement_note());
        assert!(!p.is_movement_note());
        assert_eq!(t.wire(), Some(WireId::new(2)));
        assert_eq!(t.name(), "transfer");
    }
}
