//! Fixed-boundary latency histogram (power-of-2 microsecond buckets).
//!
//! Bucket `i` counts samples in `[2^i, 2^{i+1})` microseconds; bucket 0
//! additionally includes 0 (and therefore every sub-microsecond sample —
//! the virtual clock cannot represent them any finer). `merge` exists for
//! cross-worker aggregation: per-task histograms recorded independently
//! sum into one pipeline-wide view without re-recording samples.

use crate::util::SimDuration;

#[derive(Clone, Debug, Default)]
pub struct LatencyHistogram {
    /// bucket i counts samples in [2^i, 2^{i+1}) microseconds; bucket 0
    /// includes 0.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl LatencyHistogram {
    pub fn record(&mut self, d: SimDuration) {
        let us = d.as_micros();
        // floor(log2(us)) puts us in [2^idx, 2^{idx+1}); 0 and 1 both
        // belong in bucket 0 (the former `64 - leading_zeros` shifted
        // every sample one bucket up, exiling 1µs from bucket 0)
        let idx = if us <= 1 { 0 } else { (63 - us.leading_zeros()) as usize };
        if self.buckets.len() <= idx {
            self.buckets.resize(idx + 1, 0);
        }
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> SimDuration {
        if self.count == 0 {
            return SimDuration::ZERO;
        }
        SimDuration::micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> SimDuration {
        SimDuration::micros(self.max_us)
    }

    /// The raw bucket counts (bucket i = `[2^i, 2^{i+1})` µs, bucket 0
    /// includes 0). Exposed for JSON export and aggregation tests.
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Fold another histogram into this one (cross-worker / cross-task
    /// aggregation). Bucket boundaries are fixed, so merging is a
    /// bucket-wise sum.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        if self.buckets.len() < other.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (b, &o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Upper bucket boundary below which `q` of the mass falls.
    pub fn quantile(&self, q: f64) -> SimDuration {
        if self.count == 0 {
            return SimDuration::ZERO;
        }
        let target = (self.count as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                // bucket i spans [2^i, 2^{i+1}): report the upper edge
                return SimDuration::micros(1 << (i + 1));
            }
        }
        self.max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_quantile() {
        let mut h = LatencyHistogram::default();
        for us in [1u64, 2, 4, 8, 1000] {
            h.record(SimDuration::micros(us));
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.mean().as_micros(), (1 + 2 + 4 + 8 + 1000) / 5);
        assert!(h.quantile(0.5).as_micros() <= 8);
        assert!(h.quantile(1.0).as_micros() >= 1000);
    }

    #[test]
    fn bucket_zero_includes_zero_and_one_microsecond() {
        let mut h = LatencyHistogram::default();
        h.record(SimDuration::ZERO);
        h.record(SimDuration::micros(1));
        // both land in bucket 0: [0, 2) µs
        assert_eq!(h.buckets(), &[2]);
        // powers of two start their own bucket: 2 -> bucket 1, 4 -> bucket 2
        h.record(SimDuration::micros(2));
        h.record(SimDuration::micros(3));
        h.record(SimDuration::micros(4));
        assert_eq!(h.buckets(), &[2, 2, 1]);
        // 1000 µs: floor(log2(1000)) = 9
        h.record(SimDuration::micros(1000));
        assert_eq!(h.buckets().len(), 10);
        assert_eq!(h.buckets()[9], 1);
    }

    #[test]
    fn quantile_reports_upper_bucket_edge() {
        let mut h = LatencyHistogram::default();
        h.record(SimDuration::micros(1));
        // everything is in bucket 0 = [0, 2): the q=1.0 upper edge is 2
        assert_eq!(h.quantile(1.0).as_micros(), 2);
        h.record(SimDuration::micros(5)); // bucket 2 = [4, 8)
        assert_eq!(h.quantile(1.0).as_micros(), 8);
    }

    #[test]
    fn merge_sums_buckets_and_moments() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        for us in [0u64, 1, 2] {
            a.record(SimDuration::micros(us));
        }
        for us in [4u64, 1000] {
            b.record(SimDuration::micros(us));
        }
        a.merge(&b);
        assert_eq!(a.count(), 5);
        assert_eq!(a.max().as_micros(), 1000);
        assert_eq!(a.mean().as_micros(), (0 + 1 + 2 + 4 + 1000) / 5);
        assert_eq!(a.buckets()[0], 2);
        assert_eq!(a.buckets()[1], 1);
        assert_eq!(a.buckets()[2], 1);
        assert_eq!(a.buckets()[9], 1);
        // merging preserves totals vs recording everything in one go
        let mut all = LatencyHistogram::default();
        for us in [0u64, 1, 2, 4, 1000] {
            all.record(SimDuration::micros(us));
        }
        assert_eq!(all.buckets(), a.buckets());
    }

    #[test]
    fn merge_into_empty() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        b.record(SimDuration::micros(7));
        a.merge(&b);
        assert_eq!(a.count(), 1);
        assert_eq!(a.buckets(), b.buckets());
    }
}
