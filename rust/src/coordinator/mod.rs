//! The pipeline manager — the paper's coordination contribution.
//!
//! "A pipeline manager that handles registration of processes, scheduling
//! of work and assembly of metadata" (§III-B). This is the L3 event loop:
//! a discrete-event engine over virtual time driving smart task agents and
//! smart link agents against the shared [`Platform`].
//!
//! Both trigger modes of §III-B live here:
//!  * **reactive** — arrivals at the source end push computation
//!    downstream ([`Coordinator::inject`] + [`Coordinator::run_until`]);
//!  * **make** — a request for a target pulls a hierarchical rebuild
//!    backwards through dependencies, reusing memoized intermediates
//!    ([`Coordinator::demand`], in `make.rs`).
//!
//! Ghost batches (§III-K), software-update recomputation (§III-J), poll vs
//! push wakeups (Principle 1) and scale-to-zero sweeps also dispatch here.
//!
//! Scheduling is **pipelined across instants** by default: a frontier
//! tracker ([`frontier`]) knows which tasks can still be affected by
//! in-flight work, so independent tasks from several virtual instants
//! execute concurrently while commits retire strictly in
//! `(instant, task-index)` order inside a bounded reorder window
//! ([`DeployConfig::reorder_window`]). Every committed byte is identical
//! to the per-instant schedule's — DESIGN.md §Execution model carries
//! the argument, `rust/tests/wavefront_determinism.rs` the proof.

pub mod frontier;
pub mod make;
mod wavefront;

use crate::av::{AnnotatedValue, DataClass, Payload};
use crate::breadboard::tap::TapBoard;
use crate::bus::{Exchange, NotifyMode};
use crate::fault::{
    is_panic_error, DeadLetter, DeadLetterBook, EventStorm, FaultPlan, FireGuard, FirePolicy,
    Firing, OnExhaust, Supervision,
};
use crate::graph::PipelineGraph;
use crate::ingest::{
    Feed, FeedCore, IngestPump, IngestReport, IngestStats, StalledFeed,
    DEFAULT_FEED_CAPACITY,
};
use crate::link::{Delivery, LinkAgent};
use crate::net::WanTopology;
use crate::platform::{PlacementStrategy, Platform};
use crate::policy::{InputBuffer, RateControl, Snapshot, SnapshotEngine};
use crate::provenance::{CheckpointEvent, Relation};
use crate::shard::{PlacementSpec, ShardPlan};
use crate::spec::PipelineSpec;
use crate::storage::{PurgePolicy, StorageConfig};
use crate::obs::Obs;
use crate::task::builtins::PassThrough;
use crate::task::effects::{DeferReason, FireFail, PreparedFiring, RecordedBody, RecordedRun};
use crate::task::{RunOutcome, TaskAgent, TaskCode};
use crate::util::{
    AvId, ContentHash, Json, LinkId, ObjectId, RegionId, SimDuration, SimTime, TaskId, WireId,
};
use anyhow::{anyhow, bail, Result};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap, HashSet};
use std::sync::Arc;

pub(crate) use wavefront::WaveGroup;

/// Sentinel source id for externally injected data (file drops, sensors).
pub const EXTERNAL: TaskId = TaskId(u64::MAX);
/// Sentinel link id for sink-wire emissions (no consumer).
pub const SINK: LinkId = LinkId(u64::MAX);

/// Deployment-time configuration. Clonable so a breadboard session can
/// redeploy an identical twin for forensic replay.
#[derive(Clone)]
pub struct DeployConfig {
    pub topology: WanTopology,
    pub storage: StorageConfig,
    pub seed: u64,
    pub cache_policy: PurgePolicy,
    /// Record provenance metadata (disable to measure its overhead, E6).
    pub provenance: bool,
    pub default_notify: NotifyMode,
    /// Where freshly minted artifacts physically land (network-attached
    /// store vs host-local disk) — the ρ-storage knob, nothing to do with
    /// *task* placement (that's [`DeployConfig::placement`]).
    pub storage_placement: PlacementStrategy,
    /// Task placement across regions and simulated nodes (the sharded
    /// runtime): region pins move the *semantics* (fetch latency, books,
    /// sovereignty); the node count and node pins are purely operational —
    /// any partition commits byte-identical books (see `crate::shard`).
    /// Defaults to one node (`KOALJA_NODES` overrides) and no pins.
    pub placement: PlacementSpec,
    /// Baseline arm: ignore `@region` attrs, put everything in the nearest
    /// datacentre ("push everything to the centre", E7 control).
    pub force_central: bool,
    /// Wavefront worker threads: at each virtual instant the ready,
    /// mutually independent task firings execute on a
    /// `std::thread::scope` pool this wide, then commit in task-index
    /// order — sink books, provenance stamps, memo records and tap
    /// captures are byte-identical to sequential execution for any
    /// value. `1` = the fully sequential direct path (no worker threads,
    /// no effect recording). Defaults to `KOALJA_WORKERS` when set, else
    /// `std::thread::available_parallelism()`; clamped to ≥ 1 at deploy.
    pub workers: usize,
    /// Flight recorder + id-indexed metrics registry (see [`crate::obs`]).
    /// Off by default: disabled tracing costs one branch per
    /// instrumentation site and records nothing. Turning it on never
    /// changes a committed byte (spans record at commit in canonical
    /// order); the overhead budget is benchmarked by the `obs-overhead`
    /// shape pair. Defaults to `KOALJA_TRACE` when set ("1"/"true").
    pub trace: bool,
    /// Seeded fault-injection plan (see [`crate::fault`]): deterministic
    /// panics, errors and cost spikes at chosen (task, firing-index)
    /// coordinates — the chaos-testing lever. `None` (the default unless
    /// `KOALJA_FAULT_SEED` is set) injects nothing and keeps the whole
    /// supervision layer off the hot path.
    pub fault: Option<FaultPlan>,
    /// Pipelined multi-instant scheduling window (see
    /// [`crate::coordinator::frontier`]): how many virtual instants may be
    /// in flight — extracted, executing, but not yet retired — at once.
    /// Events at instant `T+k` whose target tasks sit outside every
    /// in-flight instant's downstream shadow may start executing while
    /// instant `T` is still open; commits still land in strict
    /// `(instant, task-index)` order, so sink books, commit logs,
    /// provenance, dead letters and span projections are byte-identical
    /// for **every** window setting (the determinism invariant in
    /// DESIGN.md §Execution model). `1` disables pipelining (the pure
    /// per-instant barrier); `0` means "auto": use [`DeployConfig::workers`].
    /// Defaults to `KOALJA_REORDER_WINDOW` when set, else auto.
    pub reorder_window: usize,
}

/// The deploy-time default for [`DeployConfig::workers`]: the
/// `KOALJA_WORKERS` env override (the CI determinism matrix sets it to 1
/// and 4) or the machine's available parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("KOALJA_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The deploy-time default for [`DeployConfig::reorder_window`]: the
/// `KOALJA_REORDER_WINDOW` env override (the CI determinism matrix sets
/// it to 1 and 64), else `0` = auto (resolve to the worker-pool width at
/// deploy).
pub fn default_reorder_window() -> usize {
    if let Ok(v) = std::env::var("KOALJA_REORDER_WINDOW") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n;
        }
    }
    0
}

/// The deploy-time default for [`DeployConfig::trace`]: the `KOALJA_TRACE`
/// env override (the CI determinism matrix sets it to 0 and 1), else off.
pub fn default_trace() -> bool {
    match std::env::var("KOALJA_TRACE") {
        Ok(v) => matches!(v.trim(), "1" | "true"),
        Err(_) => false,
    }
}

impl Default for DeployConfig {
    fn default() -> Self {
        Self {
            topology: crate::net::demo_topology(2),
            storage: StorageConfig::default(),
            seed: 1,
            cache_policy: PurgePolicy::Never,
            provenance: true,
            default_notify: NotifyMode::Push,
            storage_placement: PlacementStrategy::NetworkAttached,
            placement: PlacementSpec::default(),
            force_central: false,
            workers: default_workers(),
            trace: default_trace(),
            fault: crate::fault::default_fault_plan(),
            reorder_window: default_reorder_window(),
        }
    }
}

#[derive(Debug)]
enum EventKind {
    // AV behind an Arc so heap sift operations move 24 bytes, not 140
    // (§Perf: BinaryHeap::pop was 11% of the hot path with inline AVs) —
    // and, unlike the former Box, a publication fanning out to N consumers
    // mints ONE allocation shared by every Deliver event, the tap
    // observation and the wire-currency slot (N+2 deep clones before).
    Deliver { link: u32, av: Arc<AnnotatedValue> },
    Wake { task: TaskId },
    Poll { task: TaskId },
    ScaleSweep,
    /// Breadboard tap observation, routed through the queue so samples
    /// land in virtual-time order even for future-dated publications.
    /// Only ever pushed while at least one tap watches this wire.
    TapObserve { wire: WireId, av: Arc<AnnotatedValue> },
    /// A supervised retry: a failed firing re-enters the wavefront at
    /// `T + backoff(attempt)` with its input snapshot pinned. Boxed —
    /// the snapshot would otherwise quadruple the event size (§Perf).
    RetryFire { task: TaskId, firing: Box<Firing> },
}

struct Ev {
    at: SimTime,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// A value that reached a sink wire (pipeline output).
#[derive(Clone, Debug)]
pub struct Collected {
    pub at: SimTime,
    pub av: AnnotatedValue,
    pub payload: Payload,
}

/// Sink-wire captures, stored densely per interned [`WireId`] (§Perf) with
/// the `HashMap<String, _>`-shaped read API (`get`, `[..]` indexing,
/// `iter`) preserved for examples, tests and the CLI — name resolution
/// happens only on those cold read paths, never when the event loop
/// collects an artifact. Every capture sits in the dense per-wire store:
/// since the port runtime pre-resolves emissions, nothing can be published
/// under a name outside the deploy-time wire table (unknown wires error at
/// bind/emit with did-you-mean instead of leaking into an overflow map).
#[derive(Default)]
pub struct SinkBook {
    names: Arc<Vec<String>>,
    per_wire: Vec<Vec<Collected>>,
}

impl SinkBook {
    fn bound(names: Arc<Vec<String>>) -> Self {
        let per_wire = (0..names.len()).map(|_| Vec::new()).collect();
        Self { names, per_wire }
    }

    #[inline]
    fn push(&mut self, wire: WireId, rec: Collected) {
        self.per_wire[wire.index()].push(rec);
    }

    /// Captures on `wire`, or None when nothing was collected there
    /// (matching the former `HashMap::get` contract).
    pub fn get(&self, wire: &str) -> Option<&Vec<Collected>> {
        match self.names.iter().position(|n| n == wire) {
            Some(i) if !self.per_wire[i].is_empty() => Some(&self.per_wire[i]),
            _ => None,
        }
    }

    /// (wire name, captures) for every wire that collected something.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &[Collected])> {
        self.names
            .iter()
            .zip(&self.per_wire)
            .filter(|(_, v)| !v.is_empty())
            .map(|(n, v)| (n.as_str(), v.as_slice()))
    }

    /// Dense read by interned id (the handle API's path) — empty slice
    /// when nothing was collected or the id is out of range.
    pub fn by_id(&self, wire: WireId) -> &[Collected] {
        self.per_wire.get(wire.index()).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Take everything collected on `wire` so far, leaving it empty —
    /// a consuming read for long-running sessions that would otherwise
    /// accumulate sink captures without bound.
    pub fn drain_id(&mut self, wire: WireId) -> Vec<Collected> {
        match self.per_wire.get_mut(wire.index()) {
            Some(v) => std::mem::take(v),
            None => Vec::new(),
        }
    }
}

impl<'a> std::ops::Index<&'a str> for SinkBook {
    type Output = Vec<Collected>;
    fn index(&self, wire: &str) -> &Vec<Collected> {
        match self.get(wire) {
            Some(v) => v,
            None => panic!("no collected artifacts on wire '{wire}'"),
        }
    }
}

/// Latest AV per wire (make-mode inputs; ghost-routing audit), stored as
/// one dense `Arc` slot per interned wire: the hot path bumps a refcount
/// instead of hashing a name and deep-cloning the AV (§Perf). The
/// string-keyed `get` stays for the cold readers (baselines, demand entry).
#[derive(Default)]
pub struct WireCurrency {
    names: Arc<Vec<String>>,
    slots: Vec<Option<Arc<AnnotatedValue>>>,
}

impl WireCurrency {
    fn bound(names: Arc<Vec<String>>) -> Self {
        let slots = vec![None; names.len()];
        Self { names, slots }
    }

    /// Name-resolving read (cold paths).
    pub fn get(&self, wire: &str) -> Option<&AnnotatedValue> {
        let i = self.names.iter().position(|n| n == wire)?;
        self.slots[i].as_deref()
    }

    /// Dense read by interned id (hot paths). Out-of-range ids (from a
    /// different coordinator's table) read as None rather than panicking.
    #[inline]
    pub fn by_id(&self, wire: WireId) -> Option<&Arc<AnnotatedValue>> {
        self.slots.get(wire.index())?.as_ref()
    }

    #[inline]
    fn set(&mut self, wire: WireId, av: Arc<AnnotatedValue>) {
        self.slots[wire.index()] = Some(av);
    }
}

/// Per-task output slot: one interned wire plus the consumer links fanning
/// out from it. `links` empty ⇒ the wire is a sink for this producer.
struct OutSlot {
    wire: WireId,
    links: Vec<u32>,
}

/// Where a published emission goes, resolved once per publication — by an
/// integer scan over the producer's (tiny) slot list, since emissions
/// already carry their interned [`WireId`] (§Perf: the string scan the
/// old `Vec<Output>` return paid per publication is gone).
#[derive(Clone, Copy)]
enum RouteTarget {
    /// One of the producer's declared output slots (the normal case).
    Slot(usize),
    /// A wire in the deploy-time table that this producer did not declare
    /// (user code emitting another task's wire name): a phantom sink —
    /// taps, currency and dense capture still apply; no consumer links.
    Wire(WireId),
}

/// One sink capture in the deterministic commit log: the order sink
/// artifacts were *committed*, which under the wavefront scheduler is
/// canonical (task-index order within an instant) for every `workers`
/// setting. Forensic replay diffs against this log — not against heap
/// pop order, and not against the (drainable) [`SinkBook`] — so replays
/// are identical regardless of parallelism or consumed sinks.
#[derive(Clone, Copy, Debug)]
pub struct SinkCommit {
    pub wire: WireId,
    pub at: SimTime,
    pub content: ContentHash,
}

/// A task awaiting its pump in the current same-instant event batch
/// (deduplicated; `via_poll` remembers whether the poll re-arm logic
/// applies at the epilogue).
struct PendingPump {
    task: TaskId,
    via_poll: bool,
}

/// One order-sensitive artifact produced while dispatching a *staged*
/// instant under pipelined scheduling (see [`frontier`]). Commutative
/// bookkeeping (bus pushes, byte counters, wire currency) runs live at
/// stage time; artifacts whose *sequence* is part of the determinism
/// contract — tap ring observations, transfer spans, sovereignty error
/// records — are buffered here and replayed at the unit's retirement, in
/// staged-dispatch order, so every `reorder_window` produces the same
/// books and span projections.
enum StagedArtifact {
    Tap { wire: WireId, av: Arc<AnnotatedValue> },
    Transfer(crate::bus::TransferNote),
    Denied { link_idx: usize, av: Arc<AnnotatedValue> },
}

/// One extracted-but-unretired instant under pipelined scheduling: its
/// wavefront groups (indices into the batch's flat group vector), the
/// frontier capability it holds, and its buffered dispatch artifacts.
struct InFlightUnit {
    at: SimTime,
    handled: u32,
    /// Range into the batch's flat `Vec<WaveGroup>`.
    groups: std::ops::Range<usize>,
    /// Tasks whose groups were extracted while quarantined — their
    /// firings dead-letter at retirement (commit order), not at stage
    /// time (the divert draws run ids).
    quarantined: Vec<usize>,
    mask: frontier::ShadowMask,
    artifacts: Vec<StagedArtifact>,
}

/// One structured sovereignty refusal (§IV): a delivery the zone policy
/// denied, with enough context to fix the pipeline. The delivery itself
/// keeps the established drop semantics (passport stamped, counter
/// bumped, pipeline flows on) — this record is the operator-facing error
/// surface, and it carries the did-you-mean guidance the raw drop can't.
#[derive(Clone, Debug)]
pub struct SovereigntyError {
    pub task: TaskId,
    pub wire: WireId,
    pub av: AvId,
    pub from: RegionId,
    pub to: RegionId,
    pub at: SimTime,
    /// Human-readable diagnosis, including the summarize-first suggestion.
    pub error: String,
}

/// The deployed pipeline.
pub struct Coordinator {
    pub graph: PipelineGraph,
    pub agents: Vec<TaskAgent>,
    pub links: Vec<LinkAgent>,
    pub plat: Platform,
    queue: BinaryHeap<Reverse<Ev>>,
    seq: u64,
    /// Sink-wire captures, dense per wire (string-keyed reads preserved).
    pub collected: SinkBook,
    /// Latest AV seen per wire (make-mode inputs; ghost-routing audit).
    pub latest_on_wire: WireCurrency,
    /// Tasks with an outstanding Poll event (avoid duplicates).
    polls_pending: HashSet<TaskId>,
    /// Last arrival per polling task (to let idle polls wind down).
    last_arrival: HashMap<TaskId, SimTime>,
    pub events_processed: u64,
    scale_sweep_every: Option<SimDuration>,
    /// Make-mode flag: outputs update wires/sinks but schedule no reactive
    /// deliveries (demand drives the ordering itself).
    pub(crate) suppress_routing: bool,
    // ---- hot-path adjacency (precomputed at deploy; see §Perf) ----
    /// link indices delivering into each task
    in_links: Vec<Vec<usize>>,
    /// per task: output slots (interned wire → consumer link indices)
    out_links: Vec<Vec<OutSlot>>,
    /// per link: position of the consumer's input buffer in its engine
    link_buffer: Vec<usize>,
    /// Breadboard wire taps (§III-H). Dispatch is guarded by a single
    /// `is_empty()` branch plus a dense per-wire mask, so an untapped
    /// pipeline pays nothing — see benches/tap_overhead.rs.
    pub taps: TapBoard,
    /// Wavefront worker-pool width (see [`DeployConfig::workers`]).
    workers: usize,
    /// Resolved pipelining window (see [`DeployConfig::reorder_window`]):
    /// `1` = per-instant barrier, `> 1` = up to that many instants in
    /// flight. The `0 = auto` sentinel was resolved to `workers` at deploy.
    reorder_window: usize,
    /// Per-task input-frontier tracker (see [`frontier`]): which tasks sit
    /// under an in-flight instant's downstream shadow, plus the ingest
    /// watermark the pump last sealed to.
    frontier: frontier::FrontierTracker,
    /// Order-sensitive artifacts of the instant currently being *staged*
    /// (`Some` only inside the pipelined drain's dispatch phase — the
    /// dispatch hooks divert taps, transfer spans and sovereignty errors
    /// here for replay at retirement).
    stage_buf: Option<Vec<StagedArtifact>>,
    /// Tasks woken during the current same-instant event batch, awaiting
    /// the wavefront flush (dedup'd, flushed in task-index order).
    pending_pumps: Vec<PendingPump>,
    /// Deterministic commit log of sink captures (see [`SinkCommit`]).
    commit_log: Vec<SinkCommit>,
    /// Flight recorder + id-indexed metrics (see [`crate::obs`]). Every
    /// instrumentation site guards on `obs.enabled`, so a trace-off
    /// deployment pays one branch per site (benchmarked: `obs-overhead`).
    obs: Obs,
    /// Supervised firing lifecycle (see [`crate::fault`]): per-task fire
    /// policies, dead-letter books, quarantine breakers, and the seeded
    /// fault plan. Idle (one branch per firing) unless a policy or plan
    /// is installed — benchmarked by the `fault-overhead` shape pair.
    pub supervision: Supervision,
    /// The node partition this deployment runs under (see
    /// [`crate::shard`]): purely operational — every plan commits
    /// byte-identical books.
    shard: ShardPlan,
    /// Per-cross-node-wire transfer accounting (see [`crate::bus::Exchange`]).
    exchange: Exchange,
    /// Structured sovereignty refusals, event order (see
    /// [`SovereigntyError`]). Region-determined, so identical for every
    /// node partition and worker count.
    sovereignty_errors: Vec<SovereigntyError>,
    /// `run_until_idle` gives up after this many events in one call and
    /// reports an [`EventStorm`] instead of looping forever.
    storm_cap: u64,
    /// The storm report from the most recent `run_until_idle`, if it
    /// tripped (cleared on the next run call).
    last_storm: Option<EventStorm>,
    /// Interned wire names shared with every injection ledger record, so
    /// large batches pay a refcount bump per event instead of a fresh
    /// `String` allocation (§Perf; see [`InjectionRecord`]).
    ledger_names: Vec<Arc<str>>,
    /// The streaming ingestion pump, created lazily by the first
    /// [`Coordinator::open_feed`] (see [`crate::ingest`]).
    ingest: Option<Box<IngestPump>>,
}

impl Coordinator {
    /// Deploy a validated spec. Every task gets default pass-through code;
    /// plug real logic with [`Coordinator::set_code`].
    pub fn deploy(spec: &PipelineSpec, cfg: DeployConfig) -> Result<Self> {
        spec.validate().map_err(|e| anyhow!("invalid spec: {e}"))?;
        let graph = PipelineGraph::build(spec);
        let mut plat = Platform::new(cfg.topology, cfg.storage, cfg.seed);
        plat.storage_placement = cfg.storage_placement;
        if !cfg.provenance {
            plat.prov = crate::provenance::ProvenanceRegistry::disabled();
        }

        // Region assignment: @region attr, else a placement pin, else the
        // nearest datacentre.
        let default_region = plat
            .net
            .regions
            .iter()
            .find(|r| !r.is_edge)
            .map(|r| r.id)
            .unwrap_or(RegionId::new(0));
        let mut agents = Vec::with_capacity(graph.n_tasks());
        for (i, t) in graph.tasks.iter().enumerate() {
            let id = TaskId::new(i as u64);
            let region = if cfg.force_central {
                default_region
            } else {
                match t.attr("region") {
                    Some(name) => plat
                        .net
                        .by_name(name)
                        .ok_or_else(|| anyhow!("task '{}': unknown region '{name}'", t.name))?,
                    None => match cfg.placement.regions.get(&t.name) {
                        Some(name) => plat.net.by_name(name).ok_or_else(|| {
                            anyhow!("task '{}': unknown placement region '{name}'", t.name)
                        })?,
                        None => default_region,
                    },
                }
            };
            plat.cluster.place(id, region, plat.now);

            let notify = match t.attr("notify") {
                Some("push") => NotifyMode::Push,
                Some(s) if s.starts_with("poll:") => {
                    let ms: u64 = s[5..]
                        .trim_end_matches("ms")
                        .parse()
                        .map_err(|_| anyhow!("task '{}': bad notify '{s}'", t.name))?;
                    NotifyMode::Poll(SimDuration::millis(ms))
                }
                _ => cfg.default_notify,
            };

            // one buffer per distinct stream-input port
            let mut buffers: Vec<InputBuffer> = Vec::new();
            for inp in t.stream_inputs() {
                if !buffers.iter().any(|b| &*b.name == inp.wire.as_str()) {
                    buffers.push(InputBuffer::new(&inp.wire, inp.buffer));
                }
            }
            let rate = match t.attr("rate") {
                Some(s) => RateControl::new(SimDuration::millis(
                    s.trim_end_matches("ms")
                        .parse()
                        .map_err(|_| anyhow!("task '{}': bad rate '{s}'", t.name))?,
                )),
                None => RateControl::default(),
            };
            let engine = SnapshotEngine::new(t.policy(), buffers, rate);
            // default code: pass inputs through on the first declared port
            // (or the interned "void" fallback for output-less tasks)
            let code: Box<dyn TaskCode> = Box::new(PassThrough::new(
                t.outputs.first().map(|s| s.as_str()).unwrap_or("void"),
            ));
            agents.push(TaskAgent::new(
                id,
                t.clone(),
                region,
                engine,
                code,
                notify,
                cfg.cache_policy,
                &graph.wires,
            )?);

            // concept map: the long-term design story (§III-C story 3)
            for inp in &t.inputs {
                plat.prov.concept(&t.name, Relation::Consumes, &inp.wire);
            }
            for out in &t.outputs {
                plat.prov.concept(&t.name, Relation::Produces, out);
            }
        }
        // precedes edges between tasks
        for l in &graph.links {
            if let Some(from) = l.from {
                plat.prov.concept(
                    &graph.task(from).name,
                    Relation::Precedes,
                    &graph.task(l.to).name,
                );
            }
        }

        // link agents + bus topics
        let mut links = Vec::with_capacity(graph.links.len());
        for l in &graph.links {
            let consumer = &agents[l.to.index()];
            plat.bus.subscribe(l.id, l.to);
            links.push(LinkAgent::new(l.clone(), consumer.region, consumer.notify));
        }

        // §Perf: precompute adjacency so the event loop never scans the
        // global link list (was O(links) per delivery/pull/publish).
        // Output slots carry the interned WireId: one name resolution per
        // published Output, dense id routing everywhere after.
        let mut in_links: Vec<Vec<usize>> = vec![vec![]; graph.n_tasks()];
        let mut out_links: Vec<Vec<OutSlot>> = (0..graph.n_tasks()).map(|_| vec![]).collect();
        let mut link_buffer = Vec::with_capacity(graph.links.len());
        for (li, l) in graph.links.iter().enumerate() {
            in_links[l.to.index()].push(li);
            if let Some(from) = l.from {
                let slots = &mut out_links[from.index()];
                match slots.iter_mut().find(|s| s.wire == l.wire_id) {
                    Some(s) => s.links.push(li as u32),
                    None => slots.push(OutSlot { wire: l.wire_id, links: vec![li as u32] }),
                }
            }
            let buf_idx = agents[l.to.index()]
                .engine
                .buffers
                .iter()
                .position(|b| &*b.name == l.to_input.as_str())
                .unwrap_or(0);
            link_buffer.push(buf_idx);
        }
        // sink wires get an (empty) slot so route_output can distinguish
        for (ti, t) in graph.tasks.iter().enumerate() {
            for w in &t.outputs {
                let wid = graph.wires.id(w).expect("task outputs are interned at build");
                if !out_links[ti].iter().any(|s| s.wire == wid) {
                    out_links[ti].push(OutSlot { wire: wid, links: vec![] });
                }
            }
        }

        // one shared copy of the interned names for every dense per-wire
        // structure (sink book, wire currency, tap mask)
        let wire_names: Arc<Vec<String>> = Arc::new(graph.wires.names().to_vec());
        let wire_name_arcs: Vec<Arc<str>> =
            graph.wires.names().iter().map(|n| Arc::from(n.as_str())).collect();
        let (n_tasks, n_wires) = (graph.n_tasks(), graph.wires.len());

        // the node partition and its exchange: which simulated node runs
        // each task, and a channel per wire that crosses nodes. Regions
        // were settled above, so the plan sees the final assignment.
        let regions: Vec<RegionId> = agents.iter().map(|a| a.region).collect();
        let shard = ShardPlan::build(&graph, &regions, &cfg.placement);
        let exchange = Exchange::build(&graph, &shard, &regions, &plat.net, &plat.metrics.energy);

        let workers = cfg.workers.max(1);
        // resolve the 0 = auto sentinel: pipeline as deep as the pool is
        // wide (a deeper window cannot be *wrong* — commits stay ordered —
        // it just holds more memory in flight)
        let reorder_window =
            if cfg.reorder_window == 0 { workers } else { cfg.reorder_window }.max(1);
        let frontier_tracker =
            frontier::FrontierTracker::new(n_tasks, |t| graph.reachable_downstream(t));

        Ok(Self {
            graph,
            agents,
            links,
            plat,
            queue: BinaryHeap::new(),
            seq: 0,
            collected: SinkBook::bound(Arc::clone(&wire_names)),
            latest_on_wire: WireCurrency::bound(Arc::clone(&wire_names)),
            polls_pending: HashSet::new(),
            last_arrival: HashMap::new(),
            events_processed: 0,
            scale_sweep_every: None,
            suppress_routing: false,
            in_links,
            out_links,
            link_buffer,
            taps: TapBoard::bound(wire_names),
            workers,
            reorder_window,
            frontier: frontier_tracker,
            stage_buf: None,
            pending_pumps: Vec::new(),
            commit_log: Vec::new(),
            obs: Obs::sized(cfg.trace, n_tasks, n_wires),
            supervision: Supervision::sized(n_tasks, cfg.fault),
            shard,
            exchange,
            sovereignty_errors: Vec::new(),
            storm_cap: 10_000_000,
            last_storm: None,
            ledger_names: wire_name_arcs,
            ingest: None,
        })
    }

    /// Wavefront worker-pool width this deployment runs with (`1` =
    /// fully sequential).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Resolved pipelining window this deployment runs with (`1` = the
    /// per-instant barrier; see [`DeployConfig::reorder_window`]).
    pub fn reorder_window(&self) -> usize {
        self.reorder_window
    }

    /// The frontier tracker (in-flight instant shadows + ingest
    /// watermark; see [`frontier`]). Read-only — occupancy statistics
    /// also surface in the obs snapshot's `wavefront.frontier` object.
    pub fn frontier(&self) -> &frontier::FrontierTracker {
        &self.frontier
    }

    /// Ingest-pump handoff: record the watermark the pump just sealed to
    /// as the injection feeds' contribution to the input frontier.
    pub(crate) fn note_ingest_frontier(&mut self, w: SimTime) {
        self.frontier.note_ingest(w);
    }

    /// The node partition this deployment runs under.
    pub fn shard(&self) -> &ShardPlan {
        &self.shard
    }

    /// The inter-node exchange: per-cross-node-wire transfer accounting.
    /// Empty (every link same-node) on a single-node deployment.
    pub fn exchange(&self) -> &Exchange {
        &self.exchange
    }

    /// Structured sovereignty refusals recorded so far, event order. Each
    /// entry is a delivery the zone policy denied — zero bytes moved —
    /// with a did-you-mean-summarize diagnosis in `error`.
    pub fn sovereignty_errors(&self) -> &[SovereigntyError] {
        &self.sovereignty_errors
    }

    /// The observability registry: flight recorder, per-task/per-wire
    /// counters, wavefront occupancy. Empty unless the deployment set
    /// [`DeployConfig::trace`].
    pub fn obs(&self) -> &Obs {
        &self.obs
    }

    /// Schema'd JSON export of the observability registry (tasks, wires,
    /// wavefront occupancy, retained span dump), names resolved against
    /// the deploy-time intern tables.
    pub fn obs_snapshot(&self) -> Json {
        let task_names: Vec<&str> = self.graph.tasks.iter().map(|t| t.name.as_str()).collect();
        let wire_names: Vec<&str> =
            self.graph.wires.names().iter().map(|n| n.as_str()).collect();
        self.obs.snapshot(&self.graph.name, &task_names, &wire_names)
    }

    /// Plug task code into a task (recorded in the agent's versioned code
    /// slot history). Thin name→id wrapper over
    /// [`Coordinator::set_code_id`]; unknown names error with candidates.
    /// Legacy [`UserCode`](crate::task::UserCode) plugins install through
    /// [`crate::task::legacy`].
    pub fn set_code(&mut self, task: &str, code: Box<dyn TaskCode>) -> Result<()> {
        let id = self.task_id(task)?;
        self.set_code_id(id, code)
    }

    /// Id-based code install (the handle API's path — no name resolution
    /// for the *task*; the code's `bind` resolves its ports here, and a
    /// bind failure — an unknown output port, with did-you-mean — rejects
    /// the install leaving the previous code running).
    pub fn set_code_id(&mut self, task: TaskId, code: Box<dyn TaskCode>) -> Result<()> {
        let now = self.plat.now;
        self.agents[task.index()].install_code(code, &self.graph.wires, now, "plug")?;
        Ok(())
    }

    /// Resolve a task name; unknown names list near-miss candidates.
    pub fn task_id(&self, name: &str) -> Result<TaskId> {
        self.graph.task_id(name).ok_or_else(|| {
            anyhow!(
                "no task '{name}' in pipeline [{}]{}",
                self.graph.name,
                crate::util::suggest(name, "task", self.graph.tasks.iter().map(|t| t.name.as_str()))
            )
        })
    }

    pub fn agent(&self, name: &str) -> Result<&TaskAgent> {
        Ok(&self.agents[self.task_id(name)?.index()])
    }

    /// Enable periodic scale-to-zero sweeps.
    pub fn enable_scale_sweeps(&mut self, every: SimDuration) {
        self.scale_sweep_every = Some(every);
        self.push_event(self.plat.now + every, EventKind::ScaleSweep);
    }

    fn push_event(&mut self, at: SimTime, kind: EventKind) {
        self.seq += 1;
        self.queue.push(Reverse(Ev { at, seq: self.seq, kind }));
    }

    // ------------------------------------------------------------------
    // Injection (the user-facing edge: file drops, sensors, samples)
    // ------------------------------------------------------------------

    /// Inject external data onto a wire at `at` (≥ now), in `region`.
    /// Reactive mode: deliveries are scheduled and downstream computation
    /// cascades on `run_until`. Thin name→id wrapper over
    /// [`Coordinator::inject_at_id`]; unknown wire names error cleanly.
    pub fn inject_at(
        &mut self,
        wire: &str,
        payload: Payload,
        class: DataClass,
        region: RegionId,
        at: SimTime,
    ) -> Result<AvId> {
        let wid = self.wire_id(wire)?;
        self.inject_at_id(wid, payload, class, region, at)
    }

    /// Resolve a wire name against the deploy-time intern table; unknown
    /// names list near-miss candidates.
    pub fn wire_id(&self, wire: &str) -> Result<WireId> {
        self.graph.wires.id(wire).ok_or_else(|| {
            anyhow!(
                "no wire '{wire}' in pipeline [{}]{}",
                self.graph.name,
                crate::util::suggest(wire, "wire", self.graph.wires.names().iter().map(|n| n.as_str()))
            )
        })
    }

    /// Id-based injection — the hot path: no name hashing, no link-list
    /// scan (injection fan-out is precomputed per wire), and one shared
    /// `Arc` across every consumer delivery, the tap observation and the
    /// wire-currency slot (§Perf).
    pub fn inject_at_id(
        &mut self,
        wire: WireId,
        payload: Payload,
        class: DataClass,
        region: RegionId,
        at: SimTime,
    ) -> Result<AvId> {
        if wire.index() >= self.graph.wires.len() {
            bail!(
                "{wire} is out of range for pipeline [{}] ({} wires) — ids are only \
                 valid for the coordinator whose wire table minted them",
                self.graph.name,
                self.graph.wires.len()
            );
        }
        let fanout = self.graph.wires.injections(wire).len();
        if fanout == 0 {
            bail!(
                "wire '{}' has no injection point (a task produces it)",
                self.graph.wires.name(wire)
            );
        }
        let watched = self.taps.watches(wire);
        let current = at <= self.plat.now;
        let wire_name = Arc::clone(&self.ledger_names[wire.index()]);
        let id =
            self.inject_prepared(wire, &wire_name, payload, class, region, at, watched, current, fanout);
        if self.obs.enabled {
            self.obs.inject_span(at, wire, 1);
        }
        Ok(id)
    }

    /// One payload's mint → ledger → tap → currency → fan-out sequence,
    /// shared verbatim by [`Coordinator::inject_at_id`] and
    /// [`Coordinator::inject_batch_at_id`] so the single and batched
    /// paths can never drift behaviorally. Validation and the per-batch
    /// hoisting (`watched`, `current`, `fanout`, resolved wire name) live
    /// in the callers.
    #[allow(clippy::too_many_arguments)]
    fn inject_prepared(
        &mut self,
        wire: WireId,
        wire_name: &Arc<str>,
        payload: Payload,
        class: DataClass,
        region: RegionId,
        at: SimTime,
        watched: bool,
        current: bool,
        fanout: usize,
    ) -> AvId {
        // mint under the arrival clock
        let saved_now = self.plat.now;
        self.plat.now = at;
        let run = self.plat.next_run_id();
        let (av, _lat) =
            self.plat.mint_av(payload, EXTERNAL, run, 0, SINK, region, class, 0, &[], at);
        self.plat.now = saved_now;
        if self.obs.enabled {
            self.obs.inject_value(wire, av.size_bytes);
        }
        // forensic ledger: the breadboard replays a window from exactly
        // these records + the deployment seed (§III-J reconstruction)
        self.plat.prov.record_injection(crate::provenance::InjectionRecord {
            av: av.id,
            wire: Arc::clone(wire_name),
            at,
            region,
            class,
            object: av.object,
            content: av.content,
        });
        let av = Arc::new(av);
        // breadboard probe point: injected values appear on the wire once
        // (fan-out links would otherwise observe them per consumer), at
        // their virtual arrival time (via the queue, not immediately).
        // `watches` is a dense mask, so untapped wires never allocate.
        if watched {
            self.push_event(at, EventKind::TapObserve { wire, av: Arc::clone(&av) });
        }
        // Only immediately-visible injections update wire currency now;
        // future-dated arrivals become current when delivered (otherwise a
        // schedule-driven consumer could see data "from the future").
        if current {
            self.latest_on_wire.set(wire, Arc::clone(&av));
        }
        for k in 0..fanout {
            let li = self.graph.wires.injections(wire)[k];
            self.push_event(
                at,
                EventKind::Deliver { link: li.index() as u32, av: Arc::clone(&av) },
            );
        }
        av.id
    }

    /// Inject now, into the first region.
    pub fn inject(&mut self, wire: &str, payload: Payload, class: DataClass) -> Result<AvId> {
        self.inject_at(wire, payload, class, RegionId::new(0), self.plat.now)
    }

    /// Batched injection: drop `payloads` onto `wire` now, in the first
    /// region. One name resolution for the whole batch; see
    /// [`Coordinator::inject_batch_at_id`] for what else is amortized.
    pub fn inject_batch(
        &mut self,
        wire: &str,
        payloads: impl IntoIterator<Item = Payload>,
        class: DataClass,
    ) -> Result<Vec<AvId>> {
        let wid = self.wire_id(wire)?; // the batch's single name resolution
        self.inject_batch_at_id(wid, payloads, class, RegionId::new(0), self.plat.now)
    }

    /// Id-based batched injection — the bulk edge of the hot path. All
    /// payloads arrive on `wire` at the same virtual instant `at`, in
    /// iterator order (heap ties break on sequence number, so deliveries
    /// stay FIFO). Per-batch rather than per-event costs (§Perf):
    /// wire validation, the tap watch check, the injection fan-out lookup,
    /// the ledger's wire-name resolution, and one up-front heap
    /// reservation for every event the batch will enqueue. Each payload
    /// still mints its own `Arc`'d AV, ledger record and per-consumer
    /// `Deliver` events — batching amortizes bookkeeping, it never
    /// coalesces data.
    pub fn inject_batch_at_id(
        &mut self,
        wire: WireId,
        payloads: impl IntoIterator<Item = Payload>,
        class: DataClass,
        region: RegionId,
        at: SimTime,
    ) -> Result<Vec<AvId>> {
        if wire.index() >= self.graph.wires.len() {
            bail!(
                "{wire} is out of range for pipeline [{}] ({} wires) — ids are only \
                 valid for the coordinator whose wire table minted them",
                self.graph.name,
                self.graph.wires.len()
            );
        }
        let fanout = self.graph.wires.injections(wire).len();
        if fanout == 0 {
            bail!(
                "wire '{}' has no injection point (a task produces it)",
                self.graph.wires.name(wire)
            );
        }
        let watched = self.taps.watches(wire);
        let current = at <= self.plat.now;
        let wire_name = Arc::clone(&self.ledger_names[wire.index()]);
        let payloads = payloads.into_iter();
        let (size_lo, _) = payloads.size_hint();
        self.queue.reserve(size_lo * (fanout + usize::from(watched)));
        let mut ids = Vec::with_capacity(size_lo);
        for payload in payloads {
            ids.push(self.inject_prepared(
                wire, &wire_name, payload, class, region, at, watched, current, fanout,
            ));
        }
        if self.obs.enabled {
            self.obs.inject_span(at, wire, ids.len() as u32);
        }
        Ok(ids)
    }

    /// Inject a ghost batch (§III-K): routes are exercised, payloads are
    /// pretend-sized, compute is skipped.
    pub fn inject_ghost(
        &mut self,
        wire: &str,
        pretend_bytes: u64,
        region: RegionId,
    ) -> Result<AvId> {
        self.inject_at(
            wire,
            Payload::Ghost { pretend_bytes },
            DataClass::Ghost,
            region,
            self.plat.now,
        )
    }

    // ------------------------------------------------------------------
    // Streaming ingestion (the live front door; see crate::ingest)
    // ------------------------------------------------------------------

    /// Open a streaming [`Feed`] onto external wire `wire` with the
    /// default bounded-queue capacity. The returned handle is cloneable
    /// and thread-safe: producer threads push timestamped events through
    /// it concurrently with execution, and
    /// [`Coordinator::pump_ingest`] / [`Coordinator::ingest_cycle`]
    /// move them into the pipeline under watermark gating.
    pub fn open_feed(&mut self, wire: &str) -> Result<Feed> {
        self.open_feed_with(wire, DEFAULT_FEED_CAPACITY)
    }

    /// [`open_feed`](Self::open_feed) with an explicit queue capacity —
    /// the credit window producers get before `push` blocks
    /// (`try_push` returns [`crate::ingest::IngestError::Backpressure`]).
    pub fn open_feed_with(&mut self, wire: &str, capacity: usize) -> Result<Feed> {
        let wid = self.wire_id(wire)?;
        self.open_feed_id(wid, capacity)
    }

    /// Id-based feed open. Validates here (range + injectability) so the
    /// pump's injections can never fail mid-stream.
    pub fn open_feed_id(&mut self, wire: WireId, capacity: usize) -> Result<Feed> {
        if wire.index() >= self.graph.wires.len() {
            bail!(
                "{wire} is out of range for pipeline [{}] ({} wires) — ids are only \
                 valid for the coordinator whose wire table minted them",
                self.graph.name,
                self.graph.wires.len()
            );
        }
        if self.graph.wires.injections(wire).is_empty() {
            bail!(
                "wire '{}' has no injection point (a task produces it)",
                self.graph.wires.name(wire)
            );
        }
        let name = Arc::clone(&self.ledger_names[wire.index()]);
        let pump = self.ingest.get_or_insert_with(|| Box::new(IngestPump::new()));
        let core = Arc::new(FeedCore::new(capacity, Arc::clone(&pump.bell)));
        let feed = Feed { wire, name, core };
        pump.register(feed.clone());
        Ok(feed)
    }

    /// Run one ingest pump cycle: drain every feed, seal what the
    /// watermark frontier allows, and execute it. Returns whether the
    /// cycle made progress (drained, injected, or executed anything).
    /// The manual-cadence alternative to [`Coordinator::pump_ingest`]
    /// for callers interleaving their own work.
    pub fn ingest_cycle(&mut self) -> bool {
        let Some(mut pump) = self.ingest.take() else { return false };
        let out = pump.cycle(self);
        self.ingest = Some(pump);
        out.progress
    }

    /// The ingest pump loop: cycle until every feed has closed and
    /// drained (then run the pipeline to idle), parking on the wake bell
    /// when idle instead of busy-spinning. `drain_deadline` is wall
    /// clock — the escape hatch for producers that never close; on
    /// expiry the report's `timed_out` is set and buffered work stays
    /// staged for a later call.
    pub fn pump_ingest(&mut self, drain_deadline: std::time::Duration) -> IngestReport {
        let Some(mut pump) = self.ingest.take() else {
            return IngestReport {
                stats: IngestStats::default(),
                timed_out: false,
                stalled: Vec::new(),
            };
        };
        let report = pump.run(self, drain_deadline);
        self.ingest = Some(pump);
        report
    }

    /// Cumulative ingestion counters, if any feed was ever opened.
    pub fn ingest_stats(&self) -> Option<&IngestStats> {
        self.ingest.as_deref().map(|p| &p.stats)
    }

    /// Open feeds currently pinning the watermark frontier behind their
    /// peers (see [`crate::ingest::WatermarkClock`]).
    pub fn ingest_stalled(&self) -> Vec<StalledFeed> {
        self.ingest.as_deref().map(|p| p.stalled()).unwrap_or_default()
    }

    /// The virtual time of the next pending event, if any.
    pub fn next_event_at(&self) -> Option<SimTime> {
        self.queue.peek().map(|Reverse(e)| e.at)
    }

    pub(crate) fn obs_mut(&mut self) -> &mut Obs {
        &mut self.obs
    }

    // ------------------------------------------------------------------
    // Event loop
    // ------------------------------------------------------------------

    /// Process events up to and including `horizon`. Returns events handled.
    ///
    /// With `reorder_window = 1` the loop advances one virtual *instant*
    /// at a time: every event at the next instant is dispatched in heap
    /// order (cheap bookkeeping — deliveries, tap observations, sweeps;
    /// wakes and polls only enqueue their task), then the resulting
    /// **wavefront** of ready, mutually independent task firings executes
    /// — on the worker pool when `workers > 1` — and commits
    /// deterministically in task-index order.
    ///
    /// With `reorder_window > 1` the per-instant barrier is gone: up to
    /// `reorder_window` instants whose events the frontier tracker proves
    /// independent are staged and *execute* concurrently, while commits
    /// still retire in strict `(instant, task-index)` order — the books
    /// are byte-identical either way (see [`frontier`] and DESIGN.md
    /// §Execution model).
    pub fn run_until(&mut self, horizon: SimTime) -> u64 {
        let mut handled = 0;
        loop {
            let at = match self.queue.peek() {
                Some(Reverse(e)) if e.at <= horizon => e.at,
                _ => break,
            };
            handled += if self.reorder_window > 1 {
                self.drain_pipelined(horizon)
            } else {
                self.drain_instant(at)
            };
        }
        if self.plat.now < horizon {
            self.plat.now = horizon;
        }
        self.events_processed += handled;
        handled
    }

    /// Drain the queue completely (with a runaway guard). A tripped
    /// guard no longer panics: the loop stops, the structured
    /// [`EventStorm`] report (naming the hottest tasks and wires) is
    /// stashed in [`Coordinator::last_storm`], and the events handled so
    /// far are returned — a runaway pipeline degrades instead of
    /// aborting the process. Callers that want the error itself use
    /// [`Coordinator::try_run_until_idle`].
    pub fn run_until_idle(&mut self) -> u64 {
        match self.try_run_until_idle() {
            Ok(n) => n,
            Err(storm) => {
                let handled = storm.handled;
                self.last_storm = Some(storm);
                handled
            }
        }
    }

    /// [`run_until_idle`](Self::run_until_idle), surfacing the storm
    /// report as an error instead of stashing it.
    pub fn try_run_until_idle(&mut self) -> std::result::Result<u64, EventStorm> {
        self.last_storm = None;
        let mut handled = 0;
        loop {
            let at = match self.queue.peek() {
                Some(Reverse(e)) => e.at,
                None => break,
            };
            handled += if self.reorder_window > 1 {
                self.drain_pipelined(SimTime(u64::MAX))
            } else {
                self.drain_instant(at)
            };
            if handled > self.storm_cap {
                self.plat.metrics.bump("event_storms");
                self.events_processed += handled;
                return Err(self.build_storm(handled));
            }
        }
        self.events_processed += handled;
        Ok(handled)
    }

    /// The storm report from the most recent [`run_until_idle`] call, if
    /// its cap tripped.
    pub fn last_storm(&self) -> Option<&EventStorm> {
        self.last_storm.as_ref()
    }

    /// Override the runaway guard (default 10 million events per
    /// `run_until_idle` call). Mostly for tests.
    pub fn set_storm_cap(&mut self, cap: u64) {
        self.storm_cap = cap.max(1);
    }

    fn build_storm(&self, handled: u64) -> EventStorm {
        let mut tasks: Vec<(String, u64)> = self
            .agents
            .iter()
            .map(|a| (a.spec.name.clone(), a.runs))
            .filter(|(_, runs)| *runs > 0)
            .collect();
        tasks.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        tasks.truncate(3);
        let mut wires: Vec<(String, u64)> = Vec::new();
        if self.obs.enabled {
            for (i, name) in self.graph.wires.names().iter().enumerate() {
                let Some(w) = self.obs.wire_stats(WireId::new(i as u32)) else { continue };
                let traffic = w.publications + w.injections;
                if traffic > 0 {
                    wires.push((name.clone(), traffic));
                }
            }
            wires.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
            wires.truncate(3);
        }
        EventStorm {
            handled,
            cap: self.storm_cap,
            at: self.plat.now,
            pending: self.queue.len(),
            hottest_tasks: tasks,
            hottest_wires: wires,
        }
    }

    /// Pop and dispatch every event at virtual instant `at` — including
    /// same-instant events pushed during the drain (wakes spawned by
    /// deliveries) — then flush the wavefront of woken tasks.
    fn drain_instant(&mut self, at: SimTime) -> u64 {
        let mut handled = 0;
        while self.queue.peek().is_some_and(|Reverse(e)| e.at == at) {
            let Reverse(ev) = self.queue.pop().unwrap();
            self.plat.now = at;
            self.dispatch(ev.kind);
            handled += 1;
        }
        if self.obs.enabled {
            self.obs.instant(at, handled as u32);
        }
        self.flush_wavefront();
        handled
    }

    /// Which task must be clear of in-flight shadows before this event
    /// may be staged? `Deliver`/`Wake`/`Poll`/`RetryFire` gate on their
    /// target (a shadowed target means an earlier open instant may still
    /// publish into it); `TapObserve` touches no task; `ScaleSweep` is a
    /// batch barrier handled by the caller. Routing retries through this
    /// check is what keeps a quarantined task from holding back unrelated
    /// frontiers: its `RetryFire` blocks only its own closure.
    fn stage_target(&self, kind: &EventKind) -> Option<TaskId> {
        match kind {
            EventKind::Deliver { link, .. } => Some(self.links[*link as usize].link.to),
            EventKind::Wake { task } | EventKind::Poll { task } => Some(*task),
            EventKind::RetryFire { task, .. } => Some(*task),
            EventKind::TapObserve { .. } | EventKind::ScaleSweep => None,
        }
    }

    /// One pipelined scheduling round (`reorder_window > 1`): stage up to
    /// `reorder_window` frontier-independent instants ≤ `horizon`
    /// (phase A), execute all their wavefront groups in a single pool
    /// pass (phase B), then retire them in instant order (phase C).
    /// Returns events handled; `0` only when nothing ≤ `horizon` was
    /// pending.
    ///
    /// The determinism invariant (DESIGN.md §Execution model): overlap is
    /// of *execution only*. Every order-sensitive mutation — run/AV id
    /// draws, provenance stamps, sink commits, tap rings, span streams,
    /// dead letters, sovereignty errors — happens at retirement, in
    /// strict `(instant, task-index)` order, so any window setting
    /// commits byte-identical books.
    fn drain_pipelined(&mut self, horizon: SimTime) -> u64 {
        let mut handled: u64 = 0;
        let mut units: Vec<InFlightUnit> = Vec::new();
        let mut groups: Vec<WaveGroup> = Vec::new();

        // ---- phase A: stage eligible instants ----
        while units.len() < self.reorder_window {
            let at = match self.queue.peek() {
                Some(Reverse(e)) if e.at <= horizon => e.at,
                _ => break,
            };
            // pop the instant's events, vetting each against the frontier
            let mut staged: Vec<Ev> = Vec::new();
            let mut sweep = false;
            let mut blocked = false;
            while self.queue.peek().is_some_and(|Reverse(e)| e.at == at) {
                let Reverse(ev) = self.queue.pop().unwrap();
                match ev.kind {
                    EventKind::ScaleSweep => sweep = true,
                    ref k => {
                        if self.stage_target(k).is_some_and(|t| self.frontier.is_shadowed(t)) {
                            blocked = true;
                        }
                    }
                }
                staged.push(ev);
            }
            if sweep || blocked {
                // restore the heap exactly (original at/seq; the seq
                // counter is untouched, so heap order is preserved)
                for ev in staged {
                    self.queue.push(Reverse(ev));
                }
                if units.is_empty() {
                    // nothing in flight: a sweep instant (which reads
                    // cluster state commits mutate) runs on the legacy
                    // path with clean state. `blocked` is unreachable
                    // here — no shadows without in-flight units — but
                    // the legacy drain is the correct fallback anyway.
                    handled += self.drain_instant(at);
                    continue;
                }
                // conflict with an open instant: stop the batch and let
                // phase C retire what we have; the next round resumes here
                break;
            }

            // dispatch the instant's events with order-sensitive
            // artifacts diverted to the stage buffer (commutative
            // bookkeeping — bus pushes, counters, currency — runs live)
            self.plat.now = at;
            self.stage_buf = Some(Vec::new());
            let mut n: u32 = 0;
            for ev in staged {
                self.dispatch(ev.kind);
                n += 1;
            }
            // same-instant cascade: deliveries wake their tasks through
            // the queue
            while self.queue.peek().is_some_and(|Reverse(e)| e.at == at) {
                let Reverse(ev) = self.queue.pop().unwrap();
                self.dispatch(ev.kind);
                n += 1;
            }
            let artifacts = self.stage_buf.take().unwrap_or_default();

            // extract the instant's wavefront. No quarantine divert here:
            // the divert draws run ids, which must happen in commit order
            // at retirement.
            let mut pumps = std::mem::take(&mut self.pending_pumps);
            pumps.sort_by_key(|p| p.task);
            let start = groups.len();
            let mut quarantined: Vec<usize> = Vec::new();
            let supervised = self.supervision.active();
            for p in &pumps {
                let (firings, queued) = self.collect_snapshots_core(p.task);
                if supervised && !firings.is_empty() && self.supervision.quarantined(p.task) {
                    quarantined.push(groups.len());
                }
                groups.push(WaveGroup {
                    task: p.task,
                    at,
                    via_poll: p.via_poll,
                    queued,
                    firings,
                });
            }
            pumps.clear();
            self.pending_pumps = pumps;

            // the pipelining note: this instant entered execution while
            // `behind` earlier instants were still open. Never present
            // with window = 1, so it is projected out of cross-window
            // span comparisons (SpanEvent::is_pipelining_note).
            let behind = self.frontier.in_flight() as u32;
            if self.obs.enabled && behind >= 1 {
                self.obs.frontier_advance(at, behind);
            }
            let mask = self.frontier.occupy(groups[start..].iter().map(|g| g.task));
            units.push(InFlightUnit {
                at,
                handled: n,
                groups: start..groups.len(),
                quarantined,
                mask,
                artifacts,
            });
            handled += n as u64;
        }
        if units.is_empty() {
            return handled;
        }

        // ---- phase B: one pool pass over every staged instant ----
        // quarantined groups never execute: park their firings for the
        // retirement-time dead-letter divert
        let mut q_fire: HashMap<usize, Vec<Firing>> = HashMap::new();
        for u in &units {
            for &gi in &u.quarantined {
                q_fire.insert(gi, std::mem::take(&mut groups[gi].firings));
            }
        }
        let busy = groups.iter().filter(|g| !g.firings.is_empty()).count();
        let pooled = (self.workers > 1 || self.shard.nodes > 1) && busy >= 2;
        let mut prepared: Vec<Vec<PreparedFiring>> = if pooled {
            if self.obs.enabled {
                self.obs.wavefront_parallel(busy as u32);
            }
            wavefront::execute_parallel(self, &mut groups)
        } else {
            Vec::new()
        };

        // ---- phase C: retire units in instant order ----
        enum Member {
            /// Index into the batch's flat group/prepared vectors.
            Staged(usize),
            /// A straggler group pumped at retirement (quarantined flag).
            Fresh(WaveGroup, bool),
        }
        for ui in 0..units.len() {
            let at = units[ui].at;
            // instants created by earlier retirements that precede this
            // unit are complete window-1 instants: legacy path
            loop {
                let next = match self.queue.peek() {
                    Some(Reverse(e)) if e.at < at => e.at,
                    _ => break,
                };
                handled += self.drain_instant(next);
            }
            self.plat.now = at;
            // replay the staged dispatch's order-sensitive artifacts, in
            // staged-dispatch order
            for art in std::mem::take(&mut units[ui].artifacts) {
                match art {
                    StagedArtifact::Tap { wire, av } => {
                        if self.obs.enabled {
                            self.obs.tap_observe(at, wire, av.id);
                        }
                        self.taps.observe(wire, &av, &self.plat.store, at);
                    }
                    StagedArtifact::Transfer(note) => {
                        if self.obs.enabled {
                            self.obs.transfer(
                                at,
                                note.wire,
                                note.from_node as u32,
                                note.to_node as u32,
                                note.bytes,
                                note.tier,
                            );
                        }
                    }
                    StagedArtifact::Denied { link_idx, av } => {
                        self.record_sovereignty_error(link_idx, &av);
                    }
                }
            }
            // stragglers: events at exactly this instant pushed by
            // earlier retirements. Dispatched live (the stage buffer is
            // off) — they sort after the staged events by sequence
            // number, exactly as the window-1 drain would pop them.
            let mut n = units[ui].handled;
            while self.queue.peek().is_some_and(|Reverse(e)| e.at == at) {
                let Reverse(ev) = self.queue.pop().unwrap();
                self.dispatch(ev.kind);
                n += 1;
                handled += 1;
            }
            if self.obs.enabled {
                self.obs.instant(at, n);
            }
            // straggler wavefront groups (targets provably disjoint from
            // this unit's staged groups — else this unit would not have
            // been eligible)
            let mut pumps = std::mem::take(&mut self.pending_pumps);
            pumps.sort_by_key(|p| p.task);
            let supervised = self.supervision.active();
            let mut members: Vec<(TaskId, Member)> = units[ui]
                .groups
                .clone()
                .map(|gi| (groups[gi].task, Member::Staged(gi)))
                .collect();
            for p in &pumps {
                let (firings, queued) = self.collect_snapshots_core(p.task);
                let q =
                    supervised && !firings.is_empty() && self.supervision.quarantined(p.task);
                members.push((
                    p.task,
                    Member::Fresh(
                        WaveGroup { task: p.task, at, via_poll: p.via_poll, queued, firings },
                        q,
                    ),
                ));
            }
            pumps.clear();
            self.pending_pumps = pumps;
            members.sort_by_key(|(t, _)| *t);

            // quarantine diverts first, in task order — the same point
            // (phase 1, pre-commit) and id order as the window-1 drain
            for (task, m) in &mut members {
                match m {
                    Member::Staged(gi) => {
                        if units[ui].quarantined.contains(gi) {
                            let f = q_fire.remove(gi).unwrap_or_default();
                            self.quarantine_divert(*task, f);
                        }
                    }
                    Member::Fresh(g, q) => {
                        if *q {
                            let f = std::mem::take(&mut g.firings);
                            self.quarantine_divert(*task, f);
                        }
                    }
                }
            }
            let width: u32 = members
                .iter()
                .map(|(_, m)| match m {
                    Member::Staged(gi) => {
                        if pooled {
                            prepared[*gi].len() as u32
                        } else {
                            groups[*gi].firings.len() as u32
                        }
                    }
                    Member::Fresh(g, _) => g.firings.len() as u32,
                })
                .sum();
            if self.obs.enabled && width > 0 {
                self.obs.wavefront_begin(at, width);
            }
            // commit in task-index order: replay recorded effects /
            // execute fresh firings, then the pump epilogue — the same
            // per-group sequence as the per-instant flush
            for (task, m) in members {
                match m {
                    Member::Staged(gi) => {
                        if pooled {
                            for item in std::mem::take(&mut prepared[gi]) {
                                match item {
                                    PreparedFiring::Deferred(firing, reason) => {
                                        if self.obs.enabled {
                                            match reason {
                                                DeferReason::Sequential => self
                                                    .obs
                                                    .note_deferred_sequential(at, task),
                                                DeferReason::Direct => {
                                                    self.obs.note_rollback(at, task)
                                                }
                                                DeferReason::MemoHit => {
                                                    self.obs.note_deferred_memo()
                                                }
                                            }
                                        }
                                        self.fire_supervised(task, firing);
                                    }
                                    PreparedFiring::Recorded(rec) => {
                                        self.commit_recorded(task, rec)
                                    }
                                }
                            }
                        } else {
                            for firing in std::mem::take(&mut groups[gi].firings) {
                                self.fire_supervised(task, firing);
                            }
                        }
                        self.pump_epilogue(task, groups[gi].queued, groups[gi].via_poll);
                    }
                    Member::Fresh(g, _) => {
                        for firing in g.firings {
                            self.fire_supervised(task, firing);
                        }
                        self.pump_epilogue(task, g.queued, g.via_poll);
                    }
                }
            }
            if self.obs.enabled && width > 0 {
                self.obs.wavefront_commit(at, width);
            }
            self.frontier.release(&units[ui].mask);
        }
        handled
    }

    pub fn pending_events(&self) -> usize {
        self.queue.len()
    }

    /// Single-step the event loop: process exactly one pending event
    /// (flushing any task firing it triggers) and return its virtual
    /// time (breadboard pause/step/resume, §III-H).
    pub fn step_event(&mut self) -> Option<SimTime> {
        let Reverse(ev) = self.queue.pop()?;
        let at = ev.at;
        self.plat.now = at;
        self.dispatch(ev.kind);
        self.flush_wavefront();
        self.events_processed += 1;
        Some(at)
    }

    /// Resume: advance virtual time by `d`, processing everything due.
    pub fn run_for(&mut self, d: SimDuration) -> u64 {
        let horizon = self.plat.now + d;
        self.run_until(horizon)
    }

    fn dispatch(&mut self, kind: EventKind) {
        match kind {
            EventKind::Deliver { link, av } => self.on_deliver(link as usize, av),
            EventKind::Wake { task } => self.enqueue_pump(task, false),
            EventKind::Poll { task } => self.on_poll(task),
            EventKind::ScaleSweep => {
                self.plat.cluster.scale_to_zero_sweep(self.plat.now);
                if let Some(iv) = self.scale_sweep_every {
                    if !self.queue.is_empty() {
                        self.push_event(self.plat.now + iv, EventKind::ScaleSweep);
                    }
                }
            }
            EventKind::TapObserve { wire, av } => {
                // staged instant: tap rings are ordered, so the
                // observation replays at retirement (canonical order),
                // not now
                if let Some(buf) = self.stage_buf.as_mut() {
                    buf.push(StagedArtifact::Tap { wire, av });
                } else {
                    if self.obs.enabled {
                        self.obs.tap_observe(self.plat.now, wire, av.id);
                    }
                    self.taps.observe(wire, &av, &self.plat.store, self.plat.now);
                }
            }
            EventKind::RetryFire { task, firing } => {
                // the retry joins this instant's wavefront like any fresh
                // snapshot — collect_snapshots drains it ahead of new work
                self.supervision.push_retry(task, *firing);
                self.enqueue_pump(task, false);
            }
        }
    }

    /// Mark `task` for the current batch's wavefront (deduplicated: a
    /// task delivered to N times at one instant pumps once, seeing all N
    /// arrivals — the pull/take loop consumes them in the same order the
    /// per-event pumps would have).
    fn enqueue_pump(&mut self, task: TaskId, via_poll: bool) {
        match self.pending_pumps.iter_mut().find(|p| p.task == task) {
            Some(p) => p.via_poll |= via_poll,
            None => self.pending_pumps.push(PendingPump { task, via_poll }),
        }
    }

    fn on_deliver(&mut self, link_idx: usize, av: Arc<AnnotatedValue>) {
        let task = self.links[link_idx].link.to;
        // the verdict is decided on the shared Arc; only a successful
        // delivery pays clones (inside the link, for bus + history), and a
        // denied one pays none at all (§Perf)
        let verdict = self.links[link_idx].deliver(&mut self.plat, &av);
        match verdict {
            Delivery::Denied => {
                // staged instant: the error book is event-ordered, so the
                // record (and its exchange/metrics bookkeeping) replays at
                // retirement in staged-dispatch order
                match self.stage_buf.as_mut() {
                    Some(buf) => {
                        buf.push(StagedArtifact::Denied { link_idx, av: Arc::clone(&av) })
                    }
                    None => self.record_sovereignty_error(link_idx, &av),
                }
            }
            Delivery::NotifyNow => {
                self.last_arrival.insert(task, self.plat.now);
                self.push_event(self.plat.now, EventKind::Wake { task });
            }
            Delivery::Queued => {
                self.last_arrival.insert(task, self.plat.now);
                if let NotifyMode::Poll(iv) = self.agents[task.index()].notify {
                    if self.polls_pending.insert(task) {
                        self.push_event(self.plat.now + iv, EventKind::Poll { task });
                    }
                }
            }
        }
        if verdict != Delivery::Denied {
            // cross-node hop? account it on the exchange and stamp the
            // movement note. Pure bookkeeping — the ledger and the span
            // are the only places the node partition is visible, and the
            // span is projected out of placement-identity comparisons.
            if let Some(note) = self.exchange.record(self.links[link_idx].link.id, av.size_bytes)
            {
                // staged instant: the exchange sums are commutative (they
                // ran just now), but the span stream is ordered — defer
                // the recording to retirement
                if let Some(buf) = self.stage_buf.as_mut() {
                    buf.push(StagedArtifact::Transfer(note));
                } else if self.obs.enabled {
                    self.obs.transfer(
                        self.plat.now,
                        note.wire,
                        note.from_node as u32,
                        note.to_node as u32,
                        note.bytes,
                        note.tier,
                    );
                }
            }
            // a successful delivery makes this AV the wire's current value:
            // move the event's Arc into the dense slot — no clone, no hash
            let wire = self.links[link_idx].link.wire_id;
            self.latest_on_wire.set(wire, av);
        }
    }

    /// Record the structured error surface for a sovereignty-denied
    /// delivery: the exchange books the refusal (zero bytes moved) and
    /// the error book gains a did-you-mean-summarize diagnosis. Runs on
    /// the coordinator thread in event order, and the verdict depends on
    /// regions only — identical for every node partition.
    fn record_sovereignty_error(&mut self, link_idx: usize, av: &AnnotatedValue) {
        let link = &self.links[link_idx].link;
        self.exchange.record_denied(link.id);
        self.plat.metrics.bump("sovereignty_errors");
        let from = av.region;
        let to = self.links[link_idx].consumer_region;
        let wire_name = self.graph.wires.name(link.wire_id);
        let task_name = &self.graph.task(link.to).name;
        let error = format!(
            "sovereignty: {:?} data on wire '{wire_name}' may not cross from zone '{}' \
             ({}) into zone '{}' ({}) toward task '{task_name}' — zero bytes moved. \
             Did you mean to summarize first? Emit the wire as DataClass::Summary \
             (or place '{task_name}' inside zone '{}').",
            av.class,
            self.plat.net.region(from).zone,
            self.plat.net.region(from).name,
            self.plat.net.region(to).zone,
            self.plat.net.region(to).name,
            self.plat.net.region(from).zone,
        );
        self.sovereignty_errors.push(SovereigntyError {
            task: link.to,
            wire: link.wire_id,
            av: av.id,
            from,
            to,
            at: self.plat.now,
            error,
        });
    }

    /// Pull the single oldest queued AV (FCFS across this task's incoming
    /// topics) into its snapshot buffers — the "tap or resample" pull of
    /// §III-E's pub-sub handover.
    fn pull_one(&mut self, task: TaskId) -> bool {
        let mut best: Option<(usize, SimTime, u64)> = None;
        for &li in &self.in_links[task.index()] {
            let lid = self.links[li].link.id;
            if let Some(head) = self.plat.bus.peek_head(lid) {
                let key = (head.created, head.seq);
                if best.as_ref().is_none_or(|b| key < (b.1, b.2)) {
                    best = Some((li, head.created, head.seq));
                }
            }
        }
        match best {
            Some((li, ..)) => {
                let lid = self.links[li].link.id;
                let av = self.plat.bus.consume(lid).expect("peeked head vanished");
                self.agents[task.index()].engine.push_idx(self.link_buffer[li], av);
                true
            }
            None => false,
        }
    }

    fn on_poll(&mut self, task: TaskId) {
        self.polls_pending.remove(&task);
        self.plat.metrics.polls_performed += 1;
        let had_news = self.in_links[task.index()]
            .iter()
            .any(|&li| self.plat.bus.depth(self.links[li].link.id) > 0);
        if !had_news {
            self.plat.metrics.polls_empty += 1;
        }
        self.enqueue_pump(task, true);
    }

    // ------------------------------------------------------------------
    // Wavefront scheduler: extract → execute → deterministic commit
    // ------------------------------------------------------------------

    /// Flush the tasks woken during the current same-instant batch.
    ///
    /// Three phases, all in canonical task-index order so every
    /// `workers` setting produces the same books:
    ///  1. **extract** — interleave pulls and snapshot takes per task
    ///     (each queued AV gets its chance at a snapshot before the next
    ///     overwrites a bounded buffer position), yielding each task's
    ///     ready firings;
    ///  2. **execute** — with `workers > 1` and ≥ 2 busy tasks, firings
    ///     run on a `std::thread::scope` worker pool, each worker owning
    ///     its task's agent exclusively and recording platform effects
    ///     (see `task::effects`); otherwise everything defers to phase 3;
    ///  3. **commit** — per task, in index order: replay/execute each
    ///     firing against the live platform (ids drawn here, so the
    ///     dispensers allocate in canonical order), publish, then the
    ///     pump epilogue (rate re-arm, poll re-arm, autoscale).
    fn flush_wavefront(&mut self) {
        if self.pending_pumps.is_empty() {
            return;
        }
        let mut pumps = std::mem::take(&mut self.pending_pumps);
        pumps.sort_by_key(|p| p.task);
        // phase 1: extract each task's ready firings
        let mut groups: Vec<WaveGroup> = Vec::with_capacity(pumps.len());
        for p in &pumps {
            let (firings, queued) = self.collect_snapshots(p.task);
            groups.push(WaveGroup {
                task: p.task,
                at: self.plat.now,
                via_poll: p.via_poll,
                queued,
                firings,
            });
        }
        let busy = groups.iter().filter(|g| !g.firings.is_empty()).count();
        // wavefront spans carry the width only (identical for every
        // `workers` setting); occupancy lands in stats, never in spans
        let width: u32 = groups.iter().map(|g| g.firings.len() as u32).sum();
        if self.obs.enabled && width > 0 {
            self.obs.wavefront_begin(self.plat.now, width);
        }
        if (self.workers > 1 || self.shard.nodes > 1) && busy >= 2 {
            if self.obs.enabled {
                self.obs.wavefront_parallel(busy as u32);
            }
            // phases 2+3: execute on the worker pool, then commit in
            // task-index order
            let prepared = wavefront::execute_parallel(self, &mut groups);
            for (g, items) in groups.iter().zip(prepared) {
                for item in items {
                    match item {
                        PreparedFiring::Deferred(firing, reason) => {
                            if self.obs.enabled {
                                // scheduling notes, not behavior: these
                                // spans exist only on the pool path and
                                // are projected out of the cross-worker
                                // span-identity comparison
                                match reason {
                                    DeferReason::Sequential => self
                                        .obs
                                        .note_deferred_sequential(self.plat.now, g.task),
                                    DeferReason::Direct => {
                                        self.obs.note_rollback(self.plat.now, g.task)
                                    }
                                    DeferReason::MemoHit => self.obs.note_deferred_memo(),
                                }
                            }
                            self.fire_supervised(g.task, firing);
                        }
                        PreparedFiring::Recorded(rec) => self.commit_recorded(g.task, rec),
                    }
                }
                self.pump_epilogue(g.task, g.queued, g.via_poll);
            }
        } else {
            // sequential wavefront (the 1-wide chain hot path): fire
            // directly, moving each group's existing firing Vec — no
            // PreparedFiring wrapping, no extra allocation (§Perf)
            for gi in 0..groups.len() {
                let task = groups[gi].task;
                for firing in std::mem::take(&mut groups[gi].firings) {
                    self.fire_supervised(task, firing);
                }
                self.pump_epilogue(task, groups[gi].queued, groups[gi].via_poll);
            }
        }
        if self.obs.enabled && width > 0 {
            self.obs.wavefront_commit(self.plat.now, width);
        }
        // hand the drained pump list back: steady state reuses its
        // capacity instant after instant (§Perf)
        pumps.clear();
        self.pending_pumps = pumps;
    }

    /// Phase-1 extraction for one task: the pull/take interleave the old
    /// sequential pump performed, minus the fires (which commit later).
    /// Fires never feed the same instant back (publication costs are
    /// strictly positive), so the snapshot sequence is identical to
    /// firing inline. This wrapper adds the inline quarantine divert the
    /// per-instant path wants; the pipelined path calls
    /// [`Self::collect_snapshots_core`] and diverts at retirement instead
    /// (the divert draws run ids, which must be allocated in commit
    /// order).
    fn collect_snapshots(&mut self, task: TaskId) -> (Vec<Firing>, usize) {
        let (mut firings, queued) = self.collect_snapshots_core(task);
        if self.supervision.active()
            && !firings.is_empty()
            && self.supervision.quarantined(task)
        {
            // circuit open: dead-letter everything without executing
            self.quarantine_divert(task, std::mem::take(&mut firings));
        }
        (firings, queued)
    }

    /// The divert-free body of [`Self::collect_snapshots`]: drain retries
    /// and ready snapshots into firings, reporting the queued backlog.
    fn collect_snapshots_core(&mut self, task: TaskId) -> (Vec<Firing>, usize) {
        // autoscaling signal: how much work was waiting when we woke (the
        // bounded snapshot buffers hide the burst; the topics don't)
        let queued: usize = self.in_links[task.index()]
            .iter()
            .map(|&li| self.plat.bus.depth(self.links[li].link.id))
            .sum();
        let active = self.supervision.active();
        // retries scheduled for this instant re-enter ahead of fresh
        // work: their index (and hence provenance order) predates it
        let mut firings: Vec<Firing> = if active {
            let mut retries = self.supervision.take_retries(task);
            for f in &mut retries {
                f.guard = self.supervision.guard(task, f.index, f.attempt);
            }
            retries
        } else {
            Vec::new()
        };
        loop {
            loop {
                let now = self.plat.now;
                match self.agents[task.index()].engine.take(now) {
                    Some(s) => {
                        // the guard is computed ONCE here, on the
                        // coordinator thread, so workers never touch
                        // supervision state — and the verdict is pinned
                        // to the firing's (task, index, attempt)
                        // coordinate, identical for every worker count
                        let (index, guard) = if active {
                            let i = self.supervision.assign_index(task);
                            (i, self.supervision.guard(task, i, 1))
                        } else {
                            (0, FireGuard::NONE)
                        };
                        firings.push(Firing { snapshot: s, index, attempt: 1, guard });
                    }
                    None => break,
                }
            }
            if !self.pull_one(task) {
                break;
            }
        }
        (firings, queued)
    }

    /// Dead-letter a quarantined task's ready firings without executing
    /// them (the circuit breaker is open).
    fn quarantine_divert(&mut self, task: TaskId, firings: Vec<Firing>) {
        for f in firings {
            let run = self.plat.next_run_id();
            self.plat.metrics.bump("quarantine_dropped");
            if self.obs.enabled {
                self.obs.firing_exhausted(self.plat.now, task, run, 0);
            }
            self.plat.prov.checkpoint(
                task,
                run,
                self.plat.now,
                CheckpointEvent::Remark(format!(
                    "quarantined: firing {} dead-lettered without execution",
                    f.index
                )),
            );
            self.supervision.book_mut(task).push(DeadLetter {
                index: f.index,
                at: self.plat.now,
                attempts: 0,
                error: "quarantined: dead-lettered without execution".to_string(),
                panicked: false,
                quarantine_drop: true,
                snapshot: f.snapshot,
            });
        }
    }

    /// The tail of the old pump, run after a task's wavefront commits.
    fn pump_epilogue(&mut self, task: TaskId, queued: usize, via_poll: bool) {
        // Rate-suppressed but ready: re-arm a wake for when firing is allowed.
        let eng = &self.agents[task.index()].engine;
        if eng.ready() {
            let next = eng.rate.next_allowed(self.plat.now);
            if next > self.plat.now {
                self.push_event(next, EventKind::Wake { task });
            }
        }
        // Poll links re-arm while the stream looks alive (recently active
        // or backlog).
        if via_poll {
            if let NotifyMode::Poll(iv) = self.agents[task.index()].notify {
                let recently_active = self
                    .last_arrival
                    .get(&task)
                    .map(|t| self.plat.now.saturating_sub(*t) <= iv.scale(10.0))
                    .unwrap_or(false);
                let backlog = self.agents[task.index()].engine.backlog() > 0;
                if (recently_active || backlog) && self.polls_pending.insert(task) {
                    self.push_event(self.plat.now + iv, EventKind::Poll { task });
                }
            }
        }
        // autoscale on the burst size seen at wake (or remaining backlog)
        let backlog = self.agents[task.index()].engine.backlog().max(queued);
        self.plat.cluster.autoscale(task, backlog);
    }

    /// Task-error bookkeeping (metrics + checkpoint remark) — shared by
    /// the deferred and recorded commit paths. Returns the run id drawn
    /// for the failure record and whether the error was a caught panic
    /// (the panic guard marks its errors, so the distinction survives
    /// into the remark, the span event, and the dead-letter record).
    fn record_task_error(&mut self, task: TaskId, e: &anyhow::Error) -> (crate::util::RunId, bool) {
        let panicked = is_panic_error(e);
        self.plat.metrics.bump("task_errors");
        let run = self.plat.next_run_id();
        if self.obs.enabled {
            self.obs.firing_failed(self.plat.now, task, run, panicked);
        }
        self.plat.prov.checkpoint(
            task,
            run,
            self.plat.now,
            CheckpointEvent::Remark(format!(
                "{}: {e}",
                if panicked { "task panic" } else { "task error" }
            )),
        );
        (run, panicked)
    }

    /// Commit one worker-executed firing: draw the run id (canonical
    /// order), replay the effect tape, then publish — the exact mutation
    /// sequence direct execution performs.
    fn commit_recorded(&mut self, task: TaskId, rec: RecordedRun) {
        let cold = self.plat.cluster.activate(task, self.plat.now);
        if cold > SimDuration::ZERO {
            self.plat.metrics.bump("cold_starts");
        }
        let run = self.plat.next_run_id();
        let RecordedRun { recipe, parents, born, version, region, fx, body } = rec;
        fx.apply(&mut self.plat, task, run, version, region);
        match body {
            Ok(RecordedBody { emissions, hashes, cost, ghost }) => {
                let outcome = RunOutcome::Ran { run, emissions, cost, ghost };
                self.publish_outcome(task, recipe, &parents, born, cold, outcome, Some(&hashes));
                if self.supervision.active() {
                    self.supervision.note_success(task);
                }
            }
            Err(FireFail { error, firing }) => self.supervise_failure(task, Some(firing), error),
        }
    }

    /// Execute one snapshot on a task and publish the results.
    pub fn fire_snapshot(&mut self, task: TaskId, snapshot: Snapshot) -> Result<()> {
        self.fire_snapshot_inner(task, snapshot, false, FireGuard::NONE)
    }

    /// Execute bypassing memoization — the schedule-driven baseline's
    /// data-unaware behaviour (E8).
    pub fn fire_snapshot_forced(&mut self, task: TaskId, snapshot: Snapshot) -> Result<()> {
        self.fire_snapshot_inner(task, snapshot, true, FireGuard::NONE)
    }

    fn fire_snapshot_inner(
        &mut self,
        task: TaskId,
        snapshot: Snapshot,
        forced: bool,
        guard: FireGuard,
    ) -> Result<()> {
        let cold = self.plat.cluster.activate(task, self.plat.now);
        if cold > SimDuration::ZERO {
            self.plat.metrics.bump("cold_starts");
        }
        let recipe = self.agents[task.index()].recipe(&snapshot);
        let parents: Vec<AvId> = snapshot.all_avs().map(|a| a.id).collect();
        let born = snapshot.born;
        let outcome = if forced {
            self.agents[task.index()].execute_forced(&mut self.plat, &self.graph.wires, snapshot)?
        } else {
            self.agents[task.index()].execute_guarded(
                &mut self.plat,
                &self.graph.wires,
                snapshot,
                guard,
            )?
        };
        self.publish_outcome(task, recipe, &parents, born, cold, outcome, None);
        Ok(())
    }

    /// Fire one supervised firing on the direct (commit-phase) path:
    /// execute under its guard, and hand any failure to the supervision
    /// machinery. The firing is cloned into a pinned copy first only
    /// when the task actually carries a policy (retries / dead-letter /
    /// degrade need the inputs back); unsupervised failures keep the old
    /// record-and-drop behaviour with no clone.
    fn fire_supervised(&mut self, task: TaskId, firing: Firing) {
        let guard = firing.guard;
        let pinned = if self.supervision.active() && self.supervision.policy(task).is_some() {
            Some(firing.clone())
        } else {
            None
        };
        if let Err(e) = self.fire_snapshot_inner(task, firing.snapshot, false, guard) {
            self.supervise_failure(task, pinned, e);
        } else if self.supervision.active() {
            self.supervision.note_success(task);
        }
    }

    /// The supervision state machine for one failed attempt: retry in
    /// virtual time while the budget lasts, then the policy's on-exhaust
    /// action (dead-letter / quarantine / degrade). `firing` is `None`
    /// for unsupervised tasks — they keep the record-and-drop path.
    fn supervise_failure(&mut self, task: TaskId, firing: Option<Firing>, e: anyhow::Error) {
        let (run, panicked) = self.record_task_error(task, &e);
        let Some(firing) = firing else { return };
        let Some(policy) = self.supervision.policy(task).cloned() else { return };

        if firing.attempt < policy.max_attempts && !self.supervision.quarantined(task) {
            // budget left: schedule the retry at T + backoff(attempt)
            // with the input snapshot pinned. Virtual time makes this
            // deterministic — the Wake lands at the same instant for
            // every `workers` setting.
            let delay = policy.backoff.delay(firing.attempt);
            self.plat.metrics.bump("task_retries");
            if self.obs.enabled {
                self.obs.firing_retry(self.plat.now, task, run, firing.attempt);
            }
            self.plat.prov.checkpoint(
                task,
                run,
                self.plat.now,
                CheckpointEvent::Remark(format!(
                    "retry: firing {} attempt {}/{} failed; attempt {} scheduled at +{}us",
                    firing.index,
                    firing.attempt,
                    policy.max_attempts,
                    firing.attempt + 1,
                    delay.as_micros()
                )),
            );
            let next = Firing {
                snapshot: firing.snapshot,
                index: firing.index,
                attempt: firing.attempt + 1,
                // recomputed (plan + policy may differ per attempt) when
                // the retry is collected
                guard: FireGuard::NONE,
            };
            self.push_event(
                self.plat.now + delay,
                EventKind::RetryFire { task, firing: Box::new(next) },
            );
            return;
        }

        // budget exhausted (or the breaker opened mid-flight)
        self.plat.metrics.bump("task_exhausted");
        self.supervision.breaker_mut(task).consecutive_exhausts += 1;
        if self.obs.enabled {
            self.obs.firing_exhausted(self.plat.now, task, run, firing.attempt);
        }
        self.plat.prov.checkpoint(
            task,
            run,
            self.plat.now,
            CheckpointEvent::Anomaly(format!(
                "firing {} exhausted after {} attempt(s): {e}",
                firing.index, firing.attempt
            )),
        );
        match policy.on_exhaust {
            OnExhaust::DeadLetter => {
                self.dead_letter(task, firing, &e, panicked);
            }
            OnExhaust::Quarantine { after } => {
                self.dead_letter(task, firing, &e, panicked);
                let b = self.supervision.breaker(task);
                if b.consecutive_exhausts >= after && !b.quarantined {
                    let now = self.plat.now;
                    let b = self.supervision.breaker_mut(task);
                    b.quarantined = true;
                    b.tripped_at = Some(now);
                    self.plat.metrics.bump("quarantine_trips");
                    if self.obs.enabled {
                        self.obs.quarantine(now, task, true);
                    }
                    self.plat.prov.checkpoint(
                        task,
                        run,
                        now,
                        CheckpointEvent::Remark(format!(
                            "quarantined after {after} consecutive exhausted firings"
                        )),
                    );
                }
            }
            OnExhaust::Degrade { ref fallback } => {
                self.plat.metrics.bump("task_degraded");
                if self.obs.enabled {
                    self.obs.firing_degraded(self.plat.now, task, run);
                }
                self.plat.prov.checkpoint(
                    task,
                    run,
                    self.plat.now,
                    CheckpointEvent::Remark(format!(
                        "degraded: fallback emitted after {} exhausted attempt(s)",
                        firing.attempt
                    )),
                );
                let parents: Vec<AvId> = firing.snapshot.all_avs().map(|a| a.id).collect();
                self.emit_degraded(task, fallback.clone(), &parents, firing.snapshot.born);
            }
        }
    }

    /// Record an exhausted firing into the task's dead-letter book,
    /// inputs pinned for a later redrive.
    fn dead_letter(&mut self, task: TaskId, firing: Firing, e: &anyhow::Error, panicked: bool) {
        self.plat.metrics.bump("dead_letters");
        self.supervision.book_mut(task).push(DeadLetter {
            index: firing.index,
            at: self.plat.now,
            attempts: firing.attempt,
            error: format!("{e}"),
            panicked,
            quarantine_drop: false,
            snapshot: firing.snapshot,
        });
    }

    /// Publish a declared fallback on every output wire of `task` so
    /// downstream keeps flowing (the Degrade on-exhaust action). The
    /// emission publishes through the normal outcome path — minted AVs,
    /// provenance, routing, sink capture — but as a ghost-flagged run so
    /// the fallback is never memoized as the recipe's real result.
    fn emit_degraded(&mut self, task: TaskId, fallback: Payload, parents: &[AvId], born: SimTime) {
        let run = self.plat.next_run_id();
        let emissions: Vec<crate::task::Emission> = self.out_links[task.index()]
            .iter()
            .map(|slot| crate::task::Emission {
                wire: slot.wire,
                payload: fallback.clone(),
                class: DataClass::Summary,
                defer: SimDuration::ZERO,
            })
            .collect();
        let recipe = fallback.content_hash();
        let outcome = RunOutcome::Ran {
            run,
            emissions,
            cost: SimDuration::micros(10),
            ghost: true,
        };
        self.publish_outcome(task, recipe, parents, born, SimDuration::ZERO, outcome, None);
    }

    /// Publish a run outcome: mint AVs, stamp provenance, route/collect,
    /// memoize. Shared verbatim by direct execution
    /// ([`fire_snapshot`](Self::fire_snapshot)) and the wavefront
    /// scheduler's recorded commit, so the two paths cannot drift.
    /// `prehashed` carries per-emission payload content hashes when a
    /// worker already computed them (§Perf: the commit never hashes).
    #[allow(clippy::too_many_arguments)]
    fn publish_outcome(
        &mut self,
        task: TaskId,
        recipe: ContentHash,
        parents: &[AvId],
        born: SimTime,
        cold: SimDuration,
        outcome: RunOutcome,
        prehashed: Option<&[ContentHash]>,
    ) {
        match outcome {
            RunOutcome::Ran { run, mut emissions, cost, ghost } => {
                if self.obs.enabled {
                    self.obs.firing_run(self.plat.now, task, run, cost);
                }
                let publish_base = self.plat.now + cold + cost;
                let mut memo_rec = Vec::new();
                for (ei, em) in emissions.drain(..).enumerate() {
                    let region = self.agents[task.index()].region;
                    let version = self.agents[task.index()].version();
                    let seq = self.agents[task.index()].out_seq;
                    self.agents[task.index()].out_seq += 1;
                    // emissions arrive pre-resolved (the port runtime
                    // minted the WireId at bind time, or the legacy
                    // adapter's per-agent cache did): routing is a tiny
                    // integer scan over the producer's slots — no string
                    // comparison anywhere on this path (§Perf)
                    let target = match self
                        .out_links[task.index()]
                        .iter()
                        .position(|s| s.wire == em.wire)
                    {
                        Some(si) => RouteTarget::Slot(si),
                        None => RouteTarget::Wire(em.wire),
                    };
                    let publish_at = publish_base + em.defer;
                    // sink outputs keep a payload copy for `collected`;
                    // internal wires don't — consumers fetch from storage
                    // (§Perf: saves one payload clone per internal hop)
                    let is_sink = match target {
                        RouteTarget::Slot(si) => {
                            self.out_links[task.index()][si].links.is_empty()
                        }
                        RouteTarget::Wire(_) => true,
                    };
                    let sink_payload = if is_sink { Some(em.payload.clone()) } else { None };
                    // a wavefront worker already hashed this payload; the
                    // direct path hashes here (identical value either way)
                    let content = match prehashed {
                        Some(h) => h[ei],
                        None => em.payload.content_hash(),
                    };
                    let saved = self.plat.now;
                    self.plat.now = publish_at;
                    let (av, _lat) = self.plat.mint_av_prehashed(
                        em.payload,
                        content,
                        task,
                        run,
                        version,
                        SINK,
                        region,
                        em.class,
                        seq,
                        parents,
                        born,
                    );
                    self.plat.now = saved;
                    self.plat.prov.checkpoint(
                        task,
                        run,
                        publish_at,
                        CheckpointEvent::Emit { av: av.id },
                    );
                    if !ghost {
                        // every emission carries an interned wire, so a run
                        // is always fully memoizable (the port runtime has
                        // no unresolved-name escape hatch); the defer is
                        // recorded so a memo replay keeps the same timing
                        memo_rec.push((
                            em.wire,
                            av.object,
                            av.content,
                            av.size_bytes,
                            av.class,
                            em.defer,
                        ));
                    }
                    self.route_output(task, target, Arc::new(av), sink_payload, publish_at);
                }
                // hand the drained buffer back: the steady state reuses
                // one allocation run after run (§Perf)
                self.agents[task.index()].recycle_emissions(emissions);
                if !ghost && !memo_rec.is_empty() {
                    self.agents[task.index()].memoize(recipe, memo_rec);
                }
            }
            RunOutcome::Memoized { outputs } => {
                // Reuse cached objects: fresh AVs, no compute, no new bytes.
                // Memo entries carry interned WireIds, so replaying a hit
                // never touches a wire name (§Perf); each entry's recorded
                // defer keeps deferred emissions trailing the run exactly
                // as they did when computed.
                let publish_base = self.plat.now + cold + SimDuration::micros(30);
                // a memo replay draws one run id per output — the firing
                // span records the first (the id the checkpoint ledger
                // joins on); recorded after the loop so an output-less hit
                // still leaves no span
                let mut memo_run = None;
                for (wire, object, content, size, class, defer) in outputs {
                    let publish_at = publish_base + defer;
                    // every memo entry carries an interned wire: either one
                    // of this producer's slots or a phantom-sink wire
                    let target = match self
                        .out_links[task.index()]
                        .iter()
                        .position(|s| s.wire == wire)
                    {
                        Some(si) => RouteTarget::Slot(si),
                        None => RouteTarget::Wire(wire),
                    };
                    let region = self.agents[task.index()].region;
                    let seq = self.agents[task.index()].out_seq;
                    self.agents[task.index()].out_seq += 1;
                    let run = self.plat.next_run_id();
                    if memo_run.is_none() {
                        memo_run = Some(run);
                    }
                    let id = self.plat.next_av_id();
                    let av = AnnotatedValue {
                        id,
                        source_task: task,
                        link: SINK,
                        object,
                        region,
                        created: publish_at,
                        seq,
                        size_bytes: size,
                        content,
                        class,
                        ghost: false,
                        born,
                    };
                    self.plat.prov.birth(
                        av.id,
                        parents,
                        publish_at,
                        crate::provenance::Stamp::Emitted {
                            task,
                            run,
                            version: self.agents[task.index()].version(),
                            region,
                        },
                    );
                    self.plat.prov.register_object(id, object, size);
                    self.route_output(task, target, Arc::new(av), None, publish_at);
                }
                if self.obs.enabled {
                    if let Some(run) = memo_run {
                        self.obs.firing_memo(self.plat.now, task, run);
                    }
                }
            }
        }
    }

    /// Resolve a sink payload: the caller's copy if provided, else fetch
    /// from storage (memoized/ghost paths pass None).
    fn sink_payload_for(&self, av: &AnnotatedValue, sink_payload: Option<Payload>) -> Payload {
        sink_payload.unwrap_or_else(|| {
            self.plat
                .store
                .peek(av.object)
                .map(|o| o.payload.clone())
                .unwrap_or(Payload::Ghost { pretend_bytes: av.size_bytes })
        })
    }

    /// Send one produced AV down every link of its route target; sink
    /// wires are captured instead. The publication's `Arc` is shared by
    /// the tap observation, the wire-currency slot and every consumer
    /// `Deliver` event: an N-consumer wire costs one allocation, not N+2
    /// deep clones (§Perf). See [`RouteTarget`] for the two cases.
    fn route_output(
        &mut self,
        from: TaskId,
        target: RouteTarget,
        av: Arc<AnnotatedValue>,
        sink_payload: Option<Payload>,
        at: SimTime,
    ) {
        let (wire, slot) = match target {
            RouteTarget::Slot(si) => (self.out_links[from.index()][si].wire, Some(si)),
            RouteTarget::Wire(w) => (w, None),
        };
        if self.obs.enabled {
            self.obs.publish(at, from, wire, av.id, av.size_bytes);
        }
        // breadboard probe point: one observation per value published on
        // the wire, regardless of consumer fan-out, stamped at publish
        // time through the queue so rings stay time-ordered. `watches` is
        // one branch plus a dense mask load — untapped wires never pay
        // the event (§Perf).
        if self.taps.watches(wire) {
            self.push_event(at, EventKind::TapObserve { wire, av: Arc::clone(&av) });
        }
        // dense currency slot: refcount bump, no hash, no deep clone
        self.latest_on_wire.set(wire, Arc::clone(&av));
        let n_links = match slot {
            Some(si) => self.out_links[from.index()][si].links.len(),
            None => 0, // phantom sink: this producer declared no consumers
        };
        if n_links == 0 {
            self.plat.metrics.e2e(av.born, at);
            let payload = self.sink_payload_for(&av, sink_payload);
            // deterministic commit log: the canonical sink order forensic
            // replay diffs against (survives SinkBook drains, identical
            // for every `workers` setting). Gated like the injection
            // ledger: no provenance, no forensic record — and no
            // unbounded growth on provenance-off deployments.
            if self.plat.prov.enabled {
                self.commit_log.push(SinkCommit { wire, at, content: av.content });
            }
            if self.obs.enabled {
                self.obs.sink_commit(at, wire, av.id);
            }
            let rec = Collected { at, av: (*av).clone(), payload };
            self.collected.push(wire, rec);
            return;
        }
        if self.suppress_routing {
            // make mode: demand drives execution order; no reactive cascade
            return;
        }
        let si = slot.expect("n_links > 0 only for slot targets");
        // iterate by index: the steady state allocates nothing (the former
        // `link_idxs.clone()` paid a Vec per publication)
        for k in 0..n_links {
            let li = self.out_links[from.index()][si].links[k];
            self.push_event(at, EventKind::Deliver { link: li, av: Arc::clone(&av) });
        }
    }

    // ------------------------------------------------------------------
    // Software updates (§III-J)
    // ------------------------------------------------------------------

    /// §III-J staleness frontier: every AV `task` ever emitted plus all
    /// provenance descendants, returned as (stale AV count, the storage
    /// objects behind them). Shared by `software_update`'s commit-time
    /// cache eviction and the breadboard's swap preview, so dry-run and
    /// commit always agree.
    pub fn stale_frontier_of(&self, task: TaskId) -> (usize, Vec<(ObjectId, u64)>) {
        let q = crate::provenance::ProvenanceQuery::new(&self.plat.prov);
        let emitted = q.emitted_by(task);
        let mut stale: HashSet<AvId> = emitted.iter().copied().collect();
        for av in &emitted {
            for d in q.descendants(*av) {
                stale.insert(d);
            }
        }
        let mut objects: Vec<(ObjectId, u64)> =
            stale.iter().filter_map(|a| self.plat.prov.object_of(*a)).collect();
        objects.sort_unstable_by_key(|(o, _)| *o);
        objects.dedup_by_key(|(o, _)| *o);
        (stale.len(), objects)
    }

    /// Evict `objects` from every dependent-local cache downstream of
    /// `task`; returns (entries evicted, bytes freed).
    pub fn evict_stale_downstream(
        &mut self,
        task: TaskId,
        objects: &[(ObjectId, u64)],
    ) -> (usize, u64) {
        let downstream = self.graph.reachable_downstream(task);
        let obj_ids: Vec<ObjectId> = objects.iter().map(|(o, _)| *o).collect();
        let mut evicted = 0usize;
        let mut bytes = 0u64;
        for t in downstream {
            let (n, b) = self.agents[t.index()].cache.invalidate_many(&obj_ids);
            evicted += n;
            bytes += b;
        }
        (evicted, bytes)
    }

    /// Deploy new user code (a software update). Memoized results become
    /// stale (version is part of the recipe) and downstream dependent-
    /// local cache copies of this task's artifacts are evicted; if the
    /// task has a last snapshot and `recompute_last` is set, it is
    /// recomputed immediately and corrected results propagate downstream
    /// — the paper's "roll back the feed". Returns the downstream cache
    /// eviction as (entries, bytes).
    pub fn software_update(
        &mut self,
        task: &str,
        code: Box<dyn TaskCode>,
        recompute_last: bool,
    ) -> Result<(usize, u64)> {
        let id = self.task_id(task)?;
        self.software_update_id(id, code, recompute_last)
    }

    /// Id-based software update (the handle API's path); same contract as
    /// [`Coordinator::software_update`] minus the name resolution. The new
    /// code binds against the task's minted ports first — a bind failure
    /// rejects the update before anything is invalidated.
    pub fn software_update_id(
        &mut self,
        id: TaskId,
        code: Box<dyn TaskCode>,
        recompute_last: bool,
    ) -> Result<(usize, u64)> {
        let new_v = code.version();
        let now = self.plat.now;
        let old_v = self.agents[id.index()].install_code(code, &self.graph.wires, now, "update")?;
        self.agents[id.index()].invalidate_memo();
        // §III-J: everything this task produced (and its descendants) is
        // now suspect — evict downstream dependent-local cache copies so
        // stale intermediates cannot be served after the update
        let (_, stale) = self.stale_frontier_of(id);
        let evicted = self.evict_stale_downstream(id, &stale);
        let run = self.plat.next_run_id();
        self.plat.prov.checkpoint(
            id,
            run,
            self.plat.now,
            CheckpointEvent::VersionChange { from: old_v, to: new_v },
        );
        self.plat.metrics.bump("software_updates");
        // a hot-swap is the operator's "the code is fixed now" signal:
        // clear the circuit breaker so redriven / fresh firings execute
        if self.supervision.active() && self.supervision.clear_breaker(id) {
            self.plat.metrics.bump("quarantine_resets");
            if self.obs.enabled {
                self.obs.quarantine(self.plat.now, id, false);
            }
            self.plat.prov.checkpoint(
                id,
                run,
                self.plat.now,
                CheckpointEvent::Remark("quarantine cleared by software update".to_string()),
            );
        }
        if recompute_last {
            if let Some(snap) = self.agents[id.index()].last_snapshot.clone() {
                self.fire_snapshot(id, snap)?;
            }
        }
        Ok(evicted)
    }

    /// Run a task that has no stream inputs (a pure source) once.
    pub fn run_source(&mut self, task: &str) -> Result<()> {
        let id = self.task_id(task)?;
        self.run_source_id(id)
    }

    /// Id-based [`Coordinator::run_source`] (the handle API's `fire`).
    pub fn run_source_id(&mut self, task: TaskId) -> Result<()> {
        let snap = Snapshot { inputs: vec![], born: self.plat.now, ghost: false };
        self.fire_snapshot(task, snap)
    }

    // ------------------------------------------------------------------
    // Supervised firing lifecycle (see crate::fault)
    // ------------------------------------------------------------------

    /// Install a per-task [`FirePolicy`] (retries / deadline / on-exhaust
    /// action). The handle API's `set_fire_policy` lands here.
    pub fn set_fire_policy_id(&mut self, task: TaskId, policy: FirePolicy) {
        self.supervision.set_policy(task, policy);
    }

    /// The task's installed fire policy, if any.
    pub fn fire_policy_id(&self, task: TaskId) -> Option<&FirePolicy> {
        self.supervision.policy(task)
    }

    /// The task's dead-letter book (read-only).
    pub fn dead_letter_book(&self, task: TaskId) -> &DeadLetterBook {
        self.supervision.book(task)
    }

    /// Drain the task's dead-letter book, returning the letters.
    pub fn drain_dead_letters_id(&mut self, task: TaskId) -> Vec<DeadLetter> {
        self.supervision.book_mut(task).drain()
    }

    /// Is the task's circuit breaker open?
    pub fn quarantined_id(&self, task: TaskId) -> bool {
        self.supervision.quarantined(task)
    }

    /// Explicitly clear the task's circuit breaker (the breadboard's
    /// reset verb; hot-swap does this implicitly). Returns whether the
    /// breaker was actually open.
    pub fn quarantine_reset_id(&mut self, task: TaskId) -> bool {
        if !self.supervision.active() || !self.supervision.clear_breaker(task) {
            return false;
        }
        self.plat.metrics.bump("quarantine_resets");
        if self.obs.enabled {
            self.obs.quarantine(self.plat.now, task, false);
        }
        let run = self.plat.next_run_id();
        self.plat.prov.checkpoint(
            task,
            run,
            self.plat.now,
            CheckpointEvent::Remark("quarantine reset by operator".to_string()),
        );
        true
    }

    /// Redrive the task's dead-lettered firings through its current code:
    /// each letter's pinned snapshot re-enters as a fresh supervised
    /// firing (new index, attempt 1). Errors while the task is still
    /// quarantined — hot-swap a fix (or reset the breaker) first.
    pub fn redrive_id(&mut self, task: TaskId) -> Result<usize> {
        if self.supervision.quarantined(task) {
            bail!(
                "task is quarantined; hot-swap a fix or reset the breaker before redriving"
            );
        }
        let letters = self.supervision.book_mut(task).drain();
        if letters.is_empty() {
            return Ok(0);
        }
        let n = letters.len();
        self.plat.metrics.bump("redrives");
        if self.obs.enabled {
            self.obs.redrive(self.plat.now, task, n as u32);
        }
        let run = self.plat.next_run_id();
        self.plat.prov.checkpoint(
            task,
            run,
            self.plat.now,
            CheckpointEvent::Remark(format!("redrive: replaying {n} dead-lettered firing(s)")),
        );
        for letter in letters {
            let index = self.supervision.assign_index(task);
            let guard = self.supervision.guard(task, index, 1);
            let firing = Firing { snapshot: letter.snapshot, index, attempt: 1, guard };
            self.fire_supervised(task, firing);
        }
        Ok(n)
    }

    /// Total values collected on a sink wire.
    pub fn collected_count(&self, wire: &str) -> usize {
        self.collected.get(wire).map_or(0, |v| v.len())
    }

    /// Workspace-checked read of a sink wire (§IV): `principal` must hold
    /// a `Wire` grant through some workspace; denials are counted. Takes
    /// `&self` — reading an output is not an exclusive operation (the
    /// audit counters behind the gate are interior-mutable).
    pub fn read_sink(&self, principal: &str, wire: &str) -> Option<&[Collected]> {
        let resource = crate::workspace::Resource::Wire(wire.to_string());
        if !self.plat.workspaces.check(principal, &resource) {
            return None;
        }
        self.collected.get(wire).map(|v| v.as_slice())
    }

    /// The deterministic commit log of sink captures, commit order.
    pub fn commit_log(&self) -> &[SinkCommit] {
        &self.commit_log
    }

    /// Per-wire (commit time, content hash) sequences projected from the
    /// deterministic commit log — the canonical shape forensic replay
    /// diffs (see `breadboard::replay`). Unlike reading the
    /// [`SinkBook`], this survives sink drains and is independent of
    /// event-heap pop order: within an instant, entries follow the
    /// wavefront's task-index commit order for every `workers` setting.
    /// Empty when provenance was disabled at deploy — the log is gated
    /// like the injection ledger, and forensic replay (the consumer)
    /// already refuses to run without provenance.
    pub fn sink_hash_sequences(&self) -> BTreeMap<String, Vec<(SimTime, ContentHash)>> {
        let mut out: BTreeMap<String, Vec<(SimTime, ContentHash)>> = BTreeMap::new();
        for c in &self.commit_log {
            out.entry(self.graph.wires.name(c.wire).to_string())
                .or_default()
                .push((c.at, c.content));
        }
        out
    }

    /// Ghost-routing audit (§III-K "trust, but verify"): which tasks did a
    /// ghost injection reach? Read from the traveller log.
    pub fn ghost_route(&self, av: AvId) -> Vec<String> {
        use crate::provenance::Stamp;
        let q = crate::provenance::ProvenanceQuery::new(&self.plat.prov);
        let mut names = Vec::new();
        let mut avs = vec![av];
        avs.extend(q.descendants(av));
        for a in avs {
            if let Some(p) = self.plat.prov.passport(a) {
                for s in &p.stamps {
                    if let Stamp::Consumed { task, .. } = s.stamp {
                        let name = self.graph.task(task).name.clone();
                        if !names.contains(&name) {
                            names.push(name);
                        }
                    }
                }
            }
        }
        names
    }
}

#[cfg(test)]
mod tests;
