//! Coordinator unit tests: reactive cascade, make mode, ghosts, updates.

use super::*;
use crate::task::builtins::{FnTask, SummarizeRs};
use crate::task::Output;
use crate::workload::BuildTree;

fn deploy(src: &str) -> Coordinator {
    let spec = crate::spec::parse(src).unwrap();
    Coordinator::deploy(&spec, DeployConfig::default()).unwrap()
}

#[test]
fn reactive_cascade_reaches_sink() {
    let mut c = deploy("[p]\n(raw) stage1 (mid)\n(mid) stage2 (out)\n");
    c.inject("raw", Payload::tensor(&[1, 4], vec![1.0; 4]), DataClass::Summary).unwrap();
    let events = c.run_until_idle();
    assert!(events >= 4, "deliver+wake per stage, got {events}");
    assert_eq!(c.collected_count("out"), 1);
    assert_eq!(c.plat.metrics.task_runs, 2);
    // e2e latency recorded
    assert_eq!(c.plat.metrics.e2e_latency.count(), 1);
}

#[test]
fn fanout_shares_object_across_branches() {
    let mut c = deploy("[f]\n(raw) src (x)\n(x) left (l)\n(x) right (r)\n");
    c.inject("raw", Payload::tensor(&[1, 8], vec![2.0; 8]), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert_eq!(c.collected_count("l"), 1);
    assert_eq!(c.collected_count("r"), 1);
    // src's output object is stored once; both branches point at it
    let l_av = &c.collected["l"][0].av;
    let r_av = &c.collected["r"][0].av;
    let q = crate::provenance::ProvenanceQuery::new(&c.plat.prov);
    let l_parents = q.ancestors(l_av.id);
    let r_parents = q.ancestors(r_av.id);
    assert!(l_parents.iter().any(|p| r_parents.contains(p)), "shared ancestry");
}

#[test]
fn traveller_log_tells_the_journey() {
    let mut c = deploy("[p]\n(raw) stage1 (mid)\n(mid) stage2 (out)\n");
    let injected =
        c.inject("raw", Payload::tensor(&[1, 2], vec![1.0, 2.0]), DataClass::Summary).unwrap();
    c.run_until_idle();
    let passport = c.plat.prov.passport(injected).unwrap();
    use crate::provenance::Stamp;
    assert!(passport.stamps.iter().any(|s| matches!(s.stamp, Stamp::Emitted { .. })));
    assert!(passport.stamps.iter().any(|s| matches!(s.stamp, Stamp::Published { .. })));
    assert!(passport.stamps.iter().any(|s| matches!(s.stamp, Stamp::Consumed { .. })));
    // final artifact's ancestry reaches the injected AV
    let out_av = &c.collected["out"][0].av;
    let q = crate::provenance::ProvenanceQuery::new(&c.plat.prov);
    assert!(q.ancestors(out_av.id).contains(&injected));
}

#[test]
fn make_mode_rebuilds_only_stale_suffix() {
    let mut c = deploy("[mk]\n(src1) compile1 (obj1)\n(src2) compile2 (obj2)\n(obj1, obj2) link-all (bin) @policy=swap\n");
    let tree = BuildTree::default();
    c.inject("src1", tree.source_payload(1, 0), DataClass::Summary).unwrap();
    c.inject("src2", tree.source_payload(2, 0), DataClass::Summary).unwrap();
    // drop pending reactive deliveries: this test drives make mode only
    while c.pending_events() > 0 {
        c.queue_clear_for_test();
    }
    let av1 = c.demand("bin").unwrap();
    assert_eq!(c.plat.metrics.task_runs, 3, "all three built");

    // demand again with nothing changed: zero new runs (memo)
    let av2 = c.demand("bin").unwrap();
    assert_eq!(c.plat.metrics.task_runs, 3, "fully cached rebuild");
    assert_eq!(av1.content, av2.content);
    assert!(c.plat.metrics.get("memo_hits") >= 3);

    // edit src2 only: compile2 + link rerun; compile1 stays cached
    c.inject("src2", tree.source_payload(2, 1), DataClass::Summary).unwrap();
    while c.pending_events() > 0 {
        c.queue_clear_for_test();
    }
    let before = c.plat.metrics.task_runs;
    let av3 = c.demand("bin").unwrap();
    assert_eq!(c.plat.metrics.task_runs, before + 2, "only stale suffix rebuilt");
    assert_ne!(av3.content, av2.content, "output actually changed");
}

#[test]
fn ghost_batch_exposes_routing_without_payload_cost() {
    let mut c = deploy("[g]\n(raw) screen (mid)\n(mid) aggregate (out)\n");
    let wan_before = c.plat.metrics.bytes(crate::metrics::NetTier::Wan);
    let ghost = c.inject_ghost("raw", 100 << 20, RegionId::new(0)).unwrap();
    c.run_until_idle();
    // route is visible...
    let route = c.ghost_route(ghost);
    assert_eq!(route, vec!["screen".to_string(), "aggregate".to_string()]);
    // ...but no real compute ran and no payload bytes moved
    assert_eq!(c.plat.metrics.task_runs, 0);
    assert_eq!(c.plat.metrics.ghost_runs, 2);
    assert_eq!(c.plat.metrics.bytes(crate::metrics::NetTier::Wan), wan_before);
}

#[test]
fn software_update_recomputes_and_stamps() {
    let mut c = deploy("[u]\n(raw) classify (out)\n");
    c.set_code(
        "classify",
        Box::new(FnTask::versioned(
            |ctx, snap| {
                let mut outs = vec![];
                for av in snap.all_avs() {
                    let p = ctx.fetch(av)?;
                    let (_, d) = p.as_tensor().unwrap();
                    outs.push(Output::summary("out", Payload::scalar(d[0] * 1.0)));
                }
                Ok(outs)
            },
            1,
        )),
    )
    .unwrap();
    c.inject("raw", Payload::scalar(3.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert_eq!(c.collected_count("out"), 1);

    // v2 fixes a bug (doubles instead) — recompute the last snapshot
    c.software_update(
        "classify",
        Box::new(FnTask::versioned(
            |ctx, snap| {
                let mut outs = vec![];
                for av in snap.all_avs() {
                    let p = ctx.fetch(av)?;
                    let (_, d) = p.as_tensor().unwrap();
                    outs.push(Output::summary("out", Payload::scalar(d[0] * 2.0)));
                }
                Ok(outs)
            },
            2,
        )),
        true,
    )
    .unwrap();
    c.run_until_idle();
    assert_eq!(c.collected_count("out"), 2, "corrected result re-emitted");
    let vals: Vec<f32> = c.collected["out"]
        .iter()
        .map(|col| col.payload.as_tensor().unwrap().1[0])
        .collect();
    assert_eq!(vals, vec![3.0, 6.0]);
    // checkpoint log shows the version change
    let id = c.task_id("classify").unwrap();
    assert!(c
        .plat
        .prov
        .checkpoint_log(id)
        .iter()
        .any(|e| matches!(e.event, CheckpointEvent::VersionChange { from: 1, to: 2 })));
}

#[test]
fn sovereignty_blocks_raw_but_not_summary() {
    // edge-1 is in zone "eu", central in "us": raw may not travel.
    let spec = crate::spec::parse(
        "[s]\n(raw) summarize (sketch) @region=edge-1\n(sketch) hq (report) @region=central\n",
    )
    .unwrap();
    let mut c = Coordinator::deploy(&spec, DeployConfig::default()).unwrap();
    c.set_code("summarize", Box::new(SummarizeRs::new("sketch"))).unwrap();
    let eu_edge = c.plat.net.by_name("edge-1").unwrap();
    c.inject_at(
        "raw",
        Payload::tensor(&[16, 2], vec![1.0; 32]),
        DataClass::Raw,
        eu_edge,
        SimTime::ZERO,
    )
    .unwrap();
    c.run_until_idle();
    // summary crossed the zone; report produced
    assert_eq!(c.collected_count("report"), 1);
    assert_eq!(c.plat.metrics.get("sovereignty_denied"), 0);

    // now try shipping the raw itself to hq
    let spec2 = crate::spec::parse(
        "[s2]\n(raw) hq (report) @region=central\n",
    )
    .unwrap();
    let mut c2 = Coordinator::deploy(&spec2, DeployConfig::default()).unwrap();
    let eu_edge2 = c2.plat.net.by_name("edge-1").unwrap();
    c2.inject_at(
        "raw",
        Payload::tensor(&[16, 2], vec![1.0; 32]),
        DataClass::Raw,
        eu_edge2,
        SimTime::ZERO,
    )
    .unwrap();
    c2.run_until_idle();
    assert_eq!(c2.collected_count("report"), 0, "raw blocked at the border");
    assert_eq!(c2.plat.metrics.get("sovereignty_denied"), 1);
}

#[test]
fn poll_mode_samples_queue() {
    let mut c = deploy("[pl]\n(raw) worker (out) @notify=poll:10ms\n");
    for i in 0..5u64 {
        c.inject_at(
            "raw",
            Payload::scalar(i as f32),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(i),
        )
        .unwrap();
    }
    c.run_until_idle();
    assert_eq!(c.collected_count("out"), 5);
    assert!(c.plat.metrics.polls_performed >= 1);
    assert_eq!(c.plat.metrics.notifications_sent, 0, "no push on a poll link");
}

#[test]
fn rate_control_limits_fire_rate() {
    let mut c = deploy("[rc]\n(raw) limited (out) @rate=100ms\n");
    for i in 0..10u64 {
        c.inject_at(
            "raw",
            Payload::scalar(i as f32),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(i), // 10 arrivals within 10ms
        )
        .unwrap();
    }
    c.run_until_idle();
    // rate control admits the first immediately; the rest collapse into
    // at most a couple of window runs after the interval
    assert!(
        c.collected_count("out") <= 3,
        "rate-limited to {} outputs",
        c.collected_count("out")
    );
    let agent = c.agent("limited").unwrap();
    assert!(agent.engine.suppressed_by_rate > 0);
}

#[test]
fn merge_policy_folds_two_sources() {
    let mut c = deploy("[mg]\n(a, b) merger (out) @policy=merge\n");
    c.inject_at("a", Payload::scalar(1.0), DataClass::Summary, RegionId::new(0), SimTime::micros(10))
        .unwrap();
    c.inject_at("b", Payload::scalar(2.0), DataClass::Summary, RegionId::new(0), SimTime::micros(5))
        .unwrap();
    c.run_until_idle();
    // merge produces one output per merged batch (batch size 1 here)
    assert_eq!(c.collected_count("out"), 2);
}

#[test]
fn scale_to_zero_then_cold_start() {
    let mut c = deploy("[z]\n(raw) sleepy (out)\n");
    c.plat.cluster.policy.idle_to_zero = SimDuration::secs(5);
    c.enable_scale_sweeps(SimDuration::secs(2));
    c.inject("raw", Payload::scalar(1.0), DataClass::Summary).unwrap();
    c.run_until(SimTime::secs(1));
    assert_eq!(c.collected_count("out"), 1);
    // inject again far in the future: the sweep should have zeroed the pod
    c.inject_at(
        "raw",
        Payload::scalar(2.0),
        DataClass::Summary,
        RegionId::new(0),
        SimTime::secs(60),
    )
    .unwrap();
    c.run_until(SimTime::secs(61));
    let id = c.task_id("sleepy").unwrap();
    let dep = c.plat.cluster.deployment(id).unwrap();
    assert!(dep.cold_starts >= 1, "cold start after zero-scale");
    assert_eq!(c.collected_count("out"), 2);
}

#[test]
fn service_lookup_recorded_for_forensics() {
    let mut c = deploy("[svc]\n(q, dns?) resolver (out)\n");
    c.plat.services.register(
        "dns",
        Box::new(crate::platform::service::KvService::new(&[("db", "10.2.3.4")])),
    );
    c.set_code(
        "resolver",
        Box::new(FnTask::new(|ctx, snap| {
            let _ = snap;
            let addr = ctx.lookup("dns", &Payload::Text("db".into()))?;
            Ok(vec![Output::summary("out", addr)])
        })),
    )
    .unwrap();
    c.inject("q", Payload::scalar(0.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert_eq!(c.collected_count("out"), 1);
    // the lookup is in the service log AND the checkpoint log
    assert_eq!(c.plat.services.lookups.len(), 1);
    let id = c.task_id("resolver").unwrap();
    assert!(c
        .plat
        .prov
        .checkpoint_log(id)
        .iter()
        .any(|e| matches!(e.event, CheckpointEvent::ServiceLookup { .. })));
}

#[test]
fn deterministic_replay_same_seed() {
    let run = |seed: u64| -> (u64, usize) {
        let spec = crate::spec::parse("[d]\n(raw) s1 (m)\n(m) s2 (out)\n").unwrap();
        let mut cfg = DeployConfig::default();
        cfg.seed = seed;
        let mut c = Coordinator::deploy(&spec, cfg).unwrap();
        for i in 0..20u64 {
            c.inject_at(
                "raw",
                Payload::scalar(i as f32),
                DataClass::Summary,
                RegionId::new(0),
                SimTime::millis(i * 7),
            )
            .unwrap();
        }
        c.run_until_idle();
        (c.plat.prov.stamp_count, c.collected_count("out"))
    };
    assert_eq!(run(42), run(42), "byte-identical traces for equal seeds");
}

impl Coordinator {
    /// test helper: drop one pending event (used to isolate make mode)
    pub(crate) fn queue_clear_for_test(&mut self) {
        self.queue.pop();
    }
}
