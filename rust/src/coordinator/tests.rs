//! Coordinator unit tests: reactive cascade, make mode, ghosts, updates.

use super::*;
use crate::task::builtins::{FnTask, SummarizeRs};
use crate::task::Output;
use crate::workload::BuildTree;

fn deploy(src: &str) -> Coordinator {
    let spec = crate::spec::parse(src).unwrap();
    Coordinator::deploy(&spec, DeployConfig::default()).unwrap()
}

#[test]
fn reactive_cascade_reaches_sink() {
    let mut c = deploy("[p]\n(raw) stage1 (mid)\n(mid) stage2 (out)\n");
    c.inject("raw", Payload::tensor(&[1, 4], vec![1.0; 4]), DataClass::Summary).unwrap();
    let events = c.run_until_idle();
    assert!(events >= 4, "deliver+wake per stage, got {events}");
    assert_eq!(c.collected_count("out"), 1);
    assert_eq!(c.plat.metrics.task_runs, 2);
    // e2e latency recorded
    assert_eq!(c.plat.metrics.e2e_latency.count(), 1);
}

#[test]
fn fanout_shares_object_across_branches() {
    let mut c = deploy("[f]\n(raw) src (x)\n(x) left (l)\n(x) right (r)\n");
    c.inject("raw", Payload::tensor(&[1, 8], vec![2.0; 8]), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert_eq!(c.collected_count("l"), 1);
    assert_eq!(c.collected_count("r"), 1);
    // src's output object is stored once; both branches point at it
    let l_av = &c.collected["l"][0].av;
    let r_av = &c.collected["r"][0].av;
    let q = crate::provenance::ProvenanceQuery::new(&c.plat.prov);
    let l_parents = q.ancestors(l_av.id);
    let r_parents = q.ancestors(r_av.id);
    assert!(l_parents.iter().any(|p| r_parents.contains(p)), "shared ancestry");
}

#[test]
fn traveller_log_tells_the_journey() {
    let mut c = deploy("[p]\n(raw) stage1 (mid)\n(mid) stage2 (out)\n");
    let injected =
        c.inject("raw", Payload::tensor(&[1, 2], vec![1.0, 2.0]), DataClass::Summary).unwrap();
    c.run_until_idle();
    let passport = c.plat.prov.passport(injected).unwrap();
    use crate::provenance::Stamp;
    assert!(passport.stamps.iter().any(|s| matches!(s.stamp, Stamp::Emitted { .. })));
    assert!(passport.stamps.iter().any(|s| matches!(s.stamp, Stamp::Published { .. })));
    assert!(passport.stamps.iter().any(|s| matches!(s.stamp, Stamp::Consumed { .. })));
    // final artifact's ancestry reaches the injected AV
    let out_av = &c.collected["out"][0].av;
    let q = crate::provenance::ProvenanceQuery::new(&c.plat.prov);
    assert!(q.ancestors(out_av.id).contains(&injected));
}

#[test]
fn make_mode_rebuilds_only_stale_suffix() {
    let mut c = deploy("[mk]\n(src1) compile1 (obj1)\n(src2) compile2 (obj2)\n(obj1, obj2) link-all (bin) @policy=swap\n");
    let tree = BuildTree::default();
    c.inject("src1", tree.source_payload(1, 0), DataClass::Summary).unwrap();
    c.inject("src2", tree.source_payload(2, 0), DataClass::Summary).unwrap();
    // drop pending reactive deliveries: this test drives make mode only
    while c.pending_events() > 0 {
        c.queue_clear_for_test();
    }
    let av1 = c.demand("bin").unwrap();
    assert_eq!(c.plat.metrics.task_runs, 3, "all three built");

    // demand again with nothing changed: zero new runs (memo)
    let av2 = c.demand("bin").unwrap();
    assert_eq!(c.plat.metrics.task_runs, 3, "fully cached rebuild");
    assert_eq!(av1.content, av2.content);
    assert!(c.plat.metrics.get("memo_hits") >= 3);

    // edit src2 only: compile2 + link rerun; compile1 stays cached
    c.inject("src2", tree.source_payload(2, 1), DataClass::Summary).unwrap();
    while c.pending_events() > 0 {
        c.queue_clear_for_test();
    }
    let before = c.plat.metrics.task_runs;
    let av3 = c.demand("bin").unwrap();
    assert_eq!(c.plat.metrics.task_runs, before + 2, "only stale suffix rebuilt");
    assert_ne!(av3.content, av2.content, "output actually changed");
}

#[test]
fn ghost_batch_exposes_routing_without_payload_cost() {
    let mut c = deploy("[g]\n(raw) screen (mid)\n(mid) aggregate (out)\n");
    let wan_before = c.plat.metrics.bytes(crate::obs::NetTier::Wan);
    let ghost = c.inject_ghost("raw", 100 << 20, RegionId::new(0)).unwrap();
    c.run_until_idle();
    // route is visible...
    let route = c.ghost_route(ghost);
    assert_eq!(route, vec!["screen".to_string(), "aggregate".to_string()]);
    // ...but no real compute ran and no payload bytes moved
    assert_eq!(c.plat.metrics.task_runs, 0);
    assert_eq!(c.plat.metrics.ghost_runs, 2);
    assert_eq!(c.plat.metrics.bytes(crate::obs::NetTier::Wan), wan_before);
}

#[test]
fn software_update_recomputes_and_stamps() {
    let mut c = deploy("[u]\n(raw) classify (out)\n");
    c.set_code(
        "classify",
        Box::new(FnTask::versioned(
            |ctx, snap| {
                let mut outs = vec![];
                for av in snap.all_avs() {
                    let p = ctx.fetch(av)?;
                    let (_, d) = p.as_tensor().unwrap();
                    outs.push(Output::summary("out", Payload::scalar(d[0] * 1.0)));
                }
                Ok(outs)
            },
            1,
        )),
    )
    .unwrap();
    c.inject("raw", Payload::scalar(3.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert_eq!(c.collected_count("out"), 1);

    // v2 fixes a bug (doubles instead) — recompute the last snapshot
    c.software_update(
        "classify",
        Box::new(FnTask::versioned(
            |ctx, snap| {
                let mut outs = vec![];
                for av in snap.all_avs() {
                    let p = ctx.fetch(av)?;
                    let (_, d) = p.as_tensor().unwrap();
                    outs.push(Output::summary("out", Payload::scalar(d[0] * 2.0)));
                }
                Ok(outs)
            },
            2,
        )),
        true,
    )
    .unwrap();
    c.run_until_idle();
    assert_eq!(c.collected_count("out"), 2, "corrected result re-emitted");
    let vals: Vec<f32> = c.collected["out"]
        .iter()
        .map(|col| col.payload.as_tensor().unwrap().1[0])
        .collect();
    assert_eq!(vals, vec![3.0, 6.0]);
    // checkpoint log shows the version change
    let id = c.task_id("classify").unwrap();
    assert!(c
        .plat
        .prov
        .checkpoint_log(id)
        .iter()
        .any(|e| matches!(e.event, CheckpointEvent::VersionChange { from: 1, to: 2 })));
}

#[test]
fn sovereignty_blocks_raw_but_not_summary() {
    // edge-1 is in zone "eu", central in "us": raw may not travel.
    let spec = crate::spec::parse(
        "[s]\n(raw) summarize (sketch) @region=edge-1\n(sketch) hq (report) @region=central\n",
    )
    .unwrap();
    let mut c = Coordinator::deploy(&spec, DeployConfig::default()).unwrap();
    c.set_code("summarize", Box::new(SummarizeRs::new("sketch"))).unwrap();
    let eu_edge = c.plat.net.by_name("edge-1").unwrap();
    c.inject_at(
        "raw",
        Payload::tensor(&[16, 2], vec![1.0; 32]),
        DataClass::Raw,
        eu_edge,
        SimTime::ZERO,
    )
    .unwrap();
    c.run_until_idle();
    // summary crossed the zone; report produced
    assert_eq!(c.collected_count("report"), 1);
    assert_eq!(c.plat.metrics.get("sovereignty_denied"), 0);

    // now try shipping the raw itself to hq
    let spec2 = crate::spec::parse(
        "[s2]\n(raw) hq (report) @region=central\n",
    )
    .unwrap();
    let mut c2 = Coordinator::deploy(&spec2, DeployConfig::default()).unwrap();
    let eu_edge2 = c2.plat.net.by_name("edge-1").unwrap();
    c2.inject_at(
        "raw",
        Payload::tensor(&[16, 2], vec![1.0; 32]),
        DataClass::Raw,
        eu_edge2,
        SimTime::ZERO,
    )
    .unwrap();
    c2.run_until_idle();
    assert_eq!(c2.collected_count("report"), 0, "raw blocked at the border");
    assert_eq!(c2.plat.metrics.get("sovereignty_denied"), 1);
}

#[test]
fn poll_mode_samples_queue() {
    let mut c = deploy("[pl]\n(raw) worker (out) @notify=poll:10ms\n");
    for i in 0..5u64 {
        c.inject_at(
            "raw",
            Payload::scalar(i as f32),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(i),
        )
        .unwrap();
    }
    c.run_until_idle();
    assert_eq!(c.collected_count("out"), 5);
    assert!(c.plat.metrics.polls_performed >= 1);
    assert_eq!(c.plat.metrics.notifications_sent, 0, "no push on a poll link");
}

#[test]
fn rate_control_limits_fire_rate() {
    let mut c = deploy("[rc]\n(raw) limited (out) @rate=100ms\n");
    for i in 0..10u64 {
        c.inject_at(
            "raw",
            Payload::scalar(i as f32),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(i), // 10 arrivals within 10ms
        )
        .unwrap();
    }
    c.run_until_idle();
    // rate control admits the first immediately; the rest collapse into
    // at most a couple of window runs after the interval
    assert!(
        c.collected_count("out") <= 3,
        "rate-limited to {} outputs",
        c.collected_count("out")
    );
    let agent = c.agent("limited").unwrap();
    assert!(agent.engine.suppressed_by_rate > 0);
}

#[test]
fn merge_policy_folds_two_sources() {
    let mut c = deploy("[mg]\n(a, b) merger (out) @policy=merge\n");
    c.inject_at("a", Payload::scalar(1.0), DataClass::Summary, RegionId::new(0), SimTime::micros(10))
        .unwrap();
    c.inject_at("b", Payload::scalar(2.0), DataClass::Summary, RegionId::new(0), SimTime::micros(5))
        .unwrap();
    c.run_until_idle();
    // merge produces one output per merged batch (batch size 1 here)
    assert_eq!(c.collected_count("out"), 2);
}

#[test]
fn scale_to_zero_then_cold_start() {
    let mut c = deploy("[z]\n(raw) sleepy (out)\n");
    c.plat.cluster.policy.idle_to_zero = SimDuration::secs(5);
    c.enable_scale_sweeps(SimDuration::secs(2));
    c.inject("raw", Payload::scalar(1.0), DataClass::Summary).unwrap();
    c.run_until(SimTime::secs(1));
    assert_eq!(c.collected_count("out"), 1);
    // inject again far in the future: the sweep should have zeroed the pod
    c.inject_at(
        "raw",
        Payload::scalar(2.0),
        DataClass::Summary,
        RegionId::new(0),
        SimTime::secs(60),
    )
    .unwrap();
    c.run_until(SimTime::secs(61));
    let id = c.task_id("sleepy").unwrap();
    let dep = c.plat.cluster.deployment(id).unwrap();
    assert!(dep.cold_starts >= 1, "cold start after zero-scale");
    assert_eq!(c.collected_count("out"), 2);
}

#[test]
fn service_lookup_recorded_for_forensics() {
    let mut c = deploy("[svc]\n(q, dns?) resolver (out)\n");
    c.plat.services.register(
        "dns",
        Box::new(crate::platform::service::KvService::new(&[("db", "10.2.3.4")])),
    );
    c.set_code(
        "resolver",
        Box::new(
            FnTask::new(|ctx, snap| {
                let _ = snap;
                let addr = ctx.lookup("dns", &Payload::Text("db".into()))?;
                Ok(vec![Output::summary("out", addr)])
            })
            .sequential(),
        ),
    )
    .unwrap();
    c.inject("q", Payload::scalar(0.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert_eq!(c.collected_count("out"), 1);
    // the lookup is in the service log AND the checkpoint log
    assert_eq!(c.plat.services.lookups.len(), 1);
    let id = c.task_id("resolver").unwrap();
    assert!(c
        .plat
        .prov
        .checkpoint_log(id)
        .iter()
        .any(|e| matches!(e.event, CheckpointEvent::ServiceLookup { .. })));
}

#[test]
fn deterministic_replay_same_seed() {
    let run = |seed: u64| -> (u64, usize) {
        let spec = crate::spec::parse("[d]\n(raw) s1 (m)\n(m) s2 (out)\n").unwrap();
        let mut cfg = DeployConfig::default();
        cfg.seed = seed;
        let mut c = Coordinator::deploy(&spec, cfg).unwrap();
        for i in 0..20u64 {
            c.inject_at(
                "raw",
                Payload::scalar(i as f32),
                DataClass::Summary,
                RegionId::new(0),
                SimTime::millis(i * 7),
            )
            .unwrap();
        }
        c.run_until_idle();
        (c.plat.prov.stamp_count, c.collected_count("out"))
    };
    assert_eq!(run(42), run(42), "byte-identical traces for equal seeds");
}

// ---------------------------------------------------------------------------
// interned-WireId hot path invariants (§Perf)
// ---------------------------------------------------------------------------

#[test]
fn fanout_taps_sample_once_per_publication() {
    // one wire, three consumer links: the tap fires per publication, not
    // per consumer delivery
    let mut c = deploy("[ft]\n(raw) src (x)\n(x) a (sa)\n(x) b (sb)\n(x) d (sd)\n");
    let t = c.taps.attach("x", crate::breadboard::TapSpec::default());
    c.inject("raw", Payload::scalar(1.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert_eq!(c.collected_count("sa"), 1);
    assert_eq!(c.collected_count("sb"), 1);
    assert_eq!(c.collected_count("sd"), 1);
    let stats = c.taps.stats(t).unwrap();
    assert_eq!(stats.seen, 1, "one publication, three links, one sample");
    assert_eq!(c.taps.observations, 1, "observe dispatched once, not per consumer");
}

#[test]
fn future_dated_injection_does_not_update_currency_early() {
    let mut c = deploy("[fd]\n(raw) work (out)\n");
    c.inject_at(
        "raw",
        Payload::scalar(7.0),
        DataClass::Summary,
        RegionId::new(0),
        SimTime::secs(5),
    )
    .unwrap();
    assert!(
        c.latest_on_wire.get("raw").is_none(),
        "data from the future must not be current yet"
    );
    c.run_until(SimTime::secs(1));
    assert!(c.latest_on_wire.get("raw").is_none(), "still ahead of the horizon");
    c.run_until_idle();
    let av = c.latest_on_wire.get("raw").expect("current after delivery");
    assert_eq!(av.created, SimTime::secs(5));
}

#[test]
fn string_wrappers_agree_with_id_internals() {
    let mut c = deploy("[wr]\n(raw) work (out)\n");
    for i in 0..4u64 {
        c.inject_at(
            "raw",
            Payload::scalar(i as f32),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(i),
        )
        .unwrap();
    }
    c.run_until_idle();
    // name-resolving reads agree with each other and with the dense state
    assert_eq!(c.collected_count("out"), 4);
    assert_eq!(c.collected.get("out").unwrap().len(), 4);
    assert_eq!(c.collected["out"].len(), 4);
    let out_id = c.wire_id("out").unwrap();
    let by_name = c.latest_on_wire.get("out").unwrap().id;
    let by_id = c.latest_on_wire.by_id(out_id).unwrap().id;
    assert_eq!(by_name, by_id);
    assert_eq!(by_name, c.collected["out"].last().unwrap().av.id, "currency tracks the sink");
    // id-based injection is the same operation as the string wrapper
    let raw_id = c.wire_id("raw").unwrap();
    c.inject_at_id(raw_id, Payload::scalar(9.0), DataClass::Summary, RegionId::new(0), c.plat.now)
        .unwrap();
    c.run_until_idle();
    assert_eq!(c.collected_count("out"), 5);
}

#[test]
fn unknown_wire_names_error_cleanly() {
    let mut c = deploy("[uw]\n(raw) work (out)\n");
    let err = c.inject("nope", Payload::scalar(0.0), DataClass::Summary).unwrap_err();
    assert!(err.to_string().contains("no wire 'nope'"), "got: {err}");
    let err = c.demand("nope").unwrap_err();
    assert!(err.to_string().contains("no wire 'nope'"), "got: {err}");
    assert!(c.wire_id("nope").is_err());
    assert_eq!(c.collected_count("nope"), 0);
    assert!(c.latest_on_wire.get("nope").is_none());
    // injecting on a produced (non-external) wire still gets the
    // injection-point message, not the unknown-wire one
    let err = c.inject("out", Payload::scalar(0.0), DataClass::Summary).unwrap_err();
    assert!(err.to_string().contains("no injection point"), "got: {err}");
}

#[test]
fn denied_delivery_leaves_currency_untouched() {
    // raw data may not cross zones: the denied delivery must not make the
    // AV "current" on the consumer's wire (and pays no clone doing so)
    let spec = crate::spec::parse("[dc]\n(raw) hq (report) @region=central\n").unwrap();
    let mut c = Coordinator::deploy(&spec, DeployConfig::default()).unwrap();
    let eu_edge = c.plat.net.by_name("edge-1").unwrap();
    // future-dated so the injection itself does not set currency either
    c.inject_at(
        "raw",
        Payload::tensor(&[4, 2], vec![1.0; 8]),
        DataClass::Raw,
        eu_edge,
        SimTime::millis(10),
    )
    .unwrap();
    c.run_until_idle();
    assert_eq!(c.plat.metrics.get("sovereignty_denied"), 1);
    assert!(c.latest_on_wire.get("raw").is_none(), "denied AV never became current");
    assert_eq!(c.collected_count("report"), 0);
}

#[test]
fn fanout_deliveries_share_one_publication_arc() {
    // behavioural check of the zero-copy fan-out: all consumers see the
    // same AV id/object (one mint per publication), each exactly once
    let mut c = deploy("[za]\n(raw) src (x)\n(x) l (sl)\n(x) r (sr)\n");
    c.inject("raw", Payload::tensor(&[1, 4], vec![2.0; 4]), DataClass::Summary).unwrap();
    c.run_until_idle();
    let l = &c.collected["sl"][0].av;
    let r = &c.collected["sr"][0].av;
    let q = crate::provenance::ProvenanceQuery::new(&c.plat.prov);
    let lp = q.ancestors(l.id);
    let rp = q.ancestors(r.id);
    assert!(lp.iter().any(|p| rp.contains(p)), "both branches consumed the same mint");
    // both fan-out links delivered exactly once each
    let x_id = c.wire_id("x").unwrap();
    let fan: u64 = c
        .links
        .iter()
        .filter(|l| l.link.wire_id == x_id)
        .map(|l| l.delivered)
        .sum();
    assert_eq!(fan, 2);
}

#[test]
fn undeclared_output_on_interned_wire_collects_densely() {
    // user code emitting another task's wire name (not among its own
    // declared outputs) must still hit the dense path: phantom-sink
    // capture, wire currency, and memo replay all included
    let mut c = deploy("[ph]\n(raw) a (x)\n(raw2) b (y)\n");
    c.set_code(
        "b",
        Box::new(FnTask::new(|ctx, snap| {
            let mut outs = vec![];
            for av in snap.all_avs() {
                let p = ctx.fetch(av)?;
                outs.push(Output::summary("x", p)); // another task's wire
            }
            Ok(outs)
        })),
    )
    .unwrap();
    c.inject("raw2", Payload::scalar(3.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert_eq!(c.collected_count("x"), 1, "phantom sink captured densely");
    assert!(c.latest_on_wire.get("x").is_some(), "currency tracks phantom publishes");
    // identical input again: the memo hit must re-route the phantom sink
    c.inject("raw2", Payload::scalar(3.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert!(c.plat.metrics.get("memo_hits") >= 1, "second run memoized");
    assert_eq!(c.collected_count("x"), 2, "memo replay still emits the phantom sink");
}

#[test]
fn plug_time_bind_rejects_unknown_ports_with_suggestions() {
    let mut c = deploy("[bp]\n(raw) screen (clean, alerts)\n");
    // typo'd output port: rejected at plug time, previous code kept
    let err = c
        .set_code("screen", Box::new(crate::task::builtins::PassThrough::new("claen")))
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown wire 'claen'"), "{err}");
    assert!(err.contains("did you mean 'clean'?"), "{err}");
    assert!(err.contains("known output ports: clean, alerts"), "{err}");
    let id = c.task_id("screen").unwrap();
    assert_eq!(c.agents[id.index()].version(), 1, "failed plug left old code");
    assert_eq!(c.agents[id.index()].code_history.len(), 1, "no slot recorded");
    // the pipeline still runs on the original pass-through
    c.inject("raw", Payload::scalar(1.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert_eq!(c.collected_count("clean"), 1);
}

#[test]
fn runtime_unknown_wire_emission_errors_with_declared_ports() {
    // a legacy closure emitting a name outside the wire table: the
    // adapter's resolution fails with the task's declared ports listed
    // (it no longer silently lands in an overflow map)
    let mut c = deploy("[re]\n(raw) work (out)\n");
    c.set_code(
        "work",
        Box::new(FnTask::new(|ctx, snap| {
            let mut outs = vec![];
            for av in snap.all_avs() {
                outs.push(Output::summary("oot", ctx.fetch(av)?));
            }
            Ok(outs)
        })),
    )
    .unwrap();
    c.inject("raw", Payload::scalar(1.0), DataClass::Summary).unwrap();
    // demand propagates the run error (reactive pump records it instead)
    let err = c.demand("out").unwrap_err().to_string();
    assert!(err.contains("unknown wire 'oot'"), "{err}");
    assert!(err.contains("did you mean 'out'?"), "{err}");
    assert!(err.contains("known output ports: out"), "{err}");
    // the reactive path counts it as a task error, not a capture
    c.run_until_idle();
    assert!(c.plat.metrics.get("task_errors") >= 1);
    assert_eq!(c.collected_count("oot"), 0, "nothing leaked into the sink book");
}

#[test]
fn port_emissions_route_like_named_outputs() {
    use crate::task::builtins::PortFn;
    use crate::task::{PortIo, TaskCtx};
    // one task fanning out on two declared ports, port-API style
    let mut c = deploy("[pe]\n(raw) split (a, b)\n");
    c.set_code(
        "split",
        Box::new(PortFn::new(|ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
            let (a, b) = (io.out(0)?, io.out(1)?);
            for av in io.inputs.snapshot().all_avs() {
                let p = ctx.fetch(av)?;
                io.emitter.emit(a, p.clone());
                io.emitter.emit_class(b, p, DataClass::Raw);
            }
            Ok(())
        })),
    )
    .unwrap();
    c.inject("raw", Payload::scalar(4.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert_eq!(c.collected_count("a"), 1);
    assert_eq!(c.collected_count("b"), 1);
    assert_eq!(c.collected["a"][0].av.class, DataClass::Summary);
    assert_eq!(c.collected["b"][0].av.class, DataClass::Raw, "per-call class override");
    // memo replay covers multi-port emissions
    c.inject("raw", Payload::scalar(4.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert!(c.plat.metrics.get("memo_hits") >= 1);
    assert_eq!(c.collected_count("a"), 2);
    assert_eq!(c.collected_count("b"), 2);
}

#[test]
fn deferred_emissions_publish_later() {
    use crate::task::builtins::PortFn;
    use crate::task::{PortIo, TaskCtx};
    let mut c = deploy("[df]\n(raw) stamp (now, later)\n");
    c.set_code(
        "stamp",
        Box::new(PortFn::new(|ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
            let (now, later) = (io.out(0)?, io.out(1)?);
            for av in io.inputs.snapshot().all_avs() {
                let p = ctx.fetch(av)?;
                io.emitter.emit(now, p.clone());
                io.emitter.emit_after(later, p, SimDuration::millis(5));
            }
            Ok(())
        })),
    )
    .unwrap();
    c.inject("raw", Payload::scalar(1.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    let t_now = c.collected["now"][0].at;
    let t_later = c.collected["later"][0].at;
    assert_eq!(t_later.saturating_sub(t_now), SimDuration::millis(5));
    // identical recipe -> memo hit: the recorded defer must survive the
    // replay, so the deferred value still trails by the same interval
    c.inject("raw", Payload::scalar(1.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert!(c.plat.metrics.get("memo_hits") >= 1, "second run memoized");
    let t_now2 = c.collected["now"][1].at;
    let t_later2 = c.collected["later"][1].at;
    assert_eq!(
        t_later2.saturating_sub(t_now2),
        SimDuration::millis(5),
        "memo replay preserves the emission defer"
    );
}

// ---------------------------------------------------------------------------
// parallel wavefront scheduler invariants
// ---------------------------------------------------------------------------

#[test]
fn panicking_task_fails_only_its_firing() {
    use crate::task::builtins::PortFn;
    use crate::task::{PortIo, TaskCtx};
    // two independent leaves share one wavefront; one panics every run.
    // The panic is caught (on the worker when workers > 1), recorded as a
    // task error, and the merged wavefront still commits the healthy
    // firings — in both scheduler modes.
    for workers in [1usize, 4] {
        let spec = crate::spec::parse("[pk]\n(x) boom (bs)\n(x) fine (fs)\n").unwrap();
        let cfg = DeployConfig { workers, ..Default::default() };
        let mut c = Coordinator::deploy(&spec, cfg).unwrap();
        c.set_code(
            "boom",
            Box::new(PortFn::new(|_ctx: &mut TaskCtx<'_>, _io: &mut PortIo<'_>| -> Result<()> {
                panic!("kaboom")
            })),
        )
        .unwrap();
        for i in 0..3u64 {
            c.inject_at(
                "x",
                Payload::scalar(i as f32),
                DataClass::Summary,
                RegionId::new(0),
                SimTime::millis(i),
            )
            .unwrap();
        }
        c.run_until_idle();
        assert_eq!(c.collected_count("fs"), 3, "healthy task unaffected (workers={workers})");
        assert_eq!(c.collected_count("bs"), 0, "panicking task emitted nothing");
        assert_eq!(c.plat.metrics.get("task_errors"), 3, "each firing failed alone");
        let id = c.task_id("boom").unwrap();
        assert!(
            c.plat.prov.checkpoint_log(id).iter().any(|e| matches!(
                &e.event,
                CheckpointEvent::Remark(m) if m.contains("task panicked: kaboom")
            )),
            "panic surfaced as a task-error remark"
        );
        // the panicking agent can still run later firings (buffer reset)
        assert_eq!(c.agent("fine").unwrap().runs, 3);
    }
}

#[test]
fn wavefront_commits_in_task_index_order() {
    // one injection instant wakes three tasks; the commit log must list
    // their sink captures in task-index order regardless of workers
    for workers in [1usize, 4] {
        let spec =
            crate::spec::parse("[or]\n(x) alpha (sa)\n(x) beta (sb)\n(x) gamma (sc)\n").unwrap();
        let cfg = DeployConfig { workers, ..Default::default() };
        let mut c = Coordinator::deploy(&spec, cfg).unwrap();
        c.inject("x", Payload::scalar(1.0), DataClass::Summary).unwrap();
        c.run_until_idle();
        let wires: Vec<&str> =
            c.commit_log().iter().map(|sc| c.graph.wires.name(sc.wire)).collect();
        assert_eq!(wires, vec!["sa", "sb", "sc"], "workers={workers}");
    }
}

#[test]
fn parallel_and_sequential_agree_on_ids_and_stamps() {
    // the cheap in-tree twin of rust/tests/wavefront_determinism.rs: a
    // fan-out wavefront must allocate identical AV ids and stamp counts
    // under both schedulers
    let run = |workers: usize| {
        let spec = crate::spec::parse("[ag]\n(x) l0 (s0)\n(x) l1 (s1)\n(x) l2 (s2)\n").unwrap();
        let cfg = DeployConfig { workers, ..Default::default() };
        let mut c = Coordinator::deploy(&spec, cfg).unwrap();
        for i in 0..5u64 {
            c.inject_at(
                "x",
                Payload::scalar(i as f32),
                DataClass::Summary,
                RegionId::new(0),
                SimTime::millis(i),
            )
            .unwrap();
        }
        c.run_until_idle();
        let avs: Vec<String> = ["s0", "s1", "s2"]
            .iter()
            .flat_map(|w| c.collected[*w].iter().map(|r| format!("{:?}", r.av)))
            .collect();
        (avs, c.plat.prov.stamp_count, c.plat.metrics.task_runs)
    };
    assert_eq!(run(1), run(4));
}

impl Coordinator {
    /// test helper: drop one pending event (used to isolate make mode)
    pub(crate) fn queue_clear_for_test(&mut self) {
        self.queue.pop();
    }
}
