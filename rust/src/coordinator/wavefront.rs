//! The parallel half of the wavefront scheduler: execute ready, mutually
//! independent task firings on a `std::thread::scope` worker pool. One
//! call carries one instant's wavefront on the per-instant path, or the
//! groups of several overlapped instants under pipelined scheduling
//! (`reorder_window > 1`; see `coordinator::frontier`) — each group
//! executes under its own instant's clock either way.
//!
//! Safety/determinism model (see DESIGN.md §Execution model):
//!  * **Disjoint ownership** — each wavefront task's [`TaskAgent`] is
//!    handed to exactly one worker as `&mut` (split out of the agent
//!    vector), so agent-local state (snapshot engine aside — it was
//!    drained in phase 1 — the dependent-local cache, memo, code state,
//!    recycled emission buffer) mutates with no synchronization at all.
//!    The frontier tracker guarantees a task appears in at most one
//!    in-flight group, so the multi-instant case plucks disjoint agents
//!    exactly like the single-instant one.
//!  * **Frozen world** — workers read the platform through a `Sync`
//!    [`WorldView`] (committed object store, WAN topology, the group's
//!    clock). Nothing a wavefront firing can read is written until the
//!    commit phase: publications land strictly later in virtual time, and
//!    the object store is append-only, so in-flight firings are mutually
//!    independent by construction even across instants.
//!  * **Recorded effects** — would-be platform mutations go to each
//!    firing's [`EffectLog`](crate::task::effects::EffectLog); the
//!    coordinator replays them in task-index order, drawing run/AV/object
//!    ids from the shared dispensers there — which is why every
//!    `workers` value allocates identical ids and stamps identical
//!    provenance.
//!  * **Memo interplay** — a firing whose recipe matches the agent's memo
//!    (or an earlier firing of the same wavefront group) defers to the
//!    commit phase, where the direct path resolves it exactly as
//!    `workers = 1` would (the earlier firing's memoization must land
//!    before the later one probes).
//!
//! Scheduling is work-stealing over an atomic cursor; it affects only
//! *which thread* runs a group, never the committed order, so the pool
//! needs no deterministic scheduler.
//!
//! On a multi-node [`ShardPlan`](crate::shard::ShardPlan) the pool is
//! replaced by **thread-per-node** execution: each busy node gets one
//! thread that runs exactly its own tasks' groups, in task-index order —
//! the cluster-simulation execution model (§III-B deployments). The swap
//! changes only which thread prepares a group; every effect still commits
//! on the coordinator thread in canonical order, which is why node count
//! and node pins cannot perturb a committed byte.

use super::{Coordinator, TaskId};
use crate::fault::Firing;
use crate::graph::WireTable;
use crate::task::effects::{DeferReason, PreparedFiring, WorldView};
use crate::task::TaskAgent;
use crate::util::{ContentHash, SimTime};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One wavefront member: a woken task, its extracted ready firings, and
/// the pump-epilogue inputs (autoscale signal, poll re-arm flag). `at`
/// is the virtual instant the group was extracted at — equal to
/// `plat.now` on the per-instant path, but under pipelined multi-instant
/// scheduling (see `coordinator::frontier`) one execute call can carry
/// groups from several instants, each seeing its own clock.
pub(crate) struct WaveGroup {
    pub task: TaskId,
    pub at: SimTime,
    pub via_poll: bool,
    pub queued: usize,
    pub firings: Vec<Firing>,
}

/// A unit of worker work: one group's agent (exclusively borrowed) plus
/// its firings, tagged with the group's result slot and its instant
/// (the `WorldView` clock this job executes under).
struct Job<'a> {
    group_idx: usize,
    at: SimTime,
    agent: &'a mut TaskAgent,
    firings: Vec<Firing>,
}

/// Execute every busy group's firings on the worker pool. Returns one
/// `Vec<PreparedFiring>` per group (empty for idle groups), indexed like
/// `groups`; the caller commits them in group (= task-index) order.
pub(super) fn execute_parallel(
    coord: &mut Coordinator,
    groups: &mut [WaveGroup],
) -> Vec<Vec<PreparedFiring>> {
    let Coordinator { agents, plat, graph, workers, shard, .. } = coord;
    let (store, net) = (&plat.store, &plat.net);
    let wires: &WireTable = &graph.wires;

    // pluck each wavefront agent as a disjoint &mut out of the agent
    // vector; iter_mut proves disjointness to the borrow checker
    let mut slot_of: std::collections::HashMap<usize, usize> = groups
        .iter()
        .enumerate()
        .filter(|(_, g)| !g.firings.is_empty())
        .map(|(gi, g)| (g.task.index(), gi))
        .collect();
    let mut jobs: Vec<Mutex<Option<Job<'_>>>> = Vec::with_capacity(slot_of.len());
    // node hosting each job's task, parallel to `jobs`
    let mut job_node: Vec<usize> = Vec::with_capacity(slot_of.len());
    for (i, agent) in agents.iter_mut().enumerate() {
        if let Some(group_idx) = slot_of.remove(&i) {
            let firings = std::mem::take(&mut groups[group_idx].firings);
            let at = groups[group_idx].at;
            jobs.push(Mutex::new(Some(Job { group_idx, at, agent, firings })));
            job_node.push(shard.node(TaskId::new(i as u64)));
        }
    }
    debug_assert!(slot_of.is_empty(), "every busy group maps to a deployed agent");

    let results: Vec<Mutex<Vec<PreparedFiring>>> =
        groups.iter().map(|_| Mutex::new(Vec::new())).collect();
    if shard.nodes > 1 {
        // thread-per-node: each busy node runs its own tasks' groups in
        // task-index order. Worker width is ignored — the partition *is*
        // the schedule (a node is a simulated machine, not a pool slot).
        let jobs_ref = &jobs;
        let results_ref = &results;
        std::thread::scope(|s| {
            for node in 0..shard.nodes {
                let mine: Vec<usize> = job_node
                    .iter()
                    .enumerate()
                    .filter(|(_, &n)| n == node)
                    .map(|(j, _)| j)
                    .collect();
                if mine.is_empty() {
                    continue;
                }
                s.spawn(move || {
                    for j in mine {
                        let Job { group_idx, at, agent, firings } =
                            jobs_ref[j].lock().unwrap().take().expect("each job is taken once");
                        let world = WorldView { store, net, now: at };
                        let out = prepare_group(agent, wires, &world, firings);
                        *results_ref[group_idx].lock().unwrap() = out;
                    }
                });
            }
        });
        return results.into_iter().map(|m| m.into_inner().unwrap()).collect();
    }
    let cursor = AtomicUsize::new(0);
    let n_workers = (*workers).min(jobs.len()).max(1);
    std::thread::scope(|s| {
        for _ in 0..n_workers {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::SeqCst);
                if i >= jobs.len() {
                    break;
                }
                let Job { group_idx, at, agent, firings } =
                    jobs[i].lock().unwrap().take().expect("each job is taken once");
                let world = WorldView { store, net, now: at };
                let out = prepare_group(agent, wires, &world, firings);
                *results[group_idx].lock().unwrap() = out;
            });
        }
    });
    results.into_iter().map(|m| m.into_inner().unwrap()).collect()
}

/// Run one task's wavefront firings in order on this worker. Memo hits,
/// recipes already attempted earlier in the group, and
/// declared-sequential code defer to the commit phase (always
/// behavior-preserving: deferral *is* the `workers = 1` path).
fn prepare_group(
    agent: &mut TaskAgent,
    wires: &WireTable,
    world: &WorldView<'_>,
    firings: Vec<Firing>,
) -> Vec<PreparedFiring> {
    let mut out = Vec::with_capacity(firings.len());
    if !agent.code.parallel_safe() {
        out.extend(
            firings.into_iter().map(|f| PreparedFiring::Deferred(f, DeferReason::Sequential)),
        );
        return out;
    }
    let mut attempted: Vec<ContentHash> = Vec::new();
    for f in firings {
        let recipe = agent.recipe(&f.snapshot);
        let dup = attempted.contains(&recipe);
        attempted.push(recipe);
        if !f.snapshot.ghost && (dup || agent.memo_valid_in(world.store, recipe)) {
            out.push(PreparedFiring::Deferred(f, DeferReason::MemoHit));
            continue;
        }
        out.push(agent.execute_recorded(world, wires, f, recipe));
    }
    out
}
