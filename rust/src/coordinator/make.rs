//! Make-mode triggering — §III-B's first trigger case.
//!
//! "A 'make' model, in which a request for the target at the logical
//! output end of the pipes triggers a hierarchical rebuild of dependencies
//! 'backwards', recursively."
//!
//! [`Coordinator::demand`] walks producers of the requested wire
//! depth-first, refreshing every dependency, then executes each task on
//! the *latest* value of each input (Makefile semantics = SwapNewForOld
//! over currency). Staleness is decided by the recipe hash (input content
//! hashes × software version): an unchanged recipe is a memo hit and runs
//! nothing — that is precisely make's "don't rebuild what didn't change"
//! (E1/E4).

use super::Coordinator;
use crate::av::AnnotatedValue;
use crate::policy::Snapshot;
use crate::util::{TaskId, WireId};
use anyhow::{anyhow, Result};
use std::collections::HashSet;

impl Coordinator {
    /// Bring `wire` up to date, rebuilding stale dependencies backwards.
    /// Returns the (now current) AV on the wire. Thin name→id wrapper: the
    /// recursive walk itself runs on interned [`WireId`]s against the
    /// graph's precomputed per-wire producer lists (§Perf).
    pub fn demand(&mut self, wire: &str) -> Result<AnnotatedValue> {
        let wid = self.wire_id(wire)?;
        self.demand_id(wid)
    }

    /// Id-based demand (the handle API's path — `SinkHandle::demand`).
    pub fn demand_id(&mut self, wire: WireId) -> Result<AnnotatedValue> {
        if self.obs.enabled {
            self.obs.demand(self.plat.now, wire);
        }
        let mut visited = HashSet::new();
        self.suppress_routing = true;
        let r = self.demand_wire(wire, &mut visited);
        self.suppress_routing = false;
        r
    }

    /// Demand-build every producer of `wire`, then return its latest AV.
    fn demand_wire(
        &mut self,
        wire: WireId,
        visited: &mut HashSet<TaskId>,
    ) -> Result<AnnotatedValue> {
        let producers: Vec<TaskId> = self.graph.wires.producers(wire).to_vec();
        if producers.is_empty() {
            // external in-tray: someone must have dropped a file
            return self
                .latest_on_wire
                .by_id(wire)
                .map(|a| (**a).clone())
                .ok_or_else(|| {
                    anyhow!(
                        "no data ever injected on external wire '{}'",
                        self.graph.wires.name(wire)
                    )
                });
        }
        for p in producers {
            self.demand_task_inner(p, visited)?;
        }
        self.latest_on_wire
            .by_id(wire)
            .map(|a| (**a).clone())
            .ok_or_else(|| anyhow!("producers of '{}' made no output", self.graph.wires.name(wire)))
    }

    /// Demand-build one task (dependencies first).
    pub fn demand_task(&mut self, name: &str) -> Result<()> {
        let id = self.task_id(name)?;
        let mut visited = HashSet::new();
        self.suppress_routing = true;
        let r = self.demand_task_inner(id, &mut visited);
        self.suppress_routing = false;
        r
    }

    fn demand_task_inner(&mut self, task: TaskId, visited: &mut HashSet<TaskId>) -> Result<()> {
        if !visited.insert(task) {
            return Ok(()); // diamond dependency or cycle: build once per demand
        }
        // ports resolve to interned ids once; the snapshot still carries
        // names because input buffers are keyed by port name
        let ports: Vec<(std::sync::Arc<str>, WireId)> = self
            .graph
            .task(task)
            .stream_inputs()
            .map(|i| {
                let wid = self
                    .graph
                    .wires
                    .id(&i.wire)
                    .expect("spec stream inputs are interned at build");
                (std::sync::Arc::from(i.wire.as_str()), wid)
            })
            .collect();
        for (_, wid) in &ports {
            self.demand_wire(*wid, visited)?;
        }
        // assemble the Makefile-style snapshot: the latest value per port
        let mut inputs = Vec::with_capacity(ports.len());
        for (name, wid) in &ports {
            let av = self
                .latest_on_wire
                .by_id(*wid)
                .map(|a| (**a).clone())
                .ok_or_else(|| anyhow!("input '{name}' has no current value"))?;
            inputs.push((name.clone(), vec![av]));
        }
        let snapshot = Snapshot::new(inputs, self.plat.now);
        self.fire_snapshot(task, snapshot)
    }
}
