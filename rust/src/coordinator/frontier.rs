//! Frontier progress tracking for pipelined multi-instant scheduling.
//!
//! The PR 5 wavefront scheduler parallelized firings *within* one virtual
//! instant but kept a hard barrier between instants: no task could start
//! instant `T+k` until every task had committed instant `T`. This module
//! supplies the bookkeeping that breaks that barrier, in the style of
//! timely-dataflow progress tracking: each in-flight unit of work (one
//! instant's extracted wavefront) owns a *capability* over the set of tasks
//! it may still affect, and a later instant may be extracted early exactly
//! when its events touch no task under an outstanding capability.
//!
//! Two inputs feed the tracker:
//!
//! * **Tasks** contribute the minimum instant at which they may still
//!   publish. Concretely, an extracted-but-unretired unit shadows every
//!   task it woke *plus the transitive downstream closure* of those tasks,
//!   because a firing at instant `T` can publish onto wires that reach any
//!   of them at `T+δ`. While a task is shadowed its events must wait.
//! * **Injection feeds** contribute their ingest watermarks
//!   ([`crate::ingest::WatermarkClock`], PR 9): the pump reports each sealed
//!   epoch's frontier via `note_ingest`, so observers can see how far the
//!   external front door has progressed relative to the execution frontier.
//!
//! The tracker is deliberately *conservative and cheap*: closures are
//! precomputed bitsets (one `u64` word per 64 tasks) at deploy time, and
//! occupy/release are word-wise loops. It never consults payloads or wire
//! contents — eligibility is a pure graph property, which is what makes the
//! determinism argument in `DESIGN.md` §Execution model tractable: the set
//! of instants overlapped depends only on {graph, event order}, never on
//! thread timing.

use crate::util::ids::TaskId;
use crate::util::time::SimTime;

/// One in-flight unit's capability: the bitset of tasks it shadows.
///
/// Returned by [`FrontierTracker::occupy`]; hand it back to
/// [`FrontierTracker::release`] when the unit retires. The mask is plain
/// data (no lifetimes) so the coordinator can stash it inside the unit.
#[derive(Debug, Clone, Default)]
pub struct ShadowMask {
    words: Vec<u64>,
}

impl ShadowMask {
    /// True if no task is shadowed by this mask.
    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|w| *w == 0)
    }
}

/// Per-task input-frontier tracker (see module docs).
///
/// Owned by the coordinator; rebuilt at deploy time from the pipeline
/// graph. All methods are `O(n_tasks / 64)` or better — this sits on the
/// event-loop hot path.
#[derive(Debug, Default)]
pub struct FrontierTracker {
    n_tasks: usize,
    /// `closure[t]` = bitset over tasks: `t` itself plus every task
    /// transitively downstream of `t` in the wiring diagram.
    closure: Vec<Vec<u64>>,
    /// Per-task count of in-flight units shadowing it. A count (not a
    /// bit) because several units may cover the same task.
    shadow: Vec<u32>,
    /// Number of units currently extracted but not yet retired.
    in_flight: usize,
    /// Latest sealed ingest watermark reported by the pump, if any.
    ingest_frontier: Option<SimTime>,
    // -- occupancy counters (surfaced through the obs snapshot) --
    /// Total units ever occupied (== pipelined instants extracted).
    pub units_total: u64,
    /// High-water mark of simultaneously in-flight units.
    pub peak_in_flight: usize,
}

fn words_for(n: usize) -> usize {
    n.div_ceil(64)
}

impl FrontierTracker {
    /// Build the tracker for a graph with `n_tasks` tasks, given the
    /// transitive downstream closure of each task (as produced by
    /// `PipelineGraph::reachable_downstream`).
    pub fn new(n_tasks: usize, downstream: impl Fn(TaskId) -> Vec<TaskId>) -> Self {
        let w = words_for(n_tasks);
        let mut closure = vec![vec![0u64; w]; n_tasks];
        for t in 0..n_tasks {
            closure[t][t / 64] |= 1u64 << (t % 64);
            for d in downstream(TaskId::new(t as u64)) {
                let i = d.index();
                closure[t][i / 64] |= 1u64 << (i % 64);
            }
        }
        Self {
            n_tasks,
            closure,
            shadow: vec![0; n_tasks],
            in_flight: 0,
            ingest_frontier: None,
            units_total: 0,
            peak_in_flight: 0,
        }
    }

    /// True if `task` sits under an outstanding capability: some extracted
    /// but unretired unit may still publish onto a wire that reaches it.
    pub fn is_shadowed(&self, task: TaskId) -> bool {
        self.shadow.get(task.index()).is_some_and(|c| *c > 0)
    }

    /// Number of units currently extracted but not yet retired.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Claim a capability for one unit: shadow every task in `tasks` plus
    /// its transitive downstream closure. Returns the mask to pass to
    /// [`Self::release`] at retirement.
    pub fn occupy(&mut self, tasks: impl IntoIterator<Item = TaskId>) -> ShadowMask {
        let mut words = vec![0u64; words_for(self.n_tasks)];
        for t in tasks {
            if let Some(cl) = self.closure.get(t.index()) {
                for (acc, w) in words.iter_mut().zip(cl) {
                    *acc |= *w;
                }
            }
        }
        for (wi, w) in words.iter().enumerate() {
            let mut bits = *w;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                self.shadow[wi * 64 + b] += 1;
                bits &= bits - 1;
            }
        }
        self.in_flight += 1;
        self.units_total += 1;
        self.peak_in_flight = self.peak_in_flight.max(self.in_flight);
        ShadowMask { words }
    }

    /// Retire one unit's capability (the inverse of [`Self::occupy`]).
    pub fn release(&mut self, mask: &ShadowMask) {
        for (wi, w) in mask.words.iter().enumerate() {
            let mut bits = *w;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                self.shadow[wi * 64 + b] -= 1;
                bits &= bits - 1;
            }
        }
        self.in_flight -= 1;
    }

    /// Record the ingest watermark the pump just sealed to. Monotone:
    /// regressions (a late feed re-opening an epoch never happens, but
    /// defensive anyway) are ignored.
    pub fn note_ingest(&mut self, w: SimTime) {
        if self.ingest_frontier.is_none_or(|cur| w > cur) {
            self.ingest_frontier = Some(w);
        }
    }

    /// Latest sealed ingest watermark, if the pump has reported one.
    pub fn ingest_frontier(&self) -> Option<SimTime> {
        self.ingest_frontier
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // chain 0 -> 1 -> 2, plus isolated 3
    fn chain_downstream(t: TaskId) -> Vec<TaskId> {
        match t.index() {
            0 => vec![TaskId::new(1), TaskId::new(2)],
            1 => vec![TaskId::new(2)],
            _ => vec![],
        }
    }

    #[test]
    fn occupy_shadows_downstream_closure() {
        let mut fr = FrontierTracker::new(4, chain_downstream);
        let mask = fr.occupy([TaskId::new(0)]);
        assert!(fr.is_shadowed(TaskId::new(0)));
        assert!(fr.is_shadowed(TaskId::new(1)));
        assert!(fr.is_shadowed(TaskId::new(2)));
        assert!(!fr.is_shadowed(TaskId::new(3)));
        assert_eq!(fr.in_flight(), 1);
        fr.release(&mask);
        assert!(!fr.is_shadowed(TaskId::new(1)));
        assert_eq!(fr.in_flight(), 0);
    }

    #[test]
    fn overlapping_units_count_not_bit() {
        let mut fr = FrontierTracker::new(4, chain_downstream);
        let a = fr.occupy([TaskId::new(0)]);
        let b = fr.occupy([TaskId::new(1)]);
        // task 2 is shadowed by both units; releasing one must keep it.
        fr.release(&a);
        assert!(fr.is_shadowed(TaskId::new(2)));
        fr.release(&b);
        assert!(!fr.is_shadowed(TaskId::new(2)));
        assert_eq!(fr.units_total, 2);
        assert_eq!(fr.peak_in_flight, 2);
    }

    #[test]
    fn ingest_frontier_is_monotone() {
        let mut fr = FrontierTracker::new(1, |_| vec![]);
        assert_eq!(fr.ingest_frontier(), None);
        fr.note_ingest(SimTime::micros(50));
        fr.note_ingest(SimTime::micros(20));
        assert_eq!(fr.ingest_frontier(), Some(SimTime::micros(50)));
    }
}
