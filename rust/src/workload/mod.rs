//! Synthetic workload generators.
//!
//! The paper's user cases (§III-A): data replication/distribution,
//! aggregation from multiple sources at different rates, matrix operations,
//! and continuous-delivery builds. Each generator here feeds one of those
//! cases with a deterministic, seedable trace (DESIGN.md substitution for
//! the production traces we do not have).

use crate::av::Payload;
use crate::util::{Rng, SimDuration, SimTime};

/// Standard-normal sample (Box–Muller lives on the in-tree Rng).
pub fn normal(rng: &mut Rng) -> f64 {
    rng.normal()
}

/// Exponential inter-arrival sample with the given mean.
pub fn exponential(rng: &mut Rng, mean: SimDuration) -> SimDuration {
    mean.scale(rng.exp1())
}

// ---------------------------------------------------------------------------
// Sensor streams (fig. 7: weather sensors at mismatched rates)
// ---------------------------------------------------------------------------

/// One sensor emitting (1, dims) tensor samples with exponential
/// inter-arrival times around `mean_period`.
#[derive(Clone, Debug)]
pub struct SensorStream {
    pub name: String,
    pub mean_period: SimDuration,
    pub dims: usize,
    /// Channel offset so different sensors have distinct signatures.
    pub bias: f32,
    next_at: SimTime,
    pub emitted: u64,
}

impl SensorStream {
    pub fn new(name: &str, mean_period: SimDuration, dims: usize, bias: f32) -> Self {
        Self {
            name: name.to_string(),
            mean_period,
            dims,
            bias,
            next_at: SimTime::ZERO,
            emitted: 0,
        }
    }

    /// Next (arrival_time, payload) at or after the stream's own clock.
    pub fn next(&mut self, rng: &mut Rng) -> (SimTime, Payload) {
        self.next_at += exponential(rng, self.mean_period);
        self.emitted += 1;
        let data: Vec<f32> =
            (0..self.dims).map(|i| self.bias + i as f32 * 0.1 + normal(rng) as f32).collect();
        (self.next_at, Payload::tensor(&[1, self.dims], data))
    }

    /// Generate all arrivals up to `horizon`.
    pub fn arrivals_until(
        &mut self,
        rng: &mut Rng,
        horizon: SimTime,
    ) -> Vec<(SimTime, Payload)> {
        let mut out = Vec::new();
        loop {
            let (t, p) = self.next(rng);
            if t > horizon {
                // put the overshoot back by rewinding our clock
                self.next_at = t;
                break;
            }
            out.push((t, p));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Vehicle trace (§IV: "a modern 'smart' vehicle may produce terabytes ...
// most of which is transitory, and not worth keeping after screening")
// ---------------------------------------------------------------------------

/// A fleet of vehicles, each producing fixed-size raw sample chunks at its
/// edge region while "driving", to be screened/summarized before any WAN hop.
#[derive(Clone, Debug)]
pub struct VehicleTrace {
    pub n_vehicles: usize,
    pub chunks_per_vehicle: usize,
    /// Samples per chunk (rows of the (N, D) tensor the kernel reduces).
    pub chunk_rows: usize,
    pub dims: usize,
    pub chunk_period: SimDuration,
    /// Fraction of channels carrying junk (local-only relevance).
    pub junk_fraction: f64,
}

impl Default for VehicleTrace {
    fn default() -> Self {
        Self {
            n_vehicles: 4,
            chunks_per_vehicle: 16,
            chunk_rows: 1024,
            dims: 8,
            chunk_period: SimDuration::secs(2),
            junk_fraction: 0.5,
        }
    }
}

/// One emitted chunk of a vehicle journey.
#[derive(Clone, Debug)]
pub struct VehicleChunk {
    pub vehicle: usize,
    pub seq: usize,
    pub time: SimTime,
    pub payload: Payload,
    /// Ground-truth anomaly rows planted in this chunk (for recall checks).
    pub planted_anomalies: usize,
}

impl VehicleTrace {
    /// Generate the full fleet trace, interleaved by time.
    pub fn generate(&self, rng: &mut Rng) -> Vec<VehicleChunk> {
        let mut chunks = Vec::new();
        for v in 0..self.n_vehicles {
            let jitter = SimDuration::micros(rng.range_u64(0, self.chunk_period.as_micros().max(1)));
            for s in 0..self.chunks_per_vehicle {
                let time = SimTime::ZERO + self.chunk_period.scale(s as f64) + jitter;
                let mut data = Vec::with_capacity(self.chunk_rows * self.dims);
                for _ in 0..self.chunk_rows {
                    for d in 0..self.dims {
                        let base = if (d as f64) < self.junk_fraction * self.dims as f64 {
                            // junk channels: pure noise
                            normal(rng) as f32
                        } else {
                            // signal channels: vehicle-specific drift
                            v as f32 * 0.5 + s as f32 * 0.01 + 0.3 * normal(rng) as f32
                        };
                        data.push(base);
                    }
                }
                // plant a few gross anomalies (possible road defects)
                let planted = rng.range(0, 4);
                for _ in 0..planted {
                    let row = rng.range(0, self.chunk_rows);
                    let col = rng.range(0, self.dims);
                    data[row * self.dims + col] = 40.0 + normal(rng).abs() as f32 * 5.0;
                }
                chunks.push(VehicleChunk {
                    vehicle: v,
                    seq: s,
                    time,
                    payload: Payload::tensor(&[self.chunk_rows, self.dims], data),
                    planted_anomalies: planted,
                });
            }
        }
        chunks.sort_by_key(|c| c.time);
        chunks
    }

    pub fn raw_bytes(&self) -> u64 {
        (self.n_vehicles * self.chunks_per_vehicle * self.chunk_rows * self.dims * 4) as u64
    }
}

// ---------------------------------------------------------------------------
// Build tree (the make-model workload, §III-B / fig. 1)
// ---------------------------------------------------------------------------

/// A synthetic software build: a tree of source files feeding object files
/// feeding a final link target. Drives the E1/E4 make-mode experiments.
#[derive(Clone, Debug)]
pub struct BuildTree {
    /// Number of leaf source files.
    pub leaves: usize,
    /// Sources per object file (fan-in of intermediate nodes).
    pub fanin: usize,
    /// Bytes per source payload.
    pub source_bytes: usize,
}

impl Default for BuildTree {
    fn default() -> Self {
        Self { leaves: 32, fanin: 4, source_bytes: 4096 }
    }
}

impl BuildTree {
    pub fn n_objects(&self) -> usize {
        self.leaves.div_ceil(self.fanin)
    }

    /// Source payload for leaf `i` at edit-generation `gen` (the content
    /// changes when the file is edited — content hash then differs).
    pub fn source_payload(&self, i: usize, generation: u64) -> Payload {
        let mut bytes = vec![0u8; self.source_bytes];
        let tag = (i as u64) << 32 | generation;
        bytes[..8].copy_from_slice(&tag.to_le_bytes());
        // deterministic body so equal generations hash equal
        for (j, b) in bytes[8..].iter_mut().enumerate() {
            *b = ((i * 31 + j * 7) % 251) as u8;
        }
        Payload::Bytes(bytes)
    }

    /// Pick a deterministic dirty set of `k` leaves for an incremental edit.
    pub fn dirty_set(&self, rng: &mut Rng, k: usize) -> Vec<usize> {
        let mut picks: Vec<usize> = (0..self.leaves).collect();
        rng.shuffle(&mut picks);
        picks.truncate(k.min(self.leaves));
        picks.sort_unstable();
        picks
    }
}

// ---------------------------------------------------------------------------
// Image stream for the fig. 6 twin pipeline (E9)
// ---------------------------------------------------------------------------

/// Synthetic classed "images": class prototype + noise, matching
/// python/compile/model.py's `synth_classes` recipe so the rust-served
/// model sees in-distribution data.
#[derive(Clone, Debug)]
pub struct ImageStream {
    pub classes: usize,
    pub dim: usize,
    pub noise: f32,
    protos: Vec<Vec<f32>>,
}

impl ImageStream {
    pub fn new(rng: &mut Rng, classes: usize, dim: usize, noise: f32) -> Self {
        let protos = (0..classes)
            .map(|_| (0..dim).map(|_| 2.0 * normal(rng) as f32).collect())
            .collect();
        Self { classes, dim, noise, protos }
    }

    /// One labelled sample.
    pub fn sample(&self, rng: &mut Rng) -> (Vec<f32>, usize) {
        let label = rng.range(0, self.classes);
        let x = self.protos[label]
            .iter()
            .map(|p| p + self.noise * normal(rng) as f32)
            .collect();
        (x, label)
    }

    /// A (batch, dim) tensor payload plus labels.
    pub fn batch(&self, rng: &mut Rng, batch: usize) -> (Payload, Vec<usize>) {
        let mut data = Vec::with_capacity(batch * self.dim);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let (x, y) = self.sample(rng);
            data.extend(x);
            labels.push(y);
        }
        (Payload::tensor(&[batch, self.dim], data), labels)
    }

    /// One-hot labels as a (batch, classes) tensor payload.
    pub fn one_hot(&self, labels: &[usize]) -> Payload {
        let mut data = vec![0.0f32; labels.len() * self.classes];
        for (i, &l) in labels.iter().enumerate() {
            data[i * self.classes + l] = 1.0;
        }
        Payload::tensor(&[labels.len(), self.classes], data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng;

    #[test]
    fn normal_has_sane_moments() {
        let mut r = rng(7);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| normal(&mut r)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = rng(8);
        let mean = SimDuration::millis(10);
        let n = 20_000;
        let total: u64 = (0..n).map(|_| exponential(&mut r, mean).as_micros()).sum();
        let got = total as f64 / n as f64;
        assert!((got - 10_000.0).abs() < 500.0, "mean {got}us");
    }

    #[test]
    fn sensor_stream_is_monotone_and_seeded() {
        let mut r1 = rng(42);
        let mut r2 = rng(42);
        let mut s1 = SensorStream::new("wind", SimDuration::millis(100), 3, 0.0);
        let mut s2 = SensorStream::new("wind", SimDuration::millis(100), 3, 0.0);
        let a1 = s1.arrivals_until(&mut r1, SimTime::secs(2));
        let a2 = s2.arrivals_until(&mut r2, SimTime::secs(2));
        assert_eq!(a1.len(), a2.len());
        assert!(!a1.is_empty());
        assert!(a1.windows(2).all(|w| w[0].0 <= w[1].0));
        assert_eq!(a1[0].1, a2[0].1, "determinism");
    }

    #[test]
    fn vehicle_trace_shape_and_order() {
        let mut r = rng(1);
        let trace = VehicleTrace { n_vehicles: 2, chunks_per_vehicle: 3, ..Default::default() };
        let chunks = trace.generate(&mut r);
        assert_eq!(chunks.len(), 6);
        assert!(chunks.windows(2).all(|w| w[0].time <= w[1].time));
        let (shape, data) = chunks[0].payload.as_tensor().unwrap();
        assert_eq!(shape, &[trace.chunk_rows, trace.dims]);
        assert_eq!(data.len(), trace.chunk_rows * trace.dims);
        assert_eq!(trace.raw_bytes(), (2 * 3 * trace.chunk_rows * trace.dims * 4) as u64);
    }

    #[test]
    fn build_tree_payload_changes_with_generation_only() {
        let t = BuildTree::default();
        let a = t.source_payload(3, 0);
        let b = t.source_payload(3, 0);
        let c = t.source_payload(3, 1);
        assert_eq!(a.content_hash(), b.content_hash());
        assert_ne!(a.content_hash(), c.content_hash());
    }

    #[test]
    fn dirty_set_is_bounded_and_sorted() {
        let t = BuildTree { leaves: 10, ..Default::default() };
        let mut r = rng(3);
        let d = t.dirty_set(&mut r, 4);
        assert_eq!(d.len(), 4);
        assert!(d.windows(2).all(|w| w[0] < w[1]));
        let all = t.dirty_set(&mut r, 99);
        assert_eq!(all.len(), 10);
    }

    #[test]
    fn image_stream_batches() {
        let mut r = rng(5);
        let s = ImageStream::new(&mut r, 4, 16, 0.1);
        let (p, labels) = s.batch(&mut r, 8);
        let (shape, _) = p.as_tensor().unwrap();
        assert_eq!(shape, &[8, 16]);
        assert_eq!(labels.len(), 8);
        let oh = s.one_hot(&labels);
        let (sh, data) = oh.as_tensor().unwrap();
        assert_eq!(sh, &[8, 4]);
        assert_eq!(data.iter().sum::<f32>(), 8.0);
    }
}
