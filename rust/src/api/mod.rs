//! The handle-based client API — the documented entry point.
//!
//! The paper's "serverless experience" (§III) means users talk to a
//! *pipeline*, not to its plumbing: no Kubernetes, no topics, no storage
//! tiers — and, this module adds, no stringly-typed re-resolution on every
//! call either. A [`Pipeline`] wraps a deployed
//! [`Coordinator`](crate::coordinator::Coordinator) and hands back three
//! kinds of typed, pre-resolved handles:
//!
//!  * [`SourceHandle`] — an external in-tray wire (nobody produces it).
//!    The only handle that can [`inject`](SourceHandle::inject),
//!    [`inject_batch`](SourceHandle::inject_batch) and
//!    [`inject_ghost`](SourceHandle::inject_ghost).
//!  * [`SinkHandle`] — a pipeline output wire (nobody consumes it). The
//!    only handle that can [`read`](SinkHandle::read),
//!    [`count`](SinkHandle::count), [`drain`](SinkHandle::drain) and
//!    [`demand`](SinkHandle::demand).
//!  * [`TaskHandle`] — a task agent: [`plug`](TaskHandle::plug),
//!    [`hot_swap`](TaskHandle::hot_swap), [`fire`](TaskHandle::fire) and
//!    the provenance queries.
//!
//! Each handle carries its dense interned [`WireId`]/[`TaskId`], so
//! steady-state calls ride PR 2's id-routed fast path by construction —
//! no name hashing, and no `Result` for resolution failures that can no
//! longer happen (resolution happened once, at [`Pipeline::source`] &
//! friends, where unknown names fail with near-miss candidates).
//!
//! Handles are `Copy` tokens bound to the deployment that minted them; a
//! handle used against a different `Pipeline` panics with a clear message
//! rather than silently aliasing another pipeline's dense state.
//!
//! Pipelines are wired either from fig. 5 spec text
//! ([`spec::parse`](crate::spec::parse)) or programmatically with
//! [`PipelineBuilder`] — both lower to the same validated
//! [`PipelineSpec`], a property the test suite checks.
//!
//! ```text
//! let mut pipe = PipelineBuilder::new("vision")
//!     .task("detect").reads("frames[3]").emits("alerts")
//!     .deploy(DeployConfig::default())?;
//! let frames = pipe.source("frames")?;   // resolve once…
//! let alerts = pipe.sink("alerts")?;
//! frames.inject_batch(&mut pipe, &batch, DataClass::Raw); // …route on ids forever
//! pipe.run_until_idle();
//! println!("{} alerts", alerts.count(&pipe));
//! ```

pub mod builder;

pub use builder::{PipelineBuilder, TaskBuilder};

use crate::av::{AnnotatedValue, DataClass, Payload};
use crate::coordinator::{Collected, Coordinator, DeployConfig};
use crate::fault::{DeadLetter, FirePolicy};
use crate::ingest::Feed;
use crate::provenance::{CheckpointEntry, ProvenanceQuery};
use crate::spec::PipelineSpec;
use crate::task::TaskCode;
use crate::util::{suggest, AvId, ObjectId, RegionId, SimTime, TaskId, WireId};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic deployment tokens: every `Pipeline` gets a fresh one, and
/// every handle carries its pipeline's, so cross-pipeline handle misuse is
/// caught instead of silently indexing another deployment's dense state.
static NEXT_TOKEN: AtomicU64 = AtomicU64::new(1);

/// A deployed pipeline plus its typed entry points. Derefs to
/// [`Coordinator`], so the full platform surface (run control, metrics,
/// provenance registry, the string-keyed compatibility wrappers) remains
/// reachable; the handles are the steady-state API.
pub struct Pipeline {
    coord: Coordinator,
    spec: PipelineSpec,
    cfg: DeployConfig,
    token: u64,
    sources: Vec<SourceHandle>,
    sinks: Vec<SinkHandle>,
    tasks: Vec<TaskHandle>,
    /// Feeds opened through this pipeline (builder-declared or
    /// [`Pipeline::open_feed`]), lookup order = registration order.
    feeds: Vec<FeedHandle>,
}

/// The streaming counterpart of [`SourceHandle`]: a cloneable,
/// thread-safe handle onto one external wire's bounded ingest queue.
/// Unlike the `Copy` handles it is *detached* — producer threads push
/// through it without touching the `Pipeline` — so it is simply the
/// [`crate::ingest::Feed`] under its API-layer name.
pub type FeedHandle = Feed;

impl std::ops::Deref for Pipeline {
    type Target = Coordinator;
    fn deref(&self) -> &Coordinator {
        &self.coord
    }
}

impl std::ops::DerefMut for Pipeline {
    fn deref_mut(&mut self) -> &mut Coordinator {
        &mut self.coord
    }
}

impl Pipeline {
    /// Deploy a validated spec and mint handles for every source wire,
    /// sink wire and task.
    pub fn deploy(spec: &PipelineSpec, cfg: DeployConfig) -> Result<Self> {
        let coord = Coordinator::deploy(spec, cfg.clone())?;
        Self::attach(coord, spec.clone(), cfg)
    }

    /// Wrap an already-deployed coordinator. `spec` must be the spec the
    /// coordinator was deployed from (its wires/tasks are resolved against
    /// the coordinator's intern tables here, once).
    pub fn attach(coord: Coordinator, spec: PipelineSpec, cfg: DeployConfig) -> Result<Self> {
        let token = NEXT_TOKEN.fetch_add(1, Ordering::Relaxed);
        let resolve = |wire: &str| -> Result<WireId> {
            coord.graph.wires.id(wire).ok_or_else(|| {
                anyhow!("spec/coordinator mismatch: wire '{wire}' is not in the deployed wire table")
            })
        };
        let mut sources = Vec::new();
        for w in spec.external_wires() {
            sources.push(SourceHandle { token, wire: resolve(&w)? });
        }
        let mut sinks = Vec::new();
        for w in spec.sink_wires() {
            sinks.push(SinkHandle { token, wire: resolve(&w)? });
        }
        let tasks = (0..coord.graph.n_tasks())
            .map(|i| TaskHandle { token, task: TaskId::new(i as u64) })
            .collect();
        Ok(Self { coord, spec, cfg, token, sources, sinks, tasks, feeds: Vec::new() })
    }

    // ------------------------------------------------------------------
    // Streaming feeds (the live front door; see crate::ingest)
    // ------------------------------------------------------------------

    /// Open a streaming [`FeedHandle`] onto an external wire with the
    /// default queue capacity. The handle is cloneable and thread-safe;
    /// producer threads push timestamped events through it concurrently
    /// with execution, then `pump_ingest` (via `Deref` to
    /// [`Coordinator`]) moves them into the pipeline under watermark
    /// gating. Fails like [`Pipeline::source`] on non-source wires.
    pub fn open_feed(&mut self, wire: &str) -> Result<FeedHandle> {
        self.open_feed_with(wire, crate::ingest::DEFAULT_FEED_CAPACITY)
    }

    /// [`open_feed`](Self::open_feed) with an explicit bounded-queue
    /// capacity — the credit window producers get before `push` blocks.
    pub fn open_feed_with(&mut self, wire: &str, capacity: usize) -> Result<FeedHandle> {
        let src = self.source(wire)?; // source-wire validation + near-miss errors
        let feed = self.coord.open_feed_id(src.wire_id(), capacity)?;
        self.feeds.push(feed.clone());
        Ok(feed)
    }

    /// A clone of an already-opened feed (builder-declared via
    /// `source_feed`, or a prior [`Pipeline::open_feed`]).
    pub fn feed(&self, wire: &str) -> Result<FeedHandle> {
        self.feeds.iter().find(|f| f.wire_name() == wire).cloned().ok_or_else(|| {
            anyhow!(
                "no open feed on wire '{wire}' in pipeline [{}]{}",
                self.spec.name,
                suggest(wire, "feed", self.feeds.iter().map(|f| f.wire_name()))
            )
        })
    }

    /// Every feed opened through this pipeline, registration order.
    pub fn feeds(&self) -> &[FeedHandle] {
        &self.feeds
    }

    /// The wiring this pipeline was deployed from.
    pub fn spec(&self) -> &PipelineSpec {
        &self.spec
    }

    /// The deploy-time configuration (forensic replay redeploys from it).
    pub fn config(&self) -> &DeployConfig {
        &self.cfg
    }

    /// Unwrap back to the bare coordinator.
    pub fn into_inner(self) -> Coordinator {
        self.coord
    }

    // ------------------------------------------------------------------
    // Handle resolution — the one place names are looked up
    // ------------------------------------------------------------------

    /// Resolve a source (external in-tray) wire. Fails with near-miss
    /// candidates for unknown names, and explains when the wire exists
    /// but is task-produced (so injection is illegal on it).
    pub fn source(&self, wire: &str) -> Result<SourceHandle> {
        if let Some(w) = self.coord.graph.wires.id(wire) {
            if let Some(h) = self.sources.iter().find(|s| s.wire == w) {
                return Ok(*h);
            }
            let producers: Vec<&str> = self
                .coord
                .graph
                .wires
                .producers(w)
                .iter()
                .map(|t| self.coord.graph.task(*t).name.as_str())
                .collect();
            if !producers.is_empty() {
                return Err(anyhow!(
                    "wire '{wire}' is produced by task(s) {} — not an external in-tray; \
                     inject upstream of it instead",
                    producers.join(", ")
                ));
            }
        }
        Err(anyhow!(
            "no source wire '{wire}' in pipeline [{}]{}",
            self.spec.name,
            suggest(wire, "source wire", self.sources.iter().map(|h| self.wire_name(h.wire)))
        ))
    }

    /// Resolve a sink (pipeline output) wire. Fails with near-miss
    /// candidates, and explains when the wire exists but has consumers
    /// (so it never collects — probe it with a breadboard tap instead).
    pub fn sink(&self, wire: &str) -> Result<SinkHandle> {
        if let Some(w) = self.coord.graph.wires.id(wire) {
            if let Some(h) = self.sinks.iter().find(|s| s.wire == w) {
                return Ok(*h);
            }
            return Err(anyhow!(
                "wire '{wire}' is consumed inside pipeline [{}] — not a sink; \
                 probe it with a breadboard tap instead",
                self.spec.name
            ));
        }
        Err(anyhow!(
            "no sink wire '{wire}' in pipeline [{}]{}",
            self.spec.name,
            suggest(wire, "sink wire", self.sinks.iter().map(|h| self.wire_name(h.wire)))
        ))
    }

    /// Resolve a task by name; unknown names list near-miss candidates.
    pub fn task(&self, name: &str) -> Result<TaskHandle> {
        match self.coord.graph.task_id(name) {
            Some(id) => Ok(self.tasks[id.index()]),
            None => Err(anyhow!(
                "no task '{name}' in pipeline [{}]{}",
                self.spec.name,
                suggest(name, "task", self.coord.graph.tasks.iter().map(|t| t.name.as_str()))
            )),
        }
    }

    /// Every external in-tray, in spec order.
    pub fn sources(&self) -> &[SourceHandle] {
        &self.sources
    }

    /// Every pipeline output, in spec order.
    pub fn sinks(&self) -> &[SinkHandle] {
        &self.sinks
    }

    /// Every task, in spec order (index = dense [`TaskId`]).
    pub fn tasks(&self) -> &[TaskHandle] {
        &self.tasks
    }

    fn wire_name(&self, wire: WireId) -> &str {
        self.coord.graph.wires.name(wire)
    }

    /// Crate-internal guard for sibling modules (e.g. breadboard session
    /// verbs) that index on a handle's raw id: panics unless `task` was
    /// minted by this deployment, like every handle method does.
    #[track_caller]
    pub(crate) fn check_task(&self, task: TaskHandle) {
        self.check(task.token);
    }

    #[track_caller]
    fn check(&self, token: u64) {
        assert!(
            token == self.token,
            "handle belongs to a different Pipeline deployment — handles are minted \
             per deployment (pipeline [{}]) and cannot be shared across instances",
            self.spec.name
        );
    }
}

/// An external in-tray wire: the only handle that can put data into the
/// pipeline. Pre-validated at mint time — every call routes on the dense
/// [`WireId`] with no name resolution and no resolution `Result`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct SourceHandle {
    token: u64,
    wire: WireId,
}

impl SourceHandle {
    /// The interned wire id this handle routes on.
    pub fn wire_id(self) -> WireId {
        self.wire
    }

    /// The wire's spec name (cold path — display/logging only).
    pub fn name(self, pipe: &Pipeline) -> &str {
        pipe.check(self.token);
        pipe.wire_name(self.wire)
    }

    /// Inject one payload now, into the first region.
    pub fn inject(self, pipe: &mut Pipeline, payload: Payload, class: DataClass) -> AvId {
        let at = pipe.coord.plat.now;
        self.inject_at(pipe, payload, class, RegionId::new(0), at)
    }

    /// Inject one payload at `at` (≥ now) in `region`.
    pub fn inject_at(
        self,
        pipe: &mut Pipeline,
        payload: Payload,
        class: DataClass,
        region: RegionId,
        at: SimTime,
    ) -> AvId {
        pipe.check(self.token);
        pipe.coord
            .inject_at_id(self.wire, payload, class, region, at)
            .expect("source handles are pre-validated against the wire table")
    }

    /// Batched injection now, into the first region: N payloads, zero name
    /// resolutions, per-batch (not per-event) validation and heap
    /// reservation — see `Coordinator::inject_batch_at_id`.
    pub fn inject_batch(
        self,
        pipe: &mut Pipeline,
        payloads: &[Payload],
        class: DataClass,
    ) -> Vec<AvId> {
        let at = pipe.coord.plat.now;
        self.inject_batch_at(pipe, payloads, class, RegionId::new(0), at)
    }

    /// Batched injection at `at` (≥ now) in `region`.
    pub fn inject_batch_at(
        self,
        pipe: &mut Pipeline,
        payloads: &[Payload],
        class: DataClass,
        region: RegionId,
        at: SimTime,
    ) -> Vec<AvId> {
        pipe.check(self.token);
        pipe.coord
            .inject_batch_at_id(self.wire, payloads.iter().cloned(), class, region, at)
            .expect("source handles are pre-validated against the wire table")
    }

    /// Inject a ghost batch (§III-K): routes are exercised, payloads are
    /// pretend-sized, compute is skipped.
    pub fn inject_ghost(self, pipe: &mut Pipeline, pretend_bytes: u64, region: RegionId) -> AvId {
        let at = pipe.coord.plat.now;
        self.inject_at(
            pipe,
            Payload::Ghost { pretend_bytes },
            DataClass::Ghost,
            region,
            at,
        )
    }
}

/// A pipeline output wire: the only handle that can read what the
/// pipeline produced. Reads are dense [`WireId`]-indexed slices.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct SinkHandle {
    token: u64,
    wire: WireId,
}

impl SinkHandle {
    /// The interned wire id this handle routes on.
    pub fn wire_id(self) -> WireId {
        self.wire
    }

    /// The wire's spec name (cold path — display/logging only).
    pub fn name(self, pipe: &Pipeline) -> &str {
        pipe.check(self.token);
        pipe.wire_name(self.wire)
    }

    /// Everything collected on this sink so far (oldest first).
    pub fn read(self, pipe: &Pipeline) -> &[Collected] {
        pipe.check(self.token);
        pipe.coord.collected.by_id(self.wire)
    }

    /// Number of artifacts collected on this sink.
    pub fn count(self, pipe: &Pipeline) -> usize {
        self.read(pipe).len()
    }

    /// The most recent artifact, if any.
    pub fn latest(self, pipe: &Pipeline) -> Option<&Collected> {
        self.read(pipe).last()
    }

    /// Take everything collected so far, leaving the sink empty — the
    /// consuming read for long-running sessions.
    pub fn drain(self, pipe: &mut Pipeline) -> Vec<Collected> {
        pipe.check(self.token);
        pipe.coord.collected.drain_id(self.wire)
    }

    /// Make-mode pull (§III-B's first trigger case): bring this output up
    /// to date, rebuilding exactly the stale dependency suffix, and return
    /// the now-current AV. Fallible — upstream user code can fail, and an
    /// external dependency may never have been fed.
    pub fn demand(self, pipe: &mut Pipeline) -> Result<AnnotatedValue> {
        pipe.check(self.token);
        pipe.coord.demand_id(self.wire)
    }
}

/// A task agent: plug/replace code, fire sources, query provenance.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct TaskHandle {
    token: u64,
    task: TaskId,
}

impl TaskHandle {
    /// The dense task id this handle routes on.
    pub fn task_id(self) -> TaskId {
        self.task
    }

    /// The task's spec name (cold path — display/logging only).
    pub fn name(self, pipe: &Pipeline) -> &str {
        pipe.check(self.token);
        &pipe.coord.graph.task(self.task).name
    }

    /// Plug task code into this task (recorded in the agent's versioned
    /// code slot history). The handle cannot dangle, but the code's
    /// `bind` resolves its output ports here — unknown port names fail
    /// with did-you-mean candidates and leave the previous code running.
    pub fn plug(self, pipe: &mut Pipeline, code: Box<dyn TaskCode>) -> Result<()> {
        pipe.check(self.token);
        pipe.coord.set_code_id(self.task, code)
    }

    /// Run this task once with an empty snapshot (a pure source "fires").
    /// Fallible — the user code itself can error.
    pub fn fire(self, pipe: &mut Pipeline) -> Result<()> {
        pipe.check(self.token);
        pipe.coord.run_source_id(self.task)
    }

    /// Deploy new user code (§III-J software update): memo invalidation +
    /// downstream cache eviction + optional recompute of the last
    /// snapshot. Returns the eviction as (entries, bytes). For the
    /// session-recorded, dry-run-previewed variant use
    /// [`Breadboard::hot_swap`](crate::breadboard::Breadboard::hot_swap).
    pub fn hot_swap(
        self,
        pipe: &mut Pipeline,
        code: Box<dyn TaskCode>,
        recompute_last: bool,
    ) -> Result<(usize, u64)> {
        pipe.check(self.token);
        pipe.coord.software_update_id(self.task, code, recompute_last)
    }

    /// Current software version of the plugged code.
    pub fn version(self, pipe: &Pipeline) -> u32 {
        pipe.check(self.token);
        pipe.coord.agents[self.task.index()].version()
    }

    /// §III-C story 2: this task's checkpoint log, oldest first.
    pub fn checkpoint_log(self, pipe: &Pipeline) -> &[CheckpointEntry] {
        pipe.check(self.token);
        pipe.coord.plat.prov.checkpoint_log(self.task)
    }

    /// §III-J: every (time, from, to) software version change recorded
    /// for this task.
    pub fn version_changes(self, pipe: &Pipeline) -> Vec<(SimTime, u32, u32)> {
        pipe.check(self.token);
        ProvenanceQuery::new(&pipe.coord.plat.prov).version_changes(self.task)
    }

    /// §III-J staleness frontier: (stale AV count, storage objects behind
    /// them) if this task's code were replaced now.
    pub fn stale_frontier(self, pipe: &Pipeline) -> (usize, Vec<(ObjectId, u64)>) {
        pipe.check(self.token);
        pipe.coord.stale_frontier_of(self.task)
    }

    /// Declare (or replace) this task's firing supervision policy:
    /// retries with virtual-time backoff, a per-firing deadline, and the
    /// on-exhaust action (dead-letter / quarantine / degrade).
    pub fn set_fire_policy(self, pipe: &mut Pipeline, policy: FirePolicy) {
        pipe.check(self.token);
        pipe.coord.set_fire_policy_id(self.task, policy);
    }

    /// The currently declared supervision policy, if any.
    pub fn fire_policy(self, pipe: &Pipeline) -> Option<&FirePolicy> {
        pipe.check(self.token);
        pipe.coord.fire_policy_id(self.task)
    }

    /// This task's dead-letter book: every firing that exhausted its
    /// retry budget (or was dropped by an open breaker), oldest first.
    pub fn dead_letters(self, pipe: &Pipeline) -> Vec<DeadLetter> {
        pipe.check(self.token);
        pipe.coord.dead_letter_book(self.task).letters().cloned().collect()
    }

    /// Take the dead-letter book's contents, leaving it empty.
    pub fn drain_dead_letters(self, pipe: &mut Pipeline) -> Vec<DeadLetter> {
        pipe.check(self.token);
        pipe.coord.drain_dead_letters_id(self.task)
    }

    /// Whether this task's circuit breaker is open (quarantined).
    pub fn quarantined(self, pipe: &Pipeline) -> bool {
        pipe.check(self.token);
        pipe.coord.quarantined_id(self.task)
    }

    /// Replay every dead-lettered firing through the (presumably fixed)
    /// current code, with fresh retry budgets. Fails while the task is
    /// still quarantined — hot-swap a fix or reset the breaker first.
    /// Returns the number of firings redriven.
    pub fn redrive(self, pipe: &mut Pipeline) -> Result<usize> {
        pipe.check(self.token);
        pipe.coord.redrive_id(self.task)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::parse;

    fn pipe() -> Pipeline {
        let spec = parse("[h]\n(raw) work (mid)\n(mid) finish (out)\n").unwrap();
        Pipeline::deploy(&spec, DeployConfig::default()).unwrap()
    }

    #[test]
    fn handles_resolve_and_classify() {
        let p = pipe();
        assert_eq!(p.sources().len(), 1);
        assert_eq!(p.sinks().len(), 1);
        assert_eq!(p.tasks().len(), 2);
        let raw = p.source("raw").unwrap();
        assert_eq!(raw.name(&p), "raw");
        let out = p.sink("out").unwrap();
        assert_eq!(out.name(&p), "out");
        assert_eq!(p.task("work").unwrap().name(&p), "work");

        // wrong-kind resolutions explain themselves
        let e = p.source("mid").unwrap_err().to_string();
        assert!(e.contains("produced by task(s) work"), "{e}");
        let e = p.sink("mid").unwrap_err().to_string();
        assert!(e.contains("consumed inside"), "{e}");
        // unknown names get near-miss candidates
        let e = p.source("rew").unwrap_err().to_string();
        assert!(e.contains("did you mean 'raw'?"), "{e}");
        let e = p.task("wrok").unwrap_err().to_string();
        assert!(e.contains("did you mean 'work'?"), "{e}");
    }

    #[test]
    fn inject_and_read_through_handles() {
        let mut p = pipe();
        let raw = p.source("raw").unwrap();
        let out = p.sink("out").unwrap();
        let id = raw.inject(&mut p, Payload::scalar(1.0), DataClass::Summary);
        let _ = id;
        p.run_until_idle();
        assert_eq!(out.count(&p), 1);
        assert!(out.latest(&p).is_some());
        // drain empties the dense store
        let drained = out.drain(&mut p);
        assert_eq!(drained.len(), 1);
        assert_eq!(out.count(&p), 0);
    }

    #[test]
    fn batch_inject_fans_out_per_payload() {
        let mut p = pipe();
        let raw = p.source("raw").unwrap();
        let out = p.sink("out").unwrap();
        let batch: Vec<Payload> = (0..8).map(|i| Payload::scalar(i as f32)).collect();
        let ids = raw.inject_batch(&mut p, &batch, DataClass::Summary);
        assert_eq!(ids.len(), 8);
        p.run_until_idle();
        assert_eq!(out.count(&p), 8, "every batched payload traversed the pipeline");
        // the forensic ledger recorded each payload individually
        assert_eq!(p.plat.prov.injections().len(), 8);
    }

    #[test]
    #[should_panic(expected = "different Pipeline deployment")]
    fn cross_pipeline_handles_panic() {
        let p1 = pipe();
        let mut p2 = pipe();
        let alien = p1.source("raw").unwrap();
        alien.inject(&mut p2, Payload::scalar(1.0), DataClass::Summary);
    }

    #[test]
    fn task_handle_verbs() {
        let mut p = pipe();
        let work = p.task("work").unwrap();
        assert_eq!(work.version(&p), 1);
        work.plug(
            &mut p,
            Box::new(crate::task::builtins::PassThrough::new("mid")),
        )
        .unwrap();
        // bind failures surface at plug time, with suggestions
        let e = work
            .plug(&mut p, Box::new(crate::task::builtins::PassThrough::new("mdi")))
            .unwrap_err()
            .to_string();
        assert!(e.contains("did you mean 'mid'?"), "{e}");
        let (evicted, _bytes) = work
            .hot_swap(
                &mut p,
                Box::new(crate::task::builtins::FnTask::versioned(
                    |_ctx: &mut crate::task::TaskCtx<'_>, _s: &crate::policy::Snapshot| Ok(vec![]),
                    2,
                )),
                false,
            )
            .unwrap();
        let _ = evicted;
        assert_eq!(work.version(&p), 2);
        assert_eq!(work.version_changes(&p).len(), 1);
    }
}
