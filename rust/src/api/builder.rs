//! Programmatic pipeline construction — the fig. 5 language without the
//! text.
//!
//! Koji-style result-oriented wirings (PAPERS.md) are often *generated* —
//! a build tree, a per-region fan-out, a parameter sweep — and generating
//! spec text only to re-parse it is both clumsy and a second grammar to
//! get wrong. [`PipelineBuilder`] constructs the same [`PipelineSpec`] the
//! parser produces, sharing the parser's port-token grammar
//! ([`parse_input_token`]) and name rule ([`valid_name`]) so the two front
//! ends are equivalent by construction (and property-tested to stay so:
//! `rust/tests/api_handles.rs`).
//!
//! The fluent chain defers errors: malformed ports/names accumulate and
//! surface together at the lowering step ([`build`](PipelineBuilder::build)
//! / [`deploy`](PipelineBuilder::deploy)), which also runs
//! [`PipelineSpec::validate`] — exactly the checks a parsed spec gets.
//!
//! ```text
//! let mut pipe = PipelineBuilder::new("vision")
//!     .task("detect").reads("frames[3]").emits("alerts").policy("swap")
//!     .task("render").reads("alerts").emits("overlay")
//!     .deploy(DeployConfig::default())?;
//! ```

use super::Pipeline;
use crate::coordinator::DeployConfig;
use crate::policy::BufferSpec;
use crate::spec::{parse_input_token, valid_name, InputSpec, PipelineSpec, TaskSpec};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Fluent constructor for a [`PipelineSpec`]. Start tasks with
/// [`task`](PipelineBuilder::task); finish with
/// [`build`](PipelineBuilder::build) (a validated spec) or
/// [`deploy`](PipelineBuilder::deploy) (a running [`Pipeline`]).
///
/// Deliberately no `Default`: construction goes through
/// [`PipelineBuilder::new`], whose name check is part of the
/// builder/parser equivalence contract (the parser rejects `[]` too).
#[derive(Clone, Debug)]
pub struct PipelineBuilder {
    name: String,
    tasks: Vec<TaskSpec>,
    /// Deferred construction errors, reported together at lowering.
    errors: Vec<String>,
    /// Deploy-time override of [`DeployConfig::workers`] (wavefront
    /// worker-pool width); `None` = whatever the passed config says.
    workers: Option<usize>,
    /// Deploy-time override of [`DeployConfig::trace`] (flight recorder +
    /// metrics); `None` = whatever the passed config says.
    trace: Option<bool>,
    /// Deploy-time override of [`DeployConfig::reorder_window`]
    /// (pipelined multi-instant scheduling depth); `None` = config (and
    /// its `KOALJA_REORDER_WINDOW` ambient default) wins.
    reorder_window: Option<usize>,
    /// Deploy-time override of the simulated node count
    /// ([`DeployConfig::placement`]`.nodes`); `None` = config (and its
    /// `KOALJA_NODES` ambient default) wins.
    nodes: Option<usize>,
    /// Deploy-time region pins (task name → region name), merged over
    /// [`DeployConfig::placement`]`.regions` at deploy. This is where
    /// [`Placement::optimize`](crate::shard::Placement::optimize) output
    /// lands when driven through the builder.
    pins: BTreeMap<String, String>,
    /// Streaming feeds to pre-open at deploy: (source wire, queue
    /// capacity). Declared order = watermark-clock registration order.
    feeds: Vec<(String, usize)>,
}

impl PipelineBuilder {
    pub fn new(name: &str) -> Self {
        let mut b = Self {
            name: name.to_string(),
            tasks: Vec::new(),
            errors: Vec::new(),
            workers: None,
            trace: None,
            reorder_window: None,
            nodes: None,
            pins: BTreeMap::new(),
            feeds: Vec::new(),
        };
        if !valid_name(name) {
            b.errors.push(format!("bad pipeline name '{name}'"));
        }
        b
    }

    /// Set the wavefront worker-pool width the deployment runs with
    /// (`1` = fully sequential; results are byte-identical either way —
    /// see DESIGN.md §Perf notes). A deploy-time knob, not part of the
    /// wiring: `build()`'s spec is unaffected.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = Some(n.max(1));
        self
    }

    /// Turn the observability layer on (or off) for the deployment: the
    /// flight recorder and id-indexed metrics behind
    /// [`Coordinator::obs`](crate::coordinator::Coordinator::obs). A
    /// deploy-time knob like [`workers`](PipelineBuilder::workers):
    /// `build()`'s spec is unaffected.
    pub fn trace(mut self, on: bool) -> Self {
        self.trace = Some(on);
        self
    }

    /// Set the pipelined-scheduling window the deployment runs with: how
    /// many virtual instants may execute concurrently before retiring
    /// (see [`DeployConfig::reorder_window`] and DESIGN.md §Execution
    /// model). `1` restores the strict per-instant barrier; `0` = auto
    /// (the worker-pool width). Results are byte-identical for every
    /// value — commits always retire in `(instant, task-index)` order. A
    /// deploy-time knob: `build()`'s spec is unaffected.
    pub fn reorder_window(mut self, n: usize) -> Self {
        self.reorder_window = Some(n);
        self
    }

    /// Run the deployment partitioned across `n` simulated nodes (the
    /// sharded runtime, [`crate::shard`]). Purely operational: any node
    /// count commits byte-identical books; cross-node wires ride the
    /// inter-node exchange. A deploy-time knob — `build()`'s spec is
    /// unaffected.
    pub fn nodes(mut self, n: usize) -> Self {
        self.nodes = Some(n.max(1));
        self
    }

    /// Pin `task` to `region` at deploy. Semantically identical to a
    /// `@region=…` attr except it loses to one (spec text stays the
    /// source of truth) and wins over the nearest-datacentre default.
    /// Unknown task names fail at deploy; unknown regions fail inside
    /// `Coordinator::deploy` with the region named.
    pub fn place_at(mut self, task: &str, region: &str) -> Self {
        self.pins.insert(task.to_string(), region.to_string());
        self
    }

    /// Declare a streaming feed on a source wire: deploy pre-opens a
    /// bounded ingest queue there (default capacity) and registers it
    /// with the watermark clock, so the running [`Pipeline`] hands out
    /// the [`FeedHandle`](super::FeedHandle) via
    /// [`Pipeline::feed`](super::Pipeline::feed). The wire must be an
    /// external in-tray — produced wires fail at deploy with the same
    /// diagnostics as [`Pipeline::source`](super::Pipeline::source). A
    /// deploy-time knob: `build()`'s spec is unaffected.
    pub fn source_feed(self, wire: &str) -> Self {
        self.source_feed_with(wire, crate::ingest::DEFAULT_FEED_CAPACITY)
    }

    /// [`source_feed`](PipelineBuilder::source_feed) with an explicit
    /// bounded-queue capacity (the producer credit window).
    pub fn source_feed_with(mut self, wire: &str, capacity: usize) -> Self {
        self.feeds.push((wire.to_string(), capacity));
        self
    }

    /// Open a task; wire its ports on the returned [`TaskBuilder`].
    pub fn task(self, name: &str) -> TaskBuilder {
        let mut pb = self;
        if !valid_name(name) {
            pb.errors.push(format!("bad task name '{name}'"));
        }
        TaskBuilder {
            pb,
            task: TaskSpec {
                name: name.to_string(),
                inputs: Vec::new(),
                outputs: Vec::new(),
                attrs: BTreeMap::new(),
            },
        }
    }

    /// Lower to the spec without validating — the escape hatch for tests
    /// that want to inspect (or deliberately break) structure.
    pub fn into_spec_unchecked(self) -> PipelineSpec {
        PipelineSpec { name: self.name, tasks: self.tasks }
    }

    /// Lower to a validated [`PipelineSpec`]: deferred construction errors
    /// first, then the same [`PipelineSpec::validate`] a parsed spec gets.
    pub fn build(self) -> Result<PipelineSpec> {
        if !self.errors.is_empty() {
            return Err(anyhow!(
                "pipeline builder [{}]: {}",
                self.name,
                self.errors.join("; ")
            ));
        }
        let spec = PipelineSpec { name: self.name, tasks: self.tasks };
        spec.validate().map_err(|e| anyhow!("invalid spec [{}]: {e}", spec.name))?;
        Ok(spec)
    }

    /// Build, validate and deploy in one step.
    pub fn deploy(mut self, mut cfg: DeployConfig) -> Result<Pipeline> {
        if let Some(w) = self.workers {
            cfg.workers = w;
        }
        if let Some(t) = self.trace {
            cfg.trace = t;
        }
        if let Some(w) = self.reorder_window {
            cfg.reorder_window = w;
        }
        if let Some(n) = self.nodes {
            cfg.placement.nodes = n;
        }
        let pins = std::mem::take(&mut self.pins);
        let feeds = std::mem::take(&mut self.feeds);
        let spec = self.build()?;
        for (task, region) in pins {
            if !spec.tasks.iter().any(|t| t.name == task) {
                return Err(anyhow!(
                    "place_at: no task '{task}' in pipeline [{}]",
                    spec.name
                ));
            }
            cfg.placement.regions.insert(task, region);
        }
        let mut pipe = Pipeline::deploy(&spec, cfg)?;
        for (wire, capacity) in feeds {
            pipe.open_feed_with(&wire, capacity)
                .map_err(|e| anyhow!("source_feed: {e}"))?;
        }
        Ok(pipe)
    }
}

/// One task under construction. Every method returns `self`, so ports and
/// attributes chain; opening the next [`task`](TaskBuilder::task) (or
/// lowering) seals this one.
#[derive(Clone, Debug)]
pub struct TaskBuilder {
    pb: PipelineBuilder,
    task: TaskSpec,
}

impl TaskBuilder {
    /// Add an input port in the parser's token grammar: `wire`,
    /// `wire[N]` (buffer), `wire[N/S]` (sliding window), with an optional
    /// `?` suffix for an implicit service lookup.
    pub fn reads(mut self, port: &str) -> Self {
        match parse_input_token(port) {
            Ok(input) => self.task.inputs.push(input),
            Err(msg) => self.pb.errors.push(format!("task '{}': {msg}", self.task.name)),
        }
        self
    }

    /// Add a buffered input port (`wire[n]`) without going through the
    /// token grammar.
    pub fn reads_buffered(mut self, wire: &str, n: usize) -> Self {
        if !valid_name(wire) {
            self.pb.errors.push(format!("task '{}': bad wire name '{wire}'", self.task.name));
            return self;
        }
        self.task.inputs.push(InputSpec {
            wire: wire.to_string(),
            buffer: BufferSpec::buffer(n),
            service: false,
        });
        self
    }

    /// Add a sliding-window input port (`wire[n/slide]`, §III-I).
    pub fn reads_window(mut self, wire: &str, n: usize, slide: usize) -> Self {
        if !valid_name(wire) {
            self.pb.errors.push(format!("task '{}': bad wire name '{wire}'", self.task.name));
            return self;
        }
        if slide > n || slide == 0 || n == 0 {
            self.pb
                .errors
                .push(format!("task '{}': bad window [{n}/{slide}]", self.task.name));
            return self;
        }
        self.task.inputs.push(InputSpec {
            wire: wire.to_string(),
            buffer: BufferSpec::window(n, slide),
            service: false,
        });
        self
    }

    /// Add an implicit service-lookup input (`name?`, §III-D) — an
    /// out-of-band client-server call recorded for forensics, not a
    /// stream wire.
    pub fn looks_up(mut self, service: &str) -> Self {
        if !valid_name(service) {
            self.pb
                .errors
                .push(format!("task '{}': bad service name '{service}'", self.task.name));
            return self;
        }
        self.task.inputs.push(InputSpec {
            wire: service.to_string(),
            buffer: BufferSpec::default(),
            service: true,
        });
        self
    }

    /// Add an output wire.
    pub fn emits(mut self, wire: &str) -> Self {
        if !valid_name(wire) {
            self.pb.errors.push(format!("task '{}': bad wire name '{wire}'", self.task.name));
            return self;
        }
        self.task.outputs.push(wire.to_string());
        self
    }

    /// Set a raw `@key=value` attribute.
    pub fn attr(mut self, key: &str, value: &str) -> Self {
        self.task.attrs.insert(key.to_string(), value.to_string());
        self
    }

    /// Sugar for `@policy=…` (allnew / swap / merge).
    pub fn policy(self, policy: &str) -> Self {
        self.attr("policy", policy)
    }

    /// Sugar for `@region=…` (placement, §IV).
    pub fn region(self, region: &str) -> Self {
        self.attr("region", region)
    }

    /// Sugar for `@notify=…` (`push` or `poll:Nms`, Principle 1).
    pub fn notify(self, notify: &str) -> Self {
        self.attr("notify", notify)
    }

    /// Set the deployment's wavefront worker-pool width mid-chain (see
    /// [`PipelineBuilder::workers`]).
    pub fn workers(mut self, n: usize) -> Self {
        self.pb.workers = Some(n.max(1));
        self
    }

    /// Turn the observability layer on (or off) mid-chain (see
    /// [`PipelineBuilder::trace`]).
    pub fn trace(mut self, on: bool) -> Self {
        self.pb.trace = Some(on);
        self
    }

    /// Set the pipelined-scheduling window mid-chain (see
    /// [`PipelineBuilder::reorder_window`]).
    pub fn reorder_window(mut self, n: usize) -> Self {
        self.pb.reorder_window = Some(n);
        self
    }

    /// Set the simulated node count mid-chain (see
    /// [`PipelineBuilder::nodes`]).
    pub fn nodes(mut self, n: usize) -> Self {
        self.pb.nodes = Some(n.max(1));
        self
    }

    /// Pin a task to a region mid-chain (see
    /// [`PipelineBuilder::place_at`]).
    pub fn place_at(mut self, task: &str, region: &str) -> Self {
        self.pb.pins.insert(task.to_string(), region.to_string());
        self
    }

    /// Declare a streaming feed mid-chain (see
    /// [`PipelineBuilder::source_feed`]).
    pub fn source_feed(mut self, wire: &str) -> Self {
        self.pb.feeds.push((wire.to_string(), crate::ingest::DEFAULT_FEED_CAPACITY));
        self
    }

    /// Declare a streaming feed with explicit capacity mid-chain (see
    /// [`PipelineBuilder::source_feed_with`]).
    pub fn source_feed_with(mut self, wire: &str, capacity: usize) -> Self {
        self.pb.feeds.push((wire.to_string(), capacity));
        self
    }

    /// Seal this task and return to the pipeline level (for loops that
    /// add tasks programmatically).
    pub fn done(self) -> PipelineBuilder {
        let mut pb = self.pb;
        pb.tasks.push(self.task);
        pb
    }

    /// Seal this task and open the next.
    pub fn task(self, name: &str) -> TaskBuilder {
        self.done().task(name)
    }

    /// Seal this task and lower to a validated [`PipelineSpec`].
    pub fn build(self) -> Result<PipelineSpec> {
        self.done().build()
    }

    /// Seal this task, then build, validate and deploy.
    pub fn deploy(self, cfg: DeployConfig) -> Result<Pipeline> {
        self.done().deploy(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::parse;

    #[test]
    fn builder_matches_parser_on_the_fig5_wiring() {
        let built = PipelineBuilder::new("tfmodel")
            .task("learn-tf").reads("in").emits("model")
            .task("convert").reads("in[10/2]").emits("json")
            .task("predict").reads("json").looks_up("lookup").emits("result")
            .build()
            .unwrap();
        let parsed = parse(
            "[tfmodel]\n\
             (in) learn-tf (model)\n\
             (in[10/2]) convert (json)\n\
             (json, lookup?) predict (result)\n",
        )
        .unwrap();
        assert_eq!(built, parsed, "builder and parser lower to the same spec");
    }

    #[test]
    fn sugar_methods_equal_token_grammar() {
        let a = PipelineBuilder::new("p")
            .task("t").reads("w[4]").reads("v[10/2]").reads("s?").emits("o")
            .build()
            .unwrap();
        let b = PipelineBuilder::new("p")
            .task("t")
            .reads_buffered("w", 4)
            .reads_window("v", 10, 2)
            .looks_up("s")
            .emits("o")
            .build()
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn attrs_round_trip_through_text() {
        let built = PipelineBuilder::new("p")
            .task("t").reads("a").emits("b").policy("swap").region("edge-0").notify("poll:50ms")
            .build()
            .unwrap();
        let reparsed = parse(&built.to_text()).unwrap();
        assert_eq!(built, reparsed);
    }

    #[test]
    fn deferred_errors_surface_at_build() {
        let e = PipelineBuilder::new("p")
            .task("t").reads("a[").emits("b")
            .build()
            .unwrap_err()
            .to_string();
        assert!(e.contains("task 't'"), "{e}");
        assert!(e.contains("unterminated"), "{e}");

        let e = PipelineBuilder::new("p")
            .task("bad name").reads("a").emits("b")
            .build()
            .unwrap_err()
            .to_string();
        assert!(e.contains("bad task name"), "{e}");

        // window violations are caught both at the port grammar…
        assert!(PipelineBuilder::new("p").task("t").reads("a[3/9]").emits("b").build().is_err());
        // …and by the shared spec validation for the typed variant
        assert!(PipelineBuilder::new("p")
            .task("t")
            .reads_window("a", 3, 9)
            .emits("b")
            .build()
            .is_err());
    }

    #[test]
    fn trace_knob_reaches_the_deployment() {
        let pipe = PipelineBuilder::new("p")
            .task("t").reads("a").emits("b")
            .trace(true)
            .deploy(DeployConfig { trace: false, ..Default::default() })
            .unwrap();
        assert!(pipe.obs().enabled, "builder trace(true) overrides the config");

        let pipe = PipelineBuilder::new("p")
            .task("t").reads("a").emits("b")
            .deploy(DeployConfig { trace: false, ..Default::default() })
            .unwrap();
        assert!(!pipe.obs().enabled, "no override: config wins");
    }

    #[test]
    fn nodes_and_pins_reach_the_deployment() {
        let pipe = PipelineBuilder::new("p")
            .task("t").reads("a").emits("b")
            .task("u").reads("b").emits("c")
            .nodes(2)
            .place_at("t", "edge-0")
            .deploy(DeployConfig::default())
            .unwrap();
        assert_eq!(pipe.shard().nodes, 2);
        let edge0 = pipe.plat.net.by_name("edge-0").unwrap();
        let t = pipe.task("t").unwrap().task_id();
        let u = pipe.task("u").unwrap().task_id();
        assert_eq!(pipe.agents[t.index()].region, edge0, "place_at pins the region");
        assert_ne!(pipe.agents[u.index()].region, edge0, "unpinned task keeps the default");
        // the two regions rank onto different nodes, so the b wire crosses
        assert!(pipe.shard().is_cross(t, u));

        // an @region attr in the wiring beats a builder pin
        let pipe = PipelineBuilder::new("p")
            .task("t").reads("a").emits("b").region("central")
            .place_at("t", "edge-0")
            .deploy(DeployConfig::default())
            .unwrap();
        let central = pipe.plat.net.by_name("central").unwrap();
        assert_eq!(pipe.agents[0].region, central);

        // unknown pinned task fails at deploy, before the coordinator
        let e = PipelineBuilder::new("p")
            .task("t").reads("a").emits("b")
            .place_at("ghost", "central")
            .deploy(DeployConfig::default())
            .unwrap_err()
            .to_string();
        assert!(e.contains("no task 'ghost'"), "{e}");
    }

    #[test]
    fn source_feed_reaches_the_deployment() {
        let pipe = PipelineBuilder::new("p")
            .task("t").reads("a").emits("b")
            .source_feed_with("a", 64)
            .deploy(DeployConfig::default())
            .unwrap();
        let feed = pipe.feed("a").unwrap();
        assert_eq!(feed.wire_name(), "a");
        assert_eq!(feed.capacity(), 64);
        assert_eq!(pipe.feeds().len(), 1);

        // produced wires fail at deploy with the source diagnostics
        let e = PipelineBuilder::new("p")
            .task("t").reads("a").emits("b")
            .task("u").reads("b").emits("c")
            .source_feed("b")
            .deploy(DeployConfig::default())
            .unwrap_err()
            .to_string();
        assert!(e.contains("source_feed"), "{e}");
        assert!(e.contains("produced by task"), "{e}");
    }

    #[test]
    fn validation_matches_parsed_specs() {
        // self-loop: rejected exactly like a parsed spec
        let e = PipelineBuilder::new("p").task("t").reads("a").emits("a").build();
        assert!(e.is_err());
        // empty pipeline rejected
        assert!(PipelineBuilder::new("p").build().is_err());
    }
}
