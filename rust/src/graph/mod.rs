//! The deployed pipeline graph: tasks and the links between their ports.
//!
//! §III-F: "The connected graph of tasks forms a sparse square matrix
//! D_ab". We materialize that sparse structure as one [`Link`] per
//! (producer-output → consumer-input) pair sharing a wire name, plus
//! injection links (`from == None`) for wires produced by nothing — the
//! file-drop/sensor in-trays at the user-facing edge.
//!
//! Cycles are legal (DCG); [`PipelineGraph::cycles`] reports them, and the
//! make-mode scheduler treats them with a visited set.

use crate::spec::{PipelineSpec, TaskSpec};
use crate::util::{LinkId, TaskId, WireId};
use std::collections::{HashMap, HashSet};

/// One wire segment between a producer port and a consumer port.
#[derive(Clone, Debug)]
pub struct Link {
    pub id: LinkId,
    /// Wire name (the label in the fig. 5 diagram).
    pub wire: String,
    /// Interned wire id (§Perf) — what the coordinator routes on.
    pub wire_id: WireId,
    /// Producing task, or None for external injection.
    pub from: Option<TaskId>,
    /// Consuming task.
    pub to: TaskId,
    /// Input-port name on the consumer (== wire in the fig. 5 language).
    pub to_input: String,
}

/// Deploy-time wire interner (§Perf): every wire name in the spec gets a
/// dense [`WireId`] so per-wire state (currency, sink captures, tap masks,
/// injection fan-out) lives in `Vec`s indexed by id instead of
/// `HashMap<String, _>`s hashed per event. Built once in
/// [`PipelineGraph::build`]; immutable afterwards.
#[derive(Clone, Debug, Default)]
pub struct WireTable {
    names: Vec<String>,
    by_name: HashMap<String, WireId>,
    /// Tasks listing the wire among their outputs (make-mode demand walks).
    producers: Vec<Vec<TaskId>>,
    /// Injection links (`from == None`) carrying the wire — the external
    /// in-tray fan-out, precomputed so `inject` never scans the link list.
    injections: Vec<Vec<LinkId>>,
}

impl WireTable {
    fn intern(&mut self, name: &str) -> WireId {
        if let Some(&id) = self.by_name.get(name) {
            return id;
        }
        let id = WireId::new(self.names.len() as u32);
        self.names.push(name.to_string());
        self.by_name.insert(name.to_string(), id);
        self.producers.push(Vec::new());
        self.injections.push(Vec::new());
        id
    }

    /// Resolve a wire name (the one string hash on any public entry path).
    pub fn id(&self, name: &str) -> Option<WireId> {
        self.by_name.get(name).copied()
    }

    pub fn name(&self, id: WireId) -> &str {
        &self.names[id.index()]
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn producers(&self, id: WireId) -> &[TaskId] {
        &self.producers[id.index()]
    }

    pub fn injections(&self, id: WireId) -> &[LinkId] {
        &self.injections[id.index()]
    }
}

/// The compiled topology.
#[derive(Clone, Debug, Default)]
pub struct PipelineGraph {
    pub name: String,
    pub tasks: Vec<TaskSpec>,
    pub links: Vec<Link>,
    /// Interned wire names + per-wire adjacency (§Perf).
    pub wires: WireTable,
    by_name: HashMap<String, TaskId>,
}

impl PipelineGraph {
    /// Build the link set from a validated spec.
    pub fn build(spec: &PipelineSpec) -> Self {
        let by_name: HashMap<String, TaskId> = spec
            .tasks
            .iter()
            .enumerate()
            .map(|(i, t)| (t.name.clone(), TaskId::new(i as u64)))
            .collect();
        // wire table: outputs then stream inputs, spec order (deterministic)
        let mut wires = WireTable::default();
        for t in &spec.tasks {
            for w in &t.outputs {
                let wid = wires.intern(w);
                let tid = by_name[&t.name];
                if !wires.producers[wid.index()].contains(&tid) {
                    wires.producers[wid.index()].push(tid);
                }
            }
        }
        for t in &spec.tasks {
            for i in t.stream_inputs() {
                wires.intern(&i.wire);
            }
        }
        // output-less tasks run the default pass-through, which publishes
        // under the "void" fallback name (coordinator deploy): intern it
        // so those publications stay on the dense first-class path
        // (currency, taps, memoization) instead of the overflow map
        if spec.tasks.iter().any(|t| t.outputs.is_empty()) {
            wires.intern("void");
        }
        let mut links = Vec::new();
        for t in &spec.tasks {
            let to = by_name[&t.name];
            for i in t.stream_inputs() {
                let wire_id = wires.id(&i.wire).expect("stream inputs are interned above");
                let producers = wires.producers(wire_id);
                if producers.is_empty() {
                    links.push(Link {
                        id: LinkId::new(links.len() as u64),
                        wire: i.wire.clone(),
                        wire_id,
                        from: None,
                        to,
                        to_input: i.wire.clone(),
                    });
                } else {
                    for &from in producers {
                        links.push(Link {
                            id: LinkId::new(links.len() as u64),
                            wire: i.wire.clone(),
                            wire_id,
                            from: Some(from),
                            to,
                            to_input: i.wire.clone(),
                        });
                    }
                }
            }
        }
        for l in &links {
            if l.from.is_none() {
                wires.injections[l.wire_id.index()].push(l.id);
            }
        }
        Self { name: spec.name.clone(), tasks: spec.tasks.clone(), links, wires, by_name }
    }

    pub fn task_id(&self, name: &str) -> Option<TaskId> {
        self.by_name.get(name).copied()
    }

    pub fn task(&self, id: TaskId) -> &TaskSpec {
        &self.tasks[id.index()]
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Links delivering into `task`.
    pub fn links_into(&self, task: TaskId) -> impl Iterator<Item = &Link> {
        self.links.iter().filter(move |l| l.to == task)
    }

    /// Links carrying `task`'s outputs.
    pub fn links_from(&self, task: TaskId) -> impl Iterator<Item = &Link> {
        self.links.iter().filter(move |l| l.from == Some(task))
    }

    /// Links fed by external injection on `wire` (precomputed per wire —
    /// no link-list scan).
    pub fn injection_links<'a>(&'a self, wire: &'a str) -> impl Iterator<Item = &'a Link> + 'a {
        const NONE: &[LinkId] = &[];
        let ids = self.wires.id(wire).map(|w| self.wires.injections(w)).unwrap_or(NONE);
        ids.iter().map(move |l| &self.links[l.index()])
    }

    /// Upstream task dependencies of `task` (producers of its inputs).
    pub fn upstream(&self, task: TaskId) -> Vec<TaskId> {
        let mut seen = HashSet::new();
        self.links_into(task)
            .filter_map(|l| l.from)
            .filter(|t| seen.insert(*t))
            .collect()
    }

    /// Downstream consumers of `task`'s outputs.
    pub fn downstream(&self, task: TaskId) -> Vec<TaskId> {
        let mut seen = HashSet::new();
        self.links_from(task).map(|l| l.to).filter(|t| seen.insert(*t)).collect()
    }

    /// All tasks reachable downstream of `task` (for version-change
    /// invalidation, §III-J).
    pub fn reachable_downstream(&self, task: TaskId) -> Vec<TaskId> {
        let mut seen = HashSet::new();
        let mut stack = vec![task];
        let mut out = Vec::new();
        while let Some(t) = stack.pop() {
            for d in self.downstream(t) {
                if seen.insert(d) {
                    out.push(d);
                    stack.push(d);
                }
            }
        }
        out
    }

    /// Topological order over the acyclic part; tasks on cycles are
    /// appended afterwards in id order (documented, deterministic).
    pub fn topo_order(&self) -> Vec<TaskId> {
        let n = self.n_tasks();
        let mut indeg = vec![0usize; n];
        for l in &self.links {
            if l.from.is_some() {
                indeg[l.to.index()] += 1;
            }
        }
        let mut queue: Vec<TaskId> =
            (0..n).filter(|&i| indeg[i] == 0).map(|i| TaskId::new(i as u64)).collect();
        let mut order = Vec::with_capacity(n);
        let mut qi = 0;
        while qi < queue.len() {
            let t = queue[qi];
            qi += 1;
            order.push(t);
            for l in self.links_from(t) {
                indeg[l.to.index()] -= 1;
                if indeg[l.to.index()] == 0 {
                    queue.push(l.to);
                }
            }
        }
        if order.len() < n {
            for i in 0..n {
                let id = TaskId::new(i as u64);
                if !order.contains(&id) {
                    order.push(id);
                }
            }
        }
        order
    }

    /// Task ids participating in at least one cycle (informational; the
    /// platform supports DCGs, §I).
    pub fn cyclic_tasks(&self) -> Vec<TaskId> {
        // iteratively strip zero-indegree nodes; what remains is cyclic
        let n = self.n_tasks();
        let mut indeg = vec![0usize; n];
        let mut alive = vec![true; n];
        for l in &self.links {
            if l.from.is_some() {
                indeg[l.to.index()] += 1;
            }
        }
        let mut changed = true;
        while changed {
            changed = false;
            for i in 0..n {
                if alive[i] && indeg[i] == 0 {
                    alive[i] = false;
                    changed = true;
                    for l in self.links_from(TaskId::new(i as u64)) {
                        indeg[l.to.index()] -= 1;
                    }
                }
            }
        }
        // also strip nodes with no alive successors (tails feeding cycles
        // are not themselves cyclic) — iterate until fixpoint.
        let mut changed = true;
        while changed {
            changed = false;
            for i in 0..n {
                if alive[i] {
                    let has_alive_succ =
                        self.downstream(TaskId::new(i as u64)).iter().any(|d| alive[d.index()]);
                    if !has_alive_succ {
                        alive[i] = false;
                        changed = true;
                    }
                }
            }
        }
        (0..n).filter(|&i| alive[i]).map(|i| TaskId::new(i as u64)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::parse;

    fn linear() -> PipelineGraph {
        PipelineGraph::build(&parse("[lin]\n(raw) a (mid)\n(mid) b (out)\n").unwrap())
    }

    #[test]
    fn builds_injection_and_internal_links() {
        let g = linear();
        assert_eq!(g.n_tasks(), 2);
        assert_eq!(g.links.len(), 2);
        let inj: Vec<_> = g.injection_links("raw").collect();
        assert_eq!(inj.len(), 1);
        assert_eq!(inj[0].to, g.task_id("a").unwrap());
        let a = g.task_id("a").unwrap();
        let b = g.task_id("b").unwrap();
        assert_eq!(g.downstream(a), vec![b]);
        assert_eq!(g.upstream(b), vec![a]);
    }

    #[test]
    fn fanout_links_one_per_consumer() {
        let g = PipelineGraph::build(
            &parse("[f]\n(raw) src (x)\n(x) c1 (y1)\n(x) c2 (y2)\n").unwrap(),
        );
        let src = g.task_id("src").unwrap();
        assert_eq!(g.links_from(src).count(), 2, "same wire to two consumers");
    }

    #[test]
    fn fanin_merges_producers() {
        let g = PipelineGraph::build(
            &parse("[m]\n(a) p1 (x)\n(b) p2 (x)\n(x) sink ()\n").unwrap(),
        );
        let sink = g.task_id("sink").unwrap();
        assert_eq!(g.links_into(sink).count(), 2, "two producers, one input port");
        assert_eq!(g.upstream(sink).len(), 2);
    }

    #[test]
    fn topo_order_respects_dependencies() {
        let g = linear();
        let order = g.topo_order();
        let pos = |n: &str| order.iter().position(|t| *t == g.task_id(n).unwrap()).unwrap();
        assert!(pos("a") < pos("b"));
    }

    #[test]
    fn cycles_detected_but_not_fatal() {
        let g = PipelineGraph::build(
            &parse("[c]\n(seed, fb) gen (x)\n(x) refine (fb, out)\n").unwrap(),
        );
        let cyclic = g.cyclic_tasks();
        assert_eq!(cyclic.len(), 2, "gen and refine form a loop");
        assert_eq!(g.topo_order().len(), 2, "topo order still total");
    }

    #[test]
    fn acyclic_graph_reports_no_cycles() {
        assert!(linear().cyclic_tasks().is_empty());
    }

    #[test]
    fn wire_table_interns_every_wire_once() {
        let g = PipelineGraph::build(
            &parse("[w]\n(raw) src (x)\n(x) c1 (y1)\n(x) c2 (y2)\n").unwrap(),
        );
        // outputs x, y1, y2 + external input raw = 4 distinct wires
        assert_eq!(g.wires.len(), 4);
        for name in ["raw", "x", "y1", "y2"] {
            let id = g.wires.id(name).unwrap();
            assert_eq!(g.wires.name(id), name, "id↔name roundtrip");
        }
        assert!(g.wires.id("nope").is_none());
        // every link carries the id its name interns to
        for l in &g.links {
            assert_eq!(g.wires.id(&l.wire), Some(l.wire_id));
        }
        // producers: src makes x; nothing makes raw (external in-tray)
        let x = g.wires.id("x").unwrap();
        assert_eq!(g.wires.producers(x), &[g.task_id("src").unwrap()]);
        let raw = g.wires.id("raw").unwrap();
        assert!(g.wires.producers(raw).is_empty());
        // injection links precomputed per wire match the scan-free iterator
        assert_eq!(g.wires.injections(raw).len(), 1);
        assert_eq!(g.injection_links("raw").count(), 1);
        assert!(g.wires.injections(x).is_empty());
    }

    #[test]
    fn reachable_downstream_is_transitive() {
        let g = PipelineGraph::build(
            &parse("[r]\n(raw) a (x)\n(x) b (y)\n(y) c (z)\n").unwrap(),
        );
        let a = g.task_id("a").unwrap();
        let mut r = g.reachable_downstream(a);
        r.sort();
        assert_eq!(r, vec![g.task_id("b").unwrap(), g.task_id("c").unwrap()]);
    }
}
