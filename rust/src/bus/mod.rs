//! Publish–subscribe data plane with a separate notification side channel —
//! §III-F.
//!
//! "In order to scale data transfers ... we look to a publish-subscribe
//! (pull) model for data handovers, with a separate side channel for
//! instant messaging." AV *metadata* is published to a per-link topic;
//! payloads stay in object storage, so forwarding the same data to
//! multiple branches replicates nothing but a pointer.
//!
//! Principle 1 decides per link whether consumers learn of arrivals by a
//! pushed notification or by sampling (polling) the topic — see
//! [`NotifyMode`].

use crate::av::AnnotatedValue;
use crate::graph::PipelineGraph;
use crate::net::{WanLink, WanTopology};
use crate::obs::{EnergyModel, NetTier};
use crate::shard::ShardPlan;
use crate::util::{LinkId, RegionId, SimDuration, TaskId, WireId};

use std::collections::VecDeque;

/// How a consumer learns that a topic has news (Principle 1, §III-F).
///
/// * `Push` — a message on the side channel wakes the consumer immediately.
///   Right when inter-arrival time ≫ service time (no idle sampling).
/// * `Poll(interval)` — the consumer samples the queue on a timer. Right
///   when arrivals are frequent relative to the infrastructure timescale;
///   notification traffic would be pure overhead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NotifyMode {
    Push,
    Poll(SimDuration),
    /// Deliveries queue silently; an external driver (make-mode demand or
    /// the schedule-driven baseline) decides when work happens.
    Manual,
}

impl NotifyMode {
    /// The paper's rule of thumb: notify when arrivals are slower than the
    /// service timescale, sample otherwise.
    pub fn auto(mean_interarrival: SimDuration, service_time: SimDuration) -> Self {
        if mean_interarrival > service_time {
            NotifyMode::Push
        } else {
            // Sample at roughly the service timescale.
            NotifyMode::Poll(service_time)
        }
    }
}

/// One per-link topic: FCFS queue of AV metadata plus subscriber list.
#[derive(Clone, Debug, Default)]
pub struct Topic {
    pub queue: VecDeque<AnnotatedValue>,
    pub subscribers: Vec<TaskId>,
    pub published: u64,
    pub consumed: u64,
}

/// The message bus. Topics are indexed densely by `LinkId` (links are
/// created once, at pipeline deployment).
#[derive(Clone, Debug, Default)]
pub struct Bus {
    topics: Vec<Topic>,
    /// side-channel messages sent (for the E3 overhead accounting)
    pub notifications: u64,
}

impl Bus {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ensure a topic exists for `link`.
    pub fn create_topic(&mut self, link: LinkId) {
        if self.topics.len() <= link.index() {
            self.topics.resize_with(link.index() + 1, Topic::default);
        }
    }

    pub fn subscribe(&mut self, link: LinkId, task: TaskId) {
        self.create_topic(link);
        let t = &mut self.topics[link.index()];
        if !t.subscribers.contains(&task) {
            t.subscribers.push(task);
        }
    }

    /// Publish AV metadata to the link topic; returns the subscriber list
    /// (the coordinator decides whether to send side-channel notifications
    /// based on the link's [`NotifyMode`]).
    pub fn publish(&mut self, link: LinkId, av: AnnotatedValue) -> &[TaskId] {
        self.create_topic(link);
        let t = &mut self.topics[link.index()];
        t.queue.push_back(av);
        t.published += 1;
        &t.subscribers
    }

    /// Non-destructive peek at queue depth — the "is there anything new on
    /// the channel?" sample a smart task performs (§III-F).
    pub fn depth(&self, link: LinkId) -> usize {
        self.topics.get(link.index()).map_or(0, |t| t.queue.len())
    }

    /// Non-destructive peek at the head AV (for FCFS pulls across links).
    pub fn peek_head(&self, link: LinkId) -> Option<&AnnotatedValue> {
        self.topics.get(link.index())?.queue.front()
    }

    /// Consume the next AV on the topic (FCFS).
    pub fn consume(&mut self, link: LinkId) -> Option<AnnotatedValue> {
        let t = self.topics.get_mut(link.index())?;
        let av = t.queue.pop_front()?;
        t.consumed += 1;
        Some(av)
    }

    /// Drain up to `max` AVs.
    pub fn consume_up_to(&mut self, link: LinkId, max: usize) -> Vec<AnnotatedValue> {
        let mut out = Vec::new();
        while out.len() < max {
            match self.consume(link) {
                Some(av) => out.push(av),
                None => break,
            }
        }
        out
    }

    pub fn record_notification(&mut self) {
        self.notifications += 1;
    }

    pub fn topic_stats(&self, link: LinkId) -> (u64, u64) {
        self.topics
            .get(link.index())
            .map_or((0, 0), |t| (t.published, t.consumed))
    }
}

// ---------------------------------------------------------------------
// inter-node exchange (§III-B/IV: the sharded runtime's data movement)
// ---------------------------------------------------------------------

/// Running totals for one cross-node channel (and, summed, for the whole
/// exchange). This is a *separate ledger* from `Metrics::bytes_moved` —
/// the fetch path already accounts region physics there; the exchange
/// ledger answers "what did the node partition move?", so the two must
/// not be conflated or bytes double-count.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TransferStat {
    pub transfers: u64,
    pub bytes: u64,
    /// WAN microseconds attributable to these transfers (informational:
    /// the exchange never touches virtual time — node placement is
    /// time-neutral by construction).
    pub wan_us: u64,
    pub joules: f64,
    /// Sovereignty-denied deliveries on this channel; each one moved
    /// exactly zero bytes.
    pub denied: u64,
}

impl TransferStat {
    fn absorb(&mut self, other: &TransferStat) {
        self.transfers += other.transfers;
        self.bytes += other.bytes;
        self.wan_us += other.wan_us;
        self.joules += other.joules;
        self.denied += other.denied;
    }
}

/// One cross-node wire topic: the link's endpoints resolved to nodes and
/// regions at deploy, with the cost model per byte frozen in.
#[derive(Clone, Debug)]
pub struct LinkChannel {
    pub wire: WireId,
    pub from_node: usize,
    pub to_node: usize,
    pub from_region: RegionId,
    pub to_region: RegionId,
    /// `Lan` when both nodes sit in one region, `Wan` across regions.
    pub tier: NetTier,
    /// The WAN link crossed (None for LAN channels).
    pub wan: Option<WanLink>,
    joules_per_byte: f64,
    pub stat: TransferStat,
}

/// What one granted transfer looked like — the payload for
/// `SpanEvent::Transfer` stamping.
#[derive(Clone, Copy, Debug)]
pub struct TransferNote {
    pub wire: WireId,
    pub from_node: usize,
    pub to_node: usize,
    pub bytes: u64,
    pub tier: NetTier,
    pub wan_us: u64,
}

/// The inter-node exchange: every wire whose producer and consumer live on
/// different nodes gets a channel with per-link byte/latency/energy
/// accounting (DataX-style — the exchange, not the tasks, owns movement
/// between streaming stages). Built once at deploy from the [`ShardPlan`];
/// a single-node plan builds an empty exchange and costs nothing.
///
/// Injection links (`from == None`) never ride the exchange: external data
/// materializes on the consumer's node, and its *region* physics is
/// already charged on the fetch path.
#[derive(Clone, Debug, Default)]
pub struct Exchange {
    /// Dense by `LinkId`; `None` for same-node links.
    channels: Vec<Option<LinkChannel>>,
    totals: TransferStat,
}

impl Exchange {
    pub fn build(
        graph: &PipelineGraph,
        plan: &ShardPlan,
        regions: &[RegionId],
        net: &WanTopology,
        energy: &EnergyModel,
    ) -> Self {
        let mut channels: Vec<Option<LinkChannel>> = vec![None; graph.links.len()];
        for l in &graph.links {
            let Some(from) = l.from else { continue };
            let (from_node, to_node) = (plan.node(from), plan.node(l.to));
            if from_node == to_node {
                continue;
            }
            let (from_region, to_region) = (regions[from.index()], regions[l.to.index()]);
            let (tier, wan) = if from_region == to_region {
                (NetTier::Lan, None)
            } else {
                // mirror plan_transfer's fallback for unlinked region pairs
                let link = net.link(from_region, to_region).unwrap_or(WanLink {
                    rtt: SimDuration::millis(80),
                    gbps: 1.0,
                    dollars_per_gb: 0.08,
                });
                (NetTier::Wan, Some(link))
            };
            channels[l.id.index()] = Some(LinkChannel {
                wire: l.wire_id,
                from_node,
                to_node,
                from_region,
                to_region,
                tier,
                wan,
                joules_per_byte: energy.per_byte(tier),
                stat: TransferStat::default(),
            });
        }
        Self { channels, totals: TransferStat::default() }
    }

    /// Is this link cross-node?
    pub fn channel(&self, link: LinkId) -> Option<&LinkChannel> {
        self.channels.get(link.index()).and_then(|c| c.as_ref())
    }

    /// Account one granted AV transfer over `link`. Returns the note to
    /// stamp into the span stream, or None when the link is same-node
    /// (no exchange hop). Pure bookkeeping: virtual time is untouched.
    pub fn record(&mut self, link: LinkId, bytes: u64) -> Option<TransferNote> {
        let ch = self.channels.get_mut(link.index())?.as_mut()?;
        let wan_us = ch.wan.map_or(0, |w| w.transfer_time(bytes).as_micros());
        ch.stat.transfers += 1;
        ch.stat.bytes += bytes;
        ch.stat.wan_us += wan_us;
        ch.stat.joules += bytes as f64 * ch.joules_per_byte;
        self.totals.transfers += 1;
        self.totals.bytes += bytes;
        self.totals.wan_us += wan_us;
        self.totals.joules += bytes as f64 * ch.joules_per_byte;
        Some(TransferNote {
            wire: ch.wire,
            from_node: ch.from_node,
            to_node: ch.to_node,
            bytes,
            tier: ch.tier,
            wan_us,
        })
    }

    /// Account a sovereignty-denied delivery on `link`: the channel
    /// records the refusal and *zero* bytes, enforcing the "a Denied raw
    /// transfer must move zero bytes" contract at the ledger level.
    pub fn record_denied(&mut self, link: LinkId) {
        if let Some(Some(ch)) = self.channels.get_mut(link.index()) {
            ch.stat.denied += 1;
            self.totals.denied += 1;
        }
    }

    pub fn totals(&self) -> TransferStat {
        self.totals
    }

    /// Per-channel view in `LinkId` order (for `koalja trace` and tests).
    pub fn channels(&self) -> impl Iterator<Item = (LinkId, &LinkChannel)> {
        self.channels
            .iter()
            .enumerate()
            .filter_map(|(i, c)| c.as_ref().map(|ch| (LinkId::new(i as u64), ch)))
    }

    /// Recompute totals from per-channel stats (defensive; also used by
    /// tests to prove the two ledgers agree).
    pub fn recomputed_totals(&self) -> TransferStat {
        let mut t = TransferStat::default();
        for (_, ch) in self.channels() {
            t.absorb(&ch.stat);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::av::DataClass;
    use crate::util::*;

    fn av(seq: u64) -> AnnotatedValue {
        AnnotatedValue {
            id: AvId::new(seq),
            source_task: TaskId::new(0),
            link: LinkId::new(0),
            object: ObjectId::new(seq),
            region: RegionId::new(0),
            created: SimTime::micros(seq),
            seq,
            size_bytes: 8,
            content: ContentHash::of_str("p"),
            class: DataClass::Summary,
            ghost: false,
            born: SimTime::micros(seq),
        }
    }

    #[test]
    fn fcfs_ordering() {
        let mut bus = Bus::new();
        let l = LinkId::new(0);
        bus.create_topic(l);
        for i in 0..5 {
            bus.publish(l, av(i));
        }
        let drained = bus.consume_up_to(l, 10);
        let seqs: Vec<u64> = drained.iter().map(|a| a.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
        assert_eq!(bus.topic_stats(l), (5, 5));
    }

    #[test]
    fn subscribers_deduplicated() {
        let mut bus = Bus::new();
        let l = LinkId::new(2);
        bus.subscribe(l, TaskId::new(1));
        bus.subscribe(l, TaskId::new(1));
        bus.subscribe(l, TaskId::new(2));
        assert_eq!(bus.publish(l, av(0)).len(), 2);
    }

    #[test]
    fn depth_is_nondestructive() {
        let mut bus = Bus::new();
        let l = LinkId::new(0);
        bus.publish(l, av(0));
        assert_eq!(bus.depth(l), 1);
        assert_eq!(bus.depth(l), 1);
        bus.consume(l);
        assert_eq!(bus.depth(l), 0);
    }

    #[test]
    fn auto_mode_follows_principle_1() {
        // slow arrivals (1s) vs fast service (1ms) -> push notifications
        assert_eq!(
            NotifyMode::auto(SimDuration::secs(1), SimDuration::millis(1)),
            NotifyMode::Push
        );
        // fast arrivals (1ms) vs slow service (100ms) -> polling
        match NotifyMode::auto(SimDuration::millis(1), SimDuration::millis(100)) {
            NotifyMode::Poll(iv) => assert_eq!(iv, SimDuration::millis(100)),
            _ => panic!("expected poll"),
        }
    }

    #[test]
    fn consume_on_missing_topic_is_none() {
        let mut bus = Bus::new();
        assert!(bus.consume(LinkId::new(9)).is_none());
        assert_eq!(bus.depth(LinkId::new(9)), 0);
    }

    // -----------------------------------------------------------------
    // exchange
    // -----------------------------------------------------------------

    use crate::net::demo_topology;
    use crate::shard::{PlacementSpec, ShardPlan};
    use crate::spec::parse;

    /// a → b → c on wires x, y; raw is the external in-tray.
    fn chain_exchange(nodes: usize, regions: Vec<RegionId>) -> (crate::graph::PipelineGraph, Exchange) {
        let g = crate::graph::PipelineGraph::build(
            &parse("[ex]\n(raw) a (x)\n(x) b (y)\n(y) c (z)\n").unwrap(),
        );
        let net = demo_topology(2);
        let plan = ShardPlan::build(&g, &regions, &PlacementSpec::on_nodes(nodes));
        let ex = Exchange::build(&g, &plan, &regions, &net, &EnergyModel::default());
        (g, ex)
    }

    #[test]
    fn single_node_exchange_is_empty() {
        let (_, ex) = chain_exchange(1, vec![RegionId::new(0); 3]);
        assert_eq!(ex.channels().count(), 0);
        assert_eq!(ex.totals(), TransferStat::default());
    }

    #[test]
    fn cross_node_links_get_channels_with_tiers() {
        // a,c @ central (node 0); b @ eu-dc (node 1): both internal links
        // cross nodes *and* regions -> Wan channels; the injection link
        // (raw -> a) never rides the exchange
        let central = RegionId::new(0);
        let eu = RegionId::new(1);
        let (g, mut ex) = chain_exchange(2, vec![central, eu, central]);
        let chans: Vec<_> = ex.channels().map(|(l, c)| (l, c.tier, c.from_node, c.to_node)).collect();
        assert_eq!(chans.len(), 2, "x and y cross; raw does not");
        assert!(chans.iter().all(|(_, tier, ..)| *tier == NetTier::Wan));
        // record one transfer over the first cross link
        let link = chans[0].0;
        let note = ex.record(link, 4096).expect("cross-node link records");
        assert_eq!(note.bytes, 4096);
        assert_eq!(note.tier, NetTier::Wan);
        assert!(note.wan_us > 0, "WAN transfers cost wall time on the ledger");
        assert_eq!(ex.totals().bytes, 4096);
        assert_eq!(ex.totals().transfers, 1);
        assert!(ex.totals().joules > 0.0);
        assert_eq!(ex.recomputed_totals(), ex.totals());
        // same-node link records nothing
        let same_node = g
            .links
            .iter()
            .find(|l| ex.channel(l.id).is_none())
            .expect("injection link is same-node");
        assert!(ex.record(same_node.id, 100).is_none());
        assert_eq!(ex.totals().bytes, 4096, "same-node moves stay off the ledger");
    }

    #[test]
    fn cross_node_same_region_is_lan() {
        // all tasks in central, but b pinned to node 1: cross-node links
        // exist yet stay on the LAN tier with no WAN link attached
        let g = crate::graph::PipelineGraph::build(
            &parse("[ex]\n(raw) a (x)\n(x) b (y)\n(y) c (z)\n").unwrap(),
        );
        let net = demo_topology(2);
        let regions = vec![RegionId::new(0); 3];
        let spec = PlacementSpec::on_nodes(2).pin_node("b", 1);
        let plan = ShardPlan::build(&g, &regions, &spec);
        let mut ex = Exchange::build(&g, &plan, &regions, &net, &EnergyModel::default());
        let chans: Vec<_> = ex.channels().map(|(l, c)| (l, c.tier)).collect();
        assert_eq!(chans.len(), 2);
        assert!(chans.iter().all(|(_, t)| *t == NetTier::Lan));
        let note = ex.record(chans[0].0, 1000).unwrap();
        assert_eq!(note.wan_us, 0, "LAN hops cost no WAN time");
    }

    #[test]
    fn denied_deliveries_move_zero_bytes() {
        let central = RegionId::new(0);
        let eu = RegionId::new(1);
        let (g, mut ex) = chain_exchange(2, vec![central, eu, central]);
        let link = g.links.iter().find(|l| ex.channel(l.id).is_some()).unwrap().id;
        ex.record_denied(link);
        ex.record_denied(link);
        assert_eq!(ex.totals().denied, 2);
        assert_eq!(ex.totals().bytes, 0, "a denial moves exactly zero bytes");
        assert_eq!(ex.channel(link).unwrap().stat.denied, 2);
    }
}
