//! Publish–subscribe data plane with a separate notification side channel —
//! §III-F.
//!
//! "In order to scale data transfers ... we look to a publish-subscribe
//! (pull) model for data handovers, with a separate side channel for
//! instant messaging." AV *metadata* is published to a per-link topic;
//! payloads stay in object storage, so forwarding the same data to
//! multiple branches replicates nothing but a pointer.
//!
//! Principle 1 decides per link whether consumers learn of arrivals by a
//! pushed notification or by sampling (polling) the topic — see
//! [`NotifyMode`].

use crate::av::AnnotatedValue;
use crate::util::{LinkId, SimDuration, TaskId};

use std::collections::VecDeque;

/// How a consumer learns that a topic has news (Principle 1, §III-F).
///
/// * `Push` — a message on the side channel wakes the consumer immediately.
///   Right when inter-arrival time ≫ service time (no idle sampling).
/// * `Poll(interval)` — the consumer samples the queue on a timer. Right
///   when arrivals are frequent relative to the infrastructure timescale;
///   notification traffic would be pure overhead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NotifyMode {
    Push,
    Poll(SimDuration),
    /// Deliveries queue silently; an external driver (make-mode demand or
    /// the schedule-driven baseline) decides when work happens.
    Manual,
}

impl NotifyMode {
    /// The paper's rule of thumb: notify when arrivals are slower than the
    /// service timescale, sample otherwise.
    pub fn auto(mean_interarrival: SimDuration, service_time: SimDuration) -> Self {
        if mean_interarrival > service_time {
            NotifyMode::Push
        } else {
            // Sample at roughly the service timescale.
            NotifyMode::Poll(service_time)
        }
    }
}

/// One per-link topic: FCFS queue of AV metadata plus subscriber list.
#[derive(Clone, Debug, Default)]
pub struct Topic {
    pub queue: VecDeque<AnnotatedValue>,
    pub subscribers: Vec<TaskId>,
    pub published: u64,
    pub consumed: u64,
}

/// The message bus. Topics are indexed densely by `LinkId` (links are
/// created once, at pipeline deployment).
#[derive(Clone, Debug, Default)]
pub struct Bus {
    topics: Vec<Topic>,
    /// side-channel messages sent (for the E3 overhead accounting)
    pub notifications: u64,
}

impl Bus {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ensure a topic exists for `link`.
    pub fn create_topic(&mut self, link: LinkId) {
        if self.topics.len() <= link.index() {
            self.topics.resize_with(link.index() + 1, Topic::default);
        }
    }

    pub fn subscribe(&mut self, link: LinkId, task: TaskId) {
        self.create_topic(link);
        let t = &mut self.topics[link.index()];
        if !t.subscribers.contains(&task) {
            t.subscribers.push(task);
        }
    }

    /// Publish AV metadata to the link topic; returns the subscriber list
    /// (the coordinator decides whether to send side-channel notifications
    /// based on the link's [`NotifyMode`]).
    pub fn publish(&mut self, link: LinkId, av: AnnotatedValue) -> &[TaskId] {
        self.create_topic(link);
        let t = &mut self.topics[link.index()];
        t.queue.push_back(av);
        t.published += 1;
        &t.subscribers
    }

    /// Non-destructive peek at queue depth — the "is there anything new on
    /// the channel?" sample a smart task performs (§III-F).
    pub fn depth(&self, link: LinkId) -> usize {
        self.topics.get(link.index()).map_or(0, |t| t.queue.len())
    }

    /// Non-destructive peek at the head AV (for FCFS pulls across links).
    pub fn peek_head(&self, link: LinkId) -> Option<&AnnotatedValue> {
        self.topics.get(link.index())?.queue.front()
    }

    /// Consume the next AV on the topic (FCFS).
    pub fn consume(&mut self, link: LinkId) -> Option<AnnotatedValue> {
        let t = self.topics.get_mut(link.index())?;
        let av = t.queue.pop_front()?;
        t.consumed += 1;
        Some(av)
    }

    /// Drain up to `max` AVs.
    pub fn consume_up_to(&mut self, link: LinkId, max: usize) -> Vec<AnnotatedValue> {
        let mut out = Vec::new();
        while out.len() < max {
            match self.consume(link) {
                Some(av) => out.push(av),
                None => break,
            }
        }
        out
    }

    pub fn record_notification(&mut self) {
        self.notifications += 1;
    }

    pub fn topic_stats(&self, link: LinkId) -> (u64, u64) {
        self.topics
            .get(link.index())
            .map_or((0, 0), |t| (t.published, t.consumed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::av::DataClass;
    use crate::util::*;

    fn av(seq: u64) -> AnnotatedValue {
        AnnotatedValue {
            id: AvId::new(seq),
            source_task: TaskId::new(0),
            link: LinkId::new(0),
            object: ObjectId::new(seq),
            region: RegionId::new(0),
            created: SimTime::micros(seq),
            seq,
            size_bytes: 8,
            content: ContentHash::of_str("p"),
            class: DataClass::Summary,
            ghost: false,
            born: SimTime::micros(seq),
        }
    }

    #[test]
    fn fcfs_ordering() {
        let mut bus = Bus::new();
        let l = LinkId::new(0);
        bus.create_topic(l);
        for i in 0..5 {
            bus.publish(l, av(i));
        }
        let drained = bus.consume_up_to(l, 10);
        let seqs: Vec<u64> = drained.iter().map(|a| a.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
        assert_eq!(bus.topic_stats(l), (5, 5));
    }

    #[test]
    fn subscribers_deduplicated() {
        let mut bus = Bus::new();
        let l = LinkId::new(2);
        bus.subscribe(l, TaskId::new(1));
        bus.subscribe(l, TaskId::new(1));
        bus.subscribe(l, TaskId::new(2));
        assert_eq!(bus.publish(l, av(0)).len(), 2);
    }

    #[test]
    fn depth_is_nondestructive() {
        let mut bus = Bus::new();
        let l = LinkId::new(0);
        bus.publish(l, av(0));
        assert_eq!(bus.depth(l), 1);
        assert_eq!(bus.depth(l), 1);
        bus.consume(l);
        assert_eq!(bus.depth(l), 0);
    }

    #[test]
    fn auto_mode_follows_principle_1() {
        // slow arrivals (1s) vs fast service (1ms) -> push notifications
        assert_eq!(
            NotifyMode::auto(SimDuration::secs(1), SimDuration::millis(1)),
            NotifyMode::Push
        );
        // fast arrivals (1ms) vs slow service (100ms) -> polling
        match NotifyMode::auto(SimDuration::millis(1), SimDuration::millis(100)) {
            NotifyMode::Poll(iv) => assert_eq!(iv, SimDuration::millis(100)),
            _ => panic!("expected poll"),
        }
    }

    #[test]
    fn consume_on_missing_topic_is_none() {
        let mut bus = Bus::new();
        assert!(bus.consume(LinkId::new(9)).is_none());
        assert_eq!(bus.depth(LinkId::new(9)), 0);
    }
}
