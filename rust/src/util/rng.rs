//! Deterministic RNG — an in-tree splitmix64/xoshiro256** implementation.
//!
//! The build environment is offline (no `rand` crate); more importantly,
//! determinism is a *feature* here: the paper's forensic-reconstruction
//! claims are only testable if every run with the same seed reproduces a
//! byte-identical trace. xoshiro256** is the reference generator of
//! Blackman & Vigna; splitmix64 seeds it.

/// Deterministic, seedable PRNG (xoshiro256**).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::EPSILON);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with mean 1.
    pub fn exp1(&mut self) -> f64 {
        -self.f64().max(f64::EPSILON).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independent child generator (for per-entity streams).
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.range(2, 10);
            assert!((2..10).contains(&v));
            seen[v] = true;
        }
        assert!(seen[2..10].iter().all(|&b| b), "all values reachable");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(11);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp1_mean() {
        let mut r = Rng::seed_from_u64(13);
        let n = 40_000;
        let mean = (0..n).map(|_| r.exp1()).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffled order changed");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut r = Rng::seed_from_u64(9);
        let mut c1 = r.fork();
        let mut c2 = r.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
