//! Near-miss suggestions for name-resolution errors.
//!
//! Every public string-keyed entry point (wire/task/handle resolution)
//! resolves a user-typed name against a small closed set minted at deploy
//! time. When resolution fails, the error should teach: name the nearest
//! candidate (a typo is the common case) and list what actually exists,
//! matching the breadboard's explain-don't-just-refuse error style.

/// Levenshtein edit distance, early-exited once it must exceed `cap`.
/// Candidate sets are tiny (a pipeline has dozens of wires, not millions),
/// so the simple O(a·b) DP is plenty.
fn edit_distance(a: &str, b: &str, cap: usize) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    if a.len().abs_diff(b.len()) > cap {
        return cap + 1;
    }
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        let mut row_min = cur[0];
        for (j, cb) in b.iter().enumerate() {
            let cost = usize::from(ca != cb);
            cur[j + 1] = (prev[j] + cost).min(prev[j + 1] + 1).min(cur[j] + 1);
            row_min = row_min.min(cur[j + 1]);
        }
        if row_min > cap {
            return cap + 1;
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// The candidate closest to `target`, if close enough to plausibly be a
/// typo (distance ≤ max(2, target.len()/3) — 2 admits the classic
/// transposition, which costs two single-char edits). Ties keep the first.
pub fn nearest<'a>(target: &str, candidates: &[&'a str]) -> Option<&'a str> {
    let cap = (target.chars().count() / 3).max(2);
    let mut best: Option<(&str, usize)> = None;
    for &c in candidates {
        let d = edit_distance(target, c, cap);
        if d <= cap && best.map_or(true, |(_, bd)| d < bd) {
            best = Some((c, d));
        }
    }
    best.map(|(c, _)| c)
}

/// How many candidates an error message spells out before eliding.
const LIST_CAP: usize = 12;

/// Error-message suffix for a failed name resolution: a did-you-mean for
/// the nearest candidate plus the (capped) list of known names.
/// `kind` is the singular noun ("wire", "task", "source wire", …).
/// Empty when there are no candidates at all.
pub fn suggest<'a, I: IntoIterator<Item = &'a str>>(target: &str, kind: &str, candidates: I) -> String {
    let cands: Vec<&str> = candidates.into_iter().collect();
    if cands.is_empty() {
        return String::new();
    }
    let mut s = String::new();
    if let Some(best) = nearest(target, &cands) {
        s.push_str(&format!(" — did you mean '{best}'?"));
    }
    let shown = cands.len().min(LIST_CAP);
    let elided = cands.len() - shown;
    s.push_str(&format!(" (known {kind}s: {}", cands[..shown].join(", ")));
    if elided > 0 {
        s.push_str(&format!(", … {elided} more"));
    }
    s.push(')');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distance_basics() {
        assert_eq!(edit_distance("abc", "abc", 3), 0);
        assert_eq!(edit_distance("abc", "abd", 3), 1);
        assert_eq!(edit_distance("abc", "ab", 3), 1);
        assert_eq!(edit_distance("kitten", "sitting", 7), 3);
        assert!(edit_distance("short", "muchlongername", 2) > 2, "cap early-exit");
    }

    #[test]
    fn nearest_finds_typos_only() {
        let cands = ["frames", "alerts", "report"];
        assert_eq!(nearest("frames", &cands), Some("frames"));
        assert_eq!(nearest("frmes", &cands), Some("frames"));
        assert_eq!(nearest("alert", &cands), Some("alerts"));
        assert_eq!(nearest("framse", &cands), Some("frames"), "transposition");
        assert_eq!(nearest("zzzzzz", &cands), None, "nothing plausible");
    }

    #[test]
    fn suggest_formats_and_caps() {
        let s = suggest("frmes", "wire", ["frames", "alerts"]);
        assert!(s.contains("did you mean 'frames'?"), "{s}");
        assert!(s.contains("known wires: frames, alerts"), "{s}");
        assert_eq!(suggest("x", "wire", []), "");
        let many: Vec<String> = (0..20).map(|i| format!("wire-{i}")).collect();
        let s = suggest("nope", "wire", many.iter().map(|s| s.as_str()));
        assert!(s.contains("… 8 more"), "{s}");
    }
}
