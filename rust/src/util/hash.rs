//! Content hashing for the make-style staleness checks and content-addressed
//! object storage. FNV-1a 64-bit: not cryptographic, but deterministic,
//! dependency-free and fast — collisions are irrelevant to the simulation's
//! claims (we hash to *detect change*, not to authenticate).



const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// FNV-1a over a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// A content hash: of a payload, of a snapshot's inputs, of a software
/// version string. Combinable, so a task's "recipe hash" folds input
/// hashes + code version into one change detector (the Makefile semantics
/// of §III-B/§III-J).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct ContentHash(pub u64);

impl ContentHash {
    pub const EMPTY: ContentHash = ContentHash(FNV_OFFSET);

    pub fn of_bytes(bytes: &[u8]) -> Self {
        Self(fnv1a(bytes))
    }

    pub fn of_str(s: &str) -> Self {
        Self::of_bytes(s.as_bytes())
    }

    pub fn of_f32s(xs: &[f32]) -> Self {
        let mut h = FNV_OFFSET;
        for x in xs {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        }
        Self(h)
    }

    /// Order-sensitive combination (recipe hashes care about input order).
    pub fn combine(self, other: ContentHash) -> Self {
        let mut h = self.0;
        for b in other.0.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        Self(h)
    }
}

impl std::fmt::Display for ContentHash {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector() {
        // FNV-1a("a") = 0xaf63dc4c8601ec8c
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn distinct_inputs_distinct_hashes() {
        assert_ne!(ContentHash::of_str("x"), ContentHash::of_str("y"));
        assert_ne!(
            ContentHash::of_f32s(&[1.0, 2.0]),
            ContentHash::of_f32s(&[2.0, 1.0])
        );
    }

    #[test]
    fn combine_is_order_sensitive() {
        let a = ContentHash::of_str("a");
        let b = ContentHash::of_str("b");
        assert_ne!(a.combine(b), b.combine(a));
    }

    #[test]
    fn deterministic_across_calls() {
        assert_eq!(
            ContentHash::of_f32s(&[3.25, -1.0]),
            ContentHash::of_f32s(&[3.25, -1.0])
        );
    }
}

// ---------------------------------------------------------------------------
// Fast hashing for id-keyed maps (§Perf): the default SipHash defends
// against adversarial keys; our ids are sequential u64s minted in-process,
// so an FNV-mix hasher is safe and ~3x faster per map op.
// ---------------------------------------------------------------------------

/// Hasher for small fixed keys (u64 ids, ContentHash).
#[derive(Default, Clone)]
pub struct FastHasher(u64);

impl std::hash::Hasher for FastHasher {
    fn finish(&self) -> u64 {
        self.0
    }

    fn write(&mut self, bytes: &[u8]) {
        let mut h = if self.0 == 0 { FNV_OFFSET } else { self.0 };
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.0 = h;
    }

    fn write_u64(&mut self, v: u64) {
        // splitmix-style avalanche: sequential ids spread across buckets
        let mut z = self.0.wrapping_add(v).wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        self.0 = z ^ (z >> 31);
    }
}

/// BuildHasher for [`FastHasher`].
#[derive(Default, Clone)]
pub struct FastHash;

impl std::hash::BuildHasher for FastHash {
    type Hasher = FastHasher;
    fn build_hasher(&self) -> FastHasher {
        FastHasher::default()
    }
}

/// A HashMap with the fast id hasher.
pub type FastMap<K, V> = std::collections::HashMap<K, V, FastHash>;

#[cfg(test)]
mod fast_tests {
    use super::*;

    #[test]
    fn fastmap_works_like_hashmap() {
        let mut m: FastMap<u64, u32> = FastMap::default();
        for i in 0..1000u64 {
            m.insert(i, (i * 2) as u32);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m.get(&500), Some(&1000));
        assert!(m.remove(&999).is_some());
        assert_eq!(m.len(), 999);
    }

    #[test]
    fn sequential_ids_spread() {
        // bucket-collision sanity: 1024 sequential ids should produce many
        // distinct hashes
        use std::hash::{BuildHasher, Hasher};
        let b = FastHash;
        let mut seen = std::collections::HashSet::new();
        for i in 0..1024u64 {
            let mut h = b.build_hasher();
            h.write_u64(i);
            seen.insert(h.finish() & 0x3FF);
        }
        assert!(seen.len() > 500, "only {} distinct low-10-bit hashes", seen.len());
    }
}
