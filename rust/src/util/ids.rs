//! Typed id newtypes. Every entity Koalja tracks — tasks, links, annotated
//! values, stored objects, regions, runs — gets its own id space so that
//! provenance records cannot confuse them.



macro_rules! id_type {
    ($(#[$doc:meta])* $name:ident, $prefix:expr) => {
        $(#[$doc])*
        #[derive(
            Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug,
            
        )]
        pub struct $name(pub u64);

        impl $name {
            pub const fn new(v: u64) -> Self {
                Self(v)
            }
            pub fn index(self) -> usize {
                self.0 as usize
            }
        }

        impl std::fmt::Display for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, concat!($prefix, "{}"), self.0)
            }
        }
    };
}

id_type!(
    /// A smart task agent (§III-I) — one per node of the wiring diagram.
    TaskId,
    "task-"
);
id_type!(
    /// A smart link agent (§III-J) — one per wire between task ports.
    LinkId,
    "link-"
);
id_type!(
    /// An Annotated Value (§III-I): the unit of data the platform routes.
    AvId,
    "av-"
);
id_type!(
    /// A payload stored in the object store; AVs point at these by URI.
    ObjectId,
    "obj-"
);
id_type!(
    /// A cloud region / sovereignty zone (§IV).
    RegionId,
    "region-"
);
id_type!(
    /// One execution of one task's user code (for the checkpoint log).
    RunId,
    "run-"
);
id_type!(
    /// An overlapping-set workspace (§IV).
    WorkspaceId,
    "ws-"
);

/// An interned wire name: a dense `u32` index into the pipeline graph's
/// wire table, assigned once at deploy time (`graph::PipelineGraph::build`).
/// The coordinator's hot path — publication, delivery, tap checks, wire
/// currency — routes on these instead of hashing/scanning `&str` names
/// (§Perf). Deliberately `u32`, not `u64`: per-wire state is dense
/// `Vec`-indexed, and a pipeline has at most a few thousand wires.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct WireId(pub u32);

impl WireId {
    pub const fn new(v: u32) -> Self {
        Self(v)
    }
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for WireId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire-{}", self.0)
    }
}

/// Monotonic id dispenser, one per id space.
#[derive(Debug, Default, Clone)]
pub struct IdGen {
    next: u64,
}

impl IdGen {
    pub fn new() -> Self {
        Self { next: 0 }
    }
    pub fn next_raw(&mut self) -> u64 {
        let v = self.next;
        self.next += 1;
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_display_with_prefix() {
        assert_eq!(TaskId::new(3).to_string(), "task-3");
        assert_eq!(AvId::new(0).to_string(), "av-0");
        assert_eq!(RegionId::new(9).to_string(), "region-9");
    }

    #[test]
    fn idgen_is_monotonic() {
        let mut g = IdGen::new();
        assert_eq!(g.next_raw(), 0);
        assert_eq!(g.next_raw(), 1);
        assert_eq!(g.next_raw(), 2);
    }

    #[test]
    fn ids_are_distinct_types() {
        // compile-time property; runtime sanity that values don't collide
        // in maps keyed by the typed id.
        use std::collections::HashSet;
        let mut s = HashSet::new();
        for i in 0..100 {
            assert!(s.insert(AvId::new(i)));
        }
    }
}
