//! Virtual time for the discrete-event platform.
//!
//! The paper's policies are all *timescale* policies (Principle 1: "a
//! separate message notification channel ... for updates that are slow in
//! arrival time compared to the service time"). A virtual microsecond clock
//! makes those timescales explicit, deterministic, and cheap to sweep in
//! benchmarks, while the coordinator code itself stays identical to what a
//! wallclock deployment would run.


use std::ops::{Add, AddAssign, Sub};

/// Absolute virtual time, in microseconds since simulation start.
#[derive(
    Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug, Default,
)]
pub struct SimTime(pub u64);

/// A span of virtual time, in microseconds.
#[derive(
    Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug, Default,
)]
pub struct SimDuration(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);
    pub fn micros(us: u64) -> Self {
        Self(us)
    }
    pub fn millis(ms: u64) -> Self {
        Self(ms * 1_000)
    }
    pub fn secs(s: u64) -> Self {
        Self(s * 1_000_000)
    }
    pub fn as_micros(self) -> u64 {
        self.0
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
    pub fn saturating_sub(self, rhs: SimTime) -> SimDuration {
        SimDuration(self.0.saturating_sub(rhs.0))
    }
}

impl SimDuration {
    pub const ZERO: SimDuration = SimDuration(0);
    pub fn micros(us: u64) -> Self {
        Self(us)
    }
    pub fn millis(ms: u64) -> Self {
        Self(ms * 1_000)
    }
    pub fn secs(s: u64) -> Self {
        Self(s * 1_000_000)
    }
    pub fn as_micros(self) -> u64 {
        self.0
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
    /// Scale by a dimensionless factor (for ρ sweeps and jitter).
    pub fn scale(self, f: f64) -> Self {
        Self((self.0 as f64 * f).round().max(0.0) as u64)
    }
}

impl Add<SimDuration> for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimDuration) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign<SimDuration> for SimTime {
    fn add_assign(&mut self, rhs: SimDuration) {
        self.0 += rhs.0;
    }
}

impl Add for SimDuration {
    type Output = SimDuration;
    fn add(self, rhs: SimDuration) -> SimDuration {
        SimDuration(self.0 + rhs.0)
    }
}

impl AddAssign for SimDuration {
    fn add_assign(&mut self, rhs: SimDuration) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimDuration;
    fn sub(self, rhs: SimTime) -> SimDuration {
        SimDuration(self.0 - rhs.0)
    }
}

impl std::fmt::Display for SimTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "t+{:.6}s", self.as_secs_f64())
    }
}

impl std::fmt::Display for SimDuration {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.0 >= 1_000_000 {
            write!(f, "{:.3}s", self.as_secs_f64())
        } else if self.0 >= 1_000 {
            write!(f, "{:.3}ms", self.0 as f64 / 1e3)
        } else {
            write!(f, "{}us", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let t = SimTime::millis(2) + SimDuration::micros(500);
        assert_eq!(t.as_micros(), 2_500);
        assert_eq!((t - SimTime::millis(1)).as_micros(), 1_500);
    }

    #[test]
    fn scale_rounds_and_clamps() {
        assert_eq!(SimDuration::micros(100).scale(2.5).as_micros(), 250);
        assert_eq!(SimDuration::micros(100).scale(0.0).as_micros(), 0);
    }

    #[test]
    fn display_picks_unit() {
        assert_eq!(SimDuration::micros(12).to_string(), "12us");
        assert_eq!(SimDuration::micros(1_200).to_string(), "1.200ms");
        assert_eq!(SimDuration::secs(2).to_string(), "2.000s");
    }

    #[test]
    fn saturating_sub_does_not_underflow() {
        let a = SimTime::micros(5);
        let b = SimTime::micros(9);
        assert_eq!(a.saturating_sub(b).as_micros(), 0);
        assert_eq!(b.saturating_sub(a).as_micros(), 4);
    }
}
