//! Small shared utilities: ids, virtual time, hashing, deterministic rng,
//! and a minimal JSON implementation (the build environment is offline —
//! no serde/rand; see Cargo.toml).

pub mod hash;
pub mod ids;
pub mod json;
pub mod rng;
pub mod suggest;
pub mod time;

pub use hash::{fnv1a, ContentHash};
pub use ids::{AvId, IdGen, LinkId, ObjectId, RegionId, RunId, TaskId, WireId, WorkspaceId};
pub use json::Json;
pub use rng::Rng;
pub use suggest::suggest;
pub use time::{SimDuration, SimTime};

/// Deterministic RNG for all simulation randomness. Every run with the same
/// seed reproduces byte-identical traces — a prerequisite for the paper's
/// forensic-reconstruction claims to be testable.
pub fn rng(seed: u64) -> Rng {
    Rng::seed_from_u64(seed)
}
