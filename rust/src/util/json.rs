//! Minimal JSON: a value type, a recursive-descent parser, and a writer.
//!
//! In-tree because the build environment vendors no serde; scope is exactly
//! what Koalja needs — parsing `artifacts/manifest.json` and emitting
//! provenance/metric dumps. Full RFC 8259 syntax for objects, arrays,
//! strings (with escapes), numbers, booleans and null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- accessors ----------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- builders -----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // -- writer ---------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the sequence through
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    s.push_str(
                        std::str::from_utf8(&self.src[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.src[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{
          "format": "hlo-text/return-tuple",
          "artifacts": [
            {"name": "mlp_infer", "inputs": [{"shape": [32, 64], "dtype": "float32"}],
             "outputs": [{"shape": [32, 4], "dtype": "float32"}]}
          ]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("format").unwrap().as_str().unwrap(), "hlo-text/return-tuple");
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("name").unwrap().as_str().unwrap(), "mlp_infer");
        let shape = a.get("inputs").unwrap().idx(0).unwrap().get("shape").unwrap();
        let dims: Vec<usize> = shape.as_arr().unwrap().iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![32, 64]);
        // reparse of emitted text is identical
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::Str("quote\" slash\\ nl\n tab\t".into());
        let text = original.to_string();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::num(32.0).to_string(), "32");
        assert_eq!(Json::num(1.5).to_string(), "1.5");
    }

    #[test]
    fn nested_access_helpers() {
        let v = Json::parse(r#"{"a": {"b": [1, 2, {"c": true}]}}"#).unwrap();
        let c = v.get("a").unwrap().get("b").unwrap().idx(2).unwrap().get("c").unwrap();
        assert_eq!(c.as_bool(), Some(true));
        assert!(v.get("missing").is_none());
        assert!(v.idx(0).is_none());
    }
}
