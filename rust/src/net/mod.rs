//! Regions, WAN links and sovereignty zones — §IV, figs. 11–12.
//!
//! "Tasks should be freely locatable in any region, with transparent
//! interconnection between Kubernetes deployments" (§III-B) — but crossing
//! regions costs latency/bandwidth/energy, and sovereignty policy may
//! forbid raw data from leaving its zone at all ("US data cannot leave the
//! virtual boundary of the US", §III-L). This module is the substrate both
//! constraints live in.

use crate::av::DataClass;
use crate::obs::NetTier;
use crate::util::{RegionId, SimDuration};

use std::collections::HashMap;

/// One cloud region / edge site.
#[derive(Clone, Debug)]
pub struct Region {
    pub id: RegionId,
    pub name: String,
    /// Sovereignty zone tag ("eu", "us", "af-east", ...). Raw data may not
    /// cross zone boundaries; summaries may.
    pub zone: String,
    /// Edge sites have little compute; datacentres have a lot. Used by the
    /// placement policy in `cluster`.
    pub is_edge: bool,
}

/// Point-to-point WAN link model.
#[derive(Clone, Copy, Debug)]
pub struct WanLink {
    pub rtt: SimDuration,
    pub gbps: f64,
    /// $/GB — for the cost accounting of E7.
    pub dollars_per_gb: f64,
}

impl WanLink {
    pub fn transfer_time(&self, bytes: u64) -> SimDuration {
        let serialize_us = (bytes as f64 * 8.0) / (self.gbps * 1e3); // bits / (Gb/s) -> us
        // Nonzero payloads always pay at least 1 µs of serialization:
        // rounding small transfers to a free 0 µs made a 100-byte hop on a
        // 10 Gbps link indistinguishable from no transfer at all, which in
        // turn let byte-count regressions hide below the clock's tick.
        let serialize = if bytes == 0 { 0 } else { (serialize_us.ceil() as u64).max(1) };
        SimDuration::micros(self.rtt.as_micros() + serialize)
    }
}

/// What a sovereignty check decides.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransferVerdict {
    /// In-region move (no WAN involved).
    LocalOk,
    /// Cross-region, allowed.
    WanOk,
    /// Cross-region, forbidden by sovereignty policy.
    Denied,
}

/// The region graph.
#[derive(Clone, Debug, Default)]
pub struct WanTopology {
    pub regions: Vec<Region>,
    links: HashMap<(RegionId, RegionId), WanLink>,
    /// Default link used between regions with no explicit entry.
    pub default_link: Option<WanLink>,
}

impl WanTopology {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_region(&mut self, name: &str, zone: &str, is_edge: bool) -> RegionId {
        let id = RegionId::new(self.regions.len() as u64);
        self.regions.push(Region { id, name: name.to_string(), zone: zone.to_string(), is_edge });
        id
    }

    pub fn region(&self, id: RegionId) -> &Region {
        &self.regions[id.index()]
    }

    pub fn by_name(&self, name: &str) -> Option<RegionId> {
        self.regions.iter().find(|r| r.name == name).map(|r| r.id)
    }

    /// Symmetric link registration.
    pub fn connect(&mut self, a: RegionId, b: RegionId, link: WanLink) {
        self.links.insert((a, b), link);
        self.links.insert((b, a), link);
    }

    pub fn link(&self, a: RegionId, b: RegionId) -> Option<WanLink> {
        if a == b {
            return None;
        }
        self.links.get(&(a, b)).copied().or(self.default_link)
    }

    /// Sovereignty verdict for moving `class` data from `a` to `b`
    /// (fig. 11: monthly aggregates may leave, raw records may not).
    pub fn check(&self, class: DataClass, a: RegionId, b: RegionId) -> TransferVerdict {
        if a == b {
            return TransferVerdict::LocalOk;
        }
        let (za, zb) = (&self.region(a).zone, &self.region(b).zone);
        match class {
            DataClass::Raw if za != zb => TransferVerdict::Denied,
            _ => TransferVerdict::WanOk,
        }
    }

    /// Latency + tier for a transfer of `bytes` from `a` to `b`, or None if
    /// denied. In-region transfers ride the LAN storage network.
    pub fn plan_transfer(
        &self,
        class: DataClass,
        a: RegionId,
        b: RegionId,
        bytes: u64,
    ) -> Option<(SimDuration, NetTier)> {
        match self.check(class, a, b) {
            TransferVerdict::LocalOk => Some((SimDuration::ZERO, NetTier::Lan)),
            TransferVerdict::Denied => None,
            TransferVerdict::WanOk => {
                let link = self.link(a, b).unwrap_or(WanLink {
                    rtt: SimDuration::millis(80),
                    gbps: 1.0,
                    dollars_per_gb: 0.08,
                });
                Some((link.transfer_time(bytes), NetTier::Wan))
            }
        }
    }

    /// The non-edge region closest (by rtt) to `from` — used by the
    /// centralized baseline and by summary-aggregation placement.
    pub fn nearest_datacentre(&self, from: RegionId) -> Option<RegionId> {
        self.regions
            .iter()
            .filter(|r| !r.is_edge)
            .min_by_key(|r| {
                if r.id == from {
                    SimDuration::ZERO
                } else {
                    self.link(from, r.id).map(|l| l.rtt).unwrap_or(SimDuration::secs(10))
                }
            })
            .map(|r| r.id)
    }
}

/// A ready-made topology for the examples/benches: one central datacentre
/// ("central/us"), one EU datacentre, plus `n_edge` edge sites split
/// between the two zones.
pub fn demo_topology(n_edge: usize) -> WanTopology {
    let mut t = WanTopology::new();
    let central = t.add_region("central", "us", false);
    let eu = t.add_region("eu-dc", "eu", false);
    t.connect(
        central,
        eu,
        WanLink { rtt: SimDuration::millis(90), gbps: 10.0, dollars_per_gb: 0.05 },
    );
    for i in 0..n_edge {
        let zone = if i % 2 == 0 { "us" } else { "eu" };
        let e = t.add_region(&format!("edge-{i}"), zone, true);
        let dc = if i % 2 == 0 { central } else { eu };
        t.connect(
            e,
            dc,
            WanLink { rtt: SimDuration::millis(25), gbps: 0.2, dollars_per_gb: 0.09 },
        );
        t.connect(
            e,
            if dc == central { eu } else { central },
            WanLink { rtt: SimDuration::millis(120), gbps: 0.1, dollars_per_gb: 0.12 },
        );
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_includes_rtt_and_serialization() {
        let l = WanLink { rtt: SimDuration::millis(10), gbps: 1.0, dollars_per_gb: 0.1 };
        // 1 MB over 1 Gbps = 8 ms serialization + 10 ms rtt
        let t = l.transfer_time(1_000_000);
        assert_eq!(t.as_micros(), 10_000 + 8_000);
    }

    #[test]
    fn transfer_time_is_monotone_in_bytes() {
        // fast link, tiny payloads: the old `.round()` mapped 1..=62 bytes
        // to a free 0 µs of serialization, so time was flat where it should
        // grow. Now every nonzero payload costs >= 1 µs and the curve is
        // non-decreasing in bytes.
        let l = WanLink { rtt: SimDuration::millis(1), gbps: 10.0, dollars_per_gb: 0.05 };
        assert_eq!(l.transfer_time(0).as_micros(), 1_000, "empty transfer is pure rtt");
        assert_eq!(l.transfer_time(1).as_micros(), 1_001, "one byte is never free");
        let mut last = SimDuration::ZERO;
        for bytes in [0u64, 1, 62, 63, 1_000, 10_000, 1_000_000, 10_000_000] {
            let t = l.transfer_time(bytes);
            assert!(t >= last, "transfer_time({bytes}) = {t:?} dropped below {last:?}");
            last = t;
        }
    }

    #[test]
    fn raw_data_cannot_cross_zones() {
        let t = demo_topology(2);
        let us_edge = t.by_name("edge-0").unwrap();
        let eu_dc = t.by_name("eu-dc").unwrap();
        let central = t.by_name("central").unwrap();
        assert_eq!(t.check(DataClass::Raw, us_edge, eu_dc), TransferVerdict::Denied);
        assert_eq!(t.check(DataClass::Raw, us_edge, central), TransferVerdict::WanOk);
        assert_eq!(t.check(DataClass::Summary, us_edge, eu_dc), TransferVerdict::WanOk);
        assert_eq!(t.check(DataClass::Ghost, us_edge, eu_dc), TransferVerdict::WanOk);
    }

    #[test]
    fn in_region_is_lan() {
        let t = demo_topology(1);
        let c = t.by_name("central").unwrap();
        let (lat, tier) = t.plan_transfer(DataClass::Raw, c, c, 1 << 20).unwrap();
        assert_eq!(tier, NetTier::Lan);
        assert_eq!(lat, SimDuration::ZERO);
    }

    #[test]
    fn denied_transfer_plans_to_none() {
        let t = demo_topology(2);
        let us_edge = t.by_name("edge-0").unwrap();
        let eu_dc = t.by_name("eu-dc").unwrap();
        assert!(t.plan_transfer(DataClass::Raw, us_edge, eu_dc, 1024).is_none());
    }

    #[test]
    fn nearest_datacentre_prefers_same_zone() {
        let t = demo_topology(4);
        let us_edge = t.by_name("edge-0").unwrap();
        let eu_edge = t.by_name("edge-1").unwrap();
        assert_eq!(t.nearest_datacentre(us_edge), t.by_name("central"));
        assert_eq!(t.nearest_datacentre(eu_edge), t.by_name("eu-dc"));
        // a datacentre is its own nearest
        let c = t.by_name("central").unwrap();
        assert_eq!(t.nearest_datacentre(c), Some(c));
    }
}
