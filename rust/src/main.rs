//! `koalja` — the leader binary: deploy wiring specs, run them on synthetic
//! arrivals, inspect AOT artifacts, dump provenance.
//!
//! Arg parsing is hand-rolled (offline build: no clap); the surface is
//! deliberately small — the library API is the real interface, see
//! `examples/`.

use anyhow::{anyhow, bail, Context, Result};
use koalja::prelude::*;
use koalja::provenance::ProvenanceQuery;

const USAGE: &str = "\
koalja — smart data plumbing for the extended cloud (Koalja reproduction)

USAGE:
  koalja run <spec.koalja> [--seconds N] [--rate-ms M] [--ghost]
      Deploy a wiring spec; feed synthetic tensors into every external
      wire for N virtual seconds (default 10) at one arrival per M ms
      (default 200); print the metrics report. --ghost sends wireframe
      batches instead (§III-K).

  koalja soak <spec.koalja> [--seconds N] [--rate-ms M] [--capacity C]
              [--events E]
      Streaming-ingestion soak: open a bounded feed on every external
      wire, push timestamped events from one real producer thread per
      feed (watermarks advanced as they go) while the main thread pumps
      them into the pipeline with adaptive batching; print the ingest
      report and the metrics. --capacity sets the per-feed queue bound
      (default 1024); --events caps events per feed (also via
      KOALJA_SOAK_EVENTS, for bounded CI runs).

  koalja check <spec.koalja>
      Parse + validate a spec; print tasks, wires, in-trays and sinks.

  koalja artifacts [dir]
      List the AOT manifest and compile every artifact on the PJRT CPU
      client (default dir: ./artifacts).

  koalja trace <spec.koalja> [--grep PAT] [--spans N] [--json DIR]
      Run a short synthetic session with the flight recorder on; print
      the per-task and per-wire observability tables, the wavefront
      occupancy summary, and a span dump with names resolved (--grep
      filters spans by task/wire/event substring; --spans caps the dump,
      default 40). Every firing's run id is checked against the
      provenance checkpoint ledger, and the schema'd obs snapshot is
      exported as JSON (default dir: artifacts/obs).

  koalja bread <spec.koalja> [--swap TASK] [--seconds N]
      Scripted breadboard session (§III-H): attach live wire taps (plus
      the obs registry's per-wire counters) to every wire, stream
      synthetic data, hot-swap TASK (default: the producer of
      the first sink) with a dry-run invalidation preview and a version
      bump, then forensically replay the whole run from the provenance
      ledger + seed — the pre-swap window shows hash drift (old software),
      the post-swap window rebuilds hash-identical.

  koalja demo
      The paper's fig. 5 'tfmodel' wiring on synthetic data.
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args[1..]),
        Some("soak") => cmd_soak(&args[1..]),
        Some("check") => cmd_check(&args[1..]),
        Some("artifacts") => cmd_artifacts(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("bread") => cmd_bread(&args[1..]),
        Some("demo") => cmd_demo(),
        Some("--help") | Some("-h") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn load_spec(path: &str) -> Result<koalja::spec::PipelineSpec> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let spec = parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    spec.validate().map_err(|e| anyhow!("{path}: {e}"))?;
    Ok(spec)
}

fn cmd_check(args: &[String]) -> Result<()> {
    let path = args.first().ok_or_else(|| anyhow!("check: missing spec path"))?;
    let spec = load_spec(path)?;
    println!("pipeline [{}]: {} tasks", spec.name, spec.tasks.len());
    for t in &spec.tasks {
        let ins: Vec<&str> = t.inputs.iter().map(|i| i.wire.as_str()).collect();
        println!("  {} <- ({}) -> ({})", t.name, ins.join(", "), t.outputs.join(", "));
    }
    println!("in-trays (external wires): {:?}", spec.external_wires());
    println!("sinks: {:?}", spec.sink_wires());
    let graph = koalja::graph::PipelineGraph::build(&spec);
    let cyclic = graph.cyclic_tasks();
    if cyclic.is_empty() {
        println!("acyclic (pure DAG)");
    } else {
        println!("contains cycles through {} task(s) — legal DCG", cyclic.len());
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let path = args.first().ok_or_else(|| anyhow!("run: missing spec path"))?;
    let spec = load_spec(path)?;
    let seconds: u64 = flag_value(args, "--seconds").map(|v| v.parse()).transpose()?.unwrap_or(10);
    let rate_ms: u64 = flag_value(args, "--rate-ms").map(|v| v.parse()).transpose()?.unwrap_or(200);
    let ghost = args.iter().any(|a| a == "--ghost");

    let mut pipe = Pipeline::deploy(&spec, DeployConfig::default())?;
    // resolve every in-tray once; the feed loop below runs purely on handles
    let sources: Vec<SourceHandle> = pipe.sources().to_vec();
    if sources.is_empty() {
        bail!("spec has no external wires to feed");
    }
    let mut r = rng(7);
    let horizon = SimTime::secs(seconds);
    for src in &sources {
        let mut t = SimTime::ZERO;
        loop {
            t += SimDuration::millis(rate_ms).scale(r.exp1());
            if t > horizon {
                break;
            }
            if ghost {
                src.inject_at(
                    &mut pipe,
                    Payload::Ghost { pretend_bytes: 1 << 20 },
                    DataClass::Ghost,
                    RegionId::new(0),
                    t,
                );
            } else {
                let data: Vec<f32> = (0..8).map(|_| r.normal() as f32).collect();
                src.inject_at(
                    &mut pipe,
                    Payload::tensor(&[1, 8], data),
                    DataClass::Summary,
                    RegionId::new(0),
                    t,
                );
            }
        }
    }
    pipe.run_until(horizon);
    pipe.run_until_idle();
    println!("[{}] {} virtual seconds, ghost={}", spec.name, seconds, ghost);
    println!("{}", pipe.plat.metrics.report());
    for sink in pipe.sinks() {
        println!("sink '{}': {} artifacts", sink.name(&pipe), sink.count(&pipe));
    }
    Ok(())
}

/// Live-ingestion counterpart of `cmd_run`: the same synthetic arrival
/// process, but pushed through bounded feeds by real producer threads
/// concurrently with execution, instead of pre-injected into a quiescent
/// coordinator. Exercises the whole ingest path — backpressure, watermark
/// gating, adaptive batching — and prints its report.
fn cmd_soak(args: &[String]) -> Result<()> {
    let path = args.first().ok_or_else(|| anyhow!("soak: missing spec path"))?;
    let spec = load_spec(path)?;
    let seconds: u64 = flag_value(args, "--seconds").map(|v| v.parse()).transpose()?.unwrap_or(10);
    let rate_ms: u64 = flag_value(args, "--rate-ms").map(|v| v.parse()).transpose()?.unwrap_or(50);
    let capacity: usize = flag_value(args, "--capacity")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(koalja::ingest::DEFAULT_FEED_CAPACITY);
    let events_cap: u64 = match flag_value(args, "--events") {
        Some(v) => v.parse()?,
        None => std::env::var("KOALJA_SOAK_EVENTS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(u64::MAX),
    };

    let mut pipe = Pipeline::deploy(&spec, DeployConfig::default())?;
    let wires = spec.external_wires();
    if wires.is_empty() {
        bail!("spec has no external wires to feed");
    }
    let mut feeds: Vec<FeedHandle> = Vec::new();
    for w in &wires {
        feeds.push(pipe.open_feed_with(w, capacity)?);
    }
    let horizon = SimTime::secs(seconds);

    let report = std::thread::scope(|s| {
        for (i, feed) in feeds.iter().enumerate() {
            let feed = feed.clone();
            s.spawn(move || {
                let mut r = rng(41 + i as u64);
                let mut t = SimTime::ZERO;
                let mut sent = 0u64;
                while sent < events_cap {
                    let mut dt = SimDuration::millis(rate_ms).scale(r.exp1());
                    if dt.as_micros() == 0 {
                        dt = SimDuration::micros(1); // watermark needs strict progress
                    }
                    t += dt;
                    if t > horizon {
                        break;
                    }
                    let data: Vec<f32> = (0..8).map(|_| r.normal() as f32).collect();
                    feed.push(
                        t,
                        Payload::tensor(&[1, 8], data),
                        DataClass::Summary,
                        RegionId::new(0),
                    )
                    .expect("producer pushes strictly ahead of its own watermark");
                    feed.advance(t).expect("watermark advances monotonically");
                    sent += 1;
                }
                feed.close();
            });
        }
        // producers block on queue credit, so any deadline generous enough
        // for the offered load works; 60s is a stall backstop, not a pace
        pipe.pump_ingest(std::time::Duration::from_secs(60))
    });

    println!("[{}] soak: {} virtual seconds, {} feed(s)", spec.name, seconds, feeds.len());
    let st = &report.stats;
    println!(
        "ingest: {} events / {} batches (mean {:.1}, largest {}), {} cycles ({} parked)",
        st.events,
        st.batches,
        st.mean_batch(),
        st.largest_batch,
        st.cycles,
        st.parked
    );
    println!(
        "        depth high-water {}/{capacity}, {} try_push rejections, \
         watermark lag max {} us",
        st.depth_high_water,
        st.backpressure_rejections,
        st.watermark_lag_max.as_micros()
    );
    if report.timed_out {
        println!("        drain deadline hit before all feeds closed");
    }
    for sf in &report.stalled {
        println!(
            "        stalled feed '{}': watermark {:?} lags the lead by {} us",
            sf.feed,
            sf.watermark,
            sf.behind.as_micros()
        );
    }
    println!("{}", pipe.plat.metrics.report());
    for sink in pipe.sinks() {
        println!("sink '{}': {} artifacts", sink.name(&pipe), sink.count(&pipe));
    }
    Ok(())
}

fn cmd_artifacts(args: &[String]) -> Result<()> {
    let dir = args
        .first()
        .cloned()
        .unwrap_or_else(|| Runtime::default_dir().display().to_string());
    let mut rt = Runtime::open(&dir)?;
    println!("platform: {}", rt.platform());
    let names: Vec<String> = rt.manifest().iter().map(|m| m.name.clone()).collect();
    for name in names {
        let exe = rt.load(&name)?;
        let m = &exe.meta;
        let ins: Vec<String> = m.inputs.iter().map(|t| format!("{:?}", t.shape)).collect();
        let outs: Vec<String> = m.outputs.iter().map(|t| format!("{:?}", t.shape)).collect();
        println!("  {:16} {} -> {}  ({})", m.name, ins.join(","), outs.join(","), m.doc);
    }
    println!("all artifacts compiled OK");
    Ok(())
}

/// Traced synthetic session: feed every in-tray, then render the flight
/// recorder + id-indexed metrics (`Coordinator::obs`) as tables and a
/// span dump, cross-check firing run ids against the provenance
/// checkpoint ledger, and export the schema'd JSON snapshot.
fn cmd_trace(args: &[String]) -> Result<()> {
    use koalja::obs::NO_RUN;
    use koalja::util::TaskId;

    let path = args.first().ok_or_else(|| anyhow!("trace: missing spec path"))?;
    let spec = load_spec(path)?;
    let grep = flag_value(args, "--grep");
    let span_cap: usize =
        flag_value(args, "--spans").map(|v| v.parse()).transpose()?.unwrap_or(40);
    let json_dir = flag_value(args, "--json").unwrap_or_else(|| "artifacts/obs".into());

    let mut pipe = Pipeline::deploy(&spec, DeployConfig { trace: true, ..Default::default() })?;
    let mut r = rng(11);
    for src in pipe.sources().to_vec() {
        for i in 0..3u64 {
            let data: Vec<f32> = (0..4).map(|_| r.normal() as f32).collect();
            src.inject_at(
                &mut pipe,
                Payload::tensor(&[1, 4], data),
                DataClass::Summary,
                RegionId::new(0),
                SimTime::millis(i * 50),
            );
        }
    }
    pipe.run_until_idle();

    let obs = pipe.obs();
    let tname = |t: TaskId| pipe.graph.task(t).name.as_str();
    let wname = |w: WireId| pipe.graph.wires.name(w);

    println!(
        "[{}] traced session: {} spans recorded ({} retained, {} evicted)",
        spec.name,
        obs.rec.recorded(),
        obs.rec.len(),
        obs.rec.dropped()
    );
    let wf = obs.wavefront;
    println!(
        "wavefront: {} instants / {} firings, max width {}, {} parallel instants, \
         {} deferred ({} rollbacks)",
        wf.instants, wf.firings, wf.max_width, wf.parallel_instants, wf.deferred, wf.rollbacks
    );

    // per-task table, busiest first; the last three columns come from the
    // cluster substrate (scale-to-zero lifecycle), not the obs registry
    println!(
        "\n  task              firings  memo  errs  defer  rollbk  mean_us  p99_us  cold  repl  dwell_ms"
    );
    let mut rows: Vec<(usize, &TaskStats)> = obs.all_task_stats().iter().enumerate().collect();
    rows.sort_by(|a, b| b.1.firings.cmp(&a.1.firings).then(a.0.cmp(&b.0)));
    let now = pipe.plat.now;
    for (i, t) in rows.iter().take(10) {
        let task = TaskId::new(*i as u64);
        println!(
            "  {:<18} {:>6} {:>5} {:>5} {:>6} {:>7} {:>8} {:>7} {:>5} {:>5} {:>9}",
            tname(task),
            t.firings,
            t.memo_hits,
            t.errors,
            t.deferred,
            t.rollbacks,
            t.latency.mean().as_micros(),
            t.latency.quantile(0.99).as_micros(),
            pipe.plat.cluster.cold_starts(task),
            pipe.plat.cluster.replicas(task),
            pipe.plat.cluster.zero_dwell(task, now).as_micros() / 1_000,
        );
    }
    if rows.len() > 10 {
        println!("  … {} more tasks (full set in the JSON snapshot)", rows.len() - 10);
    }

    // per-wire table (only wires that saw traffic)
    println!("\n  wire               publ   inj  sink      bytes");
    for (i, w) in obs.all_wire_stats().iter().enumerate() {
        if w.publications + w.injections + w.sink_commits == 0 {
            continue;
        }
        println!(
            "  {:<18} {:>4} {:>5} {:>5} {:>10}",
            wname(WireId::new(i as u32)),
            w.publications,
            w.injections,
            w.sink_commits,
            w.bytes
        );
    }

    // sharded runtime: the node partition and what the exchange moved
    let shard = pipe.shard();
    if shard.nodes > 1 {
        println!("\nshard plan: {} node(s)", shard.nodes);
        for node in 0..shard.nodes {
            let mine: Vec<&str> =
                shard.tasks_of[node].iter().map(|&t| tname(t)).collect();
            println!("  node {node}: [{}]", mine.join(", "));
        }
    }
    let ex_totals = pipe.exchange().totals();
    if ex_totals.transfers + ex_totals.denied > 0 {
        println!("\n  exchange channel               tier  xfers      bytes    wan_us  denied");
        for (_, ch) in pipe.exchange().channels() {
            if ch.stat.transfers + ch.stat.denied == 0 {
                continue;
            }
            println!(
                "  {:<18} n{} -> n{}  {:>4} {:>6} {:>10} {:>9} {:>7}",
                wname(ch.wire),
                ch.from_node,
                ch.to_node,
                match ch.tier {
                    koalja::obs::NetTier::Wan => "wan",
                    koalja::obs::NetTier::Lan => "lan",
                    koalja::obs::NetTier::Local => "loc",
                },
                ch.stat.transfers,
                ch.stat.bytes,
                ch.stat.wan_us,
                ch.stat.denied,
            );
        }
        println!(
            "  totals: {} transfer(s), {} B, {} WAN us, {:.3} J, {} denied",
            ex_totals.transfers, ex_totals.bytes, ex_totals.wan_us, ex_totals.joules,
            ex_totals.denied
        );
    }
    for e in pipe.sovereignty_errors() {
        println!("\nsovereignty error at {}: {}", e.at, e.error);
    }

    // every execution span's run id must resolve in the checkpoint ledger
    // — the join the ids were interned for
    let mut ledger_runs = std::collections::HashSet::new();
    for i in 0..pipe.graph.n_tasks() {
        for e in pipe.plat.prov.checkpoint_log(TaskId::new(i as u64)) {
            ledger_runs.insert(e.run);
        }
    }
    let (mut firing_spans, mut resolved) = (0u64, 0u64);
    for s in obs.rec.spans() {
        if let Some(run) = s.event.run() {
            firing_spans += 1;
            if ledger_runs.contains(&run) {
                resolved += 1;
            }
        }
    }
    println!(
        "\nprovenance join: {resolved}/{firing_spans} firing run ids resolve in the \
         checkpoint ledger"
    );

    // span dump, names resolved; --grep filters on the rendered line
    let render = |s: &koalja::obs::Span| -> String {
        let detail = match s.event {
            SpanEvent::InjectBatch { wire, count } => format!("{} x{count}", wname(wire)),
            SpanEvent::InstantDrain { events } => format!("{events} events"),
            SpanEvent::WavefrontExtract { width }
            | SpanEvent::WavefrontExecute { width }
            | SpanEvent::WavefrontCommit { width } => format!("width {width}"),
            SpanEvent::Firing { task, run, kind } if run == NO_RUN => {
                format!("{} [{}]", tname(task), kind.as_str())
            }
            SpanEvent::Firing { task, run, kind } => {
                format!("{} [{}] {run}", tname(task), kind.as_str())
            }
            SpanEvent::Publish { task, wire, av, bytes } => {
                format!("{} -> {} {av} ({bytes} B)", tname(task), wname(wire))
            }
            SpanEvent::SinkCommit { wire, av } => format!("{} {av}", wname(wire)),
            SpanEvent::TapObserve { wire, av } => format!("{} {av}", wname(wire)),
            SpanEvent::Demand { wire } => wname(wire).to_string(),
            SpanEvent::FiringRetry { task, run, attempt } => {
                format!("{} attempt {attempt} failed, retry scheduled {run}", tname(task))
            }
            SpanEvent::FiringExhausted { task, run, attempts } if attempts == 0 => {
                format!("{} dropped by open breaker {run}", tname(task))
            }
            SpanEvent::FiringExhausted { task, run, attempts } => {
                format!("{} after {attempts} attempt(s) {run}", tname(task))
            }
            SpanEvent::Quarantine { task, open } => {
                format!("{} [{}]", tname(task), if open { "open" } else { "reset" })
            }
            SpanEvent::Redrive { task, count } => {
                format!("{} x{count} dead-lettered firing(s)", tname(task))
            }
            SpanEvent::FiringDegraded { task, run } => {
                format!("{} fallback emitted {run}", tname(task))
            }
            SpanEvent::Transfer { wire, from, to, bytes, tier } => {
                format!("{} n{from} -> n{to} ({bytes} B, {tier:?})", wname(wire))
            }
            SpanEvent::IngestFlush { events, batches } => {
                format!("{events} event(s) in {batches} batch(es)")
            }
        };
        format!("  {:>6}  t+{:>9}us  {:<18} {detail}", s.seq, s.at.as_micros(), s.event.name())
    };
    let lines: Vec<String> = obs
        .rec
        .spans()
        .map(render)
        .filter(|l| grep.as_deref().map_or(true, |g| l.contains(g)))
        .collect();
    match &grep {
        Some(g) => println!("\nspans matching '{g}': {}", lines.len()),
        None => println!("\nspans (last {} of {} retained):", span_cap.min(lines.len()), lines.len()),
    }
    let skip = lines.len().saturating_sub(span_cap);
    if skip > 0 {
        println!("  … {skip} earlier spans elided (--spans N to widen)");
    }
    for l in lines.iter().skip(skip) {
        println!("{l}");
    }

    // schema'd JSON export — the same artifact ci.sh publishes
    std::fs::create_dir_all(&json_dir).with_context(|| format!("creating {json_dir}"))?;
    let out = format!("{json_dir}/{}_obs.json", spec.name);
    std::fs::write(&out, pipe.obs_snapshot().to_string()).with_context(|| format!("writing {out}"))?;
    println!("\nobs snapshot -> {out}");
    Ok(())
}

/// Scripted breadboard session: tap → observe → hot-swap (dry-run first)
/// → forensic replay with drift diff. Exercises the whole §III-H/J loop
/// on any spec; exits nonzero if the post-swap window fails to rebuild
/// hash-identical (the determinism self-check).
fn cmd_bread(args: &[String]) -> Result<()> {
    use koalja::breadboard::Breadboard;
    use koalja::task::{PortIo, TaskCode};

    let path = args.first().ok_or_else(|| anyhow!("bread: missing spec path"))?;
    let spec = load_spec(path)?;
    let asked: u64 = flag_value(args, "--seconds").map(|v| v.parse()).transpose()?.unwrap_or(8);
    // the script needs room for a pre-swap window AND a post-swap window;
    // below 6 virtual seconds the second feed would be empty and the final
    // certification vacuous
    let seconds = asked.max(6);
    if seconds != asked {
        println!("note: --seconds raised {asked} -> {seconds} (two observation windows needed)");
    }

    // pick the swap target: --swap TASK, else the producer of the first sink
    let swap_task = match flag_value(args, "--swap") {
        Some(t) => t,
        None => {
            let sink = spec
                .sink_wires()
                .first()
                .cloned()
                .ok_or_else(|| anyhow!("bread: spec has no sink wire to demo on"))?;
            spec.tasks
                .iter()
                .find(|t| t.outputs.contains(&sink))
                .map(|t| t.name.clone())
                .ok_or_else(|| anyhow!("bread: no producer of sink '{sink}'"))?
        }
    };
    let wires_in = spec.external_wires();
    if wires_in.is_empty() {
        bail!("bread: spec has no external wires to feed");
    }

    // the session runs as a workspace principal with explicit grants (§IV),
    // with the flight recorder on so live wire counters sit next to the taps
    let mut bread = Breadboard::deploy(&spec, DeployConfig { trace: true, ..Default::default() })?
        .as_principal("operator");
    let ws = bread.plat.workspaces.create("breadboard");
    bread.plat.workspaces.add_member(ws, "operator");
    bread.plat.workspaces.grant(ws, koalja::workspace::Resource::Pipeline(spec.name.clone()));
    bread.plat.workspaces.grant(ws, koalja::workspace::Resource::Provenance(spec.name.clone()));
    // typed handles, resolved once: in-trays for the feed loop, the swap target
    let sources: Vec<SourceHandle> = bread.sources().to_vec();
    let swap_handle = bread.task(&swap_task)?;

    // 1. taps on every wire in the diagram
    let mut all_wires: Vec<String> = Vec::new();
    for t in &spec.tasks {
        for i in t.stream_inputs() {
            if !all_wires.contains(&i.wire) {
                all_wires.push(i.wire.clone());
            }
        }
        for o in &t.outputs {
            if !all_wires.contains(o) {
                all_wires.push(o.clone());
            }
        }
    }
    let mut taps = Vec::new();
    for w in &all_wires {
        bread.plat.workspaces.grant(ws, koalja::workspace::Resource::Wire(w.clone()));
        taps.push((w.clone(), bread.tap(w)?));
    }
    println!("[{}] breadboard up: {} wires tapped, swap target '{swap_task}'", spec.name, taps.len());

    // 2. first half: stream synthetic tensors under the original software
    let half = SimTime::secs(seconds / 2 + 1);
    let mut r = rng(23);
    let feed = |bread: &mut Breadboard, from_ms: u64, to_ms: u64, r: &mut koalja::util::Rng| {
        for src in &sources {
            let mut t = from_ms;
            while t < to_ms {
                let data: Vec<f32> = (0..8).map(|_| r.normal() as f32).collect();
                src.inject_at(
                    bread,
                    Payload::tensor(&[1, 8], data),
                    DataClass::Summary,
                    RegionId::new(0),
                    SimTime::millis(t),
                );
                t += 250;
            }
        }
    };
    feed(&mut bread, 0, half.as_micros() / 1_000 - 500, &mut r);
    bread.run_until_idle();
    bread.run_until(half);
    let t_swap = bread.plat.now;

    println!("\n-- live taps after first window --");
    for (wire, id) in &taps {
        let stats = bread.tap_stats(*id)?.unwrap();
        let last = bread.samples(*id)?.last().map(|s| s.av.uri());
        println!(
            "  tap {wire:16} seen={:4} sampled={:4} dropped={:3} last={}",
            stats.seen,
            stats.sampled,
            stats.dropped,
            last.unwrap_or_else(|| "-".into())
        );
        // the obs registry's panel meter for the same wire
        if let Some(c) = bread.wire_counters(wire)? {
            println!(
                "  obs {wire:16} publ={:4} inj={:8} sink={:6} bytes={}",
                c.publications, c.injections, c.sink_commits, c.bytes
            );
        }
    }

    // 3. hot-swap: dry-run preview, then commit a v2 that doubles tensors
    let old_v = swap_handle.version(&bread);
    let new_v = old_v + 1;
    let preview = bread.swap_preview_task(swap_handle, new_v)?;
    println!("\n-- dry-run -- {}", preview.summary());
    // port-native v2: emit the doubled tensor on every declared output
    // port — resolved by index, no wire names anywhere in the loop
    let factory = move || -> Box<dyn TaskCode> {
        Box::new(PortFn::versioned(
            move |ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
                for av in io.inputs.snapshot().all_avs() {
                    let p = ctx.fetch(av)?;
                    let doubled = match p.as_tensor() {
                        Some((shape, data)) => {
                            Payload::tensor(shape, data.iter().map(|x| x * 2.0).collect())
                        }
                        None => p,
                    };
                    for i in 0..io.outs().len() {
                        let port = io.out(i)?;
                        io.emitter.emit_class(port, doubled.clone(), av.class);
                    }
                }
                Ok(())
            },
            new_v,
        ))
    };
    let outcome = bread.hot_swap_task(swap_handle, factory, false)?;
    println!(
        "committed at {}: cache evicted {} entries / {} B downstream",
        outcome.at, outcome.cache_objects_evicted, outcome.cache_bytes_evicted
    );

    // 4. second half under the new software
    feed(
        &mut bread,
        t_swap.as_micros() / 1_000 + 500,
        seconds * 1_000,
        &mut r,
    );
    bread.run_until_idle();
    let t_end = bread.plat.now;

    // 5. the version bump is visible in provenance, straight off the handle
    for (at, from, to) in swap_handle.version_changes(&bread) {
        println!("\nprovenance: '{swap_task}' version {from} -> {to} at {at}");
    }
    if let Some(col) = bread.sinks().iter().filter_map(|s| s.latest(&bread)).next() {
        let q = ProvenanceQuery::new(&bread.plat.prov);
        println!(
            "latest sink artifact {} touched by versions {:?}",
            col.av.id,
            q.versions_touching(col.av.id)
        );
    }

    // 6. forensic replay: rebuild everything from ledger + seed and diff
    let run = bread.forensic_replay()?;
    println!(
        "\nreplayed {} injections ({} payloads missing) in {} events",
        run.injections_replayed, run.missing_payloads, run.events
    );
    let pre = bread.diff_replay(&run, SimTime::ZERO, t_swap);
    let post = bread.diff_replay(&run, t_swap, koalja::breadboard::WINDOW_END);
    let _ = t_end;
    println!("  pre-swap  {}", pre.summary());
    println!("  post-swap {}", post.summary());
    if post.total_matched() == 0 && post.total_drifted() == 0 {
        bail!("post-swap window recorded no outputs — nothing to certify (pipeline produced nothing after the swap)");
    }
    if !post.drift_free() {
        bail!("post-swap window failed to rebuild hash-identical (determinism broken)");
    }
    println!(
        "post-swap window certified: {} rebuilt content hashes match the record",
        post.total_matched()
    );
    Ok(())
}

fn cmd_demo() -> Result<()> {
    // fig. 5, wired programmatically — the builder lowers to exactly the
    // spec the parser would produce from the paper's text
    let mut pipe = PipelineBuilder::new("tfmodel")
        .task("learn-tf").reads("in").emits("model")
        .task("convert").reads("in[10/2]").emits("json")
        .task("predict").reads("json").looks_up("lookup").emits("result")
        .deploy(DeployConfig::default())?;
    pipe.plat.services.register(
        "lookup",
        Box::new(koalja::platform::service::KvService::new(&[("class", "cat")])),
    );
    // resolve handles once; everything after runs on dense ids
    let in_tray = pipe.source("in")?;
    let result = pipe.sink("result")?;
    let predict = pipe.task("predict")?;
    predict.plug(
        &mut pipe,
        Box::new(
            // service lookups need the live directory: sequential-only
            PortFn::new(|ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
                let label = ctx.lookup("lookup", &Payload::Text("class".into()))?;
                let n = io.inputs.all().count() as f32;
                ctx.remark(&format!("classified {n} windows as {label:?}"));
                let result = io.out(0)?;
                io.emitter.emit(result, Payload::scalar(n));
                Ok(())
            })
            .sequential(),
        ),
    )?;
    let mut r = rng(3);
    for i in 0..24u64 {
        let data: Vec<f32> = (0..4).map(|_| r.normal() as f32).collect();
        in_tray.inject_at(
            &mut pipe,
            Payload::tensor(&[1, 4], data),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(i * 100),
        );
    }
    pipe.run_until_idle();
    println!("fig. 5 'tfmodel' on 24 synthetic arrivals:");
    println!("{}", pipe.plat.metrics.report());
    println!("results collected: {}", result.count(&pipe));
    let q = ProvenanceQuery::new(&pipe.plat.prov);
    if let Some(col) = result.latest(&pipe) {
        println!(
            "last result {} derives from {} ancestor artifacts through versions {:?}",
            col.av.id,
            q.ancestors(col.av.id).len(),
            q.versions_touching(col.av.id)
        );
    }
    Ok(())
}
