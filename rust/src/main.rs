//! `koalja` — the leader binary: deploy wiring specs, run them on synthetic
//! arrivals, inspect AOT artifacts, dump provenance.
//!
//! Arg parsing is hand-rolled (offline build: no clap); the surface is
//! deliberately small — the library API is the real interface, see
//! `examples/`.

use anyhow::{anyhow, bail, Context, Result};
use koalja::prelude::*;
use koalja::provenance::ProvenanceQuery;

const USAGE: &str = "\
koalja — smart data plumbing for the extended cloud (Koalja reproduction)

USAGE:
  koalja run <spec.koalja> [--seconds N] [--rate-ms M] [--ghost]
      Deploy a wiring spec; feed synthetic tensors into every external
      wire for N virtual seconds (default 10) at one arrival per M ms
      (default 200); print the metrics report. --ghost sends wireframe
      batches instead (§III-K).

  koalja check <spec.koalja>
      Parse + validate a spec; print tasks, wires, in-trays and sinks.

  koalja artifacts [dir]
      List the AOT manifest and compile every artifact on the PJRT CPU
      client (default dir: ./artifacts).

  koalja trace <spec.koalja>
      Run a short synthetic session, then dump the provenance registry
      (traveller passports, checkpoint logs, concept map) as JSON.

  koalja demo
      The paper's fig. 5 'tfmodel' wiring on synthetic data.
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args[1..]),
        Some("check") => cmd_check(&args[1..]),
        Some("artifacts") => cmd_artifacts(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("demo") => cmd_demo(),
        Some("--help") | Some("-h") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn load_spec(path: &str) -> Result<koalja::spec::PipelineSpec> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let spec = parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    spec.validate().map_err(|e| anyhow!("{path}: {e}"))?;
    Ok(spec)
}

fn cmd_check(args: &[String]) -> Result<()> {
    let path = args.first().ok_or_else(|| anyhow!("check: missing spec path"))?;
    let spec = load_spec(path)?;
    println!("pipeline [{}]: {} tasks", spec.name, spec.tasks.len());
    for t in &spec.tasks {
        let ins: Vec<&str> = t.inputs.iter().map(|i| i.wire.as_str()).collect();
        println!("  {} <- ({}) -> ({})", t.name, ins.join(", "), t.outputs.join(", "));
    }
    println!("in-trays (external wires): {:?}", spec.external_wires());
    println!("sinks: {:?}", spec.sink_wires());
    let graph = koalja::graph::PipelineGraph::build(&spec);
    let cyclic = graph.cyclic_tasks();
    if cyclic.is_empty() {
        println!("acyclic (pure DAG)");
    } else {
        println!("contains cycles through {} task(s) — legal DCG", cyclic.len());
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let path = args.first().ok_or_else(|| anyhow!("run: missing spec path"))?;
    let spec = load_spec(path)?;
    let seconds: u64 = flag_value(args, "--seconds").map(|v| v.parse()).transpose()?.unwrap_or(10);
    let rate_ms: u64 = flag_value(args, "--rate-ms").map(|v| v.parse()).transpose()?.unwrap_or(200);
    let ghost = args.iter().any(|a| a == "--ghost");

    let mut coord = Coordinator::deploy(&spec, DeployConfig::default())?;
    let wires = spec.external_wires();
    if wires.is_empty() {
        bail!("spec has no external wires to feed");
    }
    let mut r = rng(7);
    let horizon = SimTime::secs(seconds);
    for wire in &wires {
        let mut t = SimTime::ZERO;
        loop {
            t += SimDuration::millis(rate_ms).scale(r.exp1());
            if t > horizon {
                break;
            }
            if ghost {
                coord.inject_at(
                    wire,
                    Payload::Ghost { pretend_bytes: 1 << 20 },
                    DataClass::Ghost,
                    RegionId::new(0),
                    t,
                )?;
            } else {
                let data: Vec<f32> = (0..8).map(|_| r.normal() as f32).collect();
                coord.inject_at(
                    wire,
                    Payload::tensor(&[1, 8], data),
                    DataClass::Summary,
                    RegionId::new(0),
                    t,
                )?;
            }
        }
    }
    coord.run_until(horizon);
    coord.run_until_idle();
    println!("[{}] {} virtual seconds, ghost={}", spec.name, seconds, ghost);
    println!("{}", coord.plat.metrics.report());
    for (wire, got) in &coord.collected {
        println!("sink '{}': {} artifacts", wire, got.len());
    }
    Ok(())
}

fn cmd_artifacts(args: &[String]) -> Result<()> {
    let dir = args
        .first()
        .cloned()
        .unwrap_or_else(|| Runtime::default_dir().display().to_string());
    let mut rt = Runtime::open(&dir)?;
    println!("platform: {}", rt.platform());
    let names: Vec<String> = rt.manifest().iter().map(|m| m.name.clone()).collect();
    for name in names {
        let exe = rt.load(&name)?;
        let m = &exe.meta;
        let ins: Vec<String> = m.inputs.iter().map(|t| format!("{:?}", t.shape)).collect();
        let outs: Vec<String> = m.outputs.iter().map(|t| format!("{:?}", t.shape)).collect();
        println!("  {:16} {} -> {}  ({})", m.name, ins.join(","), outs.join(","), m.doc);
    }
    println!("all artifacts compiled OK");
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<()> {
    let path = args.first().ok_or_else(|| anyhow!("trace: missing spec path"))?;
    let spec = load_spec(path)?;
    let mut coord = Coordinator::deploy(&spec, DeployConfig::default())?;
    let mut r = rng(11);
    for wire in spec.external_wires() {
        for i in 0..3u64 {
            let data: Vec<f32> = (0..4).map(|_| r.normal() as f32).collect();
            coord.inject_at(
                &wire,
                Payload::tensor(&[1, 4], data),
                DataClass::Summary,
                RegionId::new(0),
                SimTime::millis(i * 50),
            )?;
        }
    }
    coord.run_until_idle();
    println!("{}", coord.plat.prov.dump_json().to_string());
    Ok(())
}

fn cmd_demo() -> Result<()> {
    // fig. 5, verbatim wiring
    let spec = parse(
        "[tfmodel]\n\
         (in) learn-tf (model)\n\
         (in[10/2]) convert (json)\n\
         (json, lookup?) predict (result)\n",
    )
    .map_err(|e| anyhow!("{e}"))?;
    let mut coord = Coordinator::deploy(&spec, DeployConfig::default())?;
    coord.plat.services.register(
        "lookup",
        Box::new(koalja::platform::service::KvService::new(&[("class", "cat")])),
    );
    coord.set_code(
        "predict",
        Box::new(FnTask::new(|ctx: &mut TaskCtx<'_>, snap: &Snapshot| {
            let label = ctx.lookup("lookup", &Payload::Text("class".into()))?;
            let n = snap.all_avs().count() as f32;
            ctx.remark(&format!("classified {n} windows as {label:?}"));
            Ok(vec![Output::summary("result", Payload::scalar(n))])
        })),
    )?;
    let mut r = rng(3);
    for i in 0..24u64 {
        let data: Vec<f32> = (0..4).map(|_| r.normal() as f32).collect();
        coord.inject_at(
            "in",
            Payload::tensor(&[1, 4], data),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(i * 100),
        )?;
    }
    coord.run_until_idle();
    println!("fig. 5 'tfmodel' on 24 synthetic arrivals:");
    println!("{}", coord.plat.metrics.report());
    println!("results collected: {}", coord.collected_count("result"));
    let q = ProvenanceQuery::new(&coord.plat.prov);
    if let Some(col) = coord.collected.get("result").and_then(|v| v.last()) {
        println!(
            "last result {} derives from {} ancestor artifacts through versions {:?}",
            col.av.id,
            q.ancestors(col.av.id).len(),
            q.versions_touching(col.av.id)
        );
    }
    Ok(())
}
