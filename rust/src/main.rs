//! `koalja` — the leader binary: deploy wiring specs, run them on synthetic
//! arrivals, inspect AOT artifacts, dump provenance.
//!
//! Arg parsing is hand-rolled (offline build: no clap); the surface is
//! deliberately small — the library API is the real interface, see
//! `examples/`.

use anyhow::{anyhow, bail, Context, Result};
use koalja::prelude::*;
use koalja::provenance::ProvenanceQuery;

const USAGE: &str = "\
koalja — smart data plumbing for the extended cloud (Koalja reproduction)

USAGE:
  koalja run <spec.koalja> [--seconds N] [--rate-ms M] [--ghost]
      Deploy a wiring spec; feed synthetic tensors into every external
      wire for N virtual seconds (default 10) at one arrival per M ms
      (default 200); print the metrics report. --ghost sends wireframe
      batches instead (§III-K).

  koalja check <spec.koalja>
      Parse + validate a spec; print tasks, wires, in-trays and sinks.

  koalja artifacts [dir]
      List the AOT manifest and compile every artifact on the PJRT CPU
      client (default dir: ./artifacts).

  koalja trace <spec.koalja>
      Run a short synthetic session, then dump the provenance registry
      (traveller passports, checkpoint logs, concept map) as JSON.

  koalja bread <spec.koalja> [--swap TASK] [--seconds N]
      Scripted breadboard session (§III-H): attach live wire taps to every
      wire, stream synthetic data, hot-swap TASK (default: the producer of
      the first sink) with a dry-run invalidation preview and a version
      bump, then forensically replay the whole run from the provenance
      ledger + seed — the pre-swap window shows hash drift (old software),
      the post-swap window rebuilds hash-identical.

  koalja demo
      The paper's fig. 5 'tfmodel' wiring on synthetic data.
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args[1..]),
        Some("check") => cmd_check(&args[1..]),
        Some("artifacts") => cmd_artifacts(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("bread") => cmd_bread(&args[1..]),
        Some("demo") => cmd_demo(),
        Some("--help") | Some("-h") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn load_spec(path: &str) -> Result<koalja::spec::PipelineSpec> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let spec = parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    spec.validate().map_err(|e| anyhow!("{path}: {e}"))?;
    Ok(spec)
}

fn cmd_check(args: &[String]) -> Result<()> {
    let path = args.first().ok_or_else(|| anyhow!("check: missing spec path"))?;
    let spec = load_spec(path)?;
    println!("pipeline [{}]: {} tasks", spec.name, spec.tasks.len());
    for t in &spec.tasks {
        let ins: Vec<&str> = t.inputs.iter().map(|i| i.wire.as_str()).collect();
        println!("  {} <- ({}) -> ({})", t.name, ins.join(", "), t.outputs.join(", "));
    }
    println!("in-trays (external wires): {:?}", spec.external_wires());
    println!("sinks: {:?}", spec.sink_wires());
    let graph = koalja::graph::PipelineGraph::build(&spec);
    let cyclic = graph.cyclic_tasks();
    if cyclic.is_empty() {
        println!("acyclic (pure DAG)");
    } else {
        println!("contains cycles through {} task(s) — legal DCG", cyclic.len());
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let path = args.first().ok_or_else(|| anyhow!("run: missing spec path"))?;
    let spec = load_spec(path)?;
    let seconds: u64 = flag_value(args, "--seconds").map(|v| v.parse()).transpose()?.unwrap_or(10);
    let rate_ms: u64 = flag_value(args, "--rate-ms").map(|v| v.parse()).transpose()?.unwrap_or(200);
    let ghost = args.iter().any(|a| a == "--ghost");

    let mut pipe = Pipeline::deploy(&spec, DeployConfig::default())?;
    // resolve every in-tray once; the feed loop below runs purely on handles
    let sources: Vec<SourceHandle> = pipe.sources().to_vec();
    if sources.is_empty() {
        bail!("spec has no external wires to feed");
    }
    let mut r = rng(7);
    let horizon = SimTime::secs(seconds);
    for src in &sources {
        let mut t = SimTime::ZERO;
        loop {
            t += SimDuration::millis(rate_ms).scale(r.exp1());
            if t > horizon {
                break;
            }
            if ghost {
                src.inject_at(
                    &mut pipe,
                    Payload::Ghost { pretend_bytes: 1 << 20 },
                    DataClass::Ghost,
                    RegionId::new(0),
                    t,
                );
            } else {
                let data: Vec<f32> = (0..8).map(|_| r.normal() as f32).collect();
                src.inject_at(
                    &mut pipe,
                    Payload::tensor(&[1, 8], data),
                    DataClass::Summary,
                    RegionId::new(0),
                    t,
                );
            }
        }
    }
    pipe.run_until(horizon);
    pipe.run_until_idle();
    println!("[{}] {} virtual seconds, ghost={}", spec.name, seconds, ghost);
    println!("{}", pipe.plat.metrics.report());
    for sink in pipe.sinks() {
        println!("sink '{}': {} artifacts", sink.name(&pipe), sink.count(&pipe));
    }
    Ok(())
}

fn cmd_artifacts(args: &[String]) -> Result<()> {
    let dir = args
        .first()
        .cloned()
        .unwrap_or_else(|| Runtime::default_dir().display().to_string());
    let mut rt = Runtime::open(&dir)?;
    println!("platform: {}", rt.platform());
    let names: Vec<String> = rt.manifest().iter().map(|m| m.name.clone()).collect();
    for name in names {
        let exe = rt.load(&name)?;
        let m = &exe.meta;
        let ins: Vec<String> = m.inputs.iter().map(|t| format!("{:?}", t.shape)).collect();
        let outs: Vec<String> = m.outputs.iter().map(|t| format!("{:?}", t.shape)).collect();
        println!("  {:16} {} -> {}  ({})", m.name, ins.join(","), outs.join(","), m.doc);
    }
    println!("all artifacts compiled OK");
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<()> {
    let path = args.first().ok_or_else(|| anyhow!("trace: missing spec path"))?;
    let spec = load_spec(path)?;
    let mut pipe = Pipeline::deploy(&spec, DeployConfig::default())?;
    let mut r = rng(11);
    for src in pipe.sources().to_vec() {
        for i in 0..3u64 {
            let data: Vec<f32> = (0..4).map(|_| r.normal() as f32).collect();
            src.inject_at(
                &mut pipe,
                Payload::tensor(&[1, 4], data),
                DataClass::Summary,
                RegionId::new(0),
                SimTime::millis(i * 50),
            );
        }
    }
    pipe.run_until_idle();
    println!("{}", pipe.plat.prov.dump_json().to_string());
    Ok(())
}

/// Scripted breadboard session: tap → observe → hot-swap (dry-run first)
/// → forensic replay with drift diff. Exercises the whole §III-H/J loop
/// on any spec; exits nonzero if the post-swap window fails to rebuild
/// hash-identical (the determinism self-check).
fn cmd_bread(args: &[String]) -> Result<()> {
    use koalja::breadboard::Breadboard;
    use koalja::task::{PortIo, TaskCode};

    let path = args.first().ok_or_else(|| anyhow!("bread: missing spec path"))?;
    let spec = load_spec(path)?;
    let asked: u64 = flag_value(args, "--seconds").map(|v| v.parse()).transpose()?.unwrap_or(8);
    // the script needs room for a pre-swap window AND a post-swap window;
    // below 6 virtual seconds the second feed would be empty and the final
    // certification vacuous
    let seconds = asked.max(6);
    if seconds != asked {
        println!("note: --seconds raised {asked} -> {seconds} (two observation windows needed)");
    }

    // pick the swap target: --swap TASK, else the producer of the first sink
    let swap_task = match flag_value(args, "--swap") {
        Some(t) => t,
        None => {
            let sink = spec
                .sink_wires()
                .first()
                .cloned()
                .ok_or_else(|| anyhow!("bread: spec has no sink wire to demo on"))?;
            spec.tasks
                .iter()
                .find(|t| t.outputs.contains(&sink))
                .map(|t| t.name.clone())
                .ok_or_else(|| anyhow!("bread: no producer of sink '{sink}'"))?
        }
    };
    let wires_in = spec.external_wires();
    if wires_in.is_empty() {
        bail!("bread: spec has no external wires to feed");
    }

    // the session runs as a workspace principal with explicit grants (§IV)
    let mut bread = Breadboard::deploy(&spec, DeployConfig::default())?.as_principal("operator");
    let ws = bread.plat.workspaces.create("breadboard");
    bread.plat.workspaces.add_member(ws, "operator");
    bread.plat.workspaces.grant(ws, koalja::workspace::Resource::Pipeline(spec.name.clone()));
    bread.plat.workspaces.grant(ws, koalja::workspace::Resource::Provenance(spec.name.clone()));
    // typed handles, resolved once: in-trays for the feed loop, the swap target
    let sources: Vec<SourceHandle> = bread.sources().to_vec();
    let swap_handle = bread.task(&swap_task)?;

    // 1. taps on every wire in the diagram
    let mut all_wires: Vec<String> = Vec::new();
    for t in &spec.tasks {
        for i in t.stream_inputs() {
            if !all_wires.contains(&i.wire) {
                all_wires.push(i.wire.clone());
            }
        }
        for o in &t.outputs {
            if !all_wires.contains(o) {
                all_wires.push(o.clone());
            }
        }
    }
    let mut taps = Vec::new();
    for w in &all_wires {
        bread.plat.workspaces.grant(ws, koalja::workspace::Resource::Wire(w.clone()));
        taps.push((w.clone(), bread.tap(w)?));
    }
    println!("[{}] breadboard up: {} wires tapped, swap target '{swap_task}'", spec.name, taps.len());

    // 2. first half: stream synthetic tensors under the original software
    let half = SimTime::secs(seconds / 2 + 1);
    let mut r = rng(23);
    let feed = |bread: &mut Breadboard, from_ms: u64, to_ms: u64, r: &mut koalja::util::Rng| {
        for src in &sources {
            let mut t = from_ms;
            while t < to_ms {
                let data: Vec<f32> = (0..8).map(|_| r.normal() as f32).collect();
                src.inject_at(
                    bread,
                    Payload::tensor(&[1, 8], data),
                    DataClass::Summary,
                    RegionId::new(0),
                    SimTime::millis(t),
                );
                t += 250;
            }
        }
    };
    feed(&mut bread, 0, half.as_micros() / 1_000 - 500, &mut r);
    bread.run_until_idle();
    bread.run_until(half);
    let t_swap = bread.plat.now;

    println!("\n-- live taps after first window --");
    for (wire, id) in &taps {
        let stats = bread.tap_stats(*id)?.unwrap();
        let last = bread.samples(*id)?.last().map(|s| s.av.uri());
        println!(
            "  tap {wire:16} seen={:4} sampled={:4} dropped={:3} last={}",
            stats.seen,
            stats.sampled,
            stats.dropped,
            last.unwrap_or_else(|| "-".into())
        );
    }

    // 3. hot-swap: dry-run preview, then commit a v2 that doubles tensors
    let old_v = swap_handle.version(&bread);
    let new_v = old_v + 1;
    let preview = bread.swap_preview_task(swap_handle, new_v)?;
    println!("\n-- dry-run -- {}", preview.summary());
    // port-native v2: emit the doubled tensor on every declared output
    // port — resolved by index, no wire names anywhere in the loop
    let factory = move || -> Box<dyn TaskCode> {
        Box::new(PortFn::versioned(
            move |ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
                for av in io.inputs.snapshot().all_avs() {
                    let p = ctx.fetch(av)?;
                    let doubled = match p.as_tensor() {
                        Some((shape, data)) => {
                            Payload::tensor(shape, data.iter().map(|x| x * 2.0).collect())
                        }
                        None => p,
                    };
                    for i in 0..io.outs().len() {
                        let port = io.out(i)?;
                        io.emitter.emit_class(port, doubled.clone(), av.class);
                    }
                }
                Ok(())
            },
            new_v,
        ))
    };
    let outcome = bread.hot_swap_task(swap_handle, factory, false)?;
    println!(
        "committed at {}: cache evicted {} entries / {} B downstream",
        outcome.at, outcome.cache_objects_evicted, outcome.cache_bytes_evicted
    );

    // 4. second half under the new software
    feed(
        &mut bread,
        t_swap.as_micros() / 1_000 + 500,
        seconds * 1_000,
        &mut r,
    );
    bread.run_until_idle();
    let t_end = bread.plat.now;

    // 5. the version bump is visible in provenance, straight off the handle
    for (at, from, to) in swap_handle.version_changes(&bread) {
        println!("\nprovenance: '{swap_task}' version {from} -> {to} at {at}");
    }
    if let Some(col) = bread.sinks().iter().filter_map(|s| s.latest(&bread)).next() {
        let q = ProvenanceQuery::new(&bread.plat.prov);
        println!(
            "latest sink artifact {} touched by versions {:?}",
            col.av.id,
            q.versions_touching(col.av.id)
        );
    }

    // 6. forensic replay: rebuild everything from ledger + seed and diff
    let run = bread.forensic_replay()?;
    println!(
        "\nreplayed {} injections ({} payloads missing) in {} events",
        run.injections_replayed, run.missing_payloads, run.events
    );
    let pre = bread.diff_replay(&run, SimTime::ZERO, t_swap);
    let post = bread.diff_replay(&run, t_swap, koalja::breadboard::WINDOW_END);
    let _ = t_end;
    println!("  pre-swap  {}", pre.summary());
    println!("  post-swap {}", post.summary());
    if post.total_matched() == 0 && post.total_drifted() == 0 {
        bail!("post-swap window recorded no outputs — nothing to certify (pipeline produced nothing after the swap)");
    }
    if !post.drift_free() {
        bail!("post-swap window failed to rebuild hash-identical (determinism broken)");
    }
    println!(
        "post-swap window certified: {} rebuilt content hashes match the record",
        post.total_matched()
    );
    Ok(())
}

fn cmd_demo() -> Result<()> {
    // fig. 5, wired programmatically — the builder lowers to exactly the
    // spec the parser would produce from the paper's text
    let mut pipe = PipelineBuilder::new("tfmodel")
        .task("learn-tf").reads("in").emits("model")
        .task("convert").reads("in[10/2]").emits("json")
        .task("predict").reads("json").looks_up("lookup").emits("result")
        .deploy(DeployConfig::default())?;
    pipe.plat.services.register(
        "lookup",
        Box::new(koalja::platform::service::KvService::new(&[("class", "cat")])),
    );
    // resolve handles once; everything after runs on dense ids
    let in_tray = pipe.source("in")?;
    let result = pipe.sink("result")?;
    let predict = pipe.task("predict")?;
    predict.plug(
        &mut pipe,
        Box::new(
            // service lookups need the live directory: sequential-only
            PortFn::new(|ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
                let label = ctx.lookup("lookup", &Payload::Text("class".into()))?;
                let n = io.inputs.all().count() as f32;
                ctx.remark(&format!("classified {n} windows as {label:?}"));
                let result = io.out(0)?;
                io.emitter.emit(result, Payload::scalar(n));
                Ok(())
            })
            .sequential(),
        ),
    )?;
    let mut r = rng(3);
    for i in 0..24u64 {
        let data: Vec<f32> = (0..4).map(|_| r.normal() as f32).collect();
        in_tray.inject_at(
            &mut pipe,
            Payload::tensor(&[1, 4], data),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(i * 100),
        );
    }
    pipe.run_until_idle();
    println!("fig. 5 'tfmodel' on 24 synthetic arrivals:");
    println!("{}", pipe.plat.metrics.report());
    println!("results collected: {}", result.count(&pipe));
    let q = ProvenanceQuery::new(&pipe.plat.prov);
    if let Some(col) = result.latest(&pipe) {
        println!(
            "last result {} derives from {} ancestor artifacts through versions {:?}",
            col.av.id,
            q.ancestors(col.av.id).len(),
            q.versions_touching(col.av.id)
        );
    }
    Ok(())
}
