//! Supervised firing lifecycle: per-task fire policies (bounded retries
//! with virtual-time backoff, deadline budgets, on-exhaust actions),
//! capped dead-letter books, a quarantine circuit breaker, and a seeded
//! fault-injection plan.
//!
//! Determinism contract: every decision in this module is a pure
//! function of deployment-time configuration plus the (task,
//! firing-index, attempt) coordinate of the firing being supervised.
//! Nothing here consults wall-clock time, thread identity, or worker
//! count, so the whole failure machinery — injected faults included —
//! commits byte-identical books at `workers = 1` and `workers = N`.

use crate::av::Payload;
use crate::policy::Snapshot;
use crate::util::{Rng, SimDuration, SimTime, TaskId};
use anyhow::anyhow;
use std::collections::VecDeque;

/// Marker prefix carried by errors synthesized from caught panics
/// (`task/mod.rs:run_code_guarded`). The vendored `anyhow` shim
/// flattens error chains to strings, so the marker is how the panic /
/// plain-error distinction survives into remarks, dead letters, and
/// span events.
pub const PANIC_MARKER: &str = "task panicked: ";

/// True when `e` originated as a caught panic rather than a plain task
/// error return.
pub fn is_panic_error(e: &anyhow::Error) -> bool {
    format!("{e}").contains(PANIC_MARKER)
}

pub(crate) fn deadline_error(cost: SimDuration, budget: SimDuration) -> anyhow::Error {
    anyhow!(
        "deadline exceeded: firing cost {}us over budget {}us",
        cost.as_micros(),
        budget.as_micros()
    )
}

/// Backoff schedule for retries, in virtual time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Backoff {
    /// The same delay before every retry.
    Fixed(SimDuration),
    /// `base * 2^(attempt-1)`, capped.
    Exponential { base: SimDuration, cap: SimDuration },
}

impl Backoff {
    /// Delay scheduled before retrying after failed attempt `attempt`
    /// (1-based).
    pub fn delay(&self, attempt: u32) -> SimDuration {
        match *self {
            Backoff::Fixed(d) => d,
            Backoff::Exponential { base, cap } => {
                let shift = attempt.saturating_sub(1).min(20);
                let scaled = base.scale((1u64 << shift) as f64);
                if scaled > cap {
                    cap
                } else {
                    scaled
                }
            }
        }
    }
}

/// What to do once a firing has exhausted its retry budget.
#[derive(Clone, Debug, PartialEq)]
pub enum OnExhaust {
    /// Record the firing (inputs pinned) into the task's dead-letter
    /// book; redrivable later via `TaskHandle::redrive`.
    DeadLetter,
    /// Dead-letter, and after `after` consecutive exhausted firings
    /// flip the task's circuit breaker: subsequent wakes dead-letter
    /// immediately without executing. Hot-swap (or an explicit
    /// breadboard reset) clears the breaker.
    Quarantine { after: u32 },
    /// Emit the declared fallback payload on every output wire so
    /// downstream keeps flowing. The fallback is never memoized.
    Degrade { fallback: Payload },
}

/// Per-task supervision policy for firings.
///
/// Retries are ordinary future-dated events: a backed-off attempt
/// re-enters the schedule through the coordinator's frontier tracker
/// like any other wake, so under pipelined scheduling a retrying (or
/// quarantined) task delays only its own downstream closure — unrelated
/// tasks' frontiers keep advancing past it.
#[derive(Clone, Debug)]
pub struct FirePolicy {
    /// Total attempts per firing (1 = no retries).
    pub max_attempts: u32,
    /// Virtual-time delay schedule between attempts.
    pub backoff: Backoff,
    /// Optional per-firing budget checked against the firing's
    /// `compute_cost`; exceeding it fails the attempt.
    pub deadline: Option<SimDuration>,
    /// Action when `max_attempts` is exhausted.
    pub on_exhaust: OnExhaust,
}

impl Default for FirePolicy {
    fn default() -> Self {
        FirePolicy {
            max_attempts: 1,
            backoff: Backoff::Fixed(SimDuration::millis(10)),
            deadline: None,
            on_exhaust: OnExhaust::DeadLetter,
        }
    }
}

impl FirePolicy {
    /// Policy allowing `n` retries (so `n + 1` attempts total).
    pub fn retries(n: u32) -> Self {
        FirePolicy {
            max_attempts: n + 1,
            ..FirePolicy::default()
        }
    }

    pub fn with_backoff(mut self, backoff: Backoff) -> Self {
        self.backoff = backoff;
        self
    }

    pub fn with_deadline(mut self, budget: SimDuration) -> Self {
        self.deadline = Some(budget);
        self
    }

    pub fn dead_letter(mut self) -> Self {
        self.on_exhaust = OnExhaust::DeadLetter;
        self
    }

    pub fn quarantine(mut self, after: u32) -> Self {
        self.on_exhaust = OnExhaust::Quarantine { after: after.max(1) };
        self
    }

    pub fn degrade(mut self, fallback: Payload) -> Self {
        self.on_exhaust = OnExhaust::Degrade { fallback };
        self
    }
}

/// The kind of fault a `FaultPlan` injects into a firing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// The task run returns a plain error.
    Error,
    /// The task run "panics" — the injected error carries the panic
    /// marker so the supervision path classifies it like a real caught
    /// panic (without actually unwinding, which would spam stderr in
    /// property tests).
    Panic,
    /// The firing completes but its compute cost is inflated by this
    /// much — the lever for exercising deadline budgets.
    CostSpike(SimDuration),
}

/// Supervision verdict for one attempt of one firing, computed once on
/// the coordinator thread and carried with the firing so workers never
/// touch shared supervision state.
#[derive(Clone, Copy, Debug, Default)]
pub struct FireGuard {
    /// Fault to inject into this attempt, if any.
    pub fault: Option<FaultKind>,
    /// Deadline budget from the task's policy, if any.
    pub deadline: Option<SimDuration>,
}

impl FireGuard {
    pub const NONE: FireGuard = FireGuard {
        fault: None,
        deadline: None,
    };

    /// The error this guard injects before the task code runs, if any.
    pub(crate) fn injected_failure(&self) -> Option<anyhow::Error> {
        match self.fault {
            Some(FaultKind::Error) => Some(anyhow!("injected fault: error (seeded FaultPlan)")),
            Some(FaultKind::Panic) => Some(anyhow!("{PANIC_MARKER}injected fault (seeded FaultPlan)")),
            _ => None,
        }
    }
}

/// One supervised attempt: the pinned input snapshot plus its
/// per-task firing index, attempt number, and precomputed guard.
#[derive(Clone, Debug)]
pub struct Firing {
    pub snapshot: Snapshot,
    /// Per-task firing index, assigned in arrival order on the
    /// coordinator thread — the stable coordinate fault plans key on.
    pub index: u64,
    /// 1-based attempt counter.
    pub attempt: u32,
    pub guard: FireGuard,
}

/// A forced fault at a chosen (task, firing-index) coordinate —
/// the deterministic lever for targeted tests.
#[derive(Clone, Copy, Debug)]
pub struct Forced {
    /// `TaskId::index()` of the target task.
    pub task: u64,
    /// Per-task firing index to hit.
    pub firing: u64,
    /// Fault fires on attempts `1..=upto_attempt` (so retries past it
    /// succeed).
    pub upto_attempt: u32,
    pub kind: FaultKind,
}

/// Seeded fault-injection plan: deterministic per-(task, firing,
/// attempt) fault draws plus explicitly forced coordinates.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    pub p_error: f64,
    pub p_panic: f64,
    pub p_cost_spike: f64,
    /// Cost inflation applied by drawn `CostSpike` faults.
    pub spike: SimDuration,
    pub forced: Vec<Forced>,
}

impl FaultPlan {
    /// A plan with modest default rates, fully determined by `seed`.
    pub fn seeded(seed: u64) -> Self {
        FaultPlan {
            seed,
            p_error: 0.02,
            p_panic: 0.01,
            p_cost_spike: 0.01,
            spike: SimDuration::millis(5),
            forced: Vec::new(),
        }
    }

    pub fn with_rates(mut self, p_error: f64, p_panic: f64, p_cost_spike: f64) -> Self {
        self.p_error = p_error;
        self.p_panic = p_panic;
        self.p_cost_spike = p_cost_spike;
        self
    }

    /// Force `kind` at (task, firing) for attempts `1..=upto_attempt`.
    pub fn force(mut self, task: u64, firing: u64, upto_attempt: u32, kind: FaultKind) -> Self {
        self.forced.push(Forced {
            task,
            firing,
            upto_attempt,
            kind,
        });
        self
    }

    /// The fault (if any) this plan injects at the given coordinate.
    ///
    /// Order-independent: the draw is keyed on a per-coordinate seeded
    /// hash, not on a shared RNG stream, so the verdict is identical
    /// whichever order firings are evaluated in — the property that
    /// keeps injected faults byte-identical across worker counts.
    pub fn decide(&self, task: TaskId, firing: u64, attempt: u32) -> Option<FaultKind> {
        for f in &self.forced {
            if f.task == task.index() as u64 && f.firing == firing && attempt <= f.upto_attempt {
                return Some(f.kind);
            }
        }
        let key = self.seed
            ^ (task.index() as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ firing.wrapping_mul(0xBF58_476D_1CE4_E5B9)
            ^ (attempt as u64).wrapping_mul(0x94D0_49BB_1331_11EB);
        let draw = Rng::seed_from_u64(key).f64();
        if draw < self.p_panic {
            Some(FaultKind::Panic)
        } else if draw < self.p_panic + self.p_error {
            Some(FaultKind::Error)
        } else if draw < self.p_panic + self.p_error + self.p_cost_spike {
            Some(FaultKind::CostSpike(self.spike))
        } else {
            None
        }
    }
}

/// Default fault plan from the `KOALJA_FAULT_SEED` env var (unset or
/// unparsable → none). Mirrors `default_workers` / `default_trace`.
pub fn default_fault_plan() -> Option<FaultPlan> {
    let raw = std::env::var("KOALJA_FAULT_SEED").ok()?;
    let seed: u64 = raw.trim().parse().ok()?;
    Some(FaultPlan::seeded(seed))
}

/// A firing that exhausted its retry budget, with its inputs pinned so
/// it can be redriven after a hot-swap fixes the code.
#[derive(Clone, Debug)]
pub struct DeadLetter {
    /// Per-task firing index of the exhausted firing.
    pub index: u64,
    /// Virtual instant the firing was dead-lettered.
    pub at: SimTime,
    /// Attempts consumed before exhaustion (0 = dropped by quarantine
    /// without executing).
    pub attempts: u32,
    /// Flattened error chain of the final attempt.
    pub error: String,
    /// True when the final failure was a caught panic.
    pub panicked: bool,
    /// True when the firing never executed because the task was
    /// quarantined.
    pub quarantine_drop: bool,
    /// The pinned input snapshot (Arc'd AVs — cheap to clone).
    pub snapshot: Snapshot,
}

impl DeadLetter {
    /// Input wire names captured in the pinned snapshot.
    pub fn input_names(&self) -> impl Iterator<Item = &str> {
        self.snapshot.inputs.iter().map(|(n, _)| n.as_ref())
    }

    /// Ids of every annotated value pinned in the snapshot.
    pub fn av_ids(&self) -> Vec<u64> {
        self.snapshot.all_avs().map(|av| av.id.0).collect()
    }
}

/// Cap on retained letters per task; older letters are evicted (and
/// counted) once the book is full.
pub const DEAD_LETTER_CAP: usize = 256;

/// Capped per-task book of dead-lettered firings.
#[derive(Clone, Debug, Default)]
pub struct DeadLetterBook {
    letters: VecDeque<DeadLetter>,
    dropped: u64,
}

impl DeadLetterBook {
    pub(crate) fn push(&mut self, letter: DeadLetter) {
        if self.letters.len() >= DEAD_LETTER_CAP {
            self.letters.pop_front();
            self.dropped += 1;
        }
        self.letters.push_back(letter);
    }

    pub fn letters(&self) -> impl Iterator<Item = &DeadLetter> {
        self.letters.iter()
    }

    pub fn len(&self) -> usize {
        self.letters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.letters.is_empty()
    }

    /// Letters evicted by the cap since deployment.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub(crate) fn drain(&mut self) -> Vec<DeadLetter> {
        self.letters.drain(..).collect()
    }
}

/// Per-task circuit-breaker state.
#[derive(Clone, Copy, Debug, Default)]
pub struct Breaker {
    pub consecutive_exhausts: u32,
    pub quarantined: bool,
    pub tripped_at: Option<SimTime>,
}

/// Coordinator-side supervision state: policies, dead-letter books,
/// breakers, firing-index counters, pending retries, and the fault
/// plan. Lives on the coordinator thread only; workers see per-firing
/// `FireGuard`s computed here.
#[derive(Debug, Default)]
pub struct Supervision {
    policies: Vec<Option<FirePolicy>>,
    books: Vec<DeadLetterBook>,
    breakers: Vec<Breaker>,
    next_index: Vec<u64>,
    retries: Vec<Vec<Firing>>,
    pub plan: Option<FaultPlan>,
    any_policy: bool,
}

impl Supervision {
    pub fn sized(n_tasks: usize, plan: Option<FaultPlan>) -> Self {
        Supervision {
            policies: vec![None; n_tasks],
            books: (0..n_tasks).map(|_| DeadLetterBook::default()).collect(),
            breakers: vec![Breaker::default(); n_tasks],
            next_index: vec![0; n_tasks],
            retries: (0..n_tasks).map(|_| Vec::new()).collect(),
            plan,
            any_policy: false,
        }
    }

    /// True when any supervision machinery is in play — the fast-path
    /// gate: with no policies and no plan, the hot loop pays one
    /// predicted branch.
    pub fn active(&self) -> bool {
        self.any_policy || self.plan.is_some()
    }

    pub fn policy(&self, task: TaskId) -> Option<&FirePolicy> {
        self.policies[task.index()].as_ref()
    }

    pub fn set_policy(&mut self, task: TaskId, policy: FirePolicy) {
        self.policies[task.index()] = Some(policy);
        self.any_policy = true;
    }

    /// Mint the next firing index for `task` (arrival order).
    pub(crate) fn assign_index(&mut self, task: TaskId) -> u64 {
        let i = self.next_index[task.index()];
        self.next_index[task.index()] += 1;
        i
    }

    /// Compute the guard for one attempt: fault draw from the plan,
    /// deadline from the policy.
    pub(crate) fn guard(&self, task: TaskId, index: u64, attempt: u32) -> FireGuard {
        FireGuard {
            fault: self
                .plan
                .as_ref()
                .and_then(|p| p.decide(task, index, attempt)),
            deadline: self.policy(task).and_then(|p| p.deadline),
        }
    }

    pub fn quarantined(&self, task: TaskId) -> bool {
        self.breakers[task.index()].quarantined
    }

    pub(crate) fn push_retry(&mut self, task: TaskId, firing: Firing) {
        self.retries[task.index()].push(firing);
    }

    pub(crate) fn take_retries(&mut self, task: TaskId) -> Vec<Firing> {
        std::mem::take(&mut self.retries[task.index()])
    }

    pub fn book(&self, task: TaskId) -> &DeadLetterBook {
        &self.books[task.index()]
    }

    pub(crate) fn book_mut(&mut self, task: TaskId) -> &mut DeadLetterBook {
        &mut self.books[task.index()]
    }

    pub fn breaker(&self, task: TaskId) -> &Breaker {
        &self.breakers[task.index()]
    }

    pub(crate) fn breaker_mut(&mut self, task: TaskId) -> &mut Breaker {
        &mut self.breakers[task.index()]
    }

    /// A successful commit resets the consecutive-exhaust count.
    pub(crate) fn note_success(&mut self, task: TaskId) {
        self.breakers[task.index()].consecutive_exhausts = 0;
    }

    /// Clear the breaker (hot-swap / explicit reset). Returns whether
    /// the task was quarantined.
    pub(crate) fn clear_breaker(&mut self, task: TaskId) -> bool {
        let b = &mut self.breakers[task.index()];
        let was = b.quarantined;
        *b = Breaker::default();
        was
    }
}

/// Structured report for a runaway event loop: `run_until_idle` hit its
/// storm cap. Replaces the old process-aborting panic.
#[derive(Clone, Debug)]
pub struct EventStorm {
    /// Events handled before the cap tripped.
    pub handled: u64,
    pub cap: u64,
    /// Virtual instant at which the cap tripped.
    pub at: SimTime,
    /// Events still queued when the loop stopped.
    pub pending: usize,
    /// Busiest tasks by firing count (name, firings), hottest first.
    pub hottest_tasks: Vec<(String, u64)>,
    /// Busiest wires by traffic (name, publications + injections) when
    /// obs is enabled; empty otherwise.
    pub hottest_wires: Vec<(String, u64)>,
}

impl std::fmt::Display for EventStorm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "event storm: {} events handled (cap {}) at t+{}us with {} still queued",
            self.handled,
            self.cap,
            self.at.as_micros(),
            self.pending
        )?;
        if !self.hottest_tasks.is_empty() {
            let tasks: Vec<String> = self
                .hottest_tasks
                .iter()
                .map(|(n, c)| format!("{n}({c})"))
                .collect();
            write!(f, "; hottest tasks: {}", tasks.join(", "))?;
        }
        if !self.hottest_wires.is_empty() {
            let wires: Vec<String> = self
                .hottest_wires
                .iter()
                .map(|(n, c)| format!("{n}({c})"))
                .collect();
            write!(f, "; hottest wires: {}", wires.join(", "))?;
        }
        Ok(())
    }
}

impl std::error::Error for EventStorm {}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(i: usize) -> TaskId {
        TaskId::new(i as u64)
    }

    #[test]
    fn decide_is_deterministic_and_order_independent() {
        let plan = FaultPlan::seeded(7).with_rates(0.2, 0.1, 0.1);
        let coords: Vec<(usize, u64, u32)> =
            (0..8).flat_map(|t| (0..16).map(move |f| (t, f, 1u32))).collect();
        let forward: Vec<_> = coords
            .iter()
            .map(|&(ti, f, a)| plan.decide(t(ti), f, a))
            .collect();
        let reverse: Vec<_> = coords
            .iter()
            .rev()
            .map(|&(ti, f, a)| plan.decide(t(ti), f, a))
            .collect();
        let mut rev = reverse;
        rev.reverse();
        assert_eq!(forward, rev);
        // Same seed, fresh plan: identical verdicts.
        let again = FaultPlan::seeded(7).with_rates(0.2, 0.1, 0.1);
        for &(ti, f, a) in &coords {
            assert_eq!(plan.decide(t(ti), f, a), again.decide(t(ti), f, a));
        }
        // At these rates, some coordinate must draw a fault and some
        // must not.
        assert!(forward.iter().any(|v| v.is_some()));
        assert!(forward.iter().any(|v| v.is_none()));
    }

    #[test]
    fn forced_faults_take_precedence_and_respect_upto_attempt() {
        let plan = FaultPlan::seeded(1)
            .with_rates(0.0, 0.0, 0.0)
            .force(3, 5, 2, FaultKind::Error);
        assert_eq!(plan.decide(t(3), 5, 1), Some(FaultKind::Error));
        assert_eq!(plan.decide(t(3), 5, 2), Some(FaultKind::Error));
        assert_eq!(plan.decide(t(3), 5, 3), None);
        assert_eq!(plan.decide(t(3), 6, 1), None);
        assert_eq!(plan.decide(t(2), 5, 1), None);
    }

    #[test]
    fn backoff_delays() {
        let fixed = Backoff::Fixed(SimDuration::millis(10));
        assert_eq!(fixed.delay(1), SimDuration::millis(10));
        assert_eq!(fixed.delay(5), SimDuration::millis(10));
        let exp = Backoff::Exponential {
            base: SimDuration::millis(10),
            cap: SimDuration::millis(45),
        };
        assert_eq!(exp.delay(1), SimDuration::millis(10));
        assert_eq!(exp.delay(2), SimDuration::millis(20));
        assert_eq!(exp.delay(3), SimDuration::millis(40));
        assert_eq!(exp.delay(4), SimDuration::millis(45)); // capped
        assert_eq!(exp.delay(40), SimDuration::millis(45)); // shift clamp
    }

    #[test]
    fn dead_letter_book_caps_and_counts_evictions() {
        let mut book = DeadLetterBook::default();
        for i in 0..(DEAD_LETTER_CAP as u64 + 10) {
            book.push(DeadLetter {
                index: i,
                at: SimTime::ZERO,
                attempts: 1,
                error: format!("e{i}"),
                panicked: false,
                quarantine_drop: false,
                snapshot: Snapshot::new(Vec::new(), SimTime::ZERO),
            });
        }
        assert_eq!(book.len(), DEAD_LETTER_CAP);
        assert_eq!(book.dropped(), 10);
        // Oldest evicted: the first retained letter is index 10.
        assert_eq!(book.letters().next().unwrap().index, 10);
    }

    #[test]
    fn breaker_trips_and_clears() {
        let mut sup = Supervision::sized(2, None);
        sup.set_policy(t(0), FirePolicy::retries(0).quarantine(2));
        assert!(sup.active());
        sup.breaker_mut(t(0)).consecutive_exhausts = 2;
        sup.breaker_mut(t(0)).quarantined = true;
        sup.breaker_mut(t(0)).tripped_at = Some(SimTime::ZERO);
        assert!(sup.quarantined(t(0)));
        assert!(!sup.quarantined(t(1)));
        assert!(sup.clear_breaker(t(0)));
        assert!(!sup.quarantined(t(0)));
        assert_eq!(sup.breaker(t(0)).consecutive_exhausts, 0);
        assert!(!sup.clear_breaker(t(0))); // already clear
    }

    #[test]
    fn policy_builders() {
        let p = FirePolicy::retries(2)
            .with_backoff(Backoff::Fixed(SimDuration::millis(3)))
            .with_deadline(SimDuration::millis(50))
            .quarantine(0);
        assert_eq!(p.max_attempts, 3);
        assert_eq!(p.deadline, Some(SimDuration::millis(50)));
        // quarantine(0) clamps to 1
        assert_eq!(p.on_exhaust, OnExhaust::Quarantine { after: 1 });
    }
}
