//! Forensic replay — deterministic re-execution from provenance + seed.
//!
//! "Users can ... reconstruct the history of changes, down to the versions
//! of software that led to each outcome" (§III-J, §IV). The provenance
//! registry's injection ledger records every external arrival (wire, time,
//! region, class, object pointer); the object store still holds the
//! payloads; the deployment seed pins all simulated randomness. Together
//! those reconstruct the run: deploy a fresh coordinator from the same
//! spec/config, re-inject the ledger at the recorded virtual times, and
//! drain. Diffing the rebuilt sink content hashes against the recorded
//! ones detects *drift* — any divergence between what happened and what
//! the current software would produce. Matching hashes certify the record;
//! drifting hashes localize exactly which window a software change (or a
//! nondeterministic task) altered.

use crate::util::{ContentHash, SimTime};
use std::collections::BTreeMap;

// The per-wire (time, content-hash) sequences both the live record and a
// replay are diffed in come from the coordinator's *deterministic commit
// log* ([`Coordinator::sink_hash_sequences`]) — NOT from the `SinkBook`
// (drainable by sessions) and NOT from event-heap pop order (which the
// wavefront scheduler decouples from commit order). Within one virtual
// instant, commits land in task-index order for every `workers` setting,
// so live-vs-replay diffs are stable under any parallelism on either
// side.
//
// [`Coordinator::sink_hash_sequences`]: crate::coordinator::Coordinator::sink_hash_sequences

/// The rebuilt execution: per-wire (time, content-hash) sequences.
#[derive(Clone, Debug)]
pub struct ReplayRun {
    /// Sink captures of the fresh coordinator, per wire, deterministic
    /// commit order.
    pub collected: BTreeMap<String, Vec<(SimTime, ContentHash)>>,
    pub injections_replayed: usize,
    /// Ledger entries whose payloads were no longer in the object store
    /// (purged) — replay is partial if nonzero.
    pub missing_payloads: usize,
    pub events: u64,
}

/// Per-wire diff between recorded and replayed outputs inside a window.
#[derive(Clone, Debug)]
pub struct WireDiff {
    pub wire: String,
    pub recorded: usize,
    pub replayed: usize,
    /// Positions (in arrival order) whose content hashes are identical.
    pub matched: usize,
    /// Positions that differ, plus any length mismatch.
    pub drifted: usize,
}

/// The drift report over one virtual-time window.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    pub from: SimTime,
    pub to: SimTime,
    pub wires: Vec<WireDiff>,
}

impl ReplayReport {
    /// True when every recorded output in the window was rebuilt
    /// hash-identical.
    pub fn drift_free(&self) -> bool {
        self.wires.iter().all(|w| w.drifted == 0)
    }

    pub fn total_matched(&self) -> usize {
        self.wires.iter().map(|w| w.matched).sum()
    }

    pub fn total_drifted(&self) -> usize {
        self.wires.iter().map(|w| w.drifted).sum()
    }

    pub fn summary(&self) -> String {
        let status = if self.drift_free() { "MATCH" } else { "DRIFT" };
        format!(
            "replay [{} .. {}]: {} — {} hashes matched, {} drifted",
            self.from,
            self.to,
            status,
            self.total_matched(),
            self.total_drifted(),
        )
    }
}

/// End-of-time sentinel for "everything from `from` onwards" windows.
pub const WINDOW_END: SimTime = SimTime(u64::MAX);

/// Diff two per-wire hash sequences over the half-open window
/// `[from, to)` — half-open so adjacent windows split at a boundary
/// (e.g. the swap instant) never double-count an output landing exactly
/// on it. Use [`WINDOW_END`] as `to` for an unbounded tail.
pub fn diff_windows(
    live: &BTreeMap<String, Vec<(SimTime, ContentHash)>>,
    replayed: &BTreeMap<String, Vec<(SimTime, ContentHash)>>,
    from: SimTime,
    to: SimTime,
) -> ReplayReport {
    let mut wires: Vec<&String> = live.keys().chain(replayed.keys()).collect();
    wires.sort();
    wires.dedup();
    let window = |seq: Option<&Vec<(SimTime, ContentHash)>>| -> Vec<ContentHash> {
        seq.map(|v| {
            v.iter().filter(|(t, _)| *t >= from && *t < to).map(|(_, h)| *h).collect()
        })
        .unwrap_or_default()
    };
    let mut out = Vec::new();
    for w in wires {
        let a = window(live.get(w));
        let b = window(replayed.get(w));
        let matched = a.iter().zip(&b).filter(|(x, y)| x == y).count();
        let drifted = a.len().max(b.len()) - matched;
        out.push(WireDiff {
            wire: w.clone(),
            recorded: a.len(),
            replayed: b.len(),
            matched,
            drifted,
        });
    }
    ReplayReport { from, to, wires: out }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(pairs: &[(u64, u64)]) -> Vec<(SimTime, ContentHash)> {
        pairs.iter().map(|(t, h)| (SimTime::micros(*t), ContentHash(*h))).collect()
    }

    #[test]
    fn identical_sequences_are_drift_free() {
        let mut live = BTreeMap::new();
        live.insert("out".to_string(), seq(&[(1, 10), (2, 20), (3, 30)]));
        let rep = live.clone();
        let r = diff_windows(&live, &rep, SimTime::ZERO, SimTime::secs(1));
        assert!(r.drift_free());
        assert_eq!(r.total_matched(), 3);
    }

    #[test]
    fn windowing_isolates_drift() {
        let mut live = BTreeMap::new();
        live.insert("out".to_string(), seq(&[(1, 10), (100, 99)]));
        let mut rep = BTreeMap::new();
        rep.insert("out".to_string(), seq(&[(1, 10), (100, 77)]));
        // early window matches...
        let early = diff_windows(&live, &rep, SimTime::ZERO, SimTime::micros(50));
        assert!(early.drift_free());
        // ...late window shows the drift
        let late = diff_windows(&live, &rep, SimTime::micros(51), SimTime::secs(1));
        assert_eq!(late.total_drifted(), 1);
        assert!(!late.drift_free());
    }

    #[test]
    fn length_mismatch_counts_as_drift() {
        let mut live = BTreeMap::new();
        live.insert("out".to_string(), seq(&[(1, 10), (2, 20)]));
        let mut rep = BTreeMap::new();
        rep.insert("out".to_string(), seq(&[(1, 10)]));
        let r = diff_windows(&live, &rep, SimTime::ZERO, SimTime::secs(1));
        assert_eq!(r.total_matched(), 1);
        assert_eq!(r.total_drifted(), 1);
        // a wire present on one side only is all-drift
        let mut rep2 = BTreeMap::new();
        rep2.insert("other".to_string(), seq(&[(1, 1)]));
        let r2 = diff_windows(&live, &rep2, SimTime::ZERO, SimTime::secs(1));
        assert_eq!(r2.wires.len(), 2);
        assert!(!r2.drift_free());
    }
}
