//! Hot-swap: replace a task's user code mid-run — §III-J made interactive.
//!
//! The mechanism lives in the coordinator (`Coordinator::software_update`
//! stamps the version change, flushes the memo, and evicts downstream
//! dependent-local cache copies via `stale_frontier_of` /
//! `evict_stale_downstream`). What this module adds is the *breadboarding*
//! half: a dry-run [`preview`] that reports, before anything mutates, what
//! that mechanism is about to invalidate — memo entries, provenance-
//! reachable artifacts, and the cached intermediates downstream tasks are
//! holding (Principle 2). Preview and commit share the coordinator's
//! stale-frontier computation, so they always agree.

use crate::coordinator::Coordinator;
use crate::util::{ObjectId, TaskId};

/// What a code swap would (or did) invalidate.
#[derive(Clone, Debug)]
pub struct SwapPreview {
    pub task: String,
    pub old_version: u32,
    pub new_version: u32,
    /// Memoized recipes on the swapped task that become stale (version is
    /// part of the recipe hash).
    pub memo_entries: usize,
    /// Tasks downstream of the swap (their inputs may be recomputed).
    pub downstream_tasks: Vec<String>,
    /// Artifacts (AVs) emitted by the task plus all their descendants —
    /// everything §III-J's rollback would reconsider.
    pub stale_avs: usize,
    /// (object, bytes) pairs among the stale artifacts.
    pub stale_objects: Vec<(ObjectId, u64)>,
    /// Stale objects currently held in downstream dependent-local caches
    /// — what committing will evict.
    pub cached_stale_objects: usize,
    pub cached_stale_bytes: u64,
}

impl SwapPreview {
    /// One-line human summary (printed by `koalja bread`).
    pub fn summary(&self) -> String {
        format!(
            "swap {} v{} -> v{}: {} memo entries, {} stale artifacts, \
             {} cached downstream ({} B) across {:?}",
            self.task,
            self.old_version,
            self.new_version,
            self.memo_entries,
            self.stale_avs,
            self.cached_stale_objects,
            self.cached_stale_bytes,
            self.downstream_tasks,
        )
    }
}

/// Dry-run: compute the blast radius of swapping `task` to `new_version`.
/// Pure read — nothing in the coordinator changes.
pub fn preview(coord: &Coordinator, task: TaskId, new_version: u32) -> SwapPreview {
    let agent = &coord.agents[task.index()];
    let (stale_avs, stale_objects) = coord.stale_frontier_of(task);

    let downstream = coord.graph.reachable_downstream(task);
    let obj_ids: Vec<ObjectId> = stale_objects.iter().map(|(o, _)| *o).collect();
    let mut cached = 0usize;
    let mut cached_bytes = 0u64;
    for t in &downstream {
        let (n, b) = coord.agents[t.index()].cache.would_invalidate(&obj_ids);
        cached += n;
        cached_bytes += b;
    }

    SwapPreview {
        task: agent.spec.name.clone(),
        old_version: agent.version(),
        new_version,
        memo_entries: agent.memo_len(),
        downstream_tasks: downstream.iter().map(|t| coord.graph.task(*t).name.clone()).collect(),
        stale_avs,
        stale_objects,
        cached_stale_objects: cached,
        cached_stale_bytes: cached_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::av::{DataClass, Payload};
    use crate::coordinator::DeployConfig;

    #[test]
    fn preview_reports_stale_state_without_mutating() {
        let spec = crate::spec::parse("[p]\n(raw) stage1 (mid)\n(mid) stage2 (out)\n").unwrap();
        let mut c = Coordinator::deploy(&spec, DeployConfig::default()).unwrap();
        c.inject("raw", Payload::scalar(1.0), DataClass::Summary).unwrap();
        c.run_until_idle();
        let t1 = c.task_id("stage1").unwrap();

        let p = preview(&c, t1, 2);
        assert_eq!(p.old_version, 1);
        assert_eq!(p.new_version, 2);
        assert!(p.memo_entries >= 1, "stage1 memoized its run");
        assert_eq!(p.downstream_tasks, vec!["stage2".to_string()]);
        assert!(p.stale_avs >= 1, "stage1's emission is stale");
        assert!(
            p.cached_stale_objects >= 1,
            "stage2 fetched stage1's output through its local cache"
        );
        // dry run: nothing changed
        assert_eq!(c.agents[t1.index()].version(), 1);
        assert!(c.agents[t1.index()].memo_len() >= 1);
    }

    #[test]
    fn commit_eviction_matches_preview() {
        let spec = crate::spec::parse("[p]\n(raw) stage1 (mid)\n(mid) stage2 (out)\n").unwrap();
        let mut c = Coordinator::deploy(&spec, DeployConfig::default()).unwrap();
        c.inject("raw", Payload::scalar(2.0), DataClass::Summary).unwrap();
        c.run_until_idle();
        let t1 = c.task_id("stage1").unwrap();
        let t2 = c.task_id("stage2").unwrap();

        let p = preview(&c, t1, 2);
        let before = c.agents[t2.index()].cache.len();
        assert!(before >= 1);
        let (evicted, bytes) = c.evict_stale_downstream(t1, &p.stale_objects);
        assert_eq!(evicted, p.cached_stale_objects, "preview matched reality");
        assert_eq!(bytes, p.cached_stale_bytes);
        assert_eq!(c.agents[t2.index()].cache.len(), before - evicted);
    }

    #[test]
    fn software_update_evicts_downstream_caches_itself() {
        // the plain §III-J path (no Breadboard wrapper) must not leave
        // stale intermediates in downstream dependent-local caches
        let spec = crate::spec::parse("[p]\n(raw) stage1 (mid)\n(mid) stage2 (out)\n").unwrap();
        let mut c = Coordinator::deploy(&spec, DeployConfig::default()).unwrap();
        c.inject("raw", Payload::scalar(3.0), DataClass::Summary).unwrap();
        c.run_until_idle();
        let t2 = c.task_id("stage2").unwrap();
        let held = c.agents[t2.index()].cache.len();
        assert!(held >= 1, "stage2 cached stage1's output");

        let mut v2 = crate::task::builtins::PassThrough::new("mid");
        v2.version = 2;
        let (evicted, bytes) = c.software_update("stage1", Box::new(v2), false).unwrap();
        assert_eq!(evicted, held, "update reported the eviction it performed");
        assert!(bytes > 0);
        assert_eq!(c.agents[t2.index()].cache.len(), 0, "stale copies evicted on update");
    }
}
