//! Breadboard — the interactive smart-workspace layer (§III-H, §IV).
//!
//! The paper's pitch is that a pipeline should feel like an electronics
//! breadboard: probe any wire while current flows, swap a component
//! without tearing the board down, and replay the tape to see exactly how
//! an outcome came to be. This subsystem wraps a deployed [`Coordinator`]
//! in a [`Breadboard`] session offering precisely those three verbs:
//!
//!  * **wire taps** ([`tap`]) — attach/detach bounded probes on any wire at
//!    runtime; sample AV metadata (optionally payloads) through predicates,
//!    with per-tap overhead counters. The dispatch hook costs one branch
//!    when no tap is attached (`benches/tap_overhead.rs`).
//!  * **hot-swap** ([`swap`]) — replace a task's [`TaskCode`] mid-run with
//!    a version bump that flows into provenance stamps and drives the
//!    §III-J recomputation path; a dry-run preview reports which cached
//!    intermediates the swap would invalidate before committing.
//!  * **forensic replay** ([`replay`]) — rebuild any past window from the
//!    provenance injection ledger + deployment seed and diff the rebuilt
//!    content hashes against the recorded ones to detect drift.
//!
//! Sessions are workspace-aware (§IV): give the session a principal with
//! [`Breadboard::as_principal`] and every tap/swap/replay is gated through
//! the overlapping-set grant check — probing a wire needs a `Wire` grant,
//! swapping needs the `Pipeline` grant, replay needs `Provenance`.

pub mod replay;
pub mod swap;
pub mod tap;

pub use replay::{ReplayReport, ReplayRun, WireDiff, WINDOW_END};
pub use swap::SwapPreview;
pub use tap::{TapId, TapSample, TapSpec, TapStats};

use crate::api::{Pipeline, TaskHandle};
use crate::coordinator::{Coordinator, DeployConfig};
use crate::provenance::InjectionRecord;
use crate::spec::PipelineSpec;
use crate::task::TaskCode;
use crate::util::{SimDuration, SimTime, WireId};
use crate::workspace::Resource;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Factory that builds (and rebuilds, for replay) a task's code.
pub type CodeFactory = Box<dyn Fn() -> Box<dyn TaskCode>>;

/// Outcome of a committed hot-swap.
#[derive(Debug)]
pub struct SwapOutcome {
    pub preview: SwapPreview,
    /// Dependent-local cache entries actually evicted downstream.
    pub cache_objects_evicted: usize,
    pub cache_bytes_evicted: u64,
    /// Virtual time the swap was stamped.
    pub at: SimTime,
}

/// Inspection panel for a task's supervised-firing state: breaker
/// position plus the dead-letter backlog behind it.
#[derive(Clone, Copy, Debug)]
pub struct QuarantineView {
    /// Is the circuit breaker open (wakes dead-letter without executing)?
    pub quarantined: bool,
    /// Exhausted firings since the last success / reset.
    pub consecutive_exhausts: u32,
    /// Virtual instant the breaker tripped, if it is (or was) open.
    pub tripped_at: Option<SimTime>,
    /// Letters currently in the dead-letter book.
    pub dead_letters: usize,
    /// Letters evicted from the capped book since deploy.
    pub dead_letters_dropped: u64,
}

/// Record of one swap performed in this session.
#[derive(Debug)]
pub struct SwapRecord {
    pub task: String,
    pub from_version: u32,
    pub to_version: u32,
    pub at: SimTime,
}

/// An interactive session over a deployed pipeline.
///
/// Derefs to [`Pipeline`] (which derefs on to [`Coordinator`]), so both
/// the handle API (source/sink/task resolution, handle verbs) and the
/// full platform surface (run control, collected, …) stay available on
/// the session object.
pub struct Breadboard {
    pipe: Pipeline,
    /// Code factories per task — the session's record of what is plugged
    /// in, reused to provision replay coordinators.
    factories: HashMap<String, CodeFactory>,
    /// Workspace principal performing this session (None = unrestricted
    /// local bench).
    principal: Option<String>,
    /// Swaps committed through this session, oldest first.
    pub swaps: Vec<SwapRecord>,
}

impl std::ops::Deref for Breadboard {
    type Target = Pipeline;
    fn deref(&self) -> &Pipeline {
        &self.pipe
    }
}

impl std::ops::DerefMut for Breadboard {
    fn deref_mut(&mut self) -> &mut Pipeline {
        &mut self.pipe
    }
}

impl Breadboard {
    /// Deploy a spec and wrap it in a session.
    pub fn deploy(spec: &PipelineSpec, cfg: DeployConfig) -> Result<Self> {
        Ok(Self::around(Pipeline::deploy(spec, cfg)?))
    }

    /// Wrap an already-deployed coordinator. Replay needs the spec and the
    /// deploy config the coordinator was built with.
    pub fn attach(coord: Coordinator, spec: PipelineSpec, cfg: DeployConfig) -> Result<Self> {
        Ok(Self::around(Pipeline::attach(coord, spec, cfg)?))
    }

    /// Wrap a [`Pipeline`] facade in a session.
    pub fn around(pipe: Pipeline) -> Self {
        Self { pipe, factories: HashMap::new(), principal: None, swaps: Vec::new() }
    }

    /// Run the session as `who`: every tap/swap/replay is checked against
    /// the platform's workspace registry (§IV overlapping sets).
    pub fn as_principal(mut self, who: &str) -> Self {
        self.principal = Some(who.to_string());
        self
    }

    /// Unwrap back to the bare coordinator.
    pub fn into_inner(self) -> Coordinator {
        self.pipe.into_inner()
    }

    fn authorize(&self, resource: Resource) -> Result<()> {
        if let Some(p) = &self.principal {
            if !self.pipe.plat.workspaces.check(p, &resource) {
                bail!("workspace denial: '{p}' holds no grant for {resource:?}");
            }
        }
        Ok(())
    }

    /// A wire is tappable when something publishes on it: a task output
    /// or an external in-tray (stream inputs). Out-of-band service inputs
    /// (`name?`) are not wires — they never pass the publication probe
    /// points — and are rejected with their own message in [`tap_with`].
    fn wire_exists(&self, wire: &str) -> bool {
        self.pipe.spec().tasks.iter().any(|t| {
            t.outputs.iter().any(|o| o == wire) || t.stream_inputs().any(|i| i.wire == wire)
        })
    }

    fn is_service_input(&self, wire: &str) -> bool {
        self.pipe.spec().tasks.iter().any(|t| t.service_inputs().any(|i| i.wire == wire))
    }

    // ------------------------------------------------------------------
    // Code plugging (records factories so replay can re-provision)
    // ------------------------------------------------------------------

    /// Plug task code into a task handle, keeping the factory so forensic
    /// replay can rebuild an identical agent. Prefer this (or the
    /// string-keyed [`Breadboard::plug`] wrapper) over raw
    /// [`Coordinator::set_code`] inside sessions. Fails (and records no
    /// factory) when the code's port bind fails.
    pub fn plug_task<F>(&mut self, task: TaskHandle, factory: F) -> Result<()>
    where
        F: Fn() -> Box<dyn TaskCode> + 'static,
    {
        let name = task.name(&self.pipe).to_string();
        task.plug(&mut self.pipe, factory())?;
        self.factories.insert(name, Box::new(factory));
        Ok(())
    }

    /// Name-resolving wrapper over [`Breadboard::plug_task`], kept for
    /// spec-text-driven scripts; the handle form is the steady-state API.
    pub fn plug<F>(&mut self, task: &str, factory: F) -> Result<()>
    where
        F: Fn() -> Box<dyn TaskCode> + 'static,
    {
        let h = self.pipe.task(task)?;
        self.plug_task(h, factory)
    }

    // ------------------------------------------------------------------
    // Wire taps
    // ------------------------------------------------------------------

    /// Attach a metadata tap (default spec) to a wire.
    pub fn tap(&mut self, wire: &str) -> Result<TapId> {
        self.tap_with(wire, TapSpec::default())
    }

    /// Attach a configured tap (capacity / payload capture / predicate).
    pub fn tap_with(&mut self, wire: &str, spec: TapSpec) -> Result<TapId> {
        self.authorize(Resource::Wire(wire.to_string()))?;
        if !self.wire_exists(wire) {
            if self.is_service_input(wire) {
                bail!(
                    "'{wire}' is an out-of-band service input (§III-D), not a stream \
                     wire — nothing is ever published on it; probe the service \
                     directory's forensic lookup log instead"
                );
            }
            bail!("no wire '{wire}' in pipeline [{}]", self.pipe.spec().name);
        }
        Ok(self.pipe.taps.attach(wire, spec))
    }

    /// Detach a tap; its ring is discarded. (Not gated: detaching only
    /// reduces access.)
    pub fn detach(&mut self, id: TapId) -> bool {
        self.pipe.taps.detach(id)
    }

    /// The wire a tap (still) watches, re-checked against the principal's
    /// grants: revoking a Wire grant locks existing taps' rings too, not
    /// just new attachments.
    fn authorize_tap_read(&mut self, id: TapId) -> Result<bool> {
        let wire = match self.pipe.taps.wire_of(id) {
            Some(w) => w.to_string(),
            None => return Ok(false),
        };
        self.authorize(Resource::Wire(wire))?;
        Ok(true)
    }

    /// Samples currently in a tap's ring (oldest first, virtual-time
    /// order). Workspace-gated like attach; empty for unknown ids.
    pub fn samples(&mut self, id: TapId) -> Result<Vec<TapSample>> {
        if !self.authorize_tap_read(id)? {
            return Ok(Vec::new());
        }
        Ok(self.pipe.taps.samples_vec(id))
    }

    /// Read-and-clear a tap's ring. Workspace-gated like attach.
    pub fn drain_samples(&mut self, id: TapId) -> Result<Vec<TapSample>> {
        if !self.authorize_tap_read(id)? {
            return Ok(Vec::new());
        }
        Ok(self.pipe.taps.drain(id))
    }

    /// Per-tap overhead counters. Workspace-gated like the other reads
    /// (live counters are a per-wire traffic side channel); `Ok(None)`
    /// for unknown ids.
    pub fn tap_stats(&mut self, id: TapId) -> Result<Option<TapStats>> {
        if !self.authorize_tap_read(id)? {
            return Ok(None);
        }
        Ok(self.pipe.taps.stats(id))
    }

    /// Live per-wire observability counters (publications / injections /
    /// bytes / sink commits) from the deployment's [`Obs`](crate::obs::Obs)
    /// registry — the panel meter next to the tap's scope probe.
    /// Workspace-gated like tap reads (traffic volume is a side channel
    /// too); `Ok(None)` when the deployment was not traced
    /// (`DeployConfig::trace` off).
    pub fn wire_counters(&mut self, wire: &str) -> Result<Option<crate::obs::WireStats>> {
        self.authorize(Resource::Wire(wire.to_string()))?;
        if !self.pipe.obs().enabled {
            return Ok(None);
        }
        let wid = self.pipe.wire_id(wire)?;
        Ok(self.pipe.obs().wire_stats(wid))
    }

    // ------------------------------------------------------------------
    // Virtual-time control (pause / step / resume)
    // ------------------------------------------------------------------

    /// Process exactly one pending event; returns its virtual time.
    pub fn step(&mut self) -> Option<SimTime> {
        self.pipe.step_event()
    }

    /// Advance virtual time by `d`, processing everything due.
    pub fn run_for(&mut self, d: SimDuration) -> u64 {
        self.pipe.run_for(d)
    }

    // ------------------------------------------------------------------
    // Hot-swap
    // ------------------------------------------------------------------

    /// Dry-run a swap on a task handle: report what moving it to
    /// `new_version` would invalidate. Nothing mutates.
    pub fn swap_preview_task(&mut self, task: TaskHandle, new_version: u32) -> Result<SwapPreview> {
        self.pipe.check_task(task);
        self.authorize(Resource::Pipeline(self.pipe.spec().name.clone()))?;
        Ok(swap::preview(&self.pipe, task.task_id(), new_version))
    }

    /// Name-resolving wrapper over [`Breadboard::swap_preview_task`].
    pub fn swap_preview(&mut self, task: &str, new_version: u32) -> Result<SwapPreview> {
        let h = self.pipe.task(task)?;
        self.swap_preview_task(h, new_version)
    }

    /// Commit a hot-swap on a task handle: install `factory()`'s code
    /// (which must carry a version bump), stamp the version change into
    /// provenance, invalidate the task's memo plus downstream
    /// dependent-local caches, and — when `recompute_last` — immediately
    /// re-run the last snapshot so corrected results propagate (§III-J
    /// "roll back the feed").
    pub fn hot_swap_task<F>(
        &mut self,
        task: TaskHandle,
        factory: F,
        recompute_last: bool,
    ) -> Result<SwapOutcome>
    where
        F: Fn() -> Box<dyn TaskCode> + 'static,
    {
        self.authorize(Resource::Pipeline(self.pipe.spec().name.clone()))?;
        let name = task.name(&self.pipe).to_string();
        let code = factory();
        let new_v = code.version();
        let preview = swap::preview(&self.pipe, task.task_id(), new_v);
        if new_v <= preview.old_version {
            bail!(
                "hot-swap of '{name}' needs a version bump (v{} -> v{new_v}); \
                 versions must strictly increase so provenance stamps stay \
                 unambiguous about which software produced what",
                preview.old_version
            );
        }
        let at = self.pipe.plat.now;
        // software update performs the downstream cache eviction itself
        // and reports what it actually evicted; the preview above is the
        // dry-run report plus the version-bump guard.
        let (cache_objects_evicted, cache_bytes_evicted) =
            task.hot_swap(&mut self.pipe, code, recompute_last)?;
        self.factories.insert(name.clone(), Box::new(factory));
        self.swaps.push(SwapRecord {
            task: name,
            from_version: preview.old_version,
            to_version: new_v,
            at,
        });
        Ok(SwapOutcome { preview, cache_objects_evicted, cache_bytes_evicted, at })
    }

    /// Name-resolving wrapper over [`Breadboard::hot_swap_task`].
    pub fn hot_swap<F>(&mut self, task: &str, factory: F, recompute_last: bool) -> Result<SwapOutcome>
    where
        F: Fn() -> Box<dyn TaskCode> + 'static,
    {
        let h = self.pipe.task(task)?;
        self.hot_swap_task(h, factory, recompute_last)
    }

    // ------------------------------------------------------------------
    // Quarantine inspection (supervised firing lifecycle)
    // ------------------------------------------------------------------

    /// Inspect a task's supervision state: breaker position, consecutive
    /// exhaust count, when it tripped, and the dead-letter backlog.
    /// Gated like swaps — breaker state is operational pipeline state.
    pub fn quarantine_view_task(&mut self, task: TaskHandle) -> Result<QuarantineView> {
        self.pipe.check_task(task);
        self.authorize(Resource::Pipeline(self.pipe.spec().name.clone()))?;
        let id = task.task_id();
        let breaker = *self.pipe.supervision.breaker(id);
        let book = self.pipe.dead_letter_book(id);
        Ok(QuarantineView {
            quarantined: breaker.quarantined,
            consecutive_exhausts: breaker.consecutive_exhausts,
            tripped_at: breaker.tripped_at,
            dead_letters: book.len(),
            dead_letters_dropped: book.dropped(),
        })
    }

    /// Name-resolving wrapper over [`Breadboard::quarantine_view_task`].
    pub fn quarantine_view(&mut self, task: &str) -> Result<QuarantineView> {
        let h = self.pipe.task(task)?;
        self.quarantine_view_task(h)
    }

    /// Manually close a task's circuit breaker (the operator override —
    /// hot-swapping a fix clears it automatically). Returns whether the
    /// breaker was actually open. Gated like swaps.
    pub fn reset_quarantine_task(&mut self, task: TaskHandle) -> Result<bool> {
        self.pipe.check_task(task);
        self.authorize(Resource::Pipeline(self.pipe.spec().name.clone()))?;
        Ok(self.pipe.quarantine_reset_id(task.task_id()))
    }

    /// Name-resolving wrapper over [`Breadboard::reset_quarantine_task`].
    pub fn reset_quarantine(&mut self, task: &str) -> Result<bool> {
        let h = self.pipe.task(task)?;
        self.reset_quarantine_task(h)
    }

    // ------------------------------------------------------------------
    // Forensic replay
    // ------------------------------------------------------------------

    /// Rebuild the whole run from the provenance ledger + seed: deploy a
    /// fresh coordinator (same spec, same config, same seed), provision it
    /// with this session's code factories, re-inject every recorded
    /// arrival at its recorded virtual time, and drain.
    pub fn forensic_replay(&mut self) -> Result<ReplayRun> {
        self.authorize(Resource::Provenance(self.pipe.spec().name.clone()))?;
        if !self.pipe.config().provenance {
            bail!("provenance was disabled at deploy time: no ledger to replay from");
        }
        let mut fresh = Coordinator::deploy(self.pipe.spec(), self.pipe.config().clone())
            .map_err(|e| anyhow!("replay deploy: {e}"))?;
        for (task, factory) in &self.factories {
            fresh.set_code(task, factory())?;
        }
        let ledger: Vec<InjectionRecord> = self.pipe.plat.prov.injections().to_vec();
        let mut injected = 0usize;
        let mut missing = 0usize;
        // resolve each distinct ledger wire name against the fresh
        // deployment's intern table once; re-injection then runs entirely
        // on ids (§Perf — ledgers repeat a handful of wires many times)
        let mut resolved: HashMap<String, WireId> = HashMap::new();
        for rec in ledger {
            match self.pipe.plat.store.peek(rec.object) {
                Some(obj) => {
                    let wid = match resolved.get(&*rec.wire) {
                        Some(w) => *w,
                        None => {
                            let w = fresh.wire_id(&rec.wire)?;
                            resolved.insert(rec.wire.to_string(), w);
                            w
                        }
                    };
                    fresh.inject_at_id(wid, obj.payload.clone(), rec.class, rec.region, rec.at)?;
                    injected += 1;
                }
                None => missing += 1,
            }
        }
        let events = fresh.run_until_idle();
        // the rebuilt record comes from the twin's deterministic commit
        // log — identical under any `workers` setting on either side
        let collected = fresh.sink_hash_sequences();
        Ok(ReplayRun { collected, injections_replayed: injected, missing_payloads: missing, events })
    }

    /// Diff a replay against the live record over the half-open window
    /// `[from, to)`; pass [`WINDOW_END`] as `to` for the unbounded tail.
    /// Both sides are commit-log projections, so the diff is unaffected
    /// by drained sinks or by how many wavefront workers either run used.
    pub fn diff_replay(&self, run: &ReplayRun, from: SimTime, to: SimTime) -> ReplayReport {
        let live = self.pipe.sink_hash_sequences();
        replay::diff_windows(&live, &run.collected, from, to)
    }

    /// Convenience: replay everything and diff one window.
    pub fn replay_window(&mut self, from: SimTime, to: SimTime) -> Result<ReplayReport> {
        let run = self.forensic_replay()?;
        Ok(self.diff_replay(&run, from, to))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::av::{DataClass, Payload};
    use crate::task::builtins::PortFn;
    use crate::task::{PortIo, TaskCtx};
    use crate::util::RegionId;

    fn scale_factory(factor: f32, version: u32) -> impl Fn() -> Box<dyn TaskCode> {
        move || {
            Box::new(PortFn::versioned(
                move |ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
                    let port = io.out(0)?;
                    for av in io.inputs.snapshot().all_avs() {
                        let p = ctx.fetch(av)?;
                        let scaled = match p.as_tensor() {
                            Some((shape, data)) => Payload::tensor(
                                shape,
                                data.iter().map(|x| x * factor).collect(),
                            ),
                            None => p,
                        };
                        io.emitter.emit(port, scaled);
                    }
                    Ok(())
                },
                version,
            ))
        }
    }

    fn session() -> Breadboard {
        let spec = crate::spec::parse("[bb]\n(raw) work (out)\n").unwrap();
        let mut b = Breadboard::deploy(&spec, DeployConfig::default()).unwrap();
        b.plug("work", scale_factory(1.0, 1)).unwrap();
        b
    }

    fn inject_series(b: &mut Breadboard, values: &[f32], start_ms: u64) {
        for (i, v) in values.iter().enumerate() {
            b.inject_at(
                "raw",
                Payload::scalar(*v),
                DataClass::Summary,
                RegionId::new(0),
                SimTime::millis(start_ms + i as u64 * 10),
            )
            .unwrap();
        }
    }

    #[test]
    fn tap_observes_live_traffic() {
        let mut b = session();
        let t = b.tap("raw").unwrap();
        inject_series(&mut b, &[1.0, 2.0, 3.0], 0);
        b.run_until_idle();
        let stats = b.tap_stats(t).unwrap().unwrap();
        assert_eq!(stats.seen, 3);
        assert_eq!(stats.sampled, 3);
        let samples = b.samples(t).unwrap();
        assert_eq!(samples.len(), 3);
        assert!(samples.windows(2).all(|w| w[0].at <= w[1].at));
        // fan-out wires sample once per value, not once per consumer link
        let spec = crate::spec::parse("[f]\n(raw) src (x)\n(x) left (l)\n(x) right (r)\n").unwrap();
        let mut fb = Breadboard::deploy(&spec, DeployConfig::default()).unwrap();
        let xt = fb.tap("x").unwrap();
        fb.inject("raw", Payload::scalar(9.0), DataClass::Summary).unwrap();
        fb.run_until_idle();
        assert_eq!(fb.tap_stats(xt).unwrap().unwrap().seen, 1, "one value, two links, one sample");
        assert_eq!(fb.collected_count("l"), 1);
        assert_eq!(fb.collected_count("r"), 1);
        // sink wires are tappable too
        let s = b.tap("out").unwrap();
        inject_series(&mut b, &[4.0], 100);
        b.run_until_idle();
        assert_eq!(b.tap_stats(s).unwrap().unwrap().sampled, 1);
        // unknown wires are rejected
        assert!(b.tap("nope").is_err());
    }

    #[test]
    fn wire_counters_ride_the_obs_registry() {
        // untraced session: the panel meter is dark, not an error
        let mut b = session();
        assert!(b.wire_counters("raw").unwrap().is_none());

        let spec = crate::spec::parse("[bb]\n(raw) work (out)\n").unwrap();
        let mut b =
            Breadboard::deploy(&spec, DeployConfig { trace: true, ..Default::default() }).unwrap();
        b.plug("work", scale_factory(1.0, 1)).unwrap();
        inject_series(&mut b, &[1.0, 2.0], 0);
        b.run_until_idle();
        let raw = b.wire_counters("raw").unwrap().unwrap();
        assert_eq!(raw.injections, 2);
        assert!(raw.bytes > 0);
        let out = b.wire_counters("out").unwrap().unwrap();
        assert_eq!(out.publications, 2);
        assert_eq!(out.sink_commits, 2);
        // unknown wires fail resolution like every other name surface
        assert!(b.wire_counters("nope").is_err());
    }

    #[test]
    fn out_of_order_injections_observe_in_virtual_time_order() {
        // observation rides the event queue, so future-dated injections
        // issued out of order still land in the ring oldest-first
        let mut b = session();
        let t = b.tap("raw").unwrap();
        b.inject_at("raw", Payload::scalar(1.0), DataClass::Summary, RegionId::new(0), SimTime::secs(10))
            .unwrap();
        b.inject_at("raw", Payload::scalar(2.0), DataClass::Summary, RegionId::new(0), SimTime::secs(1))
            .unwrap();
        b.run_until_idle();
        let at: Vec<u64> = b.samples(t).unwrap().iter().map(|s| s.at.as_micros()).collect();
        assert_eq!(at, vec![1_000_000, 10_000_000], "ring ordered by virtual time");
    }

    #[test]
    fn detached_tap_stops_and_costs_nothing() {
        let mut b = session();
        let t = b.tap("raw").unwrap();
        inject_series(&mut b, &[1.0], 0);
        b.run_until_idle();
        assert_eq!(b.tap_stats(t).unwrap().unwrap().seen, 1);
        assert!(b.detach(t));
        assert!(b.taps.is_empty(), "hook guard is back to the zero-cost branch");
        inject_series(&mut b, &[2.0], 50);
        b.run_until_idle();
        assert!(b.tap_stats(t).unwrap().is_none());
    }

    #[test]
    fn hot_swap_bumps_version_and_invalidates() {
        let mut b = session();
        inject_series(&mut b, &[3.0], 0);
        b.run_until_idle();
        let preview = b.swap_preview("work", 2).unwrap();
        assert_eq!(preview.old_version, 1);
        assert!(preview.memo_entries >= 1);

        // same version: refused
        assert!(b.hot_swap("work", scale_factory(2.0, 1), false).is_err());

        let outcome = b.hot_swap("work", scale_factory(2.0, 2), false).unwrap();
        // downgrades are refused too — version history must stay monotone
        assert!(b.hot_swap("work", scale_factory(3.0, 1), false).is_err());
        assert_eq!(outcome.preview.new_version, 2);
        let id = b.task_id("work").unwrap();
        assert_eq!(b.agents[id.index()].version(), 2);
        assert_eq!(b.agents[id.index()].memo_len(), 0, "memo flushed");
        assert_eq!(b.swaps.len(), 1);

        // the bump is visible in provenance: new outputs carry v2
        inject_series(&mut b, &[5.0], 100);
        b.run_until_idle();
        let q = crate::provenance::ProvenanceQuery::new(&b.plat.prov);
        let last = b.collected["out"].last().unwrap().av.id;
        assert!(q.versions_touching(last).iter().any(|(_, v)| *v == 2));
        let changes = q.version_changes(id);
        assert_eq!(changes.len(), 1);
        assert_eq!((changes[0].1, changes[0].2), (1, 2));
        // and the swapped math actually ran
        let v = b.collected["out"].last().unwrap().payload.as_tensor().unwrap().1[0];
        assert_eq!(v, 10.0);
    }

    #[test]
    fn replay_matches_when_software_unchanged() {
        let mut b = session();
        inject_series(&mut b, &[1.0, 2.0, 3.0, 4.0], 0);
        b.run_until_idle();
        let run = b.forensic_replay().unwrap();
        assert_eq!(run.injections_replayed, 4);
        assert_eq!(run.missing_payloads, 0);
        let report = b.diff_replay(&run, SimTime::ZERO, WINDOW_END);
        assert!(report.drift_free(), "{}", report.summary());
        assert_eq!(report.total_matched(), 4);
    }

    #[test]
    fn replay_detects_drift_from_a_swap() {
        let mut b = session();
        inject_series(&mut b, &[1.0, 2.0], 0); // pre-swap window
        b.run_until_idle();
        b.run_until(SimTime::millis(500));
        let t_swap = b.plat.now;
        b.hot_swap("work", scale_factory(2.0, 2), false).unwrap();
        inject_series(&mut b, &[3.0, 4.0], 600); // post-swap window
        b.run_until_idle();

        let run = b.forensic_replay().unwrap();
        // pre-swap outputs were produced by v1; the replay runs v2 → drift
        let pre = b.diff_replay(&run, SimTime::ZERO, t_swap);
        assert!(!pre.drift_free(), "v1-era outputs must drift under v2");
        // post-swap outputs match hash-for-hash
        let post = b.diff_replay(&run, t_swap, WINDOW_END);
        assert!(post.drift_free(), "{}", post.summary());
        assert_eq!(post.total_matched(), 2);
    }

    #[test]
    fn workspace_grants_gate_the_session() {
        let spec = crate::spec::parse("[gated]\n(raw) work (out)\n").unwrap();
        let mut b = Breadboard::deploy(&spec, DeployConfig::default())
            .unwrap()
            .as_principal("eve");
        assert!(b.tap("raw").is_err(), "no grant, no probe");
        assert!(b.swap_preview("work", 2).is_err());
        assert!(b.forensic_replay().is_err());

        let ws = b.plat.workspaces.create("lab");
        b.plat.workspaces.add_member(ws, "eve");
        b.plat.workspaces.grant(ws, Resource::Wire("raw".into()));
        let tap = b.tap("raw").expect("wire grant unlocks the tap");
        assert!(b.swap_preview("work", 2).is_err(), "pipeline grant still missing");
        b.plat.workspaces.grant(ws, Resource::Pipeline("gated".into()));
        assert!(b.swap_preview("work", 2).is_ok());
        b.plat.workspaces.grant(ws, Resource::Provenance("gated".into()));
        assert!(b.forensic_replay().is_ok());
        assert!(b.plat.workspaces.denied() >= 3);

        // revoking the Wire grant locks the already-attached tap's ring:
        // reading samples is gated the same way attaching was
        b.inject("raw", Payload::scalar(1.0), DataClass::Summary).unwrap();
        b.run_until_idle();
        assert_eq!(b.samples(tap).unwrap().len(), 1);
        b.plat.workspaces.revoke(ws, &Resource::Wire("raw".into()));
        assert!(b.samples(tap).is_err(), "revocation is final for reads too");
        assert!(b.drain_samples(tap).is_err());
        assert!(b.tap_stats(tap).is_err(), "counters are gated like samples");
        assert!(b.wire_counters("raw").is_err(), "obs counters are gated like taps");
    }
}
