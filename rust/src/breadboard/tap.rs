//! Wire taps — zero-copy probes on live links.
//!
//! A [`TapBoard`] lives inside the coordinator; the event loop calls
//! [`TapBoard::observe`] from its publication points (task output routing
//! and external injection), so each value is sampled exactly once per
//! appearance on a wire no matter how many consumer links fan out from
//! it. The hook is a single `is_empty()` branch when no tap is attached
//! (measured in `benches/tap_overhead.rs`), so a production pipeline pays
//! nothing for the breadboarding machinery it is not using.
//!
//! Each tap watches one wire, optionally filters with a predicate over AV
//! metadata, and samples into a bounded ring buffer. Payload capture is
//! opt-in: the default tap copies only the ~140-byte annotation (the AV is
//! a pointer into object storage, §III-I), never the payload bytes.

use crate::av::{AnnotatedValue, Payload};
use crate::storage::ObjectStore;
use crate::util::{SimTime, WireId};
use std::collections::VecDeque;
use std::sync::Arc;

/// Identifies one attached tap (unique for the coordinator's lifetime).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct TapId(pub u64);

/// One sampled observation.
#[derive(Clone, Debug)]
pub struct TapSample {
    /// Virtual time the AV passed the probe point.
    pub at: SimTime,
    /// The annotation itself (metadata only — the storage pointer).
    pub av: AnnotatedValue,
    /// Payload copy, present only on payload-capturing taps.
    pub payload: Option<Payload>,
}

/// Configuration for one tap.
pub struct TapSpec {
    /// Ring-buffer capacity (oldest samples drop when full).
    pub capacity: usize,
    /// Copy payload bytes out of storage for each sample (costly; off by
    /// default — metadata is usually what a breadboarder probes).
    pub payloads: bool,
    /// Sample only AVs the predicate accepts (None = everything).
    pub predicate: Option<Box<dyn Fn(&AnnotatedValue) -> bool>>,
}

impl Default for TapSpec {
    fn default() -> Self {
        Self { capacity: 64, payloads: false, predicate: None }
    }
}

impl TapSpec {
    pub fn with_capacity(mut self, cap: usize) -> Self {
        self.capacity = cap.max(1);
        self
    }

    pub fn with_payloads(mut self) -> Self {
        self.payloads = true;
        self
    }

    pub fn with_predicate<F: Fn(&AnnotatedValue) -> bool + 'static>(mut self, f: F) -> Self {
        self.predicate = Some(Box::new(f));
        self
    }
}

/// Overhead/throughput counters for one tap.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TapStats {
    /// AVs that passed the probe point on this wire.
    pub seen: u64,
    /// AVs that entered the ring (passed the predicate).
    pub sampled: u64,
    /// Samples evicted because the ring was full.
    pub dropped: u64,
}

struct TapState {
    id: TapId,
    /// Wire name as given at attach time (kept for display / re-checking
    /// workspace grants).
    wire_name: String,
    /// Interned wire, or None when the name is not in the deploy-time
    /// table — such a tap is inert: out-of-table publications (custom
    /// user code emitting a name the spec never mentions) bypass the
    /// dense probe points and land only in the sink overflow map. It is
    /// harmless to attach, and costs untapped wires nothing.
    wire: Option<WireId>,
    spec: TapSpec,
    ring: VecDeque<TapSample>,
    stats: TapStats,
    enabled: bool,
}

/// The set of live taps, owned by the coordinator.
///
/// §Perf: the board is *bound* to the pipeline's interned wire table at
/// deploy time; the hot-path guard [`TapBoard::watches`] is then one
/// `is_empty` branch plus one dense `Vec<bool>` load indexed by [`WireId`]
/// — no name scan, no hashing — rebuilt only when taps attach/detach or
/// pause/resume (cold operations).
#[derive(Default)]
pub struct TapBoard {
    taps: Vec<TapState>,
    next_id: u64,
    /// Interned wire names, shared with the coordinator's wire table.
    names: Arc<Vec<String>>,
    /// Dense guard: `mask[w]` == some enabled tap watches wire `w`.
    mask: Vec<bool>,
    /// Observe calls actually dispatched (any tap attached) — for the
    /// overhead bench's sanity check.
    pub observations: u64,
}

impl TapBoard {
    /// A board bound to a pipeline's interned wire table (what
    /// `Coordinator::deploy` constructs). The default (unbound) board
    /// treats every attach as unknown-wire, so it only suits unit tests.
    pub fn bound(names: Arc<Vec<String>>) -> Self {
        let mask = vec![false; names.len()];
        Self { taps: Vec::new(), next_id: 0, names, mask, observations: 0 }
    }

    /// True when no tap is attached — the hot-path guard: the event loop
    /// skips [`TapBoard::observe`] entirely in that case.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.taps.is_empty()
    }

    /// Wire-precise guard: does any enabled tap watch `wire`? One branch
    /// when the board is empty, one dense bool load otherwise — untapped
    /// wires never pay for the observation event.
    #[inline]
    pub fn watches(&self, wire: WireId) -> bool {
        !self.taps.is_empty() && self.mask.get(wire.index()).copied().unwrap_or(false)
    }

    pub fn len(&self) -> usize {
        self.taps.len()
    }

    fn rebuild_mask(&mut self) {
        self.mask.clear();
        self.mask.resize(self.names.len(), false);
        for t in &self.taps {
            if let (true, Some(w)) = (t.enabled, t.wire) {
                self.mask[w.index()] = true;
            }
        }
    }

    /// Attach a probe to `wire`. Returns the handle used to read/detach.
    /// Unknown wire names attach an inert tap (see [`TapState::wire`]);
    /// callers wanting a hard error go through `Breadboard::tap`, which
    /// validates the name against the spec first.
    pub fn attach(&mut self, wire: &str, spec: TapSpec) -> TapId {
        let id = TapId(self.next_id);
        self.next_id += 1;
        let wire_id = self
            .names
            .iter()
            .position(|n| n == wire)
            .map(|i| WireId::new(i as u32));
        self.taps.push(TapState {
            id,
            wire_name: wire.to_string(),
            wire: wire_id,
            spec,
            ring: VecDeque::new(),
            stats: TapStats::default(),
            enabled: true,
        });
        self.rebuild_mask();
        id
    }

    /// Remove a tap entirely; returns false if it was never attached.
    pub fn detach(&mut self, id: TapId) -> bool {
        let before = self.taps.len();
        self.taps.retain(|t| t.id != id);
        let changed = self.taps.len() != before;
        if changed {
            self.rebuild_mask();
        }
        changed
    }

    /// Pause/resume sampling without losing the ring.
    pub fn set_enabled(&mut self, id: TapId, enabled: bool) -> bool {
        let found = match self.taps.iter_mut().find(|t| t.id == id) {
            Some(t) => {
                t.enabled = enabled;
                true
            }
            None => false,
        };
        if found {
            self.rebuild_mask();
        }
        found
    }

    fn state(&self, id: TapId) -> Option<&TapState> {
        self.taps.iter().find(|t| t.id == id)
    }

    /// Ring contents, oldest first (owned copies — the ring may wrap).
    pub fn samples_vec(&self, id: TapId) -> Vec<TapSample> {
        self.state(id).map(|t| t.ring.iter().cloned().collect()).unwrap_or_default()
    }

    /// Drain the ring (read-and-clear).
    pub fn drain(&mut self, id: TapId) -> Vec<TapSample> {
        match self.taps.iter_mut().find(|t| t.id == id) {
            Some(t) => t.ring.drain(..).collect(),
            None => Vec::new(),
        }
    }

    pub fn stats(&self, id: TapId) -> Option<TapStats> {
        self.state(id).map(|t| t.stats)
    }

    pub fn wire_of(&self, id: TapId) -> Option<&str> {
        self.state(id).map(|t| t.wire_name.as_str())
    }

    /// Dispatch point: called by the coordinator when an AV is published
    /// on `wire` (once per value — consumer fan-out does not multiply
    /// observations). The caller guards with [`TapBoard::watches`] so
    /// this is never on the hot path of an untapped pipeline.
    pub fn observe(&mut self, wire: WireId, av: &AnnotatedValue, store: &ObjectStore, now: SimTime) {
        self.observations += 1;
        for t in self.taps.iter_mut() {
            if !t.enabled || t.wire != Some(wire) {
                continue;
            }
            t.stats.seen += 1;
            if let Some(pred) = &t.spec.predicate {
                if !pred(av) {
                    continue;
                }
            }
            let payload = if t.spec.payloads {
                store.peek(av.object).map(|o| o.payload.clone())
            } else {
                None
            };
            if t.ring.len() >= t.spec.capacity {
                t.ring.pop_front();
                t.stats.dropped += 1;
            }
            t.ring.push_back(TapSample { at: now, av: av.clone(), payload });
            t.stats.sampled += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::av::DataClass;
    use crate::storage::{StorageConfig, StorageTier};
    use crate::util::{AvId, ContentHash, LinkId, ObjectId, RegionId, TaskId};

    fn av(seq: u64, object: ObjectId) -> AnnotatedValue {
        AnnotatedValue {
            id: AvId::new(seq),
            source_task: TaskId::new(0),
            link: LinkId::new(0),
            object,
            region: RegionId::new(0),
            created: SimTime::micros(seq),
            seq,
            size_bytes: 4,
            content: ContentHash::of_str("x"),
            class: DataClass::Summary,
            ghost: false,
            born: SimTime::micros(seq),
        }
    }

    fn store_with(payload: Payload) -> (ObjectStore, ObjectId) {
        let mut s = ObjectStore::new(StorageConfig::default());
        let (id, _) = s.put(
            payload,
            RegionId::new(0),
            StorageTier::ObjectStore,
            DataClass::Summary,
            SimTime::ZERO,
        );
        (s, id)
    }

    /// A board bound to two wires: "w" = WireId 0, "v" = WireId 1.
    fn board() -> TapBoard {
        TapBoard::bound(Arc::new(vec!["w".to_string(), "v".to_string()]))
    }

    const W: WireId = WireId::new(0);
    const V: WireId = WireId::new(1);

    #[test]
    fn ring_bounds_and_counters() {
        let (store, obj) = store_with(Payload::scalar(1.0));
        let mut board = board();
        let id = board.attach("w", TapSpec::default().with_capacity(3));
        for i in 0..5 {
            board.observe(W, &av(i, obj), &store, SimTime::micros(i));
        }
        let stats = board.stats(id).unwrap();
        assert_eq!(stats.seen, 5);
        assert_eq!(stats.sampled, 5);
        assert_eq!(stats.dropped, 2);
        let seqs: Vec<u64> = board.samples_vec(id).iter().map(|s| s.av.seq).collect();
        assert_eq!(seqs, vec![2, 3, 4], "oldest evicted first");
    }

    #[test]
    fn predicate_filters_and_wire_isolates() {
        let (store, obj) = store_with(Payload::scalar(1.0));
        let mut board = board();
        let even = board.attach("w", TapSpec::default().with_predicate(|a| a.seq % 2 == 0));
        let other = board.attach("v", TapSpec::default());
        for i in 0..6 {
            board.observe(W, &av(i, obj), &store, SimTime::micros(i));
        }
        assert_eq!(board.stats(even).unwrap().sampled, 3);
        assert_eq!(board.stats(even).unwrap().seen, 6);
        assert_eq!(board.stats(other).unwrap().seen, 0, "other wire untouched");
    }

    #[test]
    fn watch_mask_is_wire_precise() {
        let mut board = board();
        assert!(!board.watches(W), "empty board watches nothing");
        let id = board.attach("w", TapSpec::default());
        assert!(board.watches(W));
        assert!(!board.watches(V), "other wires stay cold");
        // unknown names attach inert: no wire lights up
        board.attach("cold-wire", TapSpec::default());
        assert!(!board.watches(V));
        board.set_enabled(id, false);
        assert!(!board.watches(W), "paused taps drop out of the mask");
        board.set_enabled(id, true);
        assert!(board.watches(W));
        board.detach(id);
        assert!(!board.watches(W), "detach clears the mask");
    }

    #[test]
    fn payload_capture_copies_bytes() {
        let p = Payload::tensor(&[2], vec![3.0, 4.0]);
        let (store, obj) = store_with(p.clone());
        let mut board = board();
        let plain = board.attach("w", TapSpec::default());
        let deep = board.attach("w", TapSpec::default().with_payloads());
        board.observe(W, &av(0, obj), &store, SimTime::ZERO);
        assert!(board.samples_vec(plain)[0].payload.is_none());
        assert_eq!(board.samples_vec(deep)[0].payload, Some(p));
    }

    #[test]
    fn detach_and_disable() {
        let (store, obj) = store_with(Payload::scalar(0.0));
        let mut board = board();
        let id = board.attach("w", TapSpec::default());
        board.observe(W, &av(0, obj), &store, SimTime::ZERO);
        assert!(board.set_enabled(id, false));
        board.observe(W, &av(1, obj), &store, SimTime::ZERO);
        assert_eq!(board.stats(id).unwrap().sampled, 1, "paused tap sampled nothing");
        assert!(board.detach(id));
        assert!(!board.detach(id), "double detach is a no-op");
        assert!(board.is_empty());
    }
}
