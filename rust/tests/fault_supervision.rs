//! Supervised firing lifecycle: deterministic retries, dead-letter
//! books, quarantine breakers, redrive after hot-swap, degrade
//! fallbacks, deadline budgets, and the structured event-storm report.
//!
//! Every scenario drives faults through a seeded [`FaultPlan`] with
//! forced (task, firing-index) coordinates and zeroed random rates, so
//! the failures land exactly where the assertions expect — at any
//! `KOALJA_WORKERS` setting, which these tests deliberately inherit
//! from the environment (the supervision machinery is part of the
//! byte-identical-provenance contract, so the CI chaos matrix runs
//! this file at several pool widths and seeds).

use koalja::breadboard::Breadboard;
use koalja::prelude::*;
use koalja::provenance::CheckpointEvent;
use koalja::util::TaskId;

/// Pass-through task code: fetch every snapshot AV, emit it on port 0.
fn passthrough() -> Box<dyn TaskCode> {
    Box::new(PortFn::new(|ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
        let port = io.out(0)?;
        for av in io.inputs.all() {
            let p = ctx.fetch(av)?;
            io.emitter.emit(port, p);
        }
        Ok(())
    }))
}

/// One-task pipeline `(x) work (y)` with the given plan, code plugged.
fn rig(plan: FaultPlan) -> Coordinator {
    let spec = parse("[sup]\n(x) work (y)\n").unwrap();
    let cfg = DeployConfig { fault: Some(plan), ..Default::default() };
    let mut c = Coordinator::deploy(&spec, cfg).unwrap();
    c.set_code("work", passthrough()).unwrap();
    c
}

fn inject_n(c: &mut Coordinator, wire: &str, n: u64) {
    for i in 0..n {
        c.inject_at(
            wire,
            Payload::scalar(i as f32),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(i),
        )
        .unwrap();
    }
}

fn remark_present(c: &Coordinator, task: TaskId, needle: &str) -> bool {
    c.plat.prov.checkpoint_log(task).iter().any(|e| match &e.event {
        CheckpointEvent::Remark(m) | CheckpointEvent::Anomaly(m) => m.contains(needle),
        _ => false,
    })
}

// ---------------------------------------------------------------------
// retries
// ---------------------------------------------------------------------

#[test]
fn retry_in_virtual_time_then_succeed() {
    // firing 0 fails on attempt 1 only; the policy allows 2 retries, so
    // attempt 2 (at T + 10ms) succeeds and the value still reaches the
    // sink — late, but intact
    let plan = FaultPlan::seeded(1).with_rates(0.0, 0.0, 0.0).force(0, 0, 1, FaultKind::Error);
    let mut c = rig(plan);
    let id = c.task_id("work").unwrap();
    c.set_fire_policy_id(
        id,
        FirePolicy::retries(2).with_backoff(Backoff::Fixed(SimDuration::millis(10))),
    );
    inject_n(&mut c, "x", 1);
    c.run_until_idle();

    assert_eq!(c.collected_count("y"), 1, "retried firing still delivered");
    let rec = &c.collected.get("y").unwrap()[0];
    assert!(
        rec.at >= SimTime::millis(10),
        "retry ran in virtual time (published at {:?}, backoff 10ms)",
        rec.at
    );
    assert_eq!(c.plat.metrics.get("task_errors"), 1);
    assert_eq!(c.plat.metrics.get("task_retries"), 1);
    assert_eq!(c.plat.metrics.get("task_exhausted"), 0);
    assert!(c.dead_letter_book(id).is_empty());
    assert!(remark_present(&c, id, "retry: firing 0 attempt 1/3"));
}

#[test]
fn exhausted_firing_lands_in_the_dead_letter_book() {
    // firing 0 fails on every attempt; retries(1) = 2 attempts total,
    // then the default on-exhaust action dead-letters it with the input
    // snapshot pinned
    let plan = FaultPlan::seeded(2).with_rates(0.0, 0.0, 0.0).force(0, 0, 9, FaultKind::Error);
    let mut c = rig(plan);
    let id = c.task_id("work").unwrap();
    c.set_fire_policy_id(id, FirePolicy::retries(1).dead_letter());
    inject_n(&mut c, "x", 2);
    c.run_until_idle();

    assert_eq!(c.collected_count("y"), 1, "only the healthy firing delivered");
    assert_eq!(c.plat.metrics.get("task_errors"), 2, "two failed attempts");
    assert_eq!(c.plat.metrics.get("task_retries"), 1);
    assert_eq!(c.plat.metrics.get("task_exhausted"), 1);
    assert_eq!(c.plat.metrics.get("dead_letters"), 1);

    let book = c.dead_letter_book(id);
    assert_eq!(book.len(), 1);
    let letter = book.letters().next().unwrap();
    assert_eq!(letter.index, 0);
    assert_eq!(letter.attempts, 2);
    assert!(!letter.panicked);
    assert!(!letter.quarantine_drop);
    assert!(letter.error.contains("injected fault"), "{}", letter.error);
    assert!(letter.input_names().any(|n| n == "x"), "snapshot pinned the input wire");
    assert!(!letter.av_ids().is_empty(), "snapshot pinned the input AVs");
    assert!(remark_present(&c, id, "exhausted after 2 attempt(s)"));
}

// ---------------------------------------------------------------------
// quarantine breaker
// ---------------------------------------------------------------------

#[test]
fn breaker_trips_diverts_and_resets_via_breadboard() {
    // firings 0 and 1 exhaust consecutively -> breaker trips at 2; the
    // third wake is diverted straight to the book without executing
    let plan = FaultPlan::seeded(3)
        .with_rates(0.0, 0.0, 0.0)
        .force(0, 0, 9, FaultKind::Error)
        .force(0, 1, 9, FaultKind::Error);
    let spec = parse("[sup]\n(x) work (y)\n").unwrap();
    let cfg = DeployConfig { fault: Some(plan), ..Default::default() };
    let mut b = Breadboard::deploy(&spec, cfg).unwrap();
    b.plug("work", passthrough).unwrap();
    let h = b.task("work").unwrap();
    h.set_fire_policy(&mut b, FirePolicy::retries(0).quarantine(2));
    for i in 0..3u64 {
        b.inject_at(
            "x",
            Payload::scalar(i as f32),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(i),
        )
        .unwrap();
    }
    b.run_until_idle();

    assert!(h.quarantined(&b), "breaker open after 2 consecutive exhausts");
    assert_eq!(b.plat.metrics.get("quarantine_trips"), 1);
    assert_eq!(b.plat.metrics.get("quarantine_dropped"), 1, "third wake diverted");
    let letters = h.dead_letters(&b);
    assert_eq!(letters.len(), 3);
    assert!(letters[2].quarantine_drop, "diverted letter marked as a breaker drop");
    assert_eq!(letters[2].attempts, 0, "diverted firing never executed");

    // breadboard inspect + reset verbs
    let view = b.quarantine_view("work").unwrap();
    assert!(view.quarantined);
    assert_eq!(view.consecutive_exhausts, 2);
    assert!(view.tripped_at.is_some());
    assert_eq!(view.dead_letters, 3);
    assert_eq!(view.dead_letters_dropped, 0);

    assert!(b.reset_quarantine("work").unwrap(), "reset reports the breaker was open");
    assert!(!h.quarantined(&b));
    assert_eq!(b.plat.metrics.get("quarantine_resets"), 1);
    assert!(!b.reset_quarantine("work").unwrap(), "idempotent: already clear");

    // healthy again: a fresh injection flows end to end
    b.inject_at("x", Payload::scalar(9.0), DataClass::Summary, RegionId::new(0), SimTime::millis(10))
        .unwrap();
    b.run_until_idle();
    assert_eq!(b.collected_count("y"), 1, "post-reset firing delivered");
}

#[test]
fn redrive_replays_dead_letters_after_hot_swap() {
    // the acceptance scenario: quarantine a task, hot-swap (which
    // clears the breaker), redrive -- the pinned snapshots replay
    // through the new code and reach the sink
    let plan = FaultPlan::seeded(4)
        .with_rates(0.0, 0.0, 0.0)
        .force(0, 0, 9, FaultKind::Error)
        .force(0, 1, 9, FaultKind::Error);
    let spec = parse("[sup]\n(x) work (y)\n").unwrap();
    let cfg = DeployConfig { fault: Some(plan), ..Default::default() };
    let mut p = Pipeline::deploy(&spec, cfg).unwrap();
    let h = p.task("work").unwrap();
    h.plug(&mut p, passthrough()).unwrap();
    h.set_fire_policy(&mut p, FirePolicy::retries(0).quarantine(1));
    for i in 0..2u64 {
        p.inject_at(
            "x",
            Payload::scalar(i as f32),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(i),
        )
        .unwrap();
    }
    p.run_until_idle();
    assert!(h.quarantined(&p));
    assert_eq!(h.dead_letters(&p).len(), 2, "one exhausted + one diverted");
    assert_eq!(p.collected_count("y"), 0);

    // redrive refuses while the breaker is open
    let e = h.redrive(&mut p).unwrap_err().to_string();
    assert!(e.contains("quarantined"), "{e}");

    // hot-swap is the "code is fixed" signal: breaker clears implicitly
    h.hot_swap(&mut p, passthrough(), false).unwrap();
    assert!(!h.quarantined(&p), "software update cleared the breaker");
    assert!(remark_present(&p, h.task_id(), "quarantine cleared by software update"));

    // redriven firings get fresh indices (2, 3) the forced coordinates
    // miss, so they succeed through the swapped code
    let n = h.redrive(&mut p).unwrap();
    assert_eq!(n, 2);
    p.run_until_idle();
    assert_eq!(p.collected_count("y"), 2, "both pinned snapshots replayed to the sink");
    assert!(h.dead_letters(&p).is_empty(), "book drained by the redrive");
    assert_eq!(p.plat.metrics.get("redrives"), 1);
    assert!(remark_present(&p, h.task_id(), "redrive: replaying 2 dead-lettered firing(s)"));
    assert_eq!(h.redrive(&mut p).unwrap(), 0, "nothing left to redrive");
}

// ---------------------------------------------------------------------
// degrade + deadline
// ---------------------------------------------------------------------

#[test]
fn degrade_emits_declared_fallback() {
    // firing 0 exhausts; the policy's fallback keeps downstream flowing
    let plan = FaultPlan::seeded(5).with_rates(0.0, 0.0, 0.0).force(0, 0, 9, FaultKind::Error);
    let mut c = rig(plan);
    let id = c.task_id("work").unwrap();
    c.set_fire_policy_id(id, FirePolicy::retries(0).degrade(Payload::scalar(-1.0)));
    inject_n(&mut c, "x", 2);
    c.run_until_idle();

    assert_eq!(c.collected_count("y"), 2, "fallback + healthy value both arrive");
    let recs = c.collected.get("y").unwrap();
    assert_eq!(recs[0].payload, Payload::scalar(-1.0), "firing 0 degraded to the fallback");
    assert_eq!(recs[1].payload, Payload::scalar(1.0), "firing 1 ran normally");
    assert_eq!(c.plat.metrics.get("task_degraded"), 1);
    assert!(c.dead_letter_book(id).is_empty(), "degrade does not dead-letter");
    assert!(remark_present(&c, id, "degraded: fallback emitted"));
}

#[test]
fn deadline_budget_fails_slow_firings() {
    // a forced cost spike inflates firing 0 far past the policy's
    // budget; the deadline check fails the attempt and the firing
    // dead-letters with a structured error
    let plan = FaultPlan::seeded(6)
        .with_rates(0.0, 0.0, 0.0)
        .force(0, 0, 9, FaultKind::CostSpike(SimDuration::secs(2)));
    let mut c = rig(plan);
    let id = c.task_id("work").unwrap();
    c.set_fire_policy_id(
        id,
        FirePolicy::retries(0).with_deadline(SimDuration::secs(1)).dead_letter(),
    );
    inject_n(&mut c, "x", 2);
    c.run_until_idle();

    assert_eq!(c.collected_count("y"), 1, "unspiked firing fits the budget");
    let book = c.dead_letter_book(id);
    assert_eq!(book.len(), 1);
    let letter = book.letters().next().unwrap();
    assert!(letter.error.contains("deadline exceeded"), "{}", letter.error);
    assert!(!letter.panicked);
}

// ---------------------------------------------------------------------
// panic / error distinction
// ---------------------------------------------------------------------

#[test]
fn injected_panic_and_error_stay_distinguishable() {
    // two unsupervised tasks (record-and-drop path): one draws a plain
    // error, the other a synthesized panic — the distinction survives
    // into remarks, metrics, and the flight recorder's firing kinds
    let plan = FaultPlan::seeded(7)
        .with_rates(0.0, 0.0, 0.0)
        .force(0, 0, 9, FaultKind::Error)
        .force(1, 0, 9, FaultKind::Panic);
    let spec = parse("[sup]\n(x) perr (a)\n(x) ppan (b)\n").unwrap();
    let cfg = DeployConfig { trace: true, fault: Some(plan), ..Default::default() };
    let mut c = Coordinator::deploy(&spec, cfg).unwrap();
    c.set_code("perr", passthrough()).unwrap();
    c.set_code("ppan", passthrough()).unwrap();
    inject_n(&mut c, "x", 1);
    c.run_until_idle();

    assert_eq!(c.collected_count("a"), 0);
    assert_eq!(c.collected_count("b"), 0);
    assert_eq!(c.plat.metrics.get("task_errors"), 2);
    let perr = c.task_id("perr").unwrap();
    let ppan = c.task_id("ppan").unwrap();
    assert!(remark_present(&c, perr, "task error: injected fault"));
    assert!(remark_present(&c, ppan, "task panic: task panicked: injected fault"));

    let kinds: Vec<(TaskId, FiringKind)> = c
        .obs()
        .rec
        .spans()
        .filter_map(|s| match s.event {
            SpanEvent::Firing { task, kind, .. }
                if matches!(kind, FiringKind::Error | FiringKind::Panic) =>
            {
                Some((task, kind))
            }
            _ => None,
        })
        .collect();
    assert!(kinds.contains(&(perr, FiringKind::Error)), "{kinds:?}");
    assert!(kinds.contains(&(ppan, FiringKind::Panic)), "{kinds:?}");
}

// ---------------------------------------------------------------------
// event storm report
// ---------------------------------------------------------------------

#[test]
fn event_storm_is_a_structured_report_not_a_panic() {
    // a tiny cap makes a modest batch look like a runaway pipeline:
    // try_run_until_idle surfaces the structured report, run_until_idle
    // stashes it — neither aborts the process
    let spec = parse("[storm]\n(x) work (y)\n").unwrap();
    let cfg = DeployConfig { trace: true, fault: None, ..Default::default() };
    let mut c = Coordinator::deploy(&spec, cfg).unwrap();
    c.set_code("work", passthrough()).unwrap();
    c.set_storm_cap(10);
    inject_n(&mut c, "x", 30);

    let storm = c.try_run_until_idle().unwrap_err();
    assert_eq!(storm.cap, 10);
    assert!(storm.handled > 10, "cap trips after the instant that crossed it");
    assert!(storm.pending > 0, "the stalled queue is reported");
    assert_eq!(c.plat.metrics.get("event_storms"), 1);
    assert!(!storm.hottest_tasks.is_empty(), "report names the busiest tasks");
    assert_eq!(storm.hottest_tasks[0].0, "work");
    assert!(storm.hottest_tasks[0].1 > 0);
    assert!(
        storm.hottest_wires.iter().any(|(n, c)| n == "x" && *c > 0),
        "with obs on, the report names hot wires: {:?}",
        storm.hottest_wires
    );
    let msg = storm.to_string();
    assert!(msg.contains("event storm"), "{msg}");
    assert!(msg.contains("hottest tasks"), "{msg}");

    // the infallible wrapper degrades instead of panicking
    let handled = c.run_until_idle();
    assert!(handled > 0);
    assert!(c.last_storm().is_some(), "report stashed for later inspection");

    // raising the cap lets the same queue drain normally
    c.set_storm_cap(10_000_000);
    assert!(c.try_run_until_idle().is_ok());
    assert!(c.last_storm().is_none(), "a clean run clears the stash");
}
