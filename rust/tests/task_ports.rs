//! Typed-port task runtime: port-API vs legacy-adapter equivalence.
//!
//! The satellite contract of the task-API redesign: a [`TaskCode`] task
//! emitting on deploy-time-minted ports and its legacy [`UserCode`]
//! equivalent (same logic, wire-name returns through the [`LegacyCode`]
//! adapter) must be *indistinguishable from outside* — byte-identical
//! `SinkBook` contents (artifacts, payloads, ids, virtual times) and
//! identical provenance stamp sequences — across randomly generated
//! wirings and arrival traces. The port runtime is a faster spelling of
//! the same semantics, never a different machine.

use koalja::prelude::*;
use koalja::util::Rng;

// ---------------------------------------------------------------------
// random wiring generator (chains, fan-out, multi-output tasks)
// ---------------------------------------------------------------------

struct Wiring {
    text: String,
    externals: Vec<String>,
}

/// Tasks consume either fresh external wires or earlier tasks' outputs
/// (acyclic by construction; fan-out arises when two tasks pick the same
/// wire) and emit 1–2 fresh wires each — so multi-output emission, the
/// path this PR redesigns, occurs in roughly half the tasks.
fn random_wiring(r: &mut Rng, case: usize) -> Wiring {
    let n_tasks = 1 + r.range(0, 4);
    let mut produced: Vec<String> = Vec::new();
    let mut externals: Vec<String> = Vec::new();
    let mut text = format!("[prop{case}]\n");
    for ti in 0..n_tasks {
        let mut inputs = Vec::new();
        for k in 0..(1 + r.range(0, 2)) {
            let wire = if !produced.is_empty() && r.bool(0.6) {
                produced[r.range(0, produced.len())].clone()
            } else {
                let w = format!("ext{}", r.range(0, 3));
                if !externals.contains(&w) {
                    externals.push(w.clone());
                }
                w
            };
            if !inputs.contains(&wire) {
                inputs.push(wire);
            }
            let _ = k;
        }
        let n_out = 1 + r.range(0, 2);
        let outputs: Vec<String> = (0..n_out).map(|k| format!("t{ti}o{k}")).collect();
        text.push_str(&format!(
            "({}) task-{ti} ({})\n",
            inputs.join(", "),
            outputs.join(", ")
        ));
        produced.extend(outputs);
    }
    // external wires that ended up produced by nobody are the in-trays
    externals.retain(|e| !produced.contains(e));
    Wiring { text, externals }
}

fn scale_payload(p: &Payload, factor: f32) -> Payload {
    match p.as_tensor() {
        Some((shape, data)) => {
            Payload::tensor(shape, data.iter().map(|x| x * factor).collect())
        }
        None => p.clone(),
    }
}

/// Port-native arm: scale every input and emit it on every declared port,
/// preserving the input's class. Ports resolved by index — no names.
fn port_code(factor: f32) -> Box<dyn TaskCode> {
    Box::new(PortFn::new(move |ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
        for av in io.inputs.all() {
            let p = ctx.fetch(av)?;
            let scaled = scale_payload(&p, factor);
            for i in 0..io.outs().len() {
                let port = io.out(i)?;
                io.emitter.emit_class(port, scaled.clone(), av.class);
            }
        }
        Ok(())
    }))
}

/// Legacy arm: the same logic as [`port_code`], but spelled as a
/// `UserCode` implementation returning wire names, installed through the
/// `LegacyCode` adapter.
struct ScaleAllNames {
    outs: Vec<String>,
    factor: f32,
}

impl UserCode for ScaleAllNames {
    fn run(
        &mut self,
        ctx: &mut TaskCtx<'_>,
        snap: &Snapshot,
    ) -> anyhow::Result<Vec<Output>> {
        let mut res = Vec::new();
        for av in snap.all_avs() {
            let p = ctx.fetch(av)?;
            let scaled = scale_payload(&p, self.factor);
            for o in &self.outs {
                res.push(Output::new(o.as_str(), scaled.clone(), av.class));
            }
        }
        Ok(res)
    }
}

/// Deploy one arm of the comparison and drive the shared arrival trace.
fn run_arm(wiring: &Wiring, port_native: bool) -> Coordinator {
    let spec = parse(&wiring.text).unwrap();
    let mut c = Coordinator::deploy(&spec, DeployConfig::default()).unwrap();
    for (ti, t) in spec.tasks.iter().enumerate() {
        let factor = 1.0 + ti as f32 * 0.5;
        let code: Box<dyn TaskCode> = if port_native {
            port_code(factor)
        } else {
            legacy(ScaleAllNames { outs: t.outputs.clone(), factor })
        };
        c.set_code(&t.name, code).unwrap();
    }
    // identical arrival trace in both arms (fresh rng per arm, same seed)
    let mut r = rng(0xF00D);
    for (wi, w) in wiring.externals.iter().enumerate() {
        for i in 0..5u64 {
            c.inject_at(
                w,
                Payload::scalar(r.normal() as f32 + i as f32),
                if i % 2 == 0 { DataClass::Summary } else { DataClass::Raw },
                RegionId::new(0),
                SimTime::millis(wi as u64 * 7 + i * 13),
            )
            .unwrap();
        }
    }
    c.run_until_idle();
    c
}

/// Full observable state of a run, rendered deterministically: per-wire
/// sink captures (ids, times, payloads) and the stamp sequence on every
/// collected artifact's passport.
fn fingerprint(c: &Coordinator) -> String {
    let mut s = String::new();
    for name in c.graph.wires.names() {
        if let Some(recs) = c.collected.get(name) {
            s.push_str(&format!("== wire {name} ({}) ==\n", recs.len()));
            for rec in recs {
                s.push_str(&format!("{} {:?} {:?}\n", rec.at, rec.av, rec.payload));
                if let Some(pass) = c.plat.prov.passport(rec.av.id) {
                    for st in &pass.stamps {
                        s.push_str(&format!("  stamp {} {:?}\n", st.time, st.stamp));
                    }
                }
            }
        }
    }
    s.push_str(&format!("stamps={} runs={}\n", c.plat.prov.stamp_count, c.plat.metrics.task_runs));
    s
}

#[test]
fn port_and_legacy_adapter_arms_are_byte_identical() {
    let mut r = rng(0x9047);
    let mut checked = 0;
    for case in 0..30 {
        let wiring = random_wiring(&mut r, case);
        if wiring.externals.is_empty() {
            continue; // nothing to inject; vacuous
        }
        let port_arm = run_arm(&wiring, true);
        let legacy_arm = run_arm(&wiring, false);
        let fp_port = fingerprint(&port_arm);
        let fp_legacy = fingerprint(&legacy_arm);
        assert_eq!(
            fp_port, fp_legacy,
            "case {case}: port-API and legacy-adapter runs diverged\n{}",
            wiring.text
        );
        assert_eq!(
            port_arm.plat.prov.stamp_count, legacy_arm.plat.prov.stamp_count,
            "case {case}: stamp sequences diverged"
        );
        if port_arm.plat.metrics.task_runs > 0 {
            checked += 1;
        }
    }
    assert!(checked >= 10, "only {checked} non-trivial cases — generator degenerated");
}

// ---------------------------------------------------------------------
// port runtime semantics: ghost + deferred emissions, Inputs view
// ---------------------------------------------------------------------

#[test]
fn emit_ghost_routes_like_injected_ghosts() {
    let spec = parse("[g]\n(raw) probe (trace)\n(trace) sinkward (out)\n").unwrap();
    let mut c = Coordinator::deploy(&spec, DeployConfig::default()).unwrap();
    c.set_code(
        "probe",
        Box::new(PortFn::new(|_ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
            let trace = io.out(0)?;
            io.emitter.emit_ghost(trace, 64 << 20);
            Ok(())
        })),
    )
    .unwrap();
    c.inject("raw", Payload::scalar(1.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    // the ghost cascaded downstream: sinkward ran as a ghost run
    assert!(c.plat.metrics.ghost_runs >= 1, "downstream saw a wireframe batch");
    assert_eq!(c.collected_count("out"), 1);
    assert!(c.collected["out"][0].av.ghost, "ghost marking survives the port path");
}

#[test]
fn inputs_view_is_port_indexed_with_lazy_fetch() {
    let spec = parse("[iv]\n(left, right) join (out)\n").unwrap();
    let mut c = Coordinator::deploy(&spec, DeployConfig::default()).unwrap();
    c.set_code(
        "join",
        Box::new(PortFn::new(|ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
            let (l, r) = (io.in_at(0)?, io.in_at(1)?);
            // the port view separates the buffers without name scans…
            let lv = io.inputs.fetch(ctx, l)?;
            let rv = io.inputs.fetch(ctx, r)?;
            let sum = |ps: &[Payload]| -> f32 {
                ps.iter().map(|p| p.as_tensor().unwrap().1[0]).sum()
            };
            // …and only fetched ports pay fetch costs (lazy per port)
            let out = io.out(0)?;
            io.emitter.emit(out, Payload::tensor(&[2], vec![sum(&lv), sum(&rv)]));
            Ok(())
        })),
    )
    .unwrap();
    c.inject("left", Payload::scalar(3.0), DataClass::Summary).unwrap();
    c.inject("right", Payload::scalar(4.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    let rec = &c.collected["out"][0];
    assert_eq!(rec.payload.as_tensor().unwrap().1, &[3.0, 4.0], "per-port separation");
}

#[test]
fn sink_book_has_no_overflow_names() {
    // the dense sink book is total now: every collected record sits under
    // an interned wire, and asking for unknown names is simply None
    let spec = parse("[sb]\n(raw) work (out)\n").unwrap();
    let mut c = Coordinator::deploy(&spec, DeployConfig::default()).unwrap();
    c.inject("raw", Payload::scalar(1.0), DataClass::Summary).unwrap();
    c.run_until_idle();
    assert!(c.collected.get("not-a-wire").is_none());
    let names: Vec<&str> = c.collected.iter().map(|(n, _)| n).collect();
    assert_eq!(names, vec!["out"]);
}
