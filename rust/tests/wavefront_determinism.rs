//! Seq-vs-par equivalence: the wavefront scheduler's byte-identical
//! provenance contract.
//!
//! The same random pipeline, the same random injection plan, run once at
//! `workers = 1` (the fully sequential direct path) and once on the
//! worker pool — then every book is compared *byte-for-byte* through a
//! canonical dump: sink captures (values, AV ids, object ids, content
//! hashes, publish times), the deterministic commit log, wire currency,
//! every provenance passport (stamps in order, parents, run/version
//! numbers), every per-task checkpoint log, tap rings, and the headline
//! counters. Run ids, AV ids and object ids come from shared dispensers,
//! so this only holds if the parallel path draws them in exactly the
//! sequential order — which is the whole design (commit in task-index
//! order, effects recorded on workers and replayed at commit).
//!
//! The CI matrix runs this file under `KOALJA_WORKERS={1,4}` ×
//! `KOALJA_TRACE={0,1}`; KOALJA_WORKERS sets the parallel arm's pool
//! width (1 makes the test a sequential-vs-sequential control), and
//! KOALJA_TRACE exercises the ambient default the flight recorder picks
//! up through `DeployConfig::default()`. The tests below additionally
//! pin the trace axis *explicitly* (env mutation is racy under the
//! multi-threaded test harness): the books must be byte-identical for
//! every {trace} × {workers} combination, and the recorded span stream
//! itself — scheduling notes projected out — must be identical at
//! workers=1 and workers=N.

use koalja::prelude::*;
use koalja::util::{Rng, TaskId};

/// Pool width for the parallel arm: `KOALJA_WORKERS` (the CI matrix
/// leg) or 4.
fn par_workers() -> usize {
    std::env::var("KOALJA_WORKERS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(4)
        .max(1)
}

// ---------------------------------------------------------------------
// random pipeline + injection-plan generator
// ---------------------------------------------------------------------

struct Case {
    text: String,
    /// (external wire, at_ms, tensor data) — applied identically to both arms.
    plan: Vec<(String, u64, Vec<f32>)>,
}

fn random_case(r: &mut Rng) -> Case {
    let n_tasks = 2 + r.range(0, 6);
    let mut produced: Vec<String> = Vec::new();
    let mut externals: Vec<String> = Vec::new();
    let mut text = String::from("[wavecase]\n");
    for ti in 0..n_tasks {
        let n_in = 1 + r.range(0, 2);
        let mut inputs: Vec<String> = Vec::new();
        for _ in 0..n_in {
            let wire = if !produced.is_empty() && r.bool(0.55) {
                produced[r.range(0, produced.len())].clone()
            } else {
                let w = format!("ext{}", r.range(0, 3));
                if !externals.contains(&w) {
                    externals.push(w.clone());
                }
                w
            };
            if inputs.contains(&wire) {
                continue; // duplicate port tokens add nothing here
            }
            let token = match r.range(0, 5) {
                0 => format!("{wire}[{}]", 2 + r.range(0, 3)),
                1 => format!("{wire}[4/2]"),
                _ => wire.clone(),
            };
            inputs.push(token);
        }
        let n_out = 1 + r.range(0, 2);
        let outputs: Vec<String> = (0..n_out).map(|k| format!("t{ti}o{k}")).collect();
        produced.extend(outputs.iter().cloned());
        text.push_str(&format!("({}) task{ti} ({})", inputs.join(", "), outputs.join(", ")));
        if r.bool(0.25) {
            text.push_str(" @policy=swap");
        }
        if r.bool(0.2) {
            text.push_str(&format!(" @rate={}ms", 2 + r.range(0, 8)));
        }
        if r.bool(0.2) {
            text.push_str(&format!(" @notify=poll:{}ms", 3 + r.range(0, 9)));
        }
        text.push('\n');
    }
    // injection plan: several payloads per external wire at random
    // instants — identical values repeat sometimes, exercising the memo
    // path inside (and across) wavefronts
    let mut plan = Vec::new();
    for w in &externals {
        let k = 3 + r.range(0, 6);
        for _ in 0..k {
            let at_ms = r.range(0, 40) as u64;
            let data: Vec<f32> = if r.bool(0.3) {
                vec![1.0, 2.0, 3.0, 4.0] // repeated content → memo hits
            } else {
                (0..4).map(|_| (r.range(0, 1000) as f32) / 10.0).collect()
            };
            plan.push((w.clone(), at_ms, data));
        }
    }
    Case { text, plan }
}

/// Deterministic multi-port task body: scale per port, defer the second
/// port's publication — covers multi-emission routing, per-port classes
/// and deferred publish under both schedulers.
fn case_code() -> Box<dyn TaskCode> {
    Box::new(PortFn::new(|ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
        let n_ports = io.outs().len();
        for av in io.inputs.snapshot().all_avs() {
            let p = ctx.fetch(av)?;
            for pi in 0..n_ports {
                let port = io.out(pi)?;
                let out = match p.as_tensor() {
                    Some((shape, data)) => Payload::tensor(
                        shape,
                        data.iter().map(|x| x * (pi as f32 + 2.0) + 1.0).collect(),
                    ),
                    None => p.clone(),
                };
                if pi % 2 == 1 {
                    io.emitter.emit_after(port, out, SimDuration::micros(150));
                } else {
                    io.emitter.emit(port, out);
                }
            }
        }
        Ok(())
    }))
}

// ---------------------------------------------------------------------
// canonical byte dump of every determinism-relevant book
// ---------------------------------------------------------------------

fn run_arm(case: &Case, workers: usize) -> String {
    run_arm_traced(case, workers, false).0
}

/// One arm with the flight recorder explicitly on/off. Returns (canonical
/// book dump, span projection). The projection renders every retained
/// span except scheduling notes (DeferredSequential / RollbackRerun) —
/// those describe *strategy*, exist only when `workers > 1`, and are the
/// one sanctioned difference between arms; it also omits `seq`, which
/// the notes consume on the parallel arm.
fn run_arm_traced(case: &Case, workers: usize, trace: bool) -> (String, String) {
    run_arm_windowed(case, workers, trace, None)
}

/// One arm with the reorder window pinned explicitly (`None` keeps the
/// ambient default: `KOALJA_REORDER_WINDOW`, else auto = workers).
fn run_arm_windowed(
    case: &Case,
    workers: usize,
    trace: bool,
    window: Option<usize>,
) -> (String, String) {
    use std::fmt::Write as _;
    let spec = parse(&case.text).expect("generated wirings parse");
    let mut cfg = DeployConfig { workers, trace, ..Default::default() };
    if let Some(w) = window {
        cfg.reorder_window = w;
    }
    let mut c = Coordinator::deploy(&spec, cfg).unwrap();
    for t in 0..c.graph.n_tasks() {
        let name = c.graph.task(TaskId::new(t as u64)).name.clone();
        c.set_code(&name, case_code()).unwrap();
    }
    // tap every wire (deterministic attach order: interned order)
    let wire_names: Vec<String> = c.graph.wires.names().to_vec();
    let taps: Vec<(String, koalja::breadboard::TapId)> = wire_names
        .iter()
        .map(|w| (w.clone(), c.taps.attach(w, TapSpec::default())))
        .collect();
    for (wire, at_ms, data) in &case.plan {
        c.inject_at(
            wire,
            Payload::tensor(&[4], data.clone()),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(*at_ms),
        )
        .unwrap();
    }
    c.run_until_idle();

    let mut s = String::new();
    writeln!(s, "== sink book ==").unwrap();
    for (w, recs) in c.collected.iter() {
        for rec in recs {
            writeln!(s, "{w} @{:?} av={:?} payload={:?}", rec.at, rec.av, rec.payload).unwrap();
        }
    }
    writeln!(s, "== commit log ==").unwrap();
    for sc in c.commit_log() {
        writeln!(s, "{sc:?}").unwrap();
    }
    writeln!(s, "== wire currency ==").unwrap();
    for w in &wire_names {
        writeln!(s, "{w}: {:?}", c.latest_on_wire.get(w)).unwrap();
    }
    writeln!(s, "== passports ==").unwrap();
    let mut av_ids: Vec<_> = c.plat.prov.passports_iter().map(|(id, _)| *id).collect();
    av_ids.sort();
    for id in av_ids {
        let p = c.plat.prov.passport(id).unwrap();
        writeln!(s, "{id}: parents={:?} stamps={:?}", p.parents, p.stamps).unwrap();
    }
    writeln!(s, "== checkpoint logs ==").unwrap();
    for t in 0..c.graph.n_tasks() {
        let id = TaskId::new(t as u64);
        writeln!(s, "task{t}: {:?}", c.plat.prov.checkpoint_log(id)).unwrap();
    }
    writeln!(s, "== taps ==").unwrap();
    for (w, id) in &taps {
        writeln!(s, "{w}: stats={:?} samples={:?}", c.taps.stats(*id), c.taps.samples_vec(*id))
            .unwrap();
    }
    writeln!(s, "== counters ==").unwrap();
    writeln!(
        s,
        "task_runs={} memo_hits={} task_errors={} cache={}h/{}m stamps={} puts={} gets={} \
         events={} joules={:.9}",
        c.plat.metrics.task_runs,
        c.plat.metrics.get("memo_hits"),
        c.plat.metrics.get("task_errors"),
        c.plat.metrics.cache_hits,
        c.plat.metrics.cache_misses,
        c.plat.prov.stamp_count,
        c.plat.store.puts,
        c.plat.store.gets,
        c.events_processed,
        c.plat.metrics.joules,
    )
    .unwrap();

    let mut spans = String::new();
    for span in c.obs().rec.spans() {
        if let SpanEvent::Firing { kind, .. } = span.event {
            if kind.is_scheduling_note() {
                continue;
            }
        }
        if span.event.is_pipelining_note() {
            continue; // frontier-advance exists only when reorder_window > 1
        }
        writeln!(spans, "{:?} {:?}", span.at, span.event).unwrap();
    }
    (s, spans)
}

// ---------------------------------------------------------------------
// the property
// ---------------------------------------------------------------------

#[test]
fn workers_one_and_n_produce_byte_identical_books() {
    let w = par_workers();
    let mut r = rng(0xA7E_F807);
    for case_idx in 0..40 {
        let case = random_case(&mut r);
        let seq = run_arm(&case, 1);
        let par = run_arm(&case, w);
        if seq != par {
            // locate the first divergent line for a readable failure
            for (ls, lp) in seq.lines().zip(par.lines()) {
                assert_eq!(
                    ls, lp,
                    "case {case_idx} (workers 1 vs {w}) diverged\nspec:\n{}",
                    case.text
                );
            }
            panic!(
                "case {case_idx}: books differ in length only (workers 1 vs {w})\nspec:\n{}",
                case.text
            );
        }
    }
}

#[test]
fn tracing_never_perturbs_the_books() {
    // the full {trace} × {workers} matrix against one untraced sequential
    // baseline: turning the flight recorder on must not move a single
    // committed byte, at any pool width
    let w = par_workers();
    let mut r = rng(0x0B5_CA5E);
    for case_idx in 0..12 {
        let case = random_case(&mut r);
        let baseline = run_arm_traced(&case, 1, false).0;
        for (workers, trace) in [(1usize, true), (w, false), (w, true)] {
            let (books, _) = run_arm_traced(&case, workers, trace);
            if baseline != books {
                for (lb, la) in baseline.lines().zip(books.lines()) {
                    assert_eq!(
                        lb, la,
                        "case {case_idx} (workers={workers} trace={trace}) diverged\nspec:\n{}",
                        case.text
                    );
                }
                panic!(
                    "case {case_idx}: books differ in length only (workers={workers} \
                     trace={trace})\nspec:\n{}",
                    case.text
                );
            }
        }
    }
}

#[test]
fn span_stream_is_identical_across_worker_counts() {
    // stronger than byte-identical books: the *trace itself* is part of
    // the determinism contract. With scheduling notes projected out (they
    // only exist when workers > 1), the retained span stream at workers=1
    // and workers=N must match event for event — same instants, same
    // dense ids, same firing kinds, same run numbers.
    let w = par_workers().max(2);
    let mut r = rng(0x5BA_2F00);
    for case_idx in 0..12 {
        let case = random_case(&mut r);
        let (_, seq_spans) = run_arm_traced(&case, 1, true);
        let (_, par_spans) = run_arm_traced(&case, w, true);
        assert!(!seq_spans.is_empty(), "case {case_idx}: traced run recorded no spans");
        if seq_spans != par_spans {
            for (ls, lp) in seq_spans.lines().zip(par_spans.lines()) {
                assert_eq!(
                    ls, lp,
                    "case {case_idx}: span streams diverged (workers 1 vs {w})\nspec:\n{}",
                    case.text
                );
            }
            panic!(
                "case {case_idx}: span streams differ in length only (workers 1 vs {w})\n\
                 spec:\n{}",
                case.text
            );
        }
    }
}

#[test]
fn wide_fanout_wavefront_is_deterministic() {
    // a directed worst case: one injection instant wakes 8 independent
    // tasks at once — the widest wavefront shape the benches measure
    let mut text = String::from("[wide]\n");
    for i in 0..8 {
        text.push_str(&format!("(x) leaf{i} (s{i})\n"));
    }
    let case = Case {
        text,
        plan: (0..12u64)
            .map(|i| ("x".to_string(), i * 3, vec![i as f32, 1.0, 2.0, 3.0]))
            .collect(),
    };
    let seq = run_arm(&case, 1);
    let par = run_arm(&case, par_workers().max(4));
    assert_eq!(seq, par, "wide fan-out books must be byte-identical");
}

#[test]
fn swallowed_direct_only_error_still_defers() {
    // an UNDECLARED service user that catches the lookup error and
    // carries on: on a worker the recording is poisoned the moment
    // lookup refuses, so the firing rolls back and re-runs sequentially
    // with the real service — workers=1 and workers=N must agree even
    // though the plugin never propagates the needs-sequential error
    let arm = |workers: usize| -> String {
        let spec = parse("[sw]\n(x) sneaky (a)\n(x) honest (b)\n").unwrap();
        let cfg = DeployConfig { workers, ..Default::default() };
        let mut c = Coordinator::deploy(&spec, cfg).unwrap();
        c.plat.services.register(
            "dns",
            Box::new(koalja::platform::service::KvService::new(&[("k", "42")])),
        );
        // note: deliberately NOT .sequential() — the poison must save us
        c.set_code(
            "sneaky",
            Box::new(PortFn::new(|ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
                let port = io.out(0)?;
                let v = match ctx.lookup("dns", &Payload::Text("k".into())) {
                    Ok(Payload::Text(s)) => s.parse::<f32>().unwrap_or(-1.0),
                    _ => 0.0, // swallows the worker-side refusal
                };
                for av in io.inputs.all() {
                    let _ = ctx.fetch(av)?;
                    io.emitter.emit(port, Payload::scalar(v));
                }
                Ok(())
            })),
        )
        .unwrap();
        for i in 0..6u64 {
            c.inject_at(
                "x",
                Payload::scalar(i as f32),
                DataClass::Summary,
                RegionId::new(0),
                SimTime::millis(i),
            )
            .unwrap();
        }
        c.run_until_idle();
        let mut s = String::new();
        for (w, recs) in c.collected.iter() {
            for rec in recs {
                use std::fmt::Write as _;
                writeln!(s, "{w} {:?} {:?} {:?}", rec.at, rec.av, rec.payload).unwrap();
            }
        }
        s
    };
    let seq = arm(1);
    let par = arm(par_workers().max(2));
    assert!(seq.contains("42"), "direct arm saw the real service value:\n{seq}");
    assert_eq!(seq, par, "swallowed refusals must not leak divergent results");
}

// ---------------------------------------------------------------------
// fault matrix: the supervision machinery is part of the contract
// ---------------------------------------------------------------------

/// One arm with a seeded fault plan and a mixed per-task policy
/// assignment (dead-letter / quarantine / degrade by task index).
/// Returns (canonical dump including every supervision book, span
/// projection) — the fault-matrix analogue of [`run_arm_traced`].
fn run_fault_arm(case: &Case, workers: usize, trace: bool, fault_seed: u64) -> (String, String) {
    use std::fmt::Write as _;
    let spec = parse(&case.text).expect("generated wirings parse");
    let plan = FaultPlan::seeded(fault_seed).with_rates(0.15, 0.10, 0.05);
    let cfg = DeployConfig { workers, trace, fault: Some(plan), ..Default::default() };
    let mut c = Coordinator::deploy(&spec, cfg).unwrap();
    for t in 0..c.graph.n_tasks() {
        let id = TaskId::new(t as u64);
        let name = c.graph.task(id).name.clone();
        c.set_code(&name, case_code()).unwrap();
        let policy = match t % 3 {
            0 => FirePolicy::retries(2)
                .with_backoff(Backoff::Fixed(SimDuration::millis(2)))
                .dead_letter(),
            1 => FirePolicy::retries(1)
                .with_backoff(Backoff::Exponential {
                    base: SimDuration::millis(1),
                    cap: SimDuration::millis(8),
                })
                .quarantine(2),
            _ => FirePolicy::retries(1)
                .with_deadline(SimDuration::millis(3))
                .degrade(Payload::scalar(-9.0)),
        };
        c.set_fire_policy_id(id, policy);
    }
    for (wire, at_ms, data) in &case.plan {
        c.inject_at(
            wire,
            Payload::tensor(&[4], data.clone()),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(*at_ms),
        )
        .unwrap();
    }
    c.run_until_idle();

    let mut s = String::new();
    writeln!(s, "== sink book ==").unwrap();
    for (w, recs) in c.collected.iter() {
        for rec in recs {
            writeln!(s, "{w} @{:?} av={:?} payload={:?}", rec.at, rec.av, rec.payload).unwrap();
        }
    }
    writeln!(s, "== commit log ==").unwrap();
    for sc in c.commit_log() {
        writeln!(s, "{sc:?}").unwrap();
    }
    writeln!(s, "== passports ==").unwrap();
    let mut av_ids: Vec<_> = c.plat.prov.passports_iter().map(|(id, _)| *id).collect();
    av_ids.sort();
    for id in av_ids {
        let p = c.plat.prov.passport(id).unwrap();
        writeln!(s, "{id}: parents={:?} stamps={:?}", p.parents, p.stamps).unwrap();
    }
    writeln!(s, "== checkpoint logs ==").unwrap();
    for t in 0..c.graph.n_tasks() {
        let id = TaskId::new(t as u64);
        writeln!(s, "task{t}: {:?}", c.plat.prov.checkpoint_log(id)).unwrap();
    }
    writeln!(s, "== dead letters ==").unwrap();
    for t in 0..c.graph.n_tasks() {
        let id = TaskId::new(t as u64);
        let book = c.dead_letter_book(id);
        writeln!(s, "task{t}: dropped={}", book.dropped()).unwrap();
        for l in book.letters() {
            writeln!(
                s,
                "  #{} @{:?} attempts={} panicked={} qdrop={} avs={:?} err={}",
                l.index,
                l.at,
                l.attempts,
                l.panicked,
                l.quarantine_drop,
                l.av_ids(),
                l.error
            )
            .unwrap();
        }
    }
    writeln!(s, "== breakers ==").unwrap();
    for t in 0..c.graph.n_tasks() {
        let b = c.supervision.breaker(TaskId::new(t as u64));
        writeln!(
            s,
            "task{t}: quarantined={} consec={} tripped_at={:?}",
            b.quarantined, b.consecutive_exhausts, b.tripped_at
        )
        .unwrap();
    }
    writeln!(s, "== counters ==").unwrap();
    writeln!(
        s,
        "task_runs={} errors={} retries={} exhausted={} dead_letters={} trips={} dropped={} \
         degraded={} events={}",
        c.plat.metrics.task_runs,
        c.plat.metrics.get("task_errors"),
        c.plat.metrics.get("task_retries"),
        c.plat.metrics.get("task_exhausted"),
        c.plat.metrics.get("dead_letters"),
        c.plat.metrics.get("quarantine_trips"),
        c.plat.metrics.get("quarantine_dropped"),
        c.plat.metrics.get("task_degraded"),
        c.events_processed,
    )
    .unwrap();

    let mut spans = String::new();
    for span in c.obs().rec.spans() {
        if let SpanEvent::Firing { kind, .. } = span.event {
            if kind.is_scheduling_note() {
                continue;
            }
        }
        if span.event.is_pipelining_note() {
            continue; // frontier-advance exists only when reorder_window > 1
        }
        writeln!(spans, "{:?} {:?}", span.at, span.event).unwrap();
    }
    (s, spans)
}

#[test]
fn fault_matrix_is_byte_identical_across_workers_and_trace() {
    // with a seeded fault plan injecting errors, panics and cost spikes
    // at ~30% of attempts, and a mixed dead-letter / quarantine /
    // degrade policy assignment, every supervision artifact — sink
    // books, provenance, dead-letter books, breaker states, fault
    // counters, and the retained span stream — must be byte-identical
    // for every {workers} × {trace} combination
    let w = par_workers().max(2);
    let mut r = rng(0xFA_017);
    let mut any_fault_engaged = false;
    for case_idx in 0..12 {
        let case = random_case(&mut r);
        let fault_seed = 1000 + case_idx as u64;
        let (baseline, base_spans) = run_fault_arm(&case, 1, true, fault_seed);
        any_fault_engaged |= !baseline.contains("errors=0 ");
        for (workers, trace) in [(1usize, false), (w, false), (w, true)] {
            let (books, spans) = run_fault_arm(&case, workers, trace, fault_seed);
            if baseline != books {
                for (lb, la) in baseline.lines().zip(books.lines()) {
                    assert_eq!(
                        lb, la,
                        "case {case_idx} (workers={workers} trace={trace}) diverged\nspec:\n{}",
                        case.text
                    );
                }
                panic!(
                    "case {case_idx}: books differ in length only (workers={workers} \
                     trace={trace})\nspec:\n{}",
                    case.text
                );
            }
            if trace && spans != base_spans {
                for (ls, lp) in base_spans.lines().zip(spans.lines()) {
                    assert_eq!(
                        ls, lp,
                        "case {case_idx}: span streams diverged (workers 1 vs {workers})\n\
                         spec:\n{}",
                        case.text
                    );
                }
                panic!(
                    "case {case_idx}: span streams differ in length only (workers 1 vs \
                     {workers})\nspec:\n{}",
                    case.text
                );
            }
        }
    }
    assert!(any_fault_engaged, "at these rates the fault plan must have fired at least once");
}

#[test]
fn sequential_fallback_code_keeps_determinism() {
    // a wavefront mixing parallel-safe and declared-sequential code:
    // the sequential member commits in its canonical slot either way
    let text = "[mix]\n(x) fast (a)\n(x) slow (b)\n(x) other (c)\n".to_string();
    let case = Case {
        text,
        plan: (0..10u64).map(|i| ("x".to_string(), i * 2, vec![i as f32; 4])).collect(),
    };
    let arm = |workers: usize| -> String {
        let spec = parse(&case.text).unwrap();
        let cfg = DeployConfig { workers, ..Default::default() };
        let mut c = Coordinator::deploy(&spec, cfg).unwrap();
        c.set_code("fast", case_code()).unwrap();
        c.set_code(
            "slow",
            Box::new(
                PortFn::new(|ctx: &mut TaskCtx<'_>, io: &mut PortIo<'_>| {
                    let port = io.out(0)?;
                    for av in io.inputs.all() {
                        let p = ctx.fetch(av)?;
                        io.emitter.emit(port, p);
                    }
                    Ok(())
                })
                .sequential(),
            ),
        )
        .unwrap();
        c.set_code("other", case_code()).unwrap();
        for (wire, at_ms, data) in &case.plan {
            c.inject_at(
                wire,
                Payload::tensor(&[4], data.clone()),
                DataClass::Summary,
                RegionId::new(0),
                SimTime::millis(*at_ms),
            )
            .unwrap();
        }
        c.run_until_idle();
        let mut s = String::new();
        for (w, recs) in c.collected.iter() {
            for rec in recs {
                use std::fmt::Write as _;
                writeln!(s, "{w} {:?} {:?} {:?}", rec.at, rec.av, rec.payload).unwrap();
            }
        }
        s
    };
    assert_eq!(arm(1), arm(par_workers().max(2)));
}

// ---------------------------------------------------------------------
// pipelined scheduling: the reorder-window axis of the contract
// ---------------------------------------------------------------------

#[test]
fn reorder_window_matrix_is_byte_identical() {
    // the tentpole invariant: breaking the per-instant barrier must not
    // move a committed byte. Every {window} × {workers} × {trace} cell —
    // window 1 (pipelining off), window = workers (the auto default) and
    // a window far wider than any batch — is compared byte-for-byte
    // against the strict per-instant sequential baseline, books and
    // span projection both (pipelining notes projected out: they are
    // the one sanctioned difference, absent by construction at
    // window = 1).
    let w = par_workers().max(4);
    let mut r = rng(0xF2_0A71E5);
    for case_idx in 0..8 {
        let case = random_case(&mut r);
        let (baseline, base_spans) = run_arm_windowed(&case, 1, true, Some(1));
        for workers in [1usize, w] {
            for window in [1usize, w, 64] {
                for trace in [false, true] {
                    let (books, spans) =
                        run_arm_windowed(&case, workers, trace, Some(window));
                    if baseline != books {
                        for (lb, la) in baseline.lines().zip(books.lines()) {
                            assert_eq!(
                                lb, la,
                                "case {case_idx} (workers={workers} window={window} \
                                 trace={trace}) diverged\nspec:\n{}",
                                case.text
                            );
                        }
                        panic!(
                            "case {case_idx}: books differ in length only (workers={workers} \
                             window={window} trace={trace})\nspec:\n{}",
                            case.text
                        );
                    }
                    if trace && spans != base_spans {
                        for (ls, lp) in base_spans.lines().zip(spans.lines()) {
                            assert_eq!(
                                ls, lp,
                                "case {case_idx}: span streams diverged (window={window} \
                                 workers={workers})\nspec:\n{}",
                                case.text
                            );
                        }
                        panic!(
                            "case {case_idx}: span streams differ in length only \
                             (window={window} workers={workers})\nspec:\n{}",
                            case.text
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn diamond_overlaps_instants_and_commits_identically() {
    // the directed overlap witness: a fan-out/fan-in diamond fed a
    // stream of arrivals. Under pipelined scheduling the join's firing
    // for arrival k (at T+δ) and the diamond arms' firings for arrival
    // k+1 (at T') execute in the same batch — the frontier-advance span
    // with behind >= 1 records exactly that: an instant entered
    // execution while an earlier instant was still open. The books must
    // nonetheless be byte-identical to the strict per-instant run.
    let text =
        "[diamond]\n(x) arm_a (ao)\n(x) arm_b (bo)\n(ao, bo) join (out)\n".to_string();
    let case = Case {
        text,
        plan: (0..10u64)
            .map(|i| ("x".to_string(), i * 3, vec![i as f32, 1.0, 2.0, 3.0]))
            .collect(),
    };
    let (seq_books, _) = run_arm_windowed(&case, 1, true, Some(1));

    // pipelined arm, instrumented directly so the raw (unprojected)
    // span stream is visible
    let spec = parse(&case.text).unwrap();
    let cfg = DeployConfig {
        workers: par_workers().max(2),
        trace: true,
        reorder_window: 64,
        ..Default::default()
    };
    let mut c = Coordinator::deploy(&spec, cfg).unwrap();
    for t in 0..c.graph.n_tasks() {
        let name = c.graph.task(TaskId::new(t as u64)).name.clone();
        c.set_code(&name, case_code()).unwrap();
    }
    for (wire, at_ms, data) in &case.plan {
        c.inject_at(
            wire,
            Payload::tensor(&[4], data.clone()),
            DataClass::Summary,
            RegionId::new(0),
            SimTime::millis(*at_ms),
        )
        .unwrap();
    }
    c.run_until_idle();
    let advances: Vec<u32> = c
        .obs()
        .rec
        .spans()
        .filter_map(|s| match s.event {
            SpanEvent::FrontierAdvance { behind } => Some(behind),
            _ => None,
        })
        .collect();
    assert!(
        advances.iter().any(|&b| b >= 1),
        "pipelined diamond must overlap instants (frontier-advance with behind >= 1); \
         recorded: {advances:?}"
    );

    // and the committed books are the sequential per-instant books
    let (par_books, _) = run_arm_windowed(&case, par_workers().max(2), true, Some(64));
    assert_eq!(seq_books, par_books, "diamond books must be byte-identical across windows");
}
